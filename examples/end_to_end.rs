//! End-to-end validation (EXPERIMENTS.md §E2E): the full three-layer stack
//! on a real workload — Pallas-kernel model AOT-compiled by JAX, loaded by
//! the Rust PS coordinator via PJRT, trained with GBA across several
//! hundred global steps of synthetic click-logs, with a mid-run tuning-free
//! switch to sync and back. Logs the loss curve and per-day AUC.
//!
//! Requires `make artifacts`.
//!
//!     cargo run --release --example end_to_end

use gba::config::{ExperimentConfig, ModeKind};
use gba::worker::session::{SessionOptions, TrainSession};
use gba::worker::BackendKind;

const CONFIG: &str = r#"
name = "e2e-pjrt"
seed = 99

[model]
variant = "deepfm"     # F=16 D=16 H=(128,64): ~3.3M dense+emb params at this vocab
fields = 16
emb_dim = 16
hidden1 = 128
hidden2 = 64
vocab_size = 200000
zipf_s = 1.1

[data]
days_base = 4
days_eval = 1
samples_per_day = 32768
teacher_seed = 5
label_noise = 0.08
drift = 0.01

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.003
lr_async = 0.1
eval_batch = 256
eval_samples = 4096

[mode.sync]
workers = 4
local_batch = 256

[mode.gba]
workers = 8
local_batch = 128    # M = 8
iota = 3
"#;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::from_toml(CONFIG)?;
    let opts = SessionOptions {
        backend: BackendKind::Pjrt,
        artifacts_dir: "artifacts".into(),
        engine_threads: 4,
        ..SessionOptions::default()
    };
    println!(
        "end-to-end: PJRT backend, variant '{}', G_sync = {}, M = {}",
        cfg.model.variant,
        cfg.global_batch_sync(),
        cfg.gba_m()
    );
    let t0 = std::time::Instant::now();
    let mut session = TrainSession::new(cfg.clone(), ModeKind::Gba, opts)
        .map_err(|e| anyhow::anyhow!("{e:#}\nhint: run `make artifacts` first"))?;

    let mut total_steps = 0u64;
    for day in 0..4 {
        // Tuning-free switches mid-run: GBA -> Sync -> GBA.
        if day == 2 {
            println!("--- switching GBA -> Sync (cluster went vacant) ---");
            session.switch_mode(ModeKind::Sync)?;
        }
        if day == 3 {
            println!("--- switching Sync -> GBA (cluster is busy again) ---");
            session.switch_mode(ModeKind::Gba)?;
        }
        let stats = session.train_day(day)?;
        total_steps += stats.counters.global_steps;
        let auc = session.eval_auc(day + 1)?;
        // Loss curve: print a few points per day.
        let curve = session.ps().loss_curve();
        let pts: Vec<String> = curve
            .iter()
            .step_by((curve.len() / 4).max(1))
            .map(|(k, l)| format!("k{}={:.4}", k, l))
            .collect();
        println!(
            "[{}] day {day}: AUC(day {}) = {auc:.4} | {:.0} samples/s | steps {} | loss {}",
            session.kind.paper_name(),
            day + 1,
            stats.qps,
            stats.counters.global_steps,
            pts.join(" "),
        );
    }
    println!(
        "total: {} global steps, {:.1}s wall — three layers composed: \
         pallas kernels -> jax train_step (HLO) -> PJRT -> rust GBA coordinator.",
        total_steps,
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}
