//! Straggler storm: a load spike hits the shared cluster mid-run
//! (Observation 1 / Fig. 1). Compares how Sync and GBA throughput respond,
//! using the discrete-event simulator with a "spike" load trace, then
//! demonstrates the adaptive switcher (the paper's future-work extension)
//! choosing modes from observed utilization.
//!
//!     cargo run --release --example straggler_storm

use gba::cluster::{LoadTrace, StragglerModel};
use gba::config::{ClusterConfig, ModeKind};
use gba::coordinator::modes::{GbaPolicy, SyncPolicy};
use gba::coordinator::switch::AdaptiveSwitcher;
use gba::sim::{simulate, SimParams};

fn main() {
    let cluster = ClusterConfig {
        trace: "spike".into(),
        base_compute_ms: 8.0,
        hetero_sigma: 0.5,
        ps_apply_ms: 0.5,
        wire_ms: 0.0,
        workers: gba::config::WorkerPlane::InProc,
        worker_listen: String::new(),
    };
    let trace = LoadTrace::from_name(&cluster.trace);
    let workers = 16;
    let seed = 11;

    println!("hour | util | sync QPS | GBA QPS | GBA/sync | adaptive mode");
    let mut switcher = AdaptiveSwitcher::new(ModeKind::Sync);
    for h in 0..24 {
        let start = h as f64 * 3600.0;
        let util = trace.utilization(start);
        let mk_params = |local_batch: usize| SimParams {
            workers,
            local_batch,
            compute: StragglerModel::new(&cluster, workers, seed),
            ps_apply_ms: cluster.ps_apply_ms,
            n_shards: 1,
            apply_threads: 1,
            wire_ms: 0.0,
            start_sec: start,
            duration_sec: 120.0,
            seed: seed ^ h,
        };
        let sync = simulate(&mk_params(256), Box::new(SyncPolicy::new(workers)));
        let gba = simulate(&mk_params(256), Box::new(GbaPolicy::with_iota(workers, 4)));
        let switched = switcher.observe(util);
        println!(
            "{:>4} | {:.2} | {:>8.0} | {:>7.0} | {:>7.2}x | {}{}",
            h,
            util,
            sync.global_qps(),
            gba.global_qps(),
            gba.global_qps() / sync.global_qps(),
            switcher.current().paper_name(),
            if switched.is_some() { "  <-- switch!" } else { "" },
        );
    }
    println!(
        "\nDuring the spike the sync barrier collapses to the slowest worker \
         while GBA keeps absorbing fast workers' gradients — the paper's \
         motivation for switching, automated by the utilization watermarks."
    );
}
