//! Mid-stream mode switching (the paper's Fig. 6 protocol in miniature):
//! train sync -> switch to an async-family mode -> switch back, and print
//! the AUC trajectory with switch annotations. GBA is the mode whose
//! switch is accuracy-neutral in both directions.
//!
//!     cargo run --release --example switch_modes

use gba::config::{ExperimentConfig, ModeKind};
use gba::coordinator::switch::SwitchTrace;
use gba::experiments::common;
use gba::experiments::ExpCtx;
use gba::worker::session::{SessionOptions, TrainSession};

fn run_plan(
    cfg: &ExperimentConfig,
    plan: &[(usize, ModeKind)],
    days: usize,
) -> anyhow::Result<Vec<f64>> {
    let mut trace = SwitchTrace::default();
    let mut session = TrainSession::new(cfg.clone(), plan[0].1, SessionOptions::default())?;
    let mut aucs = Vec::new();
    for day in 0..days {
        if let Some(&(_, to)) = plan.iter().find(|(d, m)| *d == day && *m != session.kind) {
            trace.record(day, session.kind, to);
            println!("  day {day}: switch {} -> {}", session.kind.paper_name(), to.paper_name());
            session.switch_mode(to)?;
        }
        session.train_day(day)?;
        aucs.push(session.eval_auc(day + 1)?);
    }
    Ok(aucs)
}

fn main() -> anyhow::Result<()> {
    let ctx = ExpCtx::default();
    let mut cfg = common::load_task(&ctx, "criteo")?;
    cfg.data.samples_per_day = 16384;
    cfg.data.days_base = 7;
    cfg.data.days_eval = 1;
    let days = 6;

    println!("plan A: sync all the way (baseline)");
    let base = run_plan(&cfg, &[(0, ModeKind::Sync)], days)?;

    println!("plan B: sync -> GBA at day 2 -> sync at day 4 (the paper's use case)");
    let gba =
        run_plan(&cfg, &[(0, ModeKind::Sync), (2, ModeKind::Gba), (4, ModeKind::Sync)], days)?;

    println!("plan C: sync -> Async at day 2 -> sync at day 4 (naive switching)");
    let asyn =
        run_plan(&cfg, &[(0, ModeKind::Sync), (2, ModeKind::Async), (4, ModeKind::Sync)], days)?;

    println!("\nday | sync-only | via GBA | via Async | GBA-sync | Async-sync");
    for d in 0..days {
        println!(
            "{:>3} | {:.4}    | {:.4}  | {:.4}    | {:+.4}  | {:+.4}",
            d + 1,
            base[d],
            gba[d],
            asyn[d],
            gba[d] - base[d],
            asyn[d] - base[d]
        );
    }
    Ok(())
}
