//! Quickstart: train a small recommendation model with GBA for two days
//! of synthetic click-logs and watch AUC improve, then switch to
//! synchronous training tuning-free.
//!
//!     cargo run --release --example quickstart

use gba::config::{ExperimentConfig, ModeKind};
use gba::worker::session::{SessionOptions, TrainSession};

const CONFIG: &str = r#"
name = "quickstart"
seed = 7

[model]
variant = "small"
fields = 8
emb_dim = 8
hidden1 = 64
hidden2 = 32
vocab_size = 20000
zipf_s = 1.1

[data]
days_base = 3
days_eval = 2
samples_per_day = 16384
teacher_seed = 3
label_noise = 0.05
drift = 0.01

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.004
lr_async = 0.1
eval_batch = 256
eval_samples = 4096

[mode.sync]
workers = 4
local_batch = 256

[mode.gba]
workers = 8
local_batch = 128    # M = 4*256/128 = 8
iota = 3
"#;

fn main() -> anyhow::Result<()> {
    let cfg = ExperimentConfig::from_toml(CONFIG)?;
    println!(
        "quickstart: task '{}', sync global batch {}, GBA buffer M = {}",
        cfg.name,
        cfg.global_batch_sync(),
        cfg.gba_m()
    );

    // Start in GBA (asynchronous, token-controlled) mode.
    let mut session = TrainSession::new(cfg, ModeKind::Gba, SessionOptions::default())?;
    for day in 0..2 {
        let stats = session.train_day(day)?;
        let auc = session.eval_auc(day + 1)?;
        println!(
            "[GBA ] day {day}: AUC(day {}) = {auc:.4} | {:.0} samples/s | {} global steps | staleness mean {:.2}",
            day + 1,
            stats.qps,
            stats.counters.global_steps,
            stats.counters.dense_staleness.mean(),
        );
    }

    // The cluster freed up — switch to synchronous training. No re-tuning:
    // same learning rate, same (global) batch size.
    println!("--- switch GBA -> Sync (tuning-free) ---");
    session.switch_mode(ModeKind::Sync)?;
    for day in 2..4 {
        let stats = session.train_day(day)?;
        let auc = session.eval_auc(day + 1)?;
        println!(
            "[Sync] day {day}: AUC(day {}) = {auc:.4} | {:.0} samples/s | {} global steps",
            day + 1,
            stats.qps,
            stats.counters.global_steps,
        );
    }
    println!("done — accuracy carried straight across the switch.");
    Ok(())
}
