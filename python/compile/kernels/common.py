"""Shared helpers for the Pallas kernels: block-size selection and padding.

Kernels tile their operands for the MXU (128x128 systolic array) and VMEM
(~16 MiB scratchpad per core). On this testbed they run in interpret mode
(CPU PJRT cannot execute Mosaic custom-calls), so the tiling is validated
structurally -- correctness here, TPU-efficiency estimates in DESIGN.md.
"""

from __future__ import annotations

import jax.numpy as jnp

# Flip to False to compile real Mosaic kernels on a TPU host.
INTERPRET = True

# MXU-friendly tile edge. 128 matches the MXU systolic array; smaller
# shapes fall back to the full (padded) dimension.
MXU_TILE = 128


def cdiv(a: int, b: int) -> int:
    return -(-a // b)


def round_up(x: int, m: int) -> int:
    return cdiv(x, m) * m


def pick_block(dim: int, target: int = MXU_TILE) -> int:
    """Block edge for a dimension: full dim when small, else `target`."""
    return dim if dim <= target else target


def pad_dim(x: jnp.ndarray, axis: int, multiple: int) -> jnp.ndarray:
    """Zero-pad `axis` of `x` up to the next multiple of `multiple`."""
    size = x.shape[axis]
    pad = round_up(size, multiple) - size
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
