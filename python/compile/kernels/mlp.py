"""Pallas kernels: fused dense layer act(x @ W + b) with custom VJP.

Forward kernel tiles (B, O) into MXU-sized blocks with the full K
(reduction) dimension resident per program — correct for the model widths
used here (K <= 512, so an [128, K] x [K, 128] working set stays well
under VMEM). Backward is three kernels:

    dz = g * act'(z)            (elementwise, fused into each consumer)
    dx = dz @ W^T               (tiles over (B, I))
    dW = x^T @ dz               (tiles over (I, O))
    db = sum_b dz               (tiles over (O,))

Residuals: x, W and the *post-activation* y (for ReLU, act'(z) == y > 0,
which avoids stashing pre-activations — halves residual VMEM traffic).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv, pad_dim, pick_block


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _fwd_kernel_relu(x_ref, w_ref, b_ref, o_ref):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    z = z + b_ref[...]
    o_ref[...] = jnp.maximum(z, 0.0).astype(o_ref.dtype)


def _fwd_kernel_none(x_ref, w_ref, b_ref, o_ref):
    z = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = (z + b_ref[...]).astype(o_ref.dtype)


def _matmul_bias_act_raw(x, w, b, act: str):
    bsz, kdim = x.shape
    _, odim = w.shape
    bm, bn = pick_block(bsz), pick_block(odim)
    x_p = pad_dim(x, 0, bm)
    w_p = pad_dim(w, 1, bn)
    b_p = pad_dim(b, 0, bn)
    grid = (cdiv(x_p.shape[0], bm), cdiv(w_p.shape[1], bn))
    kernel = _fwd_kernel_relu if act == "relu" else _fwd_kernel_none
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kdim), lambda i, j: (i, 0)),
            pl.BlockSpec((kdim, bn), lambda i, j: (0, j)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_p.shape[0], w_p.shape[1]), x.dtype),
        interpret=INTERPRET,
    )(x_p, w_p, b_p)
    return out[:bsz, :odim]


# --------------------------------------------------------------------------
# backward
# --------------------------------------------------------------------------

def _dx_kernel(dz_ref, w_ref, o_ref):
    # dx[b, i] = sum_o dz[b, o] * w[i, o]
    o_ref[...] = jnp.dot(
        dz_ref[...], w_ref[...].T, preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _dw_kernel(x_ref, dz_ref, o_ref):
    # dW[i, o] = sum_b x[b, i] * dz[b, o]
    o_ref[...] = jnp.dot(
        x_ref[...].T, dz_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)


def _db_kernel(dz_ref, o_ref):
    o_ref[...] = jnp.sum(dz_ref[...], axis=0).astype(o_ref.dtype)


def _backward_raw(x, w, dz):
    bsz, kdim = x.shape
    _, odim = w.shape
    # dx: tiles over (B, I)
    bm, bi = pick_block(bsz), pick_block(kdim)
    dz_p0 = pad_dim(dz, 0, bm)
    w_pi = pad_dim(w, 0, bi)
    dx = pl.pallas_call(
        _dx_kernel,
        grid=(cdiv(dz_p0.shape[0], bm), cdiv(w_pi.shape[0], bi)),
        in_specs=[
            pl.BlockSpec((bm, odim), lambda i, j: (i, 0)),
            pl.BlockSpec((bi, odim), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bi), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((dz_p0.shape[0], w_pi.shape[0]), x.dtype),
        interpret=INTERPRET,
    )(dz_p0, w_pi)[:bsz, :kdim]

    # dW: tiles over (I, O)
    bi2, bo = pick_block(kdim), pick_block(odim)
    x_pi = pad_dim(x, 1, bi2)
    dz_po = pad_dim(dz, 1, bo)
    dw = pl.pallas_call(
        _dw_kernel,
        grid=(cdiv(x_pi.shape[1], bi2), cdiv(dz_po.shape[1], bo)),
        in_specs=[
            pl.BlockSpec((bsz, bi2), lambda i, j: (0, i)),
            pl.BlockSpec((bsz, bo), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bi2, bo), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x_pi.shape[1], dz_po.shape[1]), w.dtype),
        interpret=INTERPRET,
    )(x_pi, dz_po)[:kdim, :odim]

    # db: tiles over (O,)
    db = pl.pallas_call(
        _db_kernel,
        grid=(cdiv(dz_po.shape[1], bo),),
        in_specs=[pl.BlockSpec((bsz, bo), lambda j: (0, j))],
        out_specs=pl.BlockSpec((bo,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dz_po.shape[1],), w.dtype),
        interpret=INTERPRET,
    )(dz_po)[:odim]
    return dx, dw, db


# --------------------------------------------------------------------------
# custom_vjp wrappers (one per activation: act must be trace-static)
# --------------------------------------------------------------------------

@jax.custom_vjp
def matmul_bias_relu(x, w, b):
    """ReLU(x @ w + b) via Pallas, [B,K]x[K,O] -> [B,O]."""
    return _matmul_bias_act_raw(x, w, b, "relu")


def _relu_fwd(x, w, b):
    y = _matmul_bias_act_raw(x, w, b, "relu")
    return y, (x, w, y)


def _relu_bwd(res, g):
    x, w, y = res
    dz = g * (y > 0).astype(g.dtype)
    dx, dw, db = _backward_raw(x, w, dz)
    return dx, dw, db


matmul_bias_relu.defvjp(_relu_fwd, _relu_bwd)


@jax.custom_vjp
def matmul_bias(x, w, b):
    """x @ w + b via Pallas (no activation), [B,K]x[K,O] -> [B,O]."""
    return _matmul_bias_act_raw(x, w, b, "none")


def _none_fwd(x, w, b):
    y = _matmul_bias_act_raw(x, w, b, "none")
    return y, (x, w)


def _none_bwd(res, g):
    x, w = res
    dx, dw, db = _backward_raw(x, w, g)
    return dx, dw, db


matmul_bias.defvjp(_none_fwd, _none_bwd)


def matmul_bias_act(x, w, b, act: str = "relu"):
    """Dispatch helper mirroring `ref.matmul_bias_act_ref`."""
    if act == "relu":
        return matmul_bias_relu(x, w, b)
    if act == "none":
        return matmul_bias(x, w, b)
    raise ValueError(f"unknown act {act!r}")
