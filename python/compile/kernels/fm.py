"""Pallas kernel: FM second-order interaction with custom VJP.

Forward:  out[b, d] = 0.5 * ((sum_f e[b,f,d])^2 - sum_f e[b,f,d]^2)
Backward: de[b,f,d] = g[b,d] * (S[b,d] - e[b,f,d])   with S = sum_f e

The kernel tiles over the batch dimension; each program instance holds an
[bm, F, D] block of embeddings in VMEM, reduces over the field axis (a
VPU reduction, not MXU work) and writes an [bm, D] block. The field sum S
is saved as a residual so the backward pass does not re-reduce.

TPU note (DESIGN.md §Hardware-Adaptation): on a real TPU the natural block
is bm such that bm*F*D*4B fits VMEM alongside the output; for the model
configs here (F<=32, D<=64) bm=128 keeps the working set under 1.5 MiB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv, pad_dim, pick_block


def _fwd_kernel(e_ref, out_ref, s_ref):
    e = e_ref[...]                      # [bm, F, D]
    s = jnp.sum(e, axis=1)              # [bm, D]
    sq = jnp.sum(e * e, axis=1)         # [bm, D]
    out_ref[...] = 0.5 * (s * s - sq)
    s_ref[...] = s


def _bwd_kernel(g_ref, e_ref, s_ref, de_ref):
    g = g_ref[...]                      # [bm, D]
    e = e_ref[...]                      # [bm, F, D]
    s = s_ref[...]                      # [bm, D]
    de_ref[...] = g[:, None, :] * (s[:, None, :] - e)


def _fm_fwd_raw(emb: jnp.ndarray):
    bsz, nfield, dim = emb.shape
    bm = pick_block(bsz)
    padded = pad_dim(emb, 0, bm)
    grid = (cdiv(padded.shape[0], bm),)
    out, s = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, nfield, dim), lambda i: (i, 0, 0))],
        out_specs=[
            pl.BlockSpec((bm, dim), lambda i: (i, 0)),
            pl.BlockSpec((bm, dim), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((padded.shape[0], dim), emb.dtype),
            jax.ShapeDtypeStruct((padded.shape[0], dim), emb.dtype),
        ],
        interpret=INTERPRET,
    )(padded)
    return out[:bsz], s[:bsz]


def _fm_bwd_raw(g: jnp.ndarray, emb: jnp.ndarray, s: jnp.ndarray):
    bsz, nfield, dim = emb.shape
    bm = pick_block(bsz)
    g_p = pad_dim(g, 0, bm)
    e_p = pad_dim(emb, 0, bm)
    s_p = pad_dim(s, 0, bm)
    grid = (cdiv(e_p.shape[0], bm),)
    de = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, dim), lambda i: (i, 0)),
            pl.BlockSpec((bm, nfield, dim), lambda i: (i, 0, 0)),
            pl.BlockSpec((bm, dim), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, nfield, dim), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(e_p.shape, emb.dtype),
        interpret=INTERPRET,
    )(g_p, e_p, s_p)
    return de[:bsz]


@jax.custom_vjp
def fm_interaction(emb: jnp.ndarray) -> jnp.ndarray:
    """FM bi-interaction pooling, [B, F, D] -> [B, D] (Pallas)."""
    out, _ = _fm_fwd_raw(emb)
    return out


def _vjp_fwd(emb):
    out, s = _fm_fwd_raw(emb)
    return out, (emb, s)


def _vjp_bwd(res, g):
    emb, s = res
    return (_fm_bwd_raw(g, emb, s),)


fm_interaction.defvjp(_vjp_fwd, _vjp_bwd)


@functools.partial(jax.jit, static_argnames=())
def fm_interaction_jit(emb: jnp.ndarray) -> jnp.ndarray:
    return fm_interaction(emb)
