"""Pallas kernel: numerically-stable BCE-with-logits, per-example.

Forward:  l[b] = max(z,0) - z*y + log1p(exp(-|z|))
Backward: dz[b] = g[b] * (sigmoid(z[b]) - y[b])

1-D kernel tiled over the batch. The mean-reduction lives in the L2 graph
(jnp.mean) so XLA can fuse it with the surrounding scalars.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import INTERPRET, cdiv, pad_dim, pick_block


def _fwd_kernel(z_ref, y_ref, o_ref):
    z = z_ref[...]
    y = y_ref[...]
    o_ref[...] = jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))


def _bwd_kernel(g_ref, z_ref, y_ref, o_ref):
    z = z_ref[...]
    sig = 1.0 / (1.0 + jnp.exp(-z))
    o_ref[...] = g_ref[...] * (sig - y_ref[...])


def _fwd_raw(logits, labels):
    bsz = logits.shape[0]
    bm = pick_block(bsz)
    z_p = pad_dim(logits, 0, bm)
    y_p = pad_dim(labels, 0, bm)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=(cdiv(z_p.shape[0], bm),),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(z_p.shape, logits.dtype),
        interpret=INTERPRET,
    )(z_p, y_p)
    return out[:bsz]


def _bwd_raw(g, logits, labels):
    bsz = logits.shape[0]
    bm = pick_block(bsz)
    g_p = pad_dim(g, 0, bm)
    z_p = pad_dim(logits, 0, bm)
    y_p = pad_dim(labels, 0, bm)
    out = pl.pallas_call(
        _bwd_kernel,
        grid=(cdiv(z_p.shape[0], bm),),
        in_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((bm,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(z_p.shape, logits.dtype),
        interpret=INTERPRET,
    )(g_p, z_p, y_p)
    return out[:bsz]


@jax.custom_vjp
def bce_logits(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example BCE-with-logits, [B] x [B] -> [B] (Pallas)."""
    return _fwd_raw(logits, labels)


def _vjp_fwd(logits, labels):
    return _fwd_raw(logits, labels), (logits, labels)


def _vjp_bwd(res, g):
    logits, labels = res
    # labels are data, not parameters: no gradient flows to them.
    return _bwd_raw(g, logits, labels), None


bce_logits.defvjp(_vjp_fwd, _vjp_bwd)
