"""Layer-1 Pallas kernels for the GBA recommendation model.

Public surface:
    fm_interaction   - FM bi-interaction pooling [B,F,D] -> [B,D]
    matmul_bias_act  - fused dense layer act(x@W+b)
    bce_logits       - per-example BCE-with-logits
plus the pure-jnp oracles in `ref` used by the test suite.
"""

from .fm import fm_interaction
from .loss import bce_logits
from .mlp import matmul_bias, matmul_bias_act, matmul_bias_relu
from . import ref

__all__ = [
    "fm_interaction",
    "matmul_bias",
    "matmul_bias_act",
    "matmul_bias_relu",
    "bce_logits",
    "ref",
]
