"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each function here is the mathematical definition; the Pallas kernels in
`fm.py`, `mlp.py`, `loss.py` must match these to float tolerance, both in
value and (via `jax.grad`) in VJP. pytest + hypothesis enforce this.
"""

from __future__ import annotations

import jax.numpy as jnp


def fm_interaction_ref(emb: jnp.ndarray) -> jnp.ndarray:
    """FM second-order interaction (the DeepFM bi-interaction pooling).

    emb: [B, F, D] field embeddings.
    returns [B, D]: 0.5 * ((sum_f e)^2 - sum_f e^2).
    """
    s = jnp.sum(emb, axis=1)
    sq = jnp.sum(emb * emb, axis=1)
    return 0.5 * (s * s - sq)


def matmul_bias_act_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                        act: str = "relu") -> jnp.ndarray:
    """Fused dense layer: act(x @ w + b). act in {"relu", "none"}."""
    z = x @ w + b
    if act == "relu":
        return jnp.maximum(z, 0.0)
    if act == "none":
        return z
    raise ValueError(f"unknown act {act!r}")


def bce_logits_ref(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Per-example numerically-stable binary cross-entropy with logits.

    loss = max(z, 0) - z*y + log(1 + exp(-|z|))
    """
    z, y = logits, labels
    return jnp.maximum(z, 0.0) - z * y + jnp.log1p(jnp.exp(-jnp.abs(z)))


def sigmoid_ref(z: jnp.ndarray) -> jnp.ndarray:
    return 1.0 / (1.0 + jnp.exp(-z))
