"""Layer-2: the recommendation model (JAX), calling the Pallas kernels.

The paper's three tasks (DeepFM / DIEN / YouTubeDNN, Table 5.1) share the
CTR-tower shape this module implements:

    emb[B,F,D] --+-- flatten fields --> x  [B, F*D] --+
                 +-- FM interaction --> fm [B, D]   --+-> concat -> MLP -> logit

The *sparse* half (ID -> embedding-row lookup) deliberately lives on the
Rust PS (exactly where DeepRec puts it); this graph takes the gathered
embedding block and returns the per-sample embedding gradients, which the
PS scatter-adds per ID.

Exported entry points (AOT-lowered to HLO text by `aot.py`):

    train_step(emb, w1,b1,w2,b2,w3,b3, labels)
        -> (loss, logits, d_emb, dw1, db1, dw2, db2, dw3, db3)
    predict(emb, w1,b1,w2,b2,w3,b3) -> logits

`use_pallas=False` switches every kernel to its pure-jnp oracle — the
pytest suite checks the two paths agree on values and gradients.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import bce_logits, fm_interaction, matmul_bias, matmul_bias_relu
from .kernels import ref


class ModelDims(NamedTuple):
    """Static model hyper-shapes (fixed at AOT time)."""

    fields: int      # F: categorical feature fields per sample
    emb_dim: int     # D: embedding dimension
    hidden1: int     # H1: first MLP width
    hidden2: int     # H2: second MLP width

    @property
    def mlp_in(self) -> int:
        # flattened fields + FM interaction vector
        return self.fields * self.emb_dim + self.emb_dim

    def param_shapes(self):
        """Dense parameter shapes, in the positional order of train_step."""
        return [
            ("w1", (self.mlp_in, self.hidden1)),
            ("b1", (self.hidden1,)),
            ("w2", (self.hidden1, self.hidden2)),
            ("b2", (self.hidden2,)),
            ("w3", (self.hidden2, 1)),
            ("b3", (1,)),
        ]

    def dense_param_count(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_shapes())


def init_dense_params(dims: ModelDims, seed: int = 0):
    """He-initialized dense tower parameters (same scheme as the Rust
    native model, so integration tests can cross-check numerics)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in dims.param_shapes():
        key, sub = jax.random.split(key)
        if len(shape) == 2:
            scale = jnp.sqrt(2.0 / shape[0])
            params.append(jax.random.normal(sub, shape, jnp.float32) * scale)
        else:
            params.append(jnp.zeros(shape, jnp.float32))
        del name
    return params


def forward(emb, w1, b1, w2, b2, w3, b3, *, use_pallas: bool = True):
    """Logits for a gathered embedding block emb[B, F, D]."""
    bsz = emb.shape[0]
    x = emb.reshape(bsz, -1)
    if use_pallas:
        fm = fm_interaction(emb)
        h = jnp.concatenate([x, fm], axis=1)
        h = matmul_bias_relu(h, w1, b1)
        h = matmul_bias_relu(h, w2, b2)
        logit = matmul_bias(h, w3, b3)
    else:
        fm = ref.fm_interaction_ref(emb)
        h = jnp.concatenate([x, fm], axis=1)
        h = ref.matmul_bias_act_ref(h, w1, b1, "relu")
        h = ref.matmul_bias_act_ref(h, w2, b2, "relu")
        logit = ref.matmul_bias_act_ref(h, w3, b3, "none")
    return logit[:, 0]


def loss_fn(emb, w1, b1, w2, b2, w3, b3, labels, *, use_pallas: bool = True):
    """(mean BCE loss, logits)."""
    logits = forward(emb, w1, b1, w2, b2, w3, b3, use_pallas=use_pallas)
    if use_pallas:
        per_ex = bce_logits(logits, labels)
    else:
        per_ex = ref.bce_logits_ref(logits, labels)
    return jnp.mean(per_ex), logits


def train_step(emb, w1, b1, w2, b2, w3, b3, labels, *, use_pallas: bool = True):
    """One gradient computation (NO update — updates happen on the PS).

    Returns (loss, logits, d_emb, dw1, db1, dw2, db2, dw3, db3).
    """

    def scalar_loss(emb, w1, b1, w2, b2, w3, b3):
        loss, logits = loss_fn(
            emb, w1, b1, w2, b2, w3, b3, labels, use_pallas=use_pallas
        )
        return loss, logits

    (loss, logits), grads = jax.value_and_grad(
        scalar_loss, argnums=(0, 1, 2, 3, 4, 5, 6), has_aux=True
    )(emb, w1, b1, w2, b2, w3, b3)
    return (loss, logits) + tuple(grads)


def predict(emb, w1, b1, w2, b2, w3, b3, *, use_pallas: bool = True):
    """Inference logits (AUC evaluation path)."""
    return forward(emb, w1, b1, w2, b2, w3, b3, use_pallas=use_pallas)


# ---------------------------------------------------------------------------
# AOT variants: every (name, dims, batch) tuple lowered by aot.py.
# Keep in sync with configs/*.toml (validated by the Rust config loader
# against artifacts/manifest.json).
# ---------------------------------------------------------------------------

VARIANTS = {
    # name: (dims, batch sizes to specialize) — batch sizes must cover every
    # local_batch/eval_batch that configs/*.toml may run on the PJRT backend.
    "tiny": (ModelDims(fields=4, emb_dim=4, hidden1=32, hidden2=16), [8, 32]),
    "small": (ModelDims(fields=8, emb_dim=8, hidden1=64, hidden2=32),
              [32, 64, 128, 256, 512]),
    "deepfm": (ModelDims(fields=16, emb_dim=16, hidden1=128, hidden2=64),
               [64, 128, 256, 512]),
}
