"""AOT pipeline: lower the L2 model to HLO *text* artifacts for the Rust
runtime, plus a manifest.json describing every artifact.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published `xla` 0.1.6 crate) rejects; the text parser
reassigns ids and round-trips cleanly.

Usage:  cd python && python -m compile.aot --out ../artifacts
Re-running is idempotent; `make artifacts` only invokes it when inputs
changed.
"""

from __future__ import annotations

import argparse
import functools
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="float32"):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def train_step_signature(dims: M.ModelDims, batch: int):
    """(names, ShapeDtypeStructs) for the train_step positional inputs."""
    names = ["emb"]
    specs = [_spec((batch, dims.fields, dims.emb_dim))]
    for name, shape in dims.param_shapes():
        names.append(name)
        specs.append(_spec(shape))
    names.append("labels")
    specs.append(_spec((batch,)))
    return names, specs


def predict_signature(dims: M.ModelDims, batch: int):
    names = ["emb"]
    specs = [_spec((batch, dims.fields, dims.emb_dim))]
    for name, shape in dims.param_shapes():
        names.append(name)
        specs.append(_spec(shape))
    return names, specs


TRAIN_OUTPUTS = ["loss", "logits", "d_emb", "dw1", "db1", "dw2", "db2", "dw3", "db3"]


def lower_variant(name: str, dims: M.ModelDims, batch: int, out_dir: str,
                  use_pallas: bool = True):
    """Lower train_step + predict for one (variant, batch); return manifest
    entries."""
    entries = []

    t_names, t_specs = train_step_signature(dims, batch)
    train = functools.partial(M.train_step, use_pallas=use_pallas)
    lowered = jax.jit(train).lower(*t_specs)
    fname = f"train_step_{name}_b{batch}.hlo.txt"
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entries.append({
        "function": "train_step",
        "variant": name,
        "batch": batch,
        "file": fname,
        "inputs": [{"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                   for n, s in zip(t_names, t_specs)],
        "outputs": TRAIN_OUTPUTS,
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
    })

    p_names, p_specs = predict_signature(dims, batch)
    pred = functools.partial(M.predict, use_pallas=use_pallas)
    lowered = jax.jit(pred).lower(*p_specs)
    fname = f"predict_{name}_b{batch}.hlo.txt"
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, fname), "w") as f:
        f.write(text)
    entries.append({
        "function": "predict",
        "variant": name,
        "batch": batch,
        "file": fname,
        "inputs": [{"name": n, "shape": list(s.shape), "dtype": str(s.dtype)}
                   for n, s in zip(p_names, p_specs)],
        "outputs": ["logits"],
        "hlo_sha256": hashlib.sha256(text.encode()).hexdigest(),
    })
    return entries


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--variants", nargs="*", default=None,
                    help="subset of variant names (default: all)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="lower the pure-jnp reference path instead")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    wanted = args.variants or list(M.VARIANTS)
    manifest = {
        "format": 1,
        "jax_version": jax.__version__,
        "interchange": "hlo-text",
        "variants": {},
        "artifacts": [],
    }
    for name in wanted:
        dims, batches = M.VARIANTS[name]
        manifest["variants"][name] = {
            "fields": dims.fields,
            "emb_dim": dims.emb_dim,
            "hidden1": dims.hidden1,
            "hidden2": dims.hidden2,
            "mlp_in": dims.mlp_in,
            "batches": batches,
        }
        for batch in batches:
            print(f"lowering {name} b={batch} ...", flush=True)
            manifest["artifacts"].extend(
                lower_variant(name, dims, batch, args.out,
                              use_pallas=not args.no_pallas))
    path = os.path.join(args.out, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {len(manifest['artifacts'])} artifacts + {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
