"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (batch not a multiple of the tile edge, degenerate
dims) and dtypes; values AND gradients (via jax.grad) must agree.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property sweeps need hypothesis; skip the module (rather than erroring
# at collection) where the offline image lacks it.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    bce_logits,
    fm_interaction,
    matmul_bias,
    matmul_bias_act,
    matmul_bias_relu,
    ref,
)

SETTINGS = dict(max_examples=25, deadline=None)


def rand(key, shape, dtype=jnp.float32, scale=1.0):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# fm_interaction
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 300),
    f=st.integers(1, 24),
    d=st.integers(1, 48),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm_matches_ref(b, f, d, seed):
    e = rand(jax.random.PRNGKey(seed), (b, f, d))
    np.testing.assert_allclose(
        fm_interaction(e), ref.fm_interaction_ref(e), rtol=1e-5, atol=1e-5
    )


@settings(**SETTINGS)
@given(
    b=st.integers(1, 150),
    f=st.integers(1, 12),
    d=st.integers(1, 24),
    seed=st.integers(0, 2**31 - 1),
)
def test_fm_grad_matches_ref(b, f, d, seed):
    key = jax.random.PRNGKey(seed)
    e = rand(key, (b, f, d))
    ct = rand(jax.random.fold_in(key, 1), (b, d))
    g1 = jax.grad(lambda e: jnp.sum(fm_interaction(e) * ct))(e)
    g2 = jax.grad(lambda e: jnp.sum(ref.fm_interaction_ref(e) * ct))(e)
    np.testing.assert_allclose(g1, g2, rtol=1e-4, atol=1e-5)


def test_fm_single_field_is_zero():
    # With one field, sum^2 == sum of squares -> identically zero.
    e = rand(jax.random.PRNGKey(0), (7, 1, 5))
    np.testing.assert_allclose(fm_interaction(e), jnp.zeros((7, 5)), atol=1e-6)


def test_fm_bf16_runs():
    e = rand(jax.random.PRNGKey(0), (16, 4, 8), dtype=jnp.bfloat16)
    out = fm_interaction(e)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        out.astype(jnp.float32),
        ref.fm_interaction_ref(e).astype(jnp.float32),
        rtol=5e-2,
        atol=5e-2,
    )


# ---------------------------------------------------------------------------
# matmul_bias_act
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 200),
    k=st.integers(1, 160),
    o=st.integers(1, 160),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref(b, k, o, act, seed):
    key = jax.random.PRNGKey(seed)
    x = rand(key, (b, k))
    w = rand(jax.random.fold_in(key, 1), (k, o), scale=0.3)
    bias = rand(jax.random.fold_in(key, 2), (o,))
    np.testing.assert_allclose(
        matmul_bias_act(x, w, bias, act),
        ref.matmul_bias_act_ref(x, w, bias, act),
        rtol=1e-4,
        atol=1e-4,
    )


@settings(**SETTINGS)
@given(
    b=st.integers(1, 96),
    k=st.integers(1, 80),
    o=st.integers(1, 80),
    act=st.sampled_from(["relu", "none"]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_grads_match_ref(b, k, o, act, seed):
    key = jax.random.PRNGKey(seed)
    x = rand(key, (b, k))
    w = rand(jax.random.fold_in(key, 1), (k, o), scale=0.3)
    bias = rand(jax.random.fold_in(key, 2), (o,))
    ct = rand(jax.random.fold_in(key, 3), (b, o))

    def f_pallas(x, w, bias):
        return jnp.sum(matmul_bias_act(x, w, bias, act) * ct)

    def f_ref(x, w, bias):
        return jnp.sum(ref.matmul_bias_act_ref(x, w, bias, act) * ct)

    g1 = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, bias)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, bias)
    for a, b_ in zip(g1, g2):
        np.testing.assert_allclose(a, b_, rtol=1e-4, atol=1e-4)


def test_matmul_relu_clamps_negative():
    x = -jnp.ones((4, 3))
    w = jnp.ones((3, 2))
    b = jnp.zeros((2,))
    out = matmul_bias_relu(x, w, b)
    np.testing.assert_allclose(out, jnp.zeros((4, 2)), atol=0)


def test_matmul_shapes_above_tile_edge():
    # exercise multi-tile grid (B, O > 128)
    key = jax.random.PRNGKey(3)
    x = rand(key, (257, 64))
    w = rand(jax.random.fold_in(key, 1), (64, 130), scale=0.2)
    b = rand(jax.random.fold_in(key, 2), (130,))
    np.testing.assert_allclose(
        matmul_bias(x, w, b),
        ref.matmul_bias_act_ref(x, w, b, "none"),
        rtol=1e-4,
        atol=1e-4,
    )


def test_matmul_rejects_unknown_act():
    with pytest.raises(ValueError):
        matmul_bias_act(jnp.ones((2, 2)), jnp.ones((2, 2)), jnp.ones((2,)), "gelu")


# ---------------------------------------------------------------------------
# bce_logits
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    b=st.integers(1, 400),
    scale=st.floats(0.1, 30.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_bce_matches_ref(b, scale, seed):
    key = jax.random.PRNGKey(seed)
    z = rand(key, (b,), scale=scale)
    y = (jax.random.uniform(jax.random.fold_in(key, 1), (b,)) > 0.5).astype(jnp.float32)
    np.testing.assert_allclose(
        bce_logits(z, y), ref.bce_logits_ref(z, y), rtol=1e-5, atol=1e-6
    )


@settings(**SETTINGS)
@given(b=st.integers(1, 300), seed=st.integers(0, 2**31 - 1))
def test_bce_grad_matches_ref(b, seed):
    key = jax.random.PRNGKey(seed)
    z = rand(key, (b,), scale=4.0)
    y = (jax.random.uniform(jax.random.fold_in(key, 1), (b,)) > 0.5).astype(jnp.float32)
    g1 = jax.grad(lambda z: jnp.mean(bce_logits(z, y)))(z)
    g2 = jax.grad(lambda z: jnp.mean(ref.bce_logits_ref(z, y)))(z)
    np.testing.assert_allclose(g1, g2, rtol=1e-5, atol=1e-6)


def test_bce_extreme_logits_stable():
    z = jnp.array([-80.0, -20.0, 0.0, 20.0, 80.0])
    y = jnp.array([0.0, 1.0, 1.0, 0.0, 1.0])
    out = bce_logits(z, y)
    assert bool(jnp.all(jnp.isfinite(out)))
    # loss at z=+-80 with matching label ~ 0; mismatched ~ |z|
    np.testing.assert_allclose(out[0], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[4], 0.0, atol=1e-6)
    np.testing.assert_allclose(out[3], 20.0, rtol=1e-5)


def test_bce_gradient_is_sigmoid_minus_label():
    z = jnp.array([0.0, 2.0, -2.0])
    y = jnp.array([1.0, 0.0, 1.0])
    g = jax.grad(lambda z: jnp.sum(bce_logits(z, y)))(z)
    np.testing.assert_allclose(g, ref.sigmoid_ref(z) - y, rtol=1e-5, atol=1e-6)
