"""L2 correctness: the Pallas model path vs the pure-jnp reference path,
plus shape/semantics contracts the Rust coordinator relies on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# Property sweeps need hypothesis; skip the module (rather than erroring
# at collection) where the offline image lacks it.
pytest.importorskip("hypothesis", reason="hypothesis not installed")
from hypothesis import given, settings, strategies as st

from compile import model as M

SETTINGS = dict(max_examples=10, deadline=None)

DIMS = M.ModelDims(fields=4, emb_dim=4, hidden1=32, hidden2=16)


def make_inputs(dims, batch, seed=0):
    key = jax.random.PRNGKey(seed)
    emb = jax.random.normal(key, (batch, dims.fields, dims.emb_dim)) * 0.1
    params = M.init_dense_params(dims, seed=seed)
    labels = (jax.random.uniform(jax.random.fold_in(key, 7), (batch,)) > 0.5).astype(
        jnp.float32
    )
    return emb, params, labels


@settings(**SETTINGS)
@given(batch=st.integers(1, 64), seed=st.integers(0, 10_000))
def test_forward_pallas_matches_ref(batch, seed):
    emb, params, _ = make_inputs(DIMS, batch, seed)
    got = M.forward(emb, *params, use_pallas=True)
    want = M.forward(emb, *params, use_pallas=False)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(batch=st.integers(1, 48), seed=st.integers(0, 10_000))
def test_train_step_pallas_matches_ref(batch, seed):
    emb, params, labels = make_inputs(DIMS, batch, seed)
    got = M.train_step(emb, *params, labels, use_pallas=True)
    want = M.train_step(emb, *params, labels, use_pallas=False)
    assert len(got) == len(want) == 9
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, rtol=1e-4, atol=1e-5)


def test_train_step_output_shapes():
    emb, params, labels = make_inputs(DIMS, 8)
    out = M.train_step(emb, *params, labels)
    loss, logits, d_emb, dw1, db1, dw2, db2, dw3, db3 = out
    assert loss.shape == ()
    assert logits.shape == (8,)
    assert d_emb.shape == emb.shape
    for g, p in zip([dw1, db1, dw2, db2, dw3, db3], params):
        assert g.shape == p.shape


def test_loss_decreases_under_sgd():
    """Five manual SGD steps on a fixed batch must reduce the loss — the
    end-to-end signal that gradients point the right way."""
    emb, params, labels = make_inputs(DIMS, 32, seed=3)
    lr = 0.5

    def loss_of(params, emb):
        loss, _ = M.loss_fn(emb, *params, labels, use_pallas=True)
        return float(loss)

    first = loss_of(params, emb)
    cur_emb = emb
    for _ in range(5):
        out = M.train_step(cur_emb, *params, labels, use_pallas=True)
        d_emb, grads = out[2], out[3:]
        params = [p - lr * g for p, g in zip(params, grads)]
        cur_emb = cur_emb - lr * d_emb
    last = loss_of(params, cur_emb)
    assert last < first * 0.9, f"{first} -> {last}"


def test_gradients_vanish_at_separable_optimum():
    """If logits strongly match labels, per-example grads ~ 0."""
    dims = DIMS
    emb, params, _ = make_inputs(dims, 16, seed=5)
    logits = M.forward(emb, *params)
    labels = (logits > 0).astype(jnp.float32)
    # Scale final layer up to saturate the sigmoid. (The smallest |logit|
    # in this fixed seed is ~2.6e-3, so scale 1000 gives margin >= 2.6.)
    params = params[:4] + [params[4] * 1000.0, params[5] * 1000.0]
    out = M.train_step(emb, *params, labels)
    assert float(out[0]) < 0.01
    # Note: d_emb does NOT vanish here because the chain rule multiplies
    # by the scaled w3; the loss value is the meaningful optimality signal.


def test_mlp_in_accounts_for_fm():
    assert DIMS.mlp_in == DIMS.fields * DIMS.emb_dim + DIMS.emb_dim


def test_param_order_matches_signature():
    names = [n for n, _ in DIMS.param_shapes()]
    assert names == ["w1", "b1", "w2", "b2", "w3", "b3"]


def test_variants_table_sane():
    for name, (dims, batches) in M.VARIANTS.items():
        assert dims.mlp_in > 0 and batches, name
        assert all(b > 0 for b in batches)
