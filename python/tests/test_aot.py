"""AOT pipeline contracts: HLO text artifacts parse, manifests are
complete, and the lowered module's entry layout matches the manifest."""

import json
import os
import re
import tempfile

import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    rc = aot.main(["--out", out, "--variants", "tiny"])
    assert rc == 0
    with open(os.path.join(out, "manifest.json")) as f:
        manifest = json.load(f)
    return out, manifest


def test_manifest_lists_all_artifacts(built):
    out, manifest = built
    dims, batches = M.VARIANTS["tiny"]
    assert len(manifest["artifacts"]) == 2 * len(batches)
    for entry in manifest["artifacts"]:
        path = os.path.join(out, entry["file"])
        assert os.path.exists(path), entry["file"]


def test_hlo_text_is_parseable_hlo(built):
    out, manifest = built
    for entry in manifest["artifacts"]:
        text = open(os.path.join(out, entry["file"])).read()
        assert text.startswith("HloModule"), entry["file"]
        assert "ENTRY" in text
        # Must not contain TPU-only custom calls (CPU PJRT can't run them).
        assert "custom-call" not in text, entry["file"]


def test_entry_layout_matches_manifest_shapes(built):
    out, manifest = built
    for entry in manifest["artifacts"]:
        text = open(os.path.join(out, entry["file"])).read()
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
        assert m, entry["file"]
        args = re.findall(r"f32\[([\d,]*)\]", m.group(1))
        assert len(args) == len(entry["inputs"])
        for spec, found in zip(entry["inputs"], args):
            want = ",".join(str(d) for d in spec["shape"])
            assert want == found, (entry["file"], spec, found)


def test_train_outputs_documented(built):
    _, manifest = built
    for entry in manifest["artifacts"]:
        if entry["function"] == "train_step":
            assert entry["outputs"] == aot.TRAIN_OUTPUTS


def test_manifest_variant_dims(built):
    _, manifest = built
    dims, _ = M.VARIANTS["tiny"]
    v = manifest["variants"]["tiny"]
    assert v["fields"] == dims.fields
    assert v["emb_dim"] == dims.emb_dim
    assert v["mlp_in"] == dims.mlp_in


def test_sha_recorded_and_stable(built):
    out, manifest = built
    import hashlib
    for entry in manifest["artifacts"]:
        text = open(os.path.join(out, entry["file"])).read()
        assert hashlib.sha256(text.encode()).hexdigest() == entry["hlo_sha256"]
