//! Offline drop-in subset of the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored crate
//! provides the slice of anyhow's API the workspace actually uses:
//!
//! * [`Error`] — a context-chain error type (`Display` prints the
//!   outermost message, `{:#}` prints the whole chain joined by `": "`,
//!   `Debug` prints the chain as a `Caused by:` list, like anyhow).
//! * [`Result<T>`] with the `Error` default.
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`.
//! * `anyhow!`, `bail!`, `ensure!` macros.
//!
//! Everything is intentionally API-compatible so the dependency line in
//! `rust/Cargo.toml` can be pointed back at crates.io without touching
//! any call site.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with a defaulted error type, like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A context-chain error. `chain[0]` is the outermost (most recent)
/// context; the last element is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap in another layer of context.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }

    /// Iterate the chain from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    fn from_std<E: StdError + ?Sized>(e: &E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, anyhow-style.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

// NOTE: `Error` deliberately does NOT implement `std::error::Error`; that
// is what makes the blanket `From` below coherent (same trick as the real
// anyhow crate).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::from_std(&e)
    }
}

mod private {
    /// Sealed unifier over "things that convert into [`Error`]": every
    /// std error plus [`Error`] itself. Mirrors anyhow's `ext::StdError`.
    pub trait IntoError {
        fn into_error(self) -> crate::Error;
    }

    impl<E: std::error::Error + Send + Sync + 'static> IntoError for E {
        fn into_error(self) -> crate::Error {
            crate::Error::from(self)
        }
    }

    impl IntoError for crate::Error {
        fn into_error(self) -> crate::Error {
            self
        }
    }
}

/// `.context(..)` / `.with_context(..)` on fallible values.
pub trait Context<T, E>: Sized {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error>;
    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: private::IntoError> Context<T, E> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into_error().context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| e.into_error().context(f()))
    }
}

impl<T> Context<T, std::convert::Infallible> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T, Error>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string (or any printable expression).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an error.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!(concat!("condition failed: ", stringify!($cond)));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "disk on fire")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::from(io_err()).context("reading config").context("loading task");
        assert_eq!(format!("{e}"), "loading task");
        assert_eq!(format!("{e:#}"), "loading task: reading config: disk on fire");
        let dbg = format!("{e:?}");
        assert!(dbg.contains("Caused by:"), "{dbg}");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<u32> {
            Ok(s.parse::<u32>()?)
        }
        assert_eq!(parse("7").unwrap(), 7);
        assert!(parse("x").is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(format!("{e:#}"), "ctx: disk on fire");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(format!("{e}"), "missing key");
    }

    #[test]
    fn macros() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(12).unwrap_err()), "x too big: 12");
        assert_eq!(format!("{}", f(3).unwrap_err()), "three is right out");
        let e = anyhow!("value {} here", 9);
        assert_eq!(format!("{e}"), "value 9 here");
    }
}
