//! Offline stub of the `xla` PJRT bindings.
//!
//! The PJRT backend is optional at runtime: every test and experiment can
//! run on the native Rust backend. This vendored crate provides the exact
//! API surface `runtime/{tensor,engine}.rs` compiles against, with
//! [`PjRtClient::cpu`] returning an error — so `--backend pjrt` reports
//! a clear message instead of failing the whole build when the real
//! bindings are unavailable. Host-side [`Literal`] containers are fully
//! functional (they are plain `Vec<f32>` + dims).
//!
//! Point `rust/Cargo.toml`'s `xla` dependency at the real bindings to
//! enable PJRT execution; no call site changes.

use std::fmt;

#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl fmt::Display for XlaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn stub_err(what: &str) -> XlaError {
    XlaError(format!(
        "{what}: PJRT is unavailable in this build (vendored xla stub); \
         use the native backend or point Cargo.toml at the real xla crate"
    ))
}

/// Element types a [`Literal`] can be read back as. The stub stores f32.
pub trait NativeType: Copy {
    fn from_f32(x: f32) -> Self;
}

impl NativeType for f32 {
    fn from_f32(x: f32) -> Self {
        x
    }
}

/// Host-side literal: dense f32 payload plus dimensions.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    data: Vec<f32>,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape without copying the payload; element count must match.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let numel: i64 = dims.iter().product();
        if numel as usize != self.data.len() {
            return Err(XlaError(format!(
                "reshape {:?} wants {numel} elements, literal has {}",
                dims,
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    /// Read back as a host vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Ok(self.data.iter().map(|&x| T::from_f32(x)).collect())
    }

    /// Destructure a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(stub_err("to_tuple"))
    }

    /// Destructure a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(stub_err("to_tuple1"))
    }
}

/// Parsed HLO module (stub: never constructible from files).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        Err(stub_err(&format!("loading HLO text from {path}")))
    }
}

pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device buffer handle (stub: never constructed).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("to_literal_sync"))
    }
}

pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute with host arguments; stub executables do not exist, so this
    /// is unreachable in practice but keeps the call sites compiling.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("execute"))
    }
}

pub struct PjRtClient;

impl PjRtClient {
    /// The stub has no PJRT runtime: constructing a client reports why.
    pub fn cpu() -> Result<PjRtClient> {
        Err(stub_err("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.dims(), &[6]);
        let r = l.reshape(&[2, 3]).unwrap();
        assert_eq!(r.dims(), &[2, 3]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert!(l.reshape(&[4]).is_err());
        // scalar reshape
        let s = Literal::vec1(&[7.0]).reshape(&[]).unwrap();
        assert_eq!(s.to_vec::<f32>().unwrap(), vec![7.0]);
    }

    #[test]
    fn client_reports_stub() {
        let e = PjRtClient::cpu().err().unwrap();
        assert!(format!("{e}").contains("stub"));
    }
}
