//! Optimizers — dense and sparse (per-embedding-row) update rules.
//!
//! Table 5.1 uses Adagrad for fully-asynchronous training and Adam for the
//! other modes; SGD exists for the convergence-analysis experiments (the
//! theory in §4.2 is stated for SGD). All optimizers expose a uniform
//! slot-based state layout so the embedding store and the dense store can
//! host any of them:
//!
//!   state.len() == param.len() * opt.slots()
//!   slot s of weight i lives at state[s * n + i]   (planar layout)
//!
//! The kernels are written as exact-chunk loops (`chunks_exact(CHUNK)` +
//! a scalar remainder with the identical per-element body) so the release
//! build autovectorizes them. The per-element float operation order is
//! unchanged from the original scalar loops, so the chunked kernels are
//! bit-identical to the scalar references — which are retained under
//! `#[cfg(test)]` as oracles and pinned by property tests below.

use crate::config::OptimKind;

/// Vector width the kernels are unrolled to. Eight f32s is one AVX2
/// register / two NEON registers; the value only affects codegen, never
/// results (the remainder loop runs the same per-element body).
const CHUNK: usize = 8;

pub trait Optimizer: Send + Sync {
    fn kind(&self) -> OptimKind;
    /// State floats per weight.
    fn slots(&self) -> usize;
    /// In-place parameter update. `step` is the 1-based global update
    /// index (Adam bias correction); sparse rows pass the global step too
    /// ("lazy Adam" semantics, matching DeepRec's sparse Adam).
    ///
    /// `state` is the planar buffer (`slots() * param.len()` floats).
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64);
    /// In-place update with the per-slot state planes already split out:
    /// `planes[j]` holds slot `j` and has the same length as `param`.
    /// This is the form the parallel shard apply uses — a `[a,b)`
    /// sub-range of a *planar* state buffer is not contiguous, but its
    /// per-plane views are. `apply` wraps this for planar buffers; both
    /// entry points run the same kernel.
    fn apply_planes(&self, param: &mut [f32], grad: &[f32], planes: &mut [&mut [f32]], step: u64);
    fn lr(&self) -> f32;
    /// Clone into a box (checkpoint restore paths).
    fn boxed_clone(&self) -> Box<dyn Optimizer>;
}

/// Plain SGD.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn kind(&self) -> OptimKind {
        OptimKind::Sgd
    }
    fn slots(&self) -> usize {
        0
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], _state: &mut [f32], step: u64) {
        self.apply_planes(param, grad, &mut [], step);
    }
    fn apply_planes(&self, param: &mut [f32], grad: &[f32], _planes: &mut [&mut [f32]], _step: u64) {
        debug_assert_eq!(grad.len(), param.len());
        let lr = self.lr;
        let mut pc = param.chunks_exact_mut(CHUNK);
        let mut gc = grad.chunks_exact(CHUNK);
        for (p, g) in (&mut pc).zip(&mut gc) {
            for i in 0..CHUNK {
                p[i] -= lr * g[i];
            }
        }
        for (p, g) in pc.into_remainder().iter_mut().zip(gc.remainder()) {
            *p -= lr * g;
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Adagrad with TF-style initial accumulator.
#[derive(Clone, Debug)]
pub struct Adagrad {
    pub lr: f32,
    pub eps: f32,
    pub init_acc: f32,
}

impl Adagrad {
    pub fn new(lr: f32) -> Self {
        Adagrad { lr, eps: 1e-7, init_acc: 0.1 }
    }
}

impl Optimizer for Adagrad {
    fn kind(&self) -> OptimKind {
        OptimKind::Adagrad
    }
    fn slots(&self) -> usize {
        1
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64) {
        debug_assert_eq!(state.len(), param.len());
        self.apply_planes(param, grad, &mut [state], step);
    }
    fn apply_planes(&self, param: &mut [f32], grad: &[f32], planes: &mut [&mut [f32]], _step: u64) {
        let [acc] = planes else { panic!("adagrad: expected 1 state plane, got {}", planes.len()) };
        debug_assert_eq!(grad.len(), param.len());
        debug_assert_eq!(acc.len(), param.len());
        let (lr, eps, init_acc) = (self.lr, self.eps, self.init_acc);
        let mut pc = param.chunks_exact_mut(CHUNK);
        let mut gc = grad.chunks_exact(CHUNK);
        let mut ac = acc.chunks_exact_mut(CHUNK);
        for ((p, g), a) in (&mut pc).zip(&mut gc).zip(&mut ac) {
            for i in 0..CHUNK {
                let g = g[i];
                // Zero-initialized slots get the TF init_acc on first touch.
                if a[i] == 0.0 {
                    a[i] = init_acc;
                }
                a[i] += g * g;
                p[i] -= lr * g / (a[i].sqrt() + eps);
            }
        }
        let (pr, gr, ar) = (pc.into_remainder(), gc.remainder(), ac.into_remainder());
        for ((p, &g), a) in pr.iter_mut().zip(gr).zip(ar.iter_mut()) {
            if *a == 0.0 {
                *a = init_acc;
            }
            *a += g * g;
            *p -= lr * g / (a.sqrt() + eps);
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Adam (Kingma & Ba) with bias correction off the global step.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

impl Optimizer for Adam {
    fn kind(&self) -> OptimKind {
        OptimKind::Adam
    }
    fn slots(&self) -> usize {
        2
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64) {
        let n = param.len();
        debug_assert_eq!(state.len(), 2 * n);
        let (m, v) = state.split_at_mut(n);
        self.apply_planes(param, grad, &mut [m, v], step);
    }
    fn apply_planes(&self, param: &mut [f32], grad: &[f32], planes: &mut [&mut [f32]], step: u64) {
        let [m, v] = planes else { panic!("adam: expected 2 state planes, got {}", planes.len()) };
        debug_assert_eq!(grad.len(), param.len());
        debug_assert_eq!(m.len(), param.len());
        debug_assert_eq!(v.len(), param.len());
        let t = step.max(1) as i32;
        let bc1 = 1.0 - self.beta1.powi(t);
        let bc2 = 1.0 - self.beta2.powi(t);
        let (lr, b1, b2, eps) = (self.lr, self.beta1, self.beta2, self.eps);
        let mut pc = param.chunks_exact_mut(CHUNK);
        let mut gc = grad.chunks_exact(CHUNK);
        let mut mc = m.chunks_exact_mut(CHUNK);
        let mut vc = v.chunks_exact_mut(CHUNK);
        for (((p, g), m), v) in (&mut pc).zip(&mut gc).zip(&mut mc).zip(&mut vc) {
            for i in 0..CHUNK {
                let g = g[i];
                m[i] = b1 * m[i] + (1.0 - b1) * g;
                v[i] = b2 * v[i] + (1.0 - b2) * g * g;
                let mhat = m[i] / bc1;
                let vhat = v[i] / bc2;
                p[i] -= lr * mhat / (vhat.sqrt() + eps);
            }
        }
        let (pr, gr) = (pc.into_remainder(), gc.remainder());
        let (mr, vr) = (mc.into_remainder(), vc.into_remainder());
        for (((p, &g), m), v) in pr.iter_mut().zip(gr).zip(mr.iter_mut()).zip(vr.iter_mut()) {
            *m = b1 * *m + (1.0 - b1) * g;
            *v = b2 * *v + (1.0 - b2) * g * g;
            let mhat = *m / bc1;
            let vhat = *v / bc2;
            *p -= lr * mhat / (vhat.sqrt() + eps);
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Factory from config.
pub fn make_optimizer(kind: OptimKind, lr: f64) -> Box<dyn Optimizer> {
    match kind {
        OptimKind::Sgd => Box::new(Sgd { lr: lr as f32 }),
        OptimKind::Adagrad => Box::new(Adagrad::new(lr as f32)),
        OptimKind::Adam => Box::new(Adam::new(lr as f32)),
    }
}

/// Digest of the training configuration a transport front will aggregate
/// under: optimizer kinds *and* exact learning-rate bit patterns for the
/// dense/embedding pair. Sent in the shard `Hello` so a shard server that
/// was booted with a same-shape but different-lr config (the one mismatch
/// the slot-count handshake cannot see) fails loudly at connect instead
/// of silently training two configs against one model.
pub fn config_digest(opt_dense: &dyn Optimizer, opt_emb: &dyn Optimizer) -> u64 {
    use crate::util::rng::mix64;
    let mut d = mix64(0x6762_615f_6366_6764); // "gba_cfgd"
    for opt in [opt_dense, opt_emb] {
        d = mix64(d ^ opt.kind().wire_id() as u64);
        d = mix64(d ^ opt.lr().to_bits() as u64);
    }
    d
}

/// The original scalar kernels, kept verbatim as bit-identity oracles
/// for the chunked implementations above.
#[cfg(test)]
pub(crate) mod scalar_ref {
    use super::{Adagrad, Adam, Sgd};

    pub fn sgd(opt: &Sgd, param: &mut [f32], grad: &[f32]) {
        for (p, g) in param.iter_mut().zip(grad) {
            *p -= opt.lr * g;
        }
    }

    pub fn adagrad(opt: &Adagrad, param: &mut [f32], grad: &[f32], state: &mut [f32]) {
        let n = param.len();
        debug_assert_eq!(state.len(), n);
        for i in 0..n {
            let g = grad[i];
            if state[i] == 0.0 {
                state[i] = opt.init_acc;
            }
            state[i] += g * g;
            param[i] -= opt.lr * g / (state[i].sqrt() + opt.eps);
        }
    }

    pub fn adam(opt: &Adam, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64) {
        let n = param.len();
        debug_assert_eq!(state.len(), 2 * n);
        let t = step.max(1) as i32;
        let bc1 = 1.0 - opt.beta1.powi(t);
        let bc2 = 1.0 - opt.beta2.powi(t);
        let (m, v) = state.split_at_mut(n);
        for i in 0..n {
            let g = grad[i];
            m[i] = opt.beta1 * m[i] + (1.0 - opt.beta1) * g;
            v[i] = opt.beta2 * v[i] + (1.0 - opt.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            param[i] -= opt.lr * mhat / (vhat.sqrt() + opt.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, gen};
    use crate::util::rng::Pcg64;

    fn quad_descend(opt: &dyn Optimizer, steps: u64) -> f32 {
        // minimize f(x) = 0.5*||x||^2, grad = x
        let mut x = vec![4.0f32, -3.0, 2.0];
        let mut state = vec![0.0f32; x.len() * opt.slots()];
        for t in 1..=steps {
            let g = x.clone();
            opt.apply(&mut x, &g, &mut state, t);
        }
        x.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(quad_descend(&Sgd { lr: 0.1 }, 100) < 1e-3);
    }

    #[test]
    fn adagrad_converges_on_quadratic() {
        assert!(quad_descend(&Adagrad::new(0.5), 300) < 0.05);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(quad_descend(&Adam::new(0.05), 500) < 0.01);
    }

    #[test]
    fn adam_first_step_magnitude_is_lr() {
        // With bias correction, |Δ| of the first step ≈ lr regardless of g.
        let opt = Adam::new(0.01);
        for g0 in [1e-4f32, 1.0, 1e3] {
            let mut p = vec![0.0f32];
            let mut s = vec![0.0f32; 2];
            opt.apply(&mut p, &[g0], &mut s, 1);
            assert!((p[0].abs() - 0.01).abs() < 1e-4, "g0={g0} -> {}", p[0]);
        }
    }

    #[test]
    fn adagrad_accumulates_monotonically_smaller_steps() {
        let opt = Adagrad::new(0.1);
        let mut p = vec![0.0f32];
        let mut s = vec![0.0f32];
        let mut deltas = Vec::new();
        for t in 1..=5 {
            let before = p[0];
            opt.apply(&mut p, &[1.0], &mut s, t);
            deltas.push((p[0] - before).abs());
        }
        for w in deltas.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn sgd_zero_slots() {
        assert_eq!(Sgd { lr: 0.1 }.slots(), 0);
        assert_eq!(Adagrad::new(0.1).slots(), 1);
        assert_eq!(Adam::new(0.1).slots(), 2);
    }

    #[test]
    fn factory_kinds() {
        for k in [OptimKind::Sgd, OptimKind::Adagrad, OptimKind::Adam] {
            assert_eq!(make_optimizer(k, 0.01).kind(), k);
        }
    }

    #[test]
    fn config_digest_separates_lr_and_kind() {
        let base = (make_optimizer(OptimKind::Adam, 0.001), make_optimizer(OptimKind::Adagrad, 0.01));
        let same = (make_optimizer(OptimKind::Adam, 0.001), make_optimizer(OptimKind::Adagrad, 0.01));
        let d0 = config_digest(base.0.as_ref(), base.1.as_ref());
        assert_eq!(d0, config_digest(same.0.as_ref(), same.1.as_ref()));
        // Same shape (Adam/Adagrad pair), different dense lr: must differ.
        let lr_swap = make_optimizer(OptimKind::Adam, 0.002);
        assert_ne!(d0, config_digest(lr_swap.as_ref(), base.1.as_ref()));
        // Different kind pairing must differ too.
        let kind_swap = make_optimizer(OptimKind::Sgd, 0.001);
        assert_ne!(d0, config_digest(kind_swap.as_ref(), base.1.as_ref()));
        // Order matters: (dense, emb) vs (emb, dense) are different configs.
        assert_ne!(d0, config_digest(base.1.as_ref(), base.0.as_ref()));
    }

    // --- chunked-vs-scalar bit-identity pins -------------------------------

    /// Lengths that straddle every chunking regime: empty, sub-chunk,
    /// one-off-chunk boundaries, and a large odd length (1023 = 127*8 + 7).
    const PIN_LENS: [usize; 6] = [0, 1, 7, 8, 9, 1023];

    /// A gradient stream with hostile values mixed in: NaN, ±inf,
    /// subnormals, and exact zeros alongside ordinary finite floats. The
    /// kernels must propagate every bit pattern exactly as the scalar
    /// reference does.
    fn hostile_grad(rng: &mut Pcg64, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| match rng.gen_range(10) {
                0 => f32::NAN,
                1 => f32::INFINITY,
                2 => f32::NEG_INFINITY,
                3 => f32::from_bits(rng.next_u32() & 0x007f_ffff), // subnormal / ±0
                4 => 0.0,
                _ => gen::f32_in(rng, 10.0),
            })
            .collect()
    }

    fn assert_bits_eq(tag: &str, a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), b.len(), "{tag}: length mismatch");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{tag}[{i}]: {x:?} vs {y:?}");
        }
    }

    #[test]
    fn sgd_chunked_bit_identical_to_scalar() {
        check("sgd chunked == scalar", 64, |rng| {
            let opt = Sgd { lr: gen::f32_in(rng, 1.0).abs().max(1e-4) };
            for &n in &PIN_LENS {
                let p0: Vec<f32> = (0..n).map(|_| gen::f32_in(rng, 5.0)).collect();
                let g = hostile_grad(rng, n);
                let (mut pa, mut pb) = (p0.clone(), p0);
                opt.apply(&mut pa, &g, &mut [], 1);
                scalar_ref::sgd(&opt, &mut pb, &g);
                assert_bits_eq("sgd param", &pa, &pb);
            }
        });
    }

    #[test]
    fn adagrad_chunked_bit_identical_to_scalar() {
        check("adagrad chunked == scalar", 64, |rng| {
            let opt = Adagrad::new(gen::f32_in(rng, 1.0).abs().max(1e-4));
            for &n in &PIN_LENS {
                let p0: Vec<f32> = (0..n).map(|_| gen::f32_in(rng, 5.0)).collect();
                // Mix zero slots (first-touch init_acc branch) with warm ones.
                let s0: Vec<f32> = (0..n)
                    .map(|_| if rng.gen_range(2) == 0 { 0.0 } else { gen::f32_in(rng, 3.0).abs() })
                    .collect();
                let g = hostile_grad(rng, n);
                let (mut pa, mut sa) = (p0.clone(), s0.clone());
                let (mut pb, mut sb) = (p0, s0);
                opt.apply(&mut pa, &g, &mut sa, 1);
                scalar_ref::adagrad(&opt, &mut pb, &g, &mut sb);
                assert_bits_eq("adagrad param", &pa, &pb);
                assert_bits_eq("adagrad state", &sa, &sb);
            }
        });
    }

    #[test]
    fn adagrad_all_zero_state_takes_first_touch_branch() {
        let opt = Adagrad::new(0.1);
        let n = 9;
        let mut p = vec![0.0f32; n];
        let mut s = vec![0.0f32; n];
        let g = vec![1.0f32; n];
        opt.apply(&mut p, &g, &mut s, 1);
        let (mut pr, mut sr) = (vec![0.0f32; n], vec![0.0f32; n]);
        scalar_ref::adagrad(&opt, &mut pr, &g, &mut sr);
        assert_bits_eq("first-touch param", &p, &pr);
        assert_bits_eq("first-touch state", &s, &sr);
        // And the accumulator actually got the init: 0.1 + 1*1 = 1.1.
        assert!(s.iter().all(|&a| (a - 1.1).abs() < 1e-6), "{s:?}");
    }

    #[test]
    fn adam_chunked_bit_identical_to_scalar() {
        check("adam chunked == scalar", 64, |rng| {
            let opt = Adam::new(gen::f32_in(rng, 0.1).abs().max(1e-4));
            for &n in &PIN_LENS {
                let step = 1 + rng.gen_range(1000);
                let p0: Vec<f32> = (0..n).map(|_| gen::f32_in(rng, 5.0)).collect();
                let s0: Vec<f32> = (0..2 * n).map(|_| gen::f32_in(rng, 2.0)).collect();
                let g = hostile_grad(rng, n);
                let (mut pa, mut sa) = (p0.clone(), s0.clone());
                let (mut pb, mut sb) = (p0, s0);
                opt.apply(&mut pa, &g, &mut sa, step);
                scalar_ref::adam(&opt, &mut pb, &g, &mut sb, step);
                assert_bits_eq("adam param", &pa, &pb);
                assert_bits_eq("adam state", &sa, &sb);
            }
        });
    }

    /// `apply_planes` over separately-allocated planes must match `apply`
    /// over the planar buffer — this is the contract the parallel shard
    /// apply relies on when it splits a planar buffer into plane views.
    #[test]
    fn apply_planes_matches_planar_apply() {
        check("apply_planes == apply", 32, |rng| {
            for kind in [OptimKind::Sgd, OptimKind::Adagrad, OptimKind::Adam] {
                let opt = make_optimizer(kind, 0.01);
                let n = gen::usize_in(rng, 0, 40);
                let step = 1 + rng.gen_range(50);
                let p0: Vec<f32> = (0..n).map(|_| gen::f32_in(rng, 5.0)).collect();
                let s0: Vec<f32> = (0..n * opt.slots()).map(|_| gen::f32_in(rng, 2.0)).collect();
                let g = hostile_grad(rng, n);

                let (mut pa, mut sa) = (p0.clone(), s0.clone());
                opt.apply(&mut pa, &g, &mut sa, step);

                let mut pb = p0;
                let mut planes: Vec<Vec<f32>> =
                    s0.chunks(n.max(1)).map(|c| c.to_vec()).collect();
                if n == 0 {
                    planes = vec![Vec::new(); opt.slots()];
                }
                let mut views: Vec<&mut [f32]> =
                    planes.iter_mut().map(|p| p.as_mut_slice()).collect();
                opt.apply_planes(&mut pb, &g, &mut views, step);

                assert_bits_eq("planes param", &pa, &pb);
                let flat: Vec<f32> = planes.into_iter().flatten().collect();
                assert_bits_eq("planes state", &sa, &flat);
            }
        });
    }
}
