//! Optimizers — dense and sparse (per-embedding-row) update rules.
//!
//! Table 5.1 uses Adagrad for fully-asynchronous training and Adam for the
//! other modes; SGD exists for the convergence-analysis experiments (the
//! theory in §4.2 is stated for SGD). All optimizers expose a uniform
//! slot-based state layout so the embedding store and the dense store can
//! host any of them:
//!
//!   state.len() == param.len() * opt.slots()
//!   slot s of weight i lives at state[s * n + i]   (planar layout)

use crate::config::OptimKind;

pub trait Optimizer: Send + Sync {
    fn kind(&self) -> OptimKind;
    /// State floats per weight.
    fn slots(&self) -> usize;
    /// In-place parameter update. `step` is the 1-based global update
    /// index (Adam bias correction); sparse rows pass the global step too
    /// ("lazy Adam" semantics, matching DeepRec's sparse Adam).
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64);
    fn lr(&self) -> f32;
    /// Clone into a box (checkpoint restore paths).
    fn boxed_clone(&self) -> Box<dyn Optimizer>;
}

/// Plain SGD.
#[derive(Clone, Debug)]
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn kind(&self) -> OptimKind {
        OptimKind::Sgd
    }
    fn slots(&self) -> usize {
        0
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], _state: &mut [f32], _step: u64) {
        for (p, g) in param.iter_mut().zip(grad) {
            *p -= self.lr * g;
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Adagrad with TF-style initial accumulator.
#[derive(Clone, Debug)]
pub struct Adagrad {
    pub lr: f32,
    pub eps: f32,
    pub init_acc: f32,
}

impl Adagrad {
    pub fn new(lr: f32) -> Self {
        Adagrad { lr, eps: 1e-7, init_acc: 0.1 }
    }
}

impl Optimizer for Adagrad {
    fn kind(&self) -> OptimKind {
        OptimKind::Adagrad
    }
    fn slots(&self) -> usize {
        1
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], _step: u64) {
        let n = param.len();
        debug_assert_eq!(state.len(), n);
        for i in 0..n {
            let g = grad[i];
            // Zero-initialized slots get the TF init_acc on first touch.
            if state[i] == 0.0 {
                state[i] = self.init_acc;
            }
            state[i] += g * g;
            param[i] -= self.lr * g / (state[i].sqrt() + self.eps);
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Adam (Kingma & Ba) with bias correction off the global step.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Adam {
    pub fn new(lr: f32) -> Self {
        Adam { lr, beta1: 0.9, beta2: 0.999, eps: 1e-8 }
    }
}

impl Optimizer for Adam {
    fn kind(&self) -> OptimKind {
        OptimKind::Adam
    }
    fn slots(&self) -> usize {
        2
    }
    fn apply(&self, param: &mut [f32], grad: &[f32], state: &mut [f32], step: u64) {
        let n = param.len();
        debug_assert_eq!(state.len(), 2 * n);
        let t = step.max(1) as i32;
        let bc1 = 1.0 - self.beta1.powi(t);
        let bc2 = 1.0 - self.beta2.powi(t);
        let (m, v) = state.split_at_mut(n);
        for i in 0..n {
            let g = grad[i];
            m[i] = self.beta1 * m[i] + (1.0 - self.beta1) * g;
            v[i] = self.beta2 * v[i] + (1.0 - self.beta2) * g * g;
            let mhat = m[i] / bc1;
            let vhat = v[i] / bc2;
            param[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
    fn lr(&self) -> f32 {
        self.lr
    }
    fn boxed_clone(&self) -> Box<dyn Optimizer> {
        Box::new(self.clone())
    }
}

/// Factory from config.
pub fn make_optimizer(kind: OptimKind, lr: f64) -> Box<dyn Optimizer> {
    match kind {
        OptimKind::Sgd => Box::new(Sgd { lr: lr as f32 }),
        OptimKind::Adagrad => Box::new(Adagrad::new(lr as f32)),
        OptimKind::Adam => Box::new(Adam::new(lr as f32)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quad_descend(opt: &dyn Optimizer, steps: u64) -> f32 {
        // minimize f(x) = 0.5*||x||^2, grad = x
        let mut x = vec![4.0f32, -3.0, 2.0];
        let mut state = vec![0.0f32; x.len() * opt.slots()];
        for t in 1..=steps {
            let g = x.clone();
            opt.apply(&mut x, &g, &mut state, t);
        }
        x.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    #[test]
    fn sgd_converges_on_quadratic() {
        assert!(quad_descend(&Sgd { lr: 0.1 }, 100) < 1e-3);
    }

    #[test]
    fn adagrad_converges_on_quadratic() {
        assert!(quad_descend(&Adagrad::new(0.5), 300) < 0.05);
    }

    #[test]
    fn adam_converges_on_quadratic() {
        assert!(quad_descend(&Adam::new(0.05), 500) < 0.01);
    }

    #[test]
    fn adam_first_step_magnitude_is_lr() {
        // With bias correction, |Δ| of the first step ≈ lr regardless of g.
        let opt = Adam::new(0.01);
        for g0 in [1e-4f32, 1.0, 1e3] {
            let mut p = vec![0.0f32];
            let mut s = vec![0.0f32; 2];
            opt.apply(&mut p, &[g0], &mut s, 1);
            assert!((p[0].abs() - 0.01).abs() < 1e-4, "g0={g0} -> {}", p[0]);
        }
    }

    #[test]
    fn adagrad_accumulates_monotonically_smaller_steps() {
        let opt = Adagrad::new(0.1);
        let mut p = vec![0.0f32];
        let mut s = vec![0.0f32];
        let mut deltas = Vec::new();
        for t in 1..=5 {
            let before = p[0];
            opt.apply(&mut p, &[1.0], &mut s, t);
            deltas.push((p[0] - before).abs());
        }
        for w in deltas.windows(2) {
            assert!(w[1] < w[0]);
        }
    }

    #[test]
    fn sgd_zero_slots() {
        assert_eq!(Sgd { lr: 0.1 }.slots(), 0);
        assert_eq!(Adagrad::new(0.1).slots(), 1);
        assert_eq!(Adam::new(0.1).slots(), 2);
    }

    #[test]
    fn factory_kinds() {
        for k in [OptimKind::Sgd, OptimKind::Adagrad, OptimKind::Adam] {
            assert_eq!(make_optimizer(k, 0.01).kind(), k);
        }
    }
}
