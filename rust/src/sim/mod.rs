//! Discrete-event cluster simulator.
//!
//! Runs any [`ModePolicy`] against the straggler model in *virtual time*,
//! which is what makes the paper's 100–800-worker experiments (Fig. 1,
//! Table 5.2/5.3, Fig. 7) tractable and deterministic on one machine. The
//! simulator reuses the exact policy state machines that the threaded PS
//! runtime uses — only compute is replaced by a timing model.
//!
//! Model: each worker is a loop of (pull → compute(Δt) → push). The PS
//! applies aggregated updates with a fixed cost; workers gated by their
//! policy (sync barrier, SSP bound) park until the next apply.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::cluster::StragglerModel;
use crate::config::{ExperimentConfig, ModeKind};
use crate::coordinator::modes::make_policy;
use crate::coordinator::{ModePolicy, PullDecision, PushAction};
use crate::metrics::{RateSeries, StalenessStats};
use crate::staleness::{make_staleness, GbaStaleness, StalenessPolicy};
use crate::util::rng::Pcg64;

#[derive(Clone, Debug)]
pub struct SimParams {
    pub workers: usize,
    pub local_batch: usize,
    pub compute: StragglerModel,
    /// PS cost to apply one aggregated update (ms); serializes applies.
    pub ps_apply_ms: f64,
    /// PS shards: the dense/embedding apply fans out across shards in
    /// parallel, so the effective apply cost is `ps_apply_ms / n_shards`.
    pub n_shards: usize,
    /// Per-shard apply fan-out (`[ps] apply_threads`): inside one shard
    /// the dense sweep and the embedding lock-shard groups also apply in
    /// parallel, further dividing the apply cost.
    pub apply_threads: usize,
    /// Serialization + framing cost per flush fan-out (ms) when shards
    /// sit behind a socket transport. The encode happens once on the
    /// flusher's critical path (the per-shard sends then overlap), so it
    /// adds to — and does not divide by — the shard count. 0 models the
    /// in-process transport.
    pub wire_ms: f64,
    /// Virtual time-of-day at simulation start (secs into the trace day).
    pub start_sec: f64,
    /// Virtual duration to simulate (secs).
    pub duration_sec: f64,
    pub seed: u64,
}

impl SimParams {
    /// Effective wall cost of one aggregated apply (ms): the per-shard
    /// slices apply concurrently — and each shard fans out over its
    /// apply threads — then the wire cost (if any) rides on top once.
    pub fn effective_apply_ms(&self) -> f64 {
        let lanes = (self.n_shards.max(1) * self.apply_threads.max(1)) as f64;
        self.ps_apply_ms / lanes + self.wire_ms
    }

    /// Wire cost implied by a config's `[ps] transport` choice. Remote
    /// shards pay the same per-flush framing cost as localhost sockets;
    /// inter-host latency is the operator's `wire_ms` calibration to
    /// make.
    pub fn wire_ms_of(cfg: &ExperimentConfig) -> f64 {
        match cfg.ps.transport {
            crate::config::TransportKind::InProc => 0.0,
            crate::config::TransportKind::Socket | crate::config::TransportKind::Remote => {
                cfg.cluster.wire_ms
            }
        }
    }
}

#[derive(Clone, Debug)]
pub struct SimOutcome {
    pub samples_done: u64,
    pub qps: RateSeries,
    pub global_steps: u64,
    pub dropped_batches: u64,
    pub staleness: StalenessStats,
    /// Fraction of worker-time spent parked at gates (sync barrier cost).
    pub blocked_frac: f64,
    pub per_worker_batches: Vec<u64>,
    /// Mean per-worker QPS (local QPS of Table 5.3).
    pub local_qps_mean: f64,
}

impl SimOutcome {
    pub fn global_qps(&self) -> f64 {
        self.qps.mean_qps()
    }
}

/// Simulate one mode policy under the given parameters (with the
/// default no-op `gba` staleness decay — identical to the pre-seam
/// simulator).
pub fn simulate(params: &SimParams, policy: Box<dyn ModePolicy>) -> SimOutcome {
    simulate_with_staleness(params, policy, Box::new(GbaStaleness))
}

/// Simulate one mode policy with an explicit staleness-decay policy at
/// the flush point — the simulator half of the `rust/src/staleness/`
/// seam, mirroring the control plane's hooks: `on_issue` at token
/// issue, `reweight` over the mode policy's weights at every flush,
/// and one unit of movement-clock advance per applied step (the
/// threaded plane feeds real update norms; the sim has no parameters,
/// so a unit clock makes the normalized gap read as "applies missed").
pub fn simulate_with_staleness(
    params: &SimParams,
    mut policy: Box<dyn ModePolicy>,
    mut decay: Box<dyn StalenessPolicy>,
) -> SimOutcome {
    let n = params.workers;
    let mut rng = Pcg64::new(params.seed, 0x51u64);
    let t_end = params.start_sec + params.duration_sec;

    // Event heap: Reverse((time_ns, seq, worker)).
    let mut heap: BinaryHeap<Reverse<(u64, u64, usize)>> = BinaryHeap::new();
    let mut seq = 0u64;
    let ns = |t: f64| (t * 1e9) as u64;

    let mut inflight_token = vec![0u64; n];
    let mut parked = vec![false; n];
    let mut parked_since = vec![0.0f64; n];
    let mut blocked_total = 0.0f64;
    let mut per_worker_batches = vec![0u64; n];

    let mut buffer_tokens: Vec<u64> = Vec::new();
    let mut qps = RateSeries::new();
    let mut staleness = StalenessStats::new();
    let mut dropped = 0u64;
    let mut steps = 0u64;
    let mut samples_done = 0u64;
    let mut ps_free_at = params.start_sec;

    // A worker attempts to pull at time `t`; either schedules its next
    // completion or parks.
    macro_rules! try_pull {
        ($w:expr, $t:expr) => {{
            let w: usize = $w;
            let t: f64 = $t;
            if t >= t_end {
                // Past the horizon: do not start new work.
            } else {
                match policy.on_pull(w) {
                    PullDecision::Token(tok) => {
                        inflight_token[w] = tok;
                        decay.on_issue(tok);
                        // Pushes are non-blocking for workers (Algorithm 1);
                        // the PS apply cost only gates *aggregated* updates,
                        // so it delays barrier-released cohorts (sync-family)
                        // but not free-running pulls.
                        let start = if parked[w] { t.max(ps_free_at) } else { t };
                        let dt_ms =
                            params.compute.compute_ms_batch(w, start, params.local_batch, &mut rng);
                        seq += 1;
                        heap.push(Reverse((ns(start + dt_ms / 1e3), seq, w)));
                        if parked[w] {
                            parked[w] = false;
                            blocked_total += t - parked_since[w];
                        }
                    }
                    PullDecision::Wait => {
                        if !parked[w] {
                            parked[w] = true;
                            parked_since[w] = t;
                        }
                    }
                }
            }
        }};
    }

    for w in 0..n {
        try_pull!(w, params.start_sec);
    }

    while let Some(Reverse((t_ns, _s, w))) = heap.pop() {
        let t = t_ns as f64 / 1e9;
        // Push the finished gradient.
        let token = inflight_token[w];
        qps.record(t, params.local_batch as u64);
        samples_done += params.local_batch as u64;
        per_worker_batches[w] += 1;
        match policy.on_push(w, token) {
            PushAction::Drop => {
                dropped += 1;
            }
            PushAction::Buffer => {
                buffer_tokens.push(token);
            }
            PushAction::FlushNow => {
                buffer_tokens.push(token);
                let k = policy.global_step();
                let spec = policy.flush_spec(&buffer_tokens);
                // The staleness seam, same point as the control plane's
                // begin_flush: one in-place rescale of the mode weights
                // (no-op for the default `gba` policy).
                let mut weights = spec.weights;
                decay.reweight(k, &buffer_tokens, &mut weights);
                for (tok, wgt) in buffer_tokens.iter().zip(&weights) {
                    if *wgt == 0.0 {
                        dropped += 1;
                    } else {
                        staleness.record(k.saturating_sub(*tok));
                    }
                }
                buffer_tokens.clear();
                policy.on_applied();
                // Unit movement per applied step (see doc comment).
                decay.on_update_norm(1.0);
                steps += 1;
                ps_free_at = t + params.effective_apply_ms() / 1e3;
                // The apply may unblock gated workers.
                for w2 in 0..n {
                    if parked[w2] {
                        try_pull!(w2, t);
                    }
                }
            }
        }
        // This worker pulls its next batch.
        try_pull!(w, t);
    }

    // Account workers still parked at the end.
    for w in 0..n {
        if parked[w] {
            blocked_total += t_end - parked_since[w];
        }
    }

    let duration = params.duration_sec.max(1e-9);
    let local_qps_mean = per_worker_batches
        .iter()
        .map(|&b| b as f64 * params.local_batch as f64 / duration)
        .sum::<f64>()
        / n as f64;
    SimOutcome {
        samples_done,
        qps,
        global_steps: steps,
        dropped_batches: dropped,
        staleness,
        blocked_frac: blocked_total / (n as f64 * duration),
        per_worker_batches,
        local_qps_mean,
    }
}

/// Convenience: simulate a configured mode for a window of the trace day.
pub fn simulate_mode(
    cfg: &ExperimentConfig,
    kind: ModeKind,
    start_sec: f64,
    duration_sec: f64,
    seed: u64,
) -> SimOutcome {
    let mode = cfg.mode(kind);
    let compute = StragglerModel::new(&cfg.cluster, mode.workers, seed);
    let params = SimParams {
        workers: mode.workers,
        local_batch: mode.local_batch,
        compute,
        ps_apply_ms: cfg.cluster.ps_apply_ms,
        n_shards: cfg.ps.n_shards,
        apply_threads: cfg.ps.apply_threads,
        wire_ms: SimParams::wire_ms_of(cfg),
        start_sec,
        duration_sec,
        seed,
    };
    let policy = make_policy(kind, &mode, cfg.gba_m_effective());
    // Honor `[train] staleness_policy` in simulation too, so simulated
    // sweeps (experiments/ablation.rs) exercise the same seam as the
    // threaded plane.
    simulate_with_staleness(&params, policy, make_staleness(&cfg.train.staleness))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModeConfig;
    use crate::coordinator::modes::{AsyncPolicy, GbaPolicy, SyncPolicy};

    fn params(workers: usize, hetero: bool, seed: u64) -> SimParams {
        let compute = if hetero {
            let cfg = crate::config::ClusterConfig {
                trace: "flat".into(),
                base_compute_ms: 10.0,
                hetero_sigma: 0.6,
                ps_apply_ms: 0.1,
                wire_ms: 0.0,
                workers: crate::config::WorkerPlane::InProc,
                worker_listen: String::new(),
            };
            StragglerModel::new(&cfg, workers, seed)
        } else {
            StragglerModel::constant(10.0, workers)
        };
        SimParams {
            workers,
            local_batch: 100,
            compute,
            ps_apply_ms: 0.1,
            n_shards: 1,
            apply_threads: 1,
            wire_ms: 0.0,
            start_sec: 0.0,
            duration_sec: 60.0,
            seed,
        }
    }

    #[test]
    fn wire_cost_slows_barrier_modes_monotonically() {
        // Sync parks every worker behind each apply, so per-flush wire
        // cost comes straight off the step rate.
        let mut cheap = params(8, false, 3);
        cheap.n_shards = 4;
        let fast = simulate(&cheap, Box::new(SyncPolicy::new(8)));
        let mut wired = params(8, false, 3);
        wired.n_shards = 4;
        wired.wire_ms = 8.0;
        assert!(wired.effective_apply_ms() > cheap.effective_apply_ms());
        let slow = simulate(&wired, Box::new(SyncPolicy::new(8)));
        assert!(
            slow.global_steps < fast.global_steps,
            "wire cost did not slow sync: {} vs {}",
            slow.global_steps,
            fast.global_steps
        );
    }

    #[test]
    fn apply_threads_divide_apply_cost_but_not_wire_cost() {
        let mut p = params(8, false, 3);
        p.n_shards = 4;
        p.ps_apply_ms = 8.0;
        p.wire_ms = 1.0;
        let serial = p.effective_apply_ms();
        p.apply_threads = 4;
        // The fan-out divides the apply term (8/4/1 -> 8/4/4) and leaves
        // the once-per-flush wire term alone.
        assert_eq!(serial, 8.0 / 4.0 + 1.0);
        assert_eq!(p.effective_apply_ms(), 8.0 / 16.0 + 1.0);
    }

    #[test]
    fn homogeneous_sync_and_async_similar_qps() {
        let p = params(8, false, 1);
        let sync = simulate(&p, Box::new(SyncPolicy::new(8)));
        let asyn = simulate(&p, Box::new(AsyncPolicy::new()));
        assert!(sync.global_steps > 100);
        let ratio = asyn.global_qps() / sync.global_qps();
        assert!(ratio > 0.9 && ratio < 1.3, "ratio={ratio}");
        // No staleness in sync; async has none here either (serial applies
        // per worker), but sync must record exactly zero.
        assert_eq!(sync.staleness.max(), 0);
    }

    #[test]
    fn stragglers_hurt_sync_more_than_async() {
        let p = params(16, true, 7);
        let sync = simulate(&p, Box::new(SyncPolicy::new(16)));
        let asyn = simulate(&p, Box::new(AsyncPolicy::new()));
        let speedup = asyn.global_qps() / sync.global_qps();
        assert!(speedup > 1.5, "async/sync speedup = {speedup}");
        // Sync workers spend real time at the barrier.
        assert!(sync.blocked_frac > 0.2, "blocked={}", sync.blocked_frac);
        assert!(asyn.blocked_frac < 0.01);
    }

    #[test]
    fn gba_matches_async_throughput() {
        let p = params(16, true, 3);
        let asyn = simulate(&p, Box::new(AsyncPolicy::new()));
        let gba = simulate(&p, Box::new(GbaPolicy::with_iota(16, 4)));
        let ratio = gba.global_qps() / asyn.global_qps();
        // The paper's Table 5.2: GBA within a few percent of async.
        assert!(ratio > 0.95 && ratio < 1.05, "gba/async = {ratio}");
        assert_eq!(gba.blocked_frac, 0.0);
    }

    #[test]
    fn gba_steps_equal_batches_over_m() {
        let p = params(8, false, 2);
        let gba = simulate(&p, Box::new(GbaPolicy::with_iota(8, 4)));
        let batches: u64 = gba.per_worker_batches.iter().sum();
        assert!(gba.global_steps >= batches / 8 && gba.global_steps <= batches / 8 + 1);
    }

    #[test]
    fn sharding_amortizes_apply_cost() {
        // Heavy apply cost + cheap compute: the serialized PS apply
        // throttles barrier-released cohorts; shards apply in parallel.
        let mut p = params(8, false, 4);
        p.ps_apply_ms = 20.0;
        let one = simulate(&p, Box::new(SyncPolicy::new(8)));
        p.n_shards = 8;
        let eight = simulate(&p, Box::new(SyncPolicy::new(8)));
        let ratio = eight.global_qps() / one.global_qps();
        assert!(ratio > 1.5, "8-shard/1-shard qps ratio = {ratio}");
    }

    #[test]
    fn deterministic_given_seed() {
        let p = params(8, true, 11);
        let a = simulate(&p, Box::new(GbaPolicy::with_iota(8, 4)));
        let b = simulate(&p, Box::new(GbaPolicy::with_iota(8, 4)));
        assert_eq!(a.samples_done, b.samples_done);
        assert_eq!(a.global_steps, b.global_steps);
        assert_eq!(a.per_worker_batches, b.per_worker_batches);
    }

    /// The staleness seam in the simulator: the default decay is exactly
    /// `simulate`, and a hostile zero-everything policy turns every kept
    /// batch into a drop without touching throughput accounting.
    #[test]
    fn staleness_seam_defaults_identical_and_dispatches() {
        use crate::staleness::{GbaStaleness, StalenessPolicy, StalenessPolicyKind};

        let p = params(16, true, 9);
        let a = simulate(&p, Box::new(GbaPolicy::with_iota(16, 4)));
        let b = simulate_with_staleness(
            &p,
            Box::new(GbaPolicy::with_iota(16, 4)),
            Box::new(GbaStaleness),
        );
        assert_eq!(a.global_steps, b.global_steps);
        assert_eq!(a.dropped_batches, b.dropped_batches);
        assert_eq!(a.samples_done, b.samples_done);
        assert_eq!(a.staleness.count(), b.staleness.count());

        struct DropAll;
        impl StalenessPolicy for DropAll {
            fn kind(&self) -> StalenessPolicyKind {
                StalenessPolicyKind::Abs
            }
            fn reweight(&mut self, _k: u64, _tokens: &[u64], weights: &mut [f32]) {
                for w in weights {
                    *w = 0.0;
                }
            }
        }
        let c = simulate_with_staleness(
            &p,
            Box::new(GbaPolicy::with_iota(16, 4)),
            Box::new(DropAll),
        );
        assert_eq!(c.global_steps, a.global_steps, "steps are policy-driven, not weight-driven");
        assert_eq!(c.staleness.count(), 0, "every entry decayed out");
        assert!(c.dropped_batches > a.dropped_batches);
    }

    #[test]
    fn hop_bw_drops_slowest() {
        use crate::coordinator::modes::HopBwPolicy;
        let p = params(8, true, 5);
        let bw = simulate(&p, Box::new(HopBwPolicy::new(8, 2)));
        assert!(bw.dropped_batches > 0, "no drops");
        assert!(bw.global_steps > 10);
    }

    #[test]
    fn simulate_mode_from_config() {
        let cfg = ExperimentConfig::from_toml(
            r#"
name = "sim-test"
seed = 1
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 8
hidden2 = 4
vocab_size = 100
zipf_s = 1.1
[data]
days_base = 1
days_eval = 1
samples_per_day = 1000
teacher_seed = 1
[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.001
[mode.sync]
workers = 4
local_batch = 64
[mode.gba]
workers = 8
local_batch = 32
iota = 3
[cluster]
trace = "diurnal"
base_compute_ms = 5.0
hetero_sigma = 0.4
ps_apply_ms = 0.2
"#,
        )
        .unwrap();
        let night = simulate_mode(&cfg, ModeKind::Sync, 4.0 * 3600.0, 30.0, 1);
        let peak = simulate_mode(&cfg, ModeKind::Sync, 15.0 * 3600.0, 30.0, 1);
        // Cluster load slows everything down at peak hours (Fig. 1).
        assert!(night.global_qps() > peak.global_qps() * 1.2);
    }
}
