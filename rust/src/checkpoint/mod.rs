//! Checkpointing: save / inherit base models (the switching protocol of
//! Fig. 6 trains a base model in one mode, checkpoints it, and every
//! compared mode inherits the same checkpoint).
//!
//! Two on-disk layouts share one in-memory [`Checkpoint`]:
//!
//! * **Portable single file** (little-endian, versioned):
//!
//!   ```text
//!   magic "GBACKPT2" | header_len u32 | header json | dense blobs | rows
//!   ```
//!
//!   Rows are globally key-sorted; the file is shard-layout-free and
//!   restores into any `n_shards`/transport configuration.
//!
//! * **Sharded directory** ([`Checkpoint::save_sharded`]): a
//!   `manifest.json` plus one `shard-NNN.bin` stream per PS shard, each
//!   holding that shard's dense range slices and *its own* embedding
//!   rows (key-sorted within the shard). This is the ROADMAP follow-up
//!   to the single sorted row list: each shard's state is a separate
//!   stream, written and reloadable independently — what a shard-side
//!   service persists locally in a real multi-process deployment.
//!   [`Checkpoint::load_sharded`] reassembles the portable form, so a
//!   sharded save restores at any shard count.
//!
//! Optimizer slots are deliberately *not* persisted by either layout:
//! inheriting a checkpoint into a (possibly different) training mode
//! starts fresh optimizer state, which is exactly the paper's switch
//! semantics. (The *in-memory* respawn checkpoints the
//! [`ShardSupervisor`](crate::transport::ShardSupervisor) keeps are
//! different: they carry slots, because respawn resumes mid-stream.)

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::embedding::RowMeta;
use crate::ps::PsServer;
use crate::runtime::{HostTensor, VariantDims};
use crate::util::json::{self, Json};

const MAGIC: &[u8; 8] = b"GBACKPT2";
const SHARD_MAGIC: &[u8; 8] = b"GBASHRD1";

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub dims: VariantDims,
    pub dense: Vec<HostTensor>,
    /// (key, embedding vector, metadata) — optimizer slots excluded.
    pub emb_rows: Vec<(u64, Vec<f32>, RowMeta)>,
    pub global_step: u64,
}

impl Checkpoint {
    /// Snapshot a running PS.
    pub fn from_ps(dims: VariantDims, ps: &PsServer) -> Checkpoint {
        let mut emb_rows = Vec::new();
        ps.for_each_emb_row(|key, vec, _state, meta| {
            emb_rows.push((key, vec.to_vec(), meta));
        });
        // Deterministic order for byte-stable checkpoints.
        emb_rows.sort_by_key(|(k, _, _)| *k);
        Checkpoint { dims, dense: ps.dense_params(), emb_rows, global_step: ps.global_step() }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        let header = Json::obj()
            .set("fields", self.dims.fields)
            .set("emb_dim", self.dims.emb_dim)
            .set("hidden1", self.dims.hidden1)
            .set("hidden2", self.dims.hidden2)
            .set("mlp_in", self.dims.mlp_in)
            .set("global_step", self.global_step)
            .set("n_rows", self.emb_rows.len())
            .set(
                "dense_shapes",
                Json::Arr(
                    self.dense
                        .iter()
                        .map(|t| Json::Arr(t.shape.iter().map(|&d| Json::from(d)).collect()))
                        .collect(),
                ),
            );
        let htext = header.to_string_compact();
        f.write_all(&(htext.len() as u32).to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        for t in &self.dense {
            for &x in &t.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        for (key, vec, meta) in &self.emb_rows {
            f.write_all(&key.to_le_bytes())?;
            f.write_all(&meta.last_update_step.to_le_bytes())?;
            f.write_all(&meta.update_count.to_le_bytes())?;
            for &x in vec {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let u = |k: &str| -> Result<usize> {
            header.get(k).and_then(Json::as_usize).with_context(|| format!("header.{k}"))
        };
        let dims = VariantDims {
            fields: u("fields")?,
            emb_dim: u("emb_dim")?,
            hidden1: u("hidden1")?,
            hidden2: u("hidden2")?,
            mlp_in: u("mlp_in")?,
        };
        let global_step = u("global_step")? as u64;
        let n_rows = u("n_rows")?;
        let shapes: Vec<Vec<usize>> = header
            .get("dense_shapes")
            .and_then(Json::as_arr)
            .context("dense_shapes")?
            .iter()
            .map(|s| s.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect())
            .collect();

        let read_f32 = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        };
        let mut dense = Vec::new();
        for shape in shapes {
            let n: usize = shape.iter().product();
            dense.push(HostTensor { shape, data: read_f32(&mut f, n)? });
        }
        let mut emb_rows = Vec::with_capacity(n_rows);
        let dim = dims.emb_dim;
        for _ in 0..n_rows {
            let mut k8 = [0u8; 8];
            f.read_exact(&mut k8)?;
            let key = u64::from_le_bytes(k8);
            f.read_exact(&mut k8)?;
            let last_update_step = u64::from_le_bytes(k8);
            let mut c4 = [0u8; 4];
            f.read_exact(&mut c4)?;
            let update_count = u32::from_le_bytes(c4);
            let vec = read_f32(&mut f, dim)?;
            emb_rows.push((key, vec, RowMeta { last_update_step, update_count }));
        }
        Ok(Checkpoint { dims, dense, emb_rows, global_step })
    }
}

fn read_f32s(f: &mut impl Read, n: usize) -> Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    f.read_exact(&mut buf)?;
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

impl Checkpoint {
    /// Save a running PS as one stream per shard (`manifest.json` +
    /// `shard-NNN.bin`). Each stream holds only what that shard owns:
    /// its dense range slices and its consistent-hash slice of the
    /// embedding rows, key-sorted within the shard. Like `from_ps`, the
    /// caller is responsible for quiescing training first.
    pub fn save_sharded(ps: &PsServer, dir: impl AsRef<Path>) -> Result<()> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let dims = ps.dims;
        let shapes = dims.param_shapes();
        let manifest = Json::obj()
            .set("version", 1)
            .set("n_shards", ps.n_shards())
            .set("fields", dims.fields)
            .set("emb_dim", dims.emb_dim)
            .set("hidden1", dims.hidden1)
            .set("hidden2", dims.hidden2)
            .set("mlp_in", dims.mlp_in)
            .set("global_step", ps.global_step())
            .set(
                "dense_shapes",
                Json::Arr(
                    shapes
                        .iter()
                        .map(|s| Json::Arr(s.iter().map(|&d| Json::from(d)).collect()))
                        .collect(),
                ),
            );
        std::fs::write(dir.join("manifest.json"), manifest.to_string_compact())?;
        for s in 0..ps.n_shards() {
            let (ranges, dense) = ps.dump_shard_dense(s);
            let rows = ps.dump_shard_rows(s);
            let mut f =
                std::io::BufWriter::new(std::fs::File::create(dir.join(shard_file(s)))?);
            f.write_all(SHARD_MAGIC)?;
            let header = Json::obj().set("shard", s).set("n_rows", rows.len()).set(
                "ranges",
                Json::Arr(
                    ranges
                        .iter()
                        .map(|&(lo, hi)| Json::Arr(vec![Json::from(lo), Json::from(hi)]))
                        .collect(),
                ),
            );
            let htext = header.to_string_compact();
            f.write_all(&(htext.len() as u32).to_le_bytes())?;
            f.write_all(htext.as_bytes())?;
            for (slice, &(lo, hi)) in dense.iter().zip(&ranges) {
                debug_assert_eq!(slice.len(), hi - lo);
                for &x in slice {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
            // Row layout matches the portable file; optimizer state is
            // dropped (switch semantics), key order is shard-local.
            for (key, vec, _state, meta) in &rows {
                f.write_all(&key.to_le_bytes())?;
                f.write_all(&meta.last_update_step.to_le_bytes())?;
                f.write_all(&meta.update_count.to_le_bytes())?;
                for &x in vec {
                    f.write_all(&x.to_le_bytes())?;
                }
            }
        }
        Ok(())
    }

    /// Reassemble a sharded checkpoint directory into the portable form.
    /// The result is shard-layout-free: it restores into a PS of *any*
    /// shard count and transport.
    pub fn load_sharded(dir: impl AsRef<Path>) -> Result<Checkpoint> {
        let dir = dir.as_ref();
        let mtext = std::fs::read_to_string(dir.join("manifest.json"))
            .with_context(|| format!("reading {}", dir.join("manifest.json").display()))?;
        let m = json::parse(&mtext)
            .map_err(|e| anyhow::anyhow!("sharded checkpoint manifest: {e}"))?;
        let u = |k: &str| -> Result<usize> {
            m.get(k).and_then(Json::as_usize).with_context(|| format!("manifest.{k}"))
        };
        let dims = VariantDims {
            fields: u("fields")?,
            emb_dim: u("emb_dim")?,
            hidden1: u("hidden1")?,
            hidden2: u("hidden2")?,
            mlp_in: u("mlp_in")?,
        };
        let n_shards = u("n_shards")?;
        let global_step = u("global_step")? as u64;
        let shapes: Vec<Vec<usize>> = m
            .get("dense_shapes")
            .and_then(Json::as_arr)
            .context("manifest.dense_shapes")?
            .iter()
            .map(|s| {
                s.as_arr()
                    .context("dense shape entry")
                    .map(|a| a.iter().filter_map(Json::as_usize).collect())
            })
            .collect::<Result<_>>()?;
        let numels: Vec<usize> = shapes.iter().map(|s| s.iter().product()).collect();
        let mut flats: Vec<Vec<f32>> = numels.iter().map(|&n| vec![0.0f32; n]).collect();
        let mut covered = vec![0usize; shapes.len()];
        let mut emb_rows: Vec<(u64, Vec<f32>, RowMeta)> = Vec::new();
        for s in 0..n_shards {
            let path = dir.join(shard_file(s));
            let mut f = std::io::BufReader::new(
                std::fs::File::open(&path)
                    .with_context(|| format!("opening {}", path.display()))?,
            );
            let mut magic = [0u8; 8];
            f.read_exact(&mut magic)?;
            if &magic != SHARD_MAGIC {
                bail!("shard {s}: bad stream magic");
            }
            let mut len4 = [0u8; 4];
            f.read_exact(&mut len4)?;
            let mut hbuf = vec![0u8; u32::from_le_bytes(len4) as usize];
            f.read_exact(&mut hbuf)?;
            let header = json::parse(std::str::from_utf8(&hbuf)?)
                .map_err(|e| anyhow::anyhow!("shard {s} header: {e}"))?;
            if header.get("shard").and_then(Json::as_usize) != Some(s) {
                bail!("shard {s}: stream claims a different shard index");
            }
            let n_rows =
                header.get("n_rows").and_then(Json::as_usize).context("shard header n_rows")?;
            let ranges: Vec<(usize, usize)> = header
                .get("ranges")
                .and_then(Json::as_arr)
                .context("shard header ranges")?
                .iter()
                .map(|r| {
                    let lo = r.idx(0).and_then(Json::as_usize).context("range lo")?;
                    let hi = r.idx(1).and_then(Json::as_usize).context("range hi")?;
                    Ok((lo, hi))
                })
                .collect::<Result<_>>()?;
            if ranges.len() != shapes.len() {
                bail!("shard {s}: {} ranges for {} tensors", ranges.len(), shapes.len());
            }
            for (t, &(lo, hi)) in ranges.iter().enumerate() {
                if lo > hi || hi > numels[t] {
                    bail!("shard {s}: range [{lo}, {hi}) outside tensor {t}");
                }
                // Streams are written in shard order over a contiguous
                // range partition, so each range must start exactly
                // where the previous shard's ended — this rejects
                // overlaps and gaps, not just total-count mismatches.
                if lo != covered[t] {
                    bail!(
                        "shard {s}: tensor {t} range starts at {lo}, expected {}",
                        covered[t]
                    );
                }
                let data = read_f32s(&mut f, hi - lo)?;
                flats[t][lo..hi].copy_from_slice(&data);
                covered[t] = hi;
            }
            for _ in 0..n_rows {
                let mut k8 = [0u8; 8];
                f.read_exact(&mut k8)?;
                let key = u64::from_le_bytes(k8);
                f.read_exact(&mut k8)?;
                let last_update_step = u64::from_le_bytes(k8);
                let mut c4 = [0u8; 4];
                f.read_exact(&mut c4)?;
                let update_count = u32::from_le_bytes(c4);
                let vec = read_f32s(&mut f, dims.emb_dim)?;
                emb_rows.push((key, vec, RowMeta { last_update_step, update_count }));
            }
        }
        for (t, (&c, &n)) in covered.iter().zip(&numels).enumerate() {
            if c != n {
                bail!("tensor {t}: shard ranges cover {c} of {n} elements");
            }
        }
        // Portable canonical order: global key sort (shards partition
        // the keyspace, so no key appears twice).
        emb_rows.sort_by_key(|(k, _, _)| *k);
        let dense = shapes
            .into_iter()
            .zip(flats)
            .map(|(shape, data)| HostTensor { shape, data })
            .collect();
        Ok(Checkpoint { dims, dense, emb_rows, global_step })
    }
}

fn shard_file(s: usize) -> String {
    format!("shard-{s:03}.bin")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let dims = VariantDims { fields: 2, emb_dim: 3, hidden1: 4, hidden2: 2, mlp_in: 9 };
        Checkpoint {
            dims,
            dense: dims
                .param_shapes()
                .into_iter()
                .enumerate()
                .map(|(i, s)| {
                    let n: usize = s.iter().product();
                    HostTensor { shape: s, data: (0..n).map(|j| (i * 100 + j) as f32 * 0.5).collect() }
                })
                .collect(),
            emb_rows: vec![
                (7, vec![1.0, 2.0, 3.0], RowMeta { last_update_step: 5, update_count: 2 }),
                (42, vec![-1.0, 0.5, 0.25], RowMeta { last_update_step: 9, update_count: 7 }),
            ],
            global_step: 123,
        }
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("gba_ckpt_test.bin");
        let c = sample();
        c.save(&path).unwrap();
        let r = Checkpoint::load(&path).unwrap();
        assert_eq!(r.dims, c.dims);
        assert_eq!(r.global_step, 123);
        assert_eq!(r.dense.len(), 6);
        for (a, b) in r.dense.iter().zip(&c.dense) {
            assert_eq!(a, b);
        }
        assert_eq!(r.emb_rows.len(), 2);
        assert_eq!(r.emb_rows[1].0, 42);
        assert_eq!(r.emb_rows[1].1, vec![-1.0, 0.5, 0.25]);
        assert_eq!(r.emb_rows[0].2.update_count, 2);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gba_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    fn trained_ps(n_shards: usize) -> (VariantDims, PsServer) {
        use crate::coordinator::modes::AsyncPolicy;
        use crate::embedding::EmbeddingConfig;
        use crate::optim::Sgd;
        use crate::ps::{GradPush, PullReply};

        let dims = VariantDims { fields: 2, emb_dim: 3, hidden1: 4, hidden2: 2, mlp_in: 9 };
        let init: Vec<HostTensor> = dims
            .param_shapes()
            .into_iter()
            .enumerate()
            .map(|(t, s)| {
                let n: usize = s.iter().product();
                HostTensor { shape: s, data: (0..n).map(|j| (t * 31 + j) as f32 * 0.1).collect() }
            })
            .collect();
        let ps = PsServer::with_shards(
            dims,
            init,
            EmbeddingConfig { dim: 3, init_scale: 0.05, seed: 5, shards: 2 },
            Box::new(Sgd { lr: 0.1 }),
            Box::new(Sgd { lr: 0.1 }),
            Box::new(AsyncPolicy::new()),
            n_shards,
        );
        ps.set_day(0, 100);
        for i in 0..4u64 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(GradPush {
                worker: 0,
                token: it.token,
                dense: dims
                    .param_shapes()
                    .into_iter()
                    .map(|s| {
                        let n: usize = s.iter().product();
                        HostTensor { shape: s, data: vec![0.05; n] }
                    })
                    .collect(),
                emb: vec![(i * 17 + 1, vec![0.2; 3]), (i * 17 + 2, vec![-0.1; 3])],
                n_samples: 4,
                loss: 0.4,
            });
        }
        (dims, ps)
    }

    #[test]
    fn sharded_save_load_matches_portable() {
        let (dims, ps) = trained_ps(3);
        let dir = std::env::temp_dir().join("gba_sharded_ckpt_test");
        let _ = std::fs::remove_dir_all(&dir);
        Checkpoint::save_sharded(&ps, &dir).unwrap();
        let loaded = Checkpoint::load_sharded(&dir).unwrap();
        let portable = Checkpoint::from_ps(dims, &ps);
        assert_eq!(loaded.dims, portable.dims);
        assert_eq!(loaded.global_step, portable.global_step);
        assert_eq!(loaded.dense, portable.dense);
        assert_eq!(loaded.emb_rows.len(), portable.emb_rows.len());
        for (a, b) in loaded.emb_rows.iter().zip(&portable.emb_rows) {
            assert_eq!(a.0, b.0);
            assert_eq!(a.1, b.1);
            assert_eq!(a.2.last_update_step, b.2.last_update_step);
            assert_eq!(a.2.update_count, b.2.update_count);
        }
    }

    #[test]
    fn sharded_load_rejects_missing_stream_and_bad_magic() {
        let (_dims, ps) = trained_ps(2);
        let dir = std::env::temp_dir().join("gba_sharded_ckpt_corrupt");
        let _ = std::fs::remove_dir_all(&dir);
        Checkpoint::save_sharded(&ps, &dir).unwrap();
        // Missing shard stream.
        std::fs::remove_file(dir.join("shard-001.bin")).unwrap();
        assert!(Checkpoint::load_sharded(&dir).is_err());
        // Corrupt magic on the remaining one.
        std::fs::write(dir.join("shard-001.bin"), b"XXXXXXXXjunk").unwrap();
        assert!(Checkpoint::load_sharded(&dir).is_err());
    }
}
