//! Checkpointing: save / inherit base models (the switching protocol of
//! Fig. 6 trains a base model in one mode, checkpoints it, and every
//! compared mode inherits the same checkpoint).
//!
//! Binary format (little-endian, versioned):
//!
//! ```text
//! magic "GBACKPT2" | header_len u32 | header json | dense blobs | rows
//! ```
//!
//! Optimizer slots are deliberately *not* persisted: inheriting a
//! checkpoint into a (possibly different) training mode starts fresh
//! optimizer state, which is exactly the paper's switch semantics.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::embedding::RowMeta;
use crate::ps::PsServer;
use crate::runtime::{HostTensor, VariantDims};
use crate::util::json::{self, Json};

const MAGIC: &[u8; 8] = b"GBACKPT2";

#[derive(Clone, Debug)]
pub struct Checkpoint {
    pub dims: VariantDims,
    pub dense: Vec<HostTensor>,
    /// (key, embedding vector, metadata) — optimizer slots excluded.
    pub emb_rows: Vec<(u64, Vec<f32>, RowMeta)>,
    pub global_step: u64,
}

impl Checkpoint {
    /// Snapshot a running PS.
    pub fn from_ps(dims: VariantDims, ps: &PsServer) -> Checkpoint {
        let mut emb_rows = Vec::new();
        ps.for_each_emb_row(|key, vec, _state, meta| {
            emb_rows.push((key, vec.to_vec(), meta));
        });
        // Deterministic order for byte-stable checkpoints.
        emb_rows.sort_by_key(|(k, _, _)| *k);
        Checkpoint { dims, dense: ps.dense_params(), emb_rows, global_step: ps.global_step() }
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        let header = Json::obj()
            .set("fields", self.dims.fields)
            .set("emb_dim", self.dims.emb_dim)
            .set("hidden1", self.dims.hidden1)
            .set("hidden2", self.dims.hidden2)
            .set("mlp_in", self.dims.mlp_in)
            .set("global_step", self.global_step)
            .set("n_rows", self.emb_rows.len())
            .set(
                "dense_shapes",
                Json::Arr(
                    self.dense
                        .iter()
                        .map(|t| Json::Arr(t.shape.iter().map(|&d| Json::from(d)).collect()))
                        .collect(),
                ),
            );
        let htext = header.to_string_compact();
        f.write_all(&(htext.len() as u32).to_le_bytes())?;
        f.write_all(htext.as_bytes())?;
        for t in &self.dense {
            for &x in &t.data {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        for (key, vec, meta) in &self.emb_rows {
            f.write_all(&key.to_le_bytes())?;
            f.write_all(&meta.last_update_step.to_le_bytes())?;
            f.write_all(&meta.update_count.to_le_bytes())?;
            for &x in vec {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path.as_ref())
                .with_context(|| format!("opening {}", path.as_ref().display()))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("bad checkpoint magic");
        }
        let mut len4 = [0u8; 4];
        f.read_exact(&mut len4)?;
        let hlen = u32::from_le_bytes(len4) as usize;
        let mut hbuf = vec![0u8; hlen];
        f.read_exact(&mut hbuf)?;
        let header = json::parse(std::str::from_utf8(&hbuf)?)
            .map_err(|e| anyhow::anyhow!("checkpoint header: {e}"))?;
        let u = |k: &str| -> Result<usize> {
            header.get(k).and_then(Json::as_usize).with_context(|| format!("header.{k}"))
        };
        let dims = VariantDims {
            fields: u("fields")?,
            emb_dim: u("emb_dim")?,
            hidden1: u("hidden1")?,
            hidden2: u("hidden2")?,
            mlp_in: u("mlp_in")?,
        };
        let global_step = u("global_step")? as u64;
        let n_rows = u("n_rows")?;
        let shapes: Vec<Vec<usize>> = header
            .get("dense_shapes")
            .and_then(Json::as_arr)
            .context("dense_shapes")?
            .iter()
            .map(|s| s.as_arr().unwrap().iter().map(|d| d.as_usize().unwrap()).collect())
            .collect();

        let read_f32 = |f: &mut dyn Read, n: usize| -> Result<Vec<f32>> {
            let mut buf = vec![0u8; n * 4];
            f.read_exact(&mut buf)?;
            Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
        };
        let mut dense = Vec::new();
        for shape in shapes {
            let n: usize = shape.iter().product();
            dense.push(HostTensor { shape, data: read_f32(&mut f, n)? });
        }
        let mut emb_rows = Vec::with_capacity(n_rows);
        let dim = dims.emb_dim;
        for _ in 0..n_rows {
            let mut k8 = [0u8; 8];
            f.read_exact(&mut k8)?;
            let key = u64::from_le_bytes(k8);
            f.read_exact(&mut k8)?;
            let last_update_step = u64::from_le_bytes(k8);
            let mut c4 = [0u8; 4];
            f.read_exact(&mut c4)?;
            let update_count = u32::from_le_bytes(c4);
            let vec = read_f32(&mut f, dim)?;
            emb_rows.push((key, vec, RowMeta { last_update_step, update_count }));
        }
        Ok(Checkpoint { dims, dense, emb_rows, global_step })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        let dims = VariantDims { fields: 2, emb_dim: 3, hidden1: 4, hidden2: 2, mlp_in: 9 };
        Checkpoint {
            dims,
            dense: dims
                .param_shapes()
                .into_iter()
                .enumerate()
                .map(|(i, s)| {
                    let n: usize = s.iter().product();
                    HostTensor { shape: s, data: (0..n).map(|j| (i * 100 + j) as f32 * 0.5).collect() }
                })
                .collect(),
            emb_rows: vec![
                (7, vec![1.0, 2.0, 3.0], RowMeta { last_update_step: 5, update_count: 2 }),
                (42, vec![-1.0, 0.5, 0.25], RowMeta { last_update_step: 9, update_count: 7 }),
            ],
            global_step: 123,
        }
    }

    #[test]
    fn roundtrip() {
        let path = std::env::temp_dir().join("gba_ckpt_test.bin");
        let c = sample();
        c.save(&path).unwrap();
        let r = Checkpoint::load(&path).unwrap();
        assert_eq!(r.dims, c.dims);
        assert_eq!(r.global_step, 123);
        assert_eq!(r.dense.len(), 6);
        for (a, b) in r.dense.iter().zip(&c.dense) {
            assert_eq!(a, b);
        }
        assert_eq!(r.emb_rows.len(), 2);
        assert_eq!(r.emb_rows[1].0, 42);
        assert_eq!(r.emb_rows[1].1, vec![-1.0, 0.5, 0.25]);
        assert_eq!(r.emb_rows[0].2.update_count, 2);
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("gba_ckpt_garbage.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }
}
