//! The six training-mode policies of the paper's evaluation (§5.1).

use super::{DecayStrategy, FlushSpec, ModePolicy, PullDecision, PushAction, WorkerId};
use crate::config::{ModeConfig, ModeKind};

// ---------------------------------------------------------------------------
// Sync — all-reduce-style synchronous data parallelism (emulated over PS)
// ---------------------------------------------------------------------------

/// Each global step aggregates exactly one gradient from each of the `N`
/// workers computed on the same parameter version. Workers that finished
/// wait at the barrier — which is why stragglers dominate (Obs. 1).
pub struct SyncPolicy {
    n: usize,
    step: u64,
    /// Whether worker w has pulled its batch for the current step.
    pulled: Vec<bool>,
    buffered: usize,
}

impl SyncPolicy {
    pub fn new(n: usize) -> Self {
        SyncPolicy { n, step: 0, pulled: vec![false; n], buffered: 0 }
    }
}

impl ModePolicy for SyncPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::Sync
    }

    fn on_pull(&mut self, w: WorkerId) -> PullDecision {
        if self.pulled[w] {
            PullDecision::Wait
        } else {
            self.pulled[w] = true;
            PullDecision::Token(self.step)
        }
    }

    fn on_push(&mut self, _w: WorkerId, token: u64) -> PushAction {
        if token < self.step {
            // A cohort completed without this gradient. Possible only
            // after a worker reset let another worker double-fill the
            // barrier (Appendix B tolerates lost/duplicated tokens);
            // treat the late arrival like a Hop-BW straggler: drop.
            return PushAction::Drop;
        }
        self.buffered += 1;
        if self.buffered >= self.n {
            PushAction::FlushNow
        } else {
            PushAction::Buffer
        }
    }

    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        FlushSpec { weights: vec![1.0; tokens.len()], dense_divisor: tokens.len() as f32 }
    }

    fn on_applied(&mut self) {
        self.step += 1;
        self.pulled.fill(false);
        self.buffered = 0;
    }

    fn global_step(&self) -> u64 {
        self.step
    }

    fn on_worker_reset(&mut self, w: WorkerId) {
        // The worker lost its in-flight batch; allow a fresh pull so the
        // barrier is not dead-locked.
        self.pulled[w] = false;
    }
}

// ---------------------------------------------------------------------------
// Async — canonical asynchronous PS training
// ---------------------------------------------------------------------------

/// Every gradient is applied immediately; token records the parameter
/// version the worker pulled, so `k − τ` is the classic gradient staleness.
pub struct AsyncPolicy {
    step: u64,
}

impl AsyncPolicy {
    pub fn new() -> Self {
        AsyncPolicy { step: 0 }
    }
}

impl Default for AsyncPolicy {
    fn default() -> Self {
        Self::new()
    }
}

impl ModePolicy for AsyncPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::Async
    }
    fn on_pull(&mut self, _w: WorkerId) -> PullDecision {
        PullDecision::Token(self.step)
    }
    fn on_push(&mut self, _w: WorkerId, _token: u64) -> PushAction {
        PushAction::FlushNow
    }
    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        FlushSpec { weights: vec![1.0; tokens.len()], dense_divisor: tokens.len() as f32 }
    }
    fn on_applied(&mut self) {
        self.step += 1;
    }
    fn global_step(&self) -> u64 {
        self.step
    }
    fn on_worker_reset(&mut self, _w: WorkerId) {}
}

// ---------------------------------------------------------------------------
// Hop-BS — bounded staleness (SSP), Luo et al. 2019
// ---------------------------------------------------------------------------

/// Gradients apply immediately (like async) but the fastest worker may be
/// at most `b1` *local clocks* ahead of the slowest — fast workers block.
pub struct HopBsPolicy {
    bound: u64,
    step: u64,
    /// Local clock per worker: batches completed.
    clock: Vec<u64>,
    /// In-flight pulls count toward the clock gap check.
    inflight: Vec<u64>,
}

impl HopBsPolicy {
    pub fn new(n: usize, bound: u64) -> Self {
        HopBsPolicy { bound, step: 0, clock: vec![0; n], inflight: vec![0; n] }
    }

    fn min_clock(&self) -> u64 {
        self.clock.iter().copied().min().unwrap_or(0)
    }
}

impl ModePolicy for HopBsPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::HopBs
    }

    fn on_pull(&mut self, w: WorkerId) -> PullDecision {
        // Admit only if completing this batch keeps the fastest-slowest
        // clock difference within b1: (clock + inflight + 1) - min <= b1.
        let projected = self.clock[w] + self.inflight[w];
        if projected >= self.min_clock() + self.bound {
            return PullDecision::Wait;
        }
        self.inflight[w] += 1;
        PullDecision::Token(self.step)
    }

    fn on_push(&mut self, w: WorkerId, _token: u64) -> PushAction {
        self.clock[w] += 1;
        self.inflight[w] = self.inflight[w].saturating_sub(1);
        PushAction::FlushNow
    }

    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        FlushSpec { weights: vec![1.0; tokens.len()], dense_divisor: tokens.len() as f32 }
    }

    fn on_applied(&mut self) {
        self.step += 1;
    }

    fn global_step(&self) -> u64 {
        self.step
    }

    fn on_worker_reset(&mut self, w: WorkerId) {
        self.inflight[w] = 0;
        // Bring the lost worker's clock up so it cannot stall the bound.
        self.clock[w] = self.min_clock().max(self.clock[w]);
    }
}

// ---------------------------------------------------------------------------
// BSP — asynchronous bulk synchronous parallel (aggregate b2, any version)
// ---------------------------------------------------------------------------

/// Aggregates a pre-set number `b2` of gradients before applying,
/// regardless of gradient version (§5.1).
pub struct BspPolicy {
    b2: usize,
    step: u64,
    buffered: usize,
}

impl BspPolicy {
    pub fn new(b2: usize) -> Self {
        BspPolicy { b2: b2.max(1), step: 0, buffered: 0 }
    }
}

impl ModePolicy for BspPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::Bsp
    }
    fn on_pull(&mut self, _w: WorkerId) -> PullDecision {
        PullDecision::Token(self.step)
    }
    fn on_push(&mut self, _w: WorkerId, _token: u64) -> PushAction {
        self.buffered += 1;
        if self.buffered >= self.b2 {
            PushAction::FlushNow
        } else {
            PushAction::Buffer
        }
    }
    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        FlushSpec { weights: vec![1.0; tokens.len()], dense_divisor: self.b2 as f32 }
    }
    fn on_applied(&mut self) {
        self.step += 1;
        self.buffered = 0;
    }
    fn global_step(&self) -> u64 {
        self.step
    }
    fn on_worker_reset(&mut self, _w: WorkerId) {}
}

// ---------------------------------------------------------------------------
// Hop-BW — backup workers: drop the b3 slowest gradients each step
// ---------------------------------------------------------------------------

/// Synchronous cohorts of one batch per worker, but each step applies as
/// soon as the first `N − b3` gradients arrive; late ones are discarded
/// ("ignores the gradients from the stragglers", §5.1 / Hop-BW).
pub struct HopBwPolicy {
    n: usize,
    b3: usize,
    step: u64,
    pulled: Vec<bool>,
    buffered: usize,
}

impl HopBwPolicy {
    pub fn new(n: usize, b3: usize) -> Self {
        assert!(b3 < n, "backup count must be < workers");
        HopBwPolicy { n, b3, step: 0, pulled: vec![false; n], buffered: 0 }
    }

    fn quorum(&self) -> usize {
        self.n - self.b3
    }
}

impl ModePolicy for HopBwPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::HopBw
    }

    fn on_pull(&mut self, w: WorkerId) -> PullDecision {
        if self.pulled[w] {
            PullDecision::Wait
        } else {
            self.pulled[w] = true;
            PullDecision::Token(self.step)
        }
    }

    fn on_push(&mut self, _w: WorkerId, token: u64) -> PushAction {
        if token < self.step {
            // Straggler from an already-applied cohort.
            return PushAction::Drop;
        }
        self.buffered += 1;
        if self.buffered >= self.quorum() {
            PushAction::FlushNow
        } else {
            PushAction::Buffer
        }
    }

    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        FlushSpec { weights: vec![1.0; tokens.len()], dense_divisor: tokens.len() as f32 }
    }

    fn on_applied(&mut self) {
        self.step += 1;
        // All workers may pull for the new cohort — including those whose
        // previous gradient will now arrive late and be dropped.
        self.pulled.fill(false);
        self.buffered = 0;
    }

    fn global_step(&self) -> u64 {
        self.step
    }

    fn on_worker_reset(&mut self, w: WorkerId) {
        self.pulled[w] = false;
    }
}

// ---------------------------------------------------------------------------
// GBA — Global Batch gradients Aggregation (the paper's contribution, §4)
// ---------------------------------------------------------------------------

/// Token-control mechanism: the token list yields `t_i = ⌊i/M⌋` for the
/// i-th handed-out batch (each token value repeats M times, ascending);
/// the gradient buffer aggregates `M` gradients per global step, decaying
/// entries whose data staleness `k − τ` exceeds the tolerance (Eqn. 1).
/// No pull gating: fast workers simply take more tokens (§4.1).
pub struct GbaPolicy {
    m: usize,
    decay: DecayStrategy,
    step: u64,
    /// Total batches handed out (the token-list cursor `i`).
    pull_cursor: u64,
    buffered: usize,
}

impl GbaPolicy {
    pub fn new(m: usize, decay: DecayStrategy) -> Self {
        assert!(m >= 1);
        GbaPolicy { m, decay, step: 0, pull_cursor: 0, buffered: 0 }
    }

    /// The paper's default: Eqn. (1) threshold decay with tolerance ι.
    pub fn with_iota(m: usize, iota: u64) -> Self {
        Self::new(m, DecayStrategy::Threshold { iota })
    }

    pub fn m(&self) -> usize {
        self.m
    }
}

impl ModePolicy for GbaPolicy {
    fn kind(&self) -> ModeKind {
        ModeKind::Gba
    }

    fn on_pull(&mut self, _w: WorkerId) -> PullDecision {
        // t_i = ⌊i/M⌋ — §4.1 states ⌊i/K⌋, which contradicts the stated
        // "each token value repeats M times"; ⌊i/M⌋ is the consistent
        // reading (see DESIGN.md §4 Paper-note).
        let token = self.pull_cursor / self.m as u64;
        self.pull_cursor += 1;
        PullDecision::Token(token)
    }

    fn on_push(&mut self, _w: WorkerId, _token: u64) -> PushAction {
        self.buffered += 1;
        if self.buffered >= self.m {
            PushAction::FlushNow
        } else {
            PushAction::Buffer
        }
    }

    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec {
        let k = self.step;
        let weights = tokens.iter().map(|&t| self.decay.weight(t, k)).collect();
        // Algorithm 2 L22: weighted sum divided by N_a == M.
        FlushSpec { weights, dense_divisor: self.m as f32 }
    }

    fn on_applied(&mut self) {
        self.step += 1;
        self.buffered = 0;
    }

    fn global_step(&self) -> u64 {
        self.step
    }

    fn on_worker_reset(&mut self, _w: WorkerId) {
        // A lost token is harmless (Appendix B): the buffer simply fills
        // from other workers' pushes.
    }
}

// ---------------------------------------------------------------------------

/// Build the policy for a mode from its config. `m_global` is the GBA
/// buffer capacity `M = G_s / B_a` (config-level invariant).
pub fn make_policy(kind: ModeKind, mode: &ModeConfig, m_global: usize) -> Box<dyn ModePolicy> {
    match kind {
        ModeKind::Sync => Box::new(SyncPolicy::new(mode.workers)),
        ModeKind::Async => Box::new(AsyncPolicy::new()),
        ModeKind::HopBs => Box::new(HopBsPolicy::new(mode.workers, mode.bound)),
        ModeKind::Bsp => Box::new(BspPolicy::new(mode.aggregate)),
        ModeKind::HopBw => Box::new(HopBwPolicy::new(mode.workers, mode.backup)),
        ModeKind::Gba => Box::new(GbaPolicy::with_iota(m_global, mode.iota)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sync_barrier_cycle() {
        let mut p = SyncPolicy::new(3);
        for w in 0..3 {
            assert_eq!(p.on_pull(w), PullDecision::Token(0));
        }
        // Second pull before apply blocks.
        assert_eq!(p.on_pull(0), PullDecision::Wait);
        assert_eq!(p.on_push(0, 0), PushAction::Buffer);
        assert_eq!(p.on_push(1, 0), PushAction::Buffer);
        assert_eq!(p.on_push(2, 0), PushAction::FlushNow);
        let spec = p.flush_spec(&[0, 0, 0]);
        assert_eq!(spec.weights, vec![1.0; 3]);
        assert_eq!(spec.dense_divisor, 3.0);
        p.on_applied();
        assert_eq!(p.global_step(), 1);
        assert_eq!(p.on_pull(0), PullDecision::Token(1));
    }

    #[test]
    fn async_applies_every_push() {
        let mut p = AsyncPolicy::new();
        assert_eq!(p.on_pull(0), PullDecision::Token(0));
        assert_eq!(p.on_push(0, 0), PushAction::FlushNow);
        p.on_applied();
        assert_eq!(p.on_pull(1), PullDecision::Token(1));
        assert_eq!(p.on_push(1, 0), PushAction::FlushNow); // stale ok
    }

    #[test]
    fn hop_bs_bounds_clock_gap() {
        let mut p = HopBsPolicy::new(2, 1);
        // Worker 0 completes one batch (clock gap now 1 = b1).
        assert!(matches!(p.on_pull(0), PullDecision::Token(_)));
        assert_eq!(p.on_push(0, 0), PushAction::FlushNow);
        p.on_applied();
        // clock: w0=1, w1=0, bound=1 -> another w0 batch would make the
        // fastest-slowest gap 2 > b1: must wait.
        assert_eq!(p.on_pull(0), PullDecision::Wait);
        // Slow worker catches up.
        assert!(matches!(p.on_pull(1), PullDecision::Token(_)));
        assert_eq!(p.on_push(1, 0), PushAction::FlushNow);
        p.on_applied();
        assert!(matches!(p.on_pull(0), PullDecision::Token(_)));
    }

    #[test]
    fn hop_bs_counts_inflight() {
        let mut p = HopBsPolicy::new(2, 2);
        // Without inflight tracking a worker could pull unboundedly before
        // pushing anything.
        assert!(matches!(p.on_pull(0), PullDecision::Token(_)));
        assert!(matches!(p.on_pull(0), PullDecision::Token(_)));
        assert_eq!(p.on_pull(0), PullDecision::Wait);
    }

    #[test]
    fn bsp_aggregates_fixed_count() {
        let mut p = BspPolicy::new(3);
        for i in 0..2 {
            assert_eq!(p.on_push(i, 0), PushAction::Buffer);
        }
        assert_eq!(p.on_push(2, 0), PushAction::FlushNow);
        assert_eq!(p.flush_spec(&[0, 0, 0]).dense_divisor, 3.0);
    }

    #[test]
    fn hop_bw_drops_stragglers() {
        let mut p = HopBwPolicy::new(3, 1);
        for w in 0..3 {
            assert!(matches!(p.on_pull(w), PullDecision::Token(_)));
        }
        assert_eq!(p.on_push(0, 0), PushAction::Buffer);
        assert_eq!(p.on_push(1, 0), PushAction::FlushNow); // quorum 2 of 3
        p.on_applied();
        // Worker 2's late gradient from cohort 0 is dropped.
        assert_eq!(p.on_push(2, 0), PushAction::Drop);
        // And worker 2 can pull for the new cohort.
        assert_eq!(p.on_pull(2), PullDecision::Token(1));
    }

    #[test]
    fn gba_token_list_repeats_m_times_ascending() {
        let mut p = GbaPolicy::with_iota(4, 3);
        let tokens: Vec<u64> = (0..12).map(|i| match p.on_pull(i % 3) {
            PullDecision::Token(t) => t,
            _ => panic!("gba never blocks"),
        }).collect();
        assert_eq!(tokens, vec![0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2]);
    }

    #[test]
    fn gba_flush_at_m_and_divisor_m() {
        let mut p = GbaPolicy::with_iota(3, 2);
        assert_eq!(p.on_push(0, 0), PushAction::Buffer);
        assert_eq!(p.on_push(1, 0), PushAction::Buffer);
        assert_eq!(p.on_push(2, 0), PushAction::FlushNow);
        let spec = p.flush_spec(&[0, 0, 0]);
        assert_eq!(spec.dense_divisor, 3.0);
        assert_eq!(spec.weights, vec![1.0; 3]);
    }

    #[test]
    fn gba_decays_stale_tokens() {
        let mut p = GbaPolicy::with_iota(2, 1);
        // Advance to step 3.
        for _ in 0..3 {
            p.on_push(0, 0);
            p.on_push(0, 0);
            p.on_applied();
        }
        assert_eq!(p.global_step(), 3);
        // Tokens 3 (fresh), 2 (staleness 1 = ι), 0 (staleness 3 > ι).
        let spec = p.flush_spec(&[3, 2, 0]);
        assert_eq!(spec.weights, vec![1.0, 1.0, 0.0]);
        assert_eq!(spec.dense_divisor, 2.0); // still M
    }

    #[test]
    fn factory_builds_all() {
        let mc = ModeConfig { workers: 4, local_batch: 8, iota: 3, bound: 2, aggregate: 5, backup: 1, m_override: None };
        for kind in ModeKind::ALL {
            let p = make_policy(kind, &mc, 6);
            assert_eq!(p.kind(), kind);
        }
    }
}
