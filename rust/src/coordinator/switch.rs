//! The switch plane: mode ownership, switch bookkeeping, and the
//! adaptive switching controller.
//!
//! Since the in-place switching redesign the training mode is not a
//! field a session mutates ad hoc — it is a *sequence of mode epochs*
//! owned by a [`SwitchPlane`]. Every epoch pins (id, [`ModeKind`],
//! starting day); advancing the epoch is the paper's §1 *switch*
//! operation, driven down through the layers that already exist
//! (`ControlPlane::swap_policy` for the shard plane, the
//! `SwitchMode`/`Epoch` re-handshake for remote workers) instead of
//! rebuilding the session around them. The plane also records the
//! [`SwitchTrace`] experiments annotate AUC curves with, and hosts the
//! [`AdaptiveSwitcher`] — the paper's conclusion ("make GBA adaptive to
//! the cluster status") implemented as a live hysteresis controller fed
//! by per-day straggler telemetry.

use crate::config::ModeKind;

/// One switch event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchEvent {
    /// Day index (continual-training time axis).
    pub day: usize,
    pub from: ModeKind,
    pub to: ModeKind,
    /// The straggler signal that drove the decision (`1 − median/p95`
    /// of per-worker batch latency) — `Some` only for switches the
    /// adaptive controller proposed; manual switches have no signal.
    pub signal: Option<f64>,
}

/// Trace of mode switches over a continual run.
#[derive(Clone, Debug, Default)]
pub struct SwitchTrace {
    pub events: Vec<SwitchEvent>,
}

impl SwitchTrace {
    pub fn record(&mut self, day: usize, from: ModeKind, to: ModeKind) {
        self.record_with_signal(day, from, to, None);
    }

    pub fn record_with_signal(
        &mut self,
        day: usize,
        from: ModeKind,
        to: ModeKind,
        signal: Option<f64>,
    ) {
        self.events.push(SwitchEvent { day, from, to, signal });
    }

    /// The mode in effect on `day`, given the mode the run started in.
    /// Events may have been recorded out of day order (e.g. merged from
    /// several sources); the fold sorts first — an unsorted fold would
    /// silently return whichever mode happened to be recorded last.
    /// Same-day events keep their record order (stable sort), so the
    /// last switch recorded for a day wins.
    pub fn mode_on_day(&self, initial: ModeKind, day: usize) -> ModeKind {
        let mut events: Vec<&SwitchEvent> = self.events.iter().collect();
        events.sort_by_key(|e| e.day);
        let mut mode = initial;
        for e in events {
            if e.day <= day {
                mode = e.to;
            }
        }
        mode
    }
}

/// One entry of the mode sequence: the mode the session trains in from
/// `start_day` until the next epoch begins. Epoch ids are dense and
/// monotonic; the id is what crosses the wire in the worker-plane
/// re-handshake, so both ends can assert they agree on *which* switch
/// they are performing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ModeEpoch {
    pub epoch: u64,
    pub kind: ModeKind,
    pub start_day: usize,
}

/// Owns the mode as a sequence of [`ModeEpoch`]s and decides (manually
/// or adaptively) when to start a new one. The session consults
/// `current()` for the live mode and calls [`advance`](Self::advance)
/// at each switch; experiments read the accumulated [`SwitchTrace`].
#[derive(Clone, Debug)]
pub struct SwitchPlane {
    epochs: Vec<ModeEpoch>,
    trace: SwitchTrace,
    /// `Some` when `[switch] policy = "adaptive"`.
    switcher: Option<AdaptiveSwitcher>,
}

impl SwitchPlane {
    /// Manual switching: epochs advance only on explicit request.
    pub fn manual(initial: ModeKind) -> SwitchPlane {
        SwitchPlane {
            epochs: vec![ModeEpoch { epoch: 0, kind: initial, start_day: 0 }],
            trace: SwitchTrace::default(),
            switcher: None,
        }
    }

    /// Adaptive switching with the given hysteresis watermarks.
    pub fn adaptive(initial: ModeKind, high: f64, low: f64) -> SwitchPlane {
        let mut plane = SwitchPlane::manual(initial);
        let mut switcher = AdaptiveSwitcher::new(initial);
        switcher.high_watermark = high;
        switcher.low_watermark = low;
        plane.switcher = Some(switcher);
        plane
    }

    pub fn is_adaptive(&self) -> bool {
        self.switcher.is_some()
    }

    /// The epoch currently in effect.
    pub fn current(&self) -> &ModeEpoch {
        self.epochs.last().expect("a switch plane always has an epoch")
    }

    pub fn kind(&self) -> ModeKind {
        self.current().kind
    }

    pub fn epoch(&self) -> u64 {
        self.current().epoch
    }

    /// The full epoch sequence (epoch 0 is the launch mode).
    pub fn epochs(&self) -> &[ModeEpoch] {
        &self.epochs
    }

    pub fn trace(&self) -> &SwitchTrace {
        &self.trace
    }

    /// Start a new mode epoch on `day`. Records the switch event and
    /// returns the new epoch id. A same-mode "switch" is a no-op (no
    /// event, same epoch) — callers need not special-case it.
    pub fn advance(&mut self, day: usize, to: ModeKind) -> u64 {
        self.advance_with_signal(day, to, None)
    }

    /// [`advance`](Self::advance), annotating the recorded event with
    /// the straggler signal that drove the decision (adaptive switches;
    /// manual switches pass `None`).
    pub fn advance_with_signal(&mut self, day: usize, to: ModeKind, signal: Option<f64>) -> u64 {
        let cur = *self.current();
        if cur.kind == to {
            return cur.epoch;
        }
        self.trace.record_with_signal(day, cur.kind, to, signal);
        // Keep an adaptive controller's notion of "current" honest even
        // when the operator forces a manual switch mid-run.
        if let Some(sw) = &mut self.switcher {
            sw.force(to);
        }
        let epoch = cur.epoch + 1;
        self.epochs.push(ModeEpoch { epoch, kind: to, start_day: day });
        epoch
    }

    /// Feed one day's straggler signal (`1 − median/p95` of per-worker
    /// batch latency, 0 = homogeneous fleet). Returns the mode the
    /// adaptive controller wants to switch to, if any; the *caller*
    /// performs the switch (it owns the layers the switch must drive)
    /// and then calls [`advance`](Self::advance). `None` always under
    /// manual policy.
    pub fn observe(&mut self, signal: f64) -> Option<ModeKind> {
        self.switcher.as_mut()?.observe(signal)
    }

    /// [`observe`](Self::observe) with the staleness-gap signal beside
    /// the straggler signal (see [`AdaptiveSwitcher::observe_signals`]).
    pub fn observe_signals(&mut self, straggler: f64, gap: f64) -> Option<ModeKind> {
        self.switcher.as_mut()?.observe_signals(straggler, gap)
    }
}

/// Adaptive switching controller (paper §6 future work): choose the mode
/// from the observed cluster-straggler signal with hysteresis —
/// synchronous training while the fleet is homogeneous, GBA when
/// stragglers dominate.
#[derive(Clone, Debug)]
pub struct AdaptiveSwitcher {
    /// Switch to GBA above this signal level.
    pub high_watermark: f64,
    /// Switch back to sync below this signal level.
    pub low_watermark: f64,
    current: ModeKind,
}

impl AdaptiveSwitcher {
    pub fn new(initial: ModeKind) -> Self {
        AdaptiveSwitcher { high_watermark: 0.60, low_watermark: 0.40, current: initial }
    }

    pub fn current(&self) -> ModeKind {
        self.current
    }

    /// An external (manual) switch happened; track it so hysteresis is
    /// judged against the mode actually running.
    pub fn force(&mut self, kind: ModeKind) {
        self.current = kind;
    }

    /// Feed a signal observation; returns Some(new_mode) on a switch.
    pub fn observe(&mut self, signal: f64) -> Option<ModeKind> {
        self.observe_signals(signal, 0.0)
    }

    /// Feed both controller signals for one day: the batch-latency
    /// straggler signal (`1 − median/p95`) and the normalized staleness
    /// gap from the control plane's staleness policy (0 when the `gba`
    /// policy is active — it has no gap notion, so this degenerates to
    /// [`observe`](Self::observe)). Both live in `[0, 1)` and mean
    /// "how much is asynchrony hurting us right now", so the controller
    /// acts on whichever is louder: a straggler storm *or* runaway
    /// parameter drift can push the fleet into GBA, and both must clear
    /// before it settles back to sync.
    pub fn observe_signals(&mut self, straggler: f64, gap: f64) -> Option<ModeKind> {
        let signal = straggler.max(gap);
        let next = match self.current {
            ModeKind::Sync if signal > self.high_watermark => ModeKind::Gba,
            ModeKind::Gba if signal < self.low_watermark => ModeKind::Sync,
            other => other,
        };
        if next != self.current {
            self.current = next;
            Some(next)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_resolves_mode_by_day() {
        let mut t = SwitchTrace::default();
        t.record(3, ModeKind::Sync, ModeKind::Gba);
        t.record(7, ModeKind::Gba, ModeKind::Sync);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 0), ModeKind::Sync);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 3), ModeKind::Gba);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 6), ModeKind::Gba);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 9), ModeKind::Sync);
    }

    /// The satellite fix: events recorded out of day order must resolve
    /// identically to the sorted trace — the old unsorted fold returned
    /// whichever event was *recorded* last, silently.
    #[test]
    fn trace_out_of_order_records_resolve_correctly() {
        let mut t = SwitchTrace::default();
        t.record(7, ModeKind::Gba, ModeKind::Sync);
        t.record(3, ModeKind::Sync, ModeKind::Gba);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 0), ModeKind::Sync);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 4), ModeKind::Gba, "day-3 switch applies");
        assert_eq!(t.mode_on_day(ModeKind::Sync, 8), ModeKind::Sync, "day-7 switch wins later");
        // Same-day events: the last recorded wins (stable sort).
        t.record(7, ModeKind::Sync, ModeKind::Async);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 7), ModeKind::Async);
    }

    #[test]
    fn adaptive_hysteresis() {
        let mut a = AdaptiveSwitcher::new(ModeKind::Sync);
        assert_eq!(a.observe(0.5), None); // between watermarks: no switch
        assert_eq!(a.observe(0.7), Some(ModeKind::Gba));
        assert_eq!(a.observe(0.5), None); // hysteresis holds GBA
        assert_eq!(a.observe(0.3), Some(ModeKind::Sync));
        assert_eq!(a.observe(0.3), None);
    }

    /// The second controller signal: a loud staleness gap proposes GBA
    /// even with a quiet straggler signal, and the hysteresis release
    /// needs *both* signals below the low watermark.
    #[test]
    fn gap_signal_drives_the_switcher_beside_latency() {
        let mut a = AdaptiveSwitcher::new(ModeKind::Sync);
        assert_eq!(a.observe_signals(0.1, 0.2), None, "both quiet");
        assert_eq!(a.observe_signals(0.1, 0.9), Some(ModeKind::Gba), "gap alone trips it");
        assert_eq!(a.observe_signals(0.1, 0.5), None, "gap still above low: hold GBA");
        assert_eq!(a.observe_signals(0.5, 0.1), None, "straggler above low: hold GBA");
        assert_eq!(a.observe_signals(0.1, 0.1), Some(ModeKind::Sync), "both cleared");
        // Plane-level delegation, manual plane still never volunteers.
        let mut p = SwitchPlane::adaptive(ModeKind::Sync, 0.6, 0.4);
        assert_eq!(p.observe_signals(0.0, 0.8), Some(ModeKind::Gba));
        let mut m = SwitchPlane::manual(ModeKind::Sync);
        assert_eq!(m.observe_signals(0.9, 0.9), None);
    }

    #[test]
    fn switch_plane_advances_epochs_and_records_trace() {
        let mut p = SwitchPlane::manual(ModeKind::Sync);
        assert_eq!(p.epoch(), 0);
        assert_eq!(p.kind(), ModeKind::Sync);
        // Same-mode switch: no new epoch, no event.
        assert_eq!(p.advance(1, ModeKind::Sync), 0);
        assert!(p.trace().events.is_empty());
        assert_eq!(p.advance(2, ModeKind::Gba), 1);
        assert_eq!(p.advance(5, ModeKind::Sync), 2);
        assert_eq!(p.kind(), ModeKind::Sync);
        assert_eq!(p.epochs().len(), 3);
        assert_eq!(p.epochs()[1], ModeEpoch { epoch: 1, kind: ModeKind::Gba, start_day: 2 });
        assert_eq!(
            p.trace().events,
            vec![
                SwitchEvent { day: 2, from: ModeKind::Sync, to: ModeKind::Gba, signal: None },
                SwitchEvent { day: 5, from: ModeKind::Gba, to: ModeKind::Sync, signal: None },
            ]
        );
        // Manual plane never volunteers a switch.
        assert_eq!(p.observe(0.99), None);
    }

    #[test]
    fn adaptive_plane_proposes_and_manual_advance_keeps_controller_synced() {
        let mut p = SwitchPlane::adaptive(ModeKind::Sync, 0.6, 0.4);
        assert!(p.is_adaptive());
        assert_eq!(p.observe(0.7), Some(ModeKind::Gba));
        p.advance(1, ModeKind::Gba);
        assert_eq!(p.observe(0.7), None, "already in GBA");
        // Operator forces sync manually; controller follows, so the next
        // straggler storm proposes GBA again instead of thinking it is
        // still in GBA.
        p.advance(2, ModeKind::Sync);
        assert_eq!(p.observe(0.9), Some(ModeKind::Gba));
    }

    /// Adaptive switches carry the signal that drove them into the
    /// trace; manual advances record no signal.
    #[test]
    fn advance_with_signal_annotates_the_event() {
        let mut p = SwitchPlane::adaptive(ModeKind::Sync, 0.6, 0.4);
        assert_eq!(p.observe(0.8), Some(ModeKind::Gba));
        p.advance_with_signal(4, ModeKind::Gba, Some(0.8));
        assert_eq!(p.trace().events.len(), 1);
        assert_eq!(p.trace().events[0].signal, Some(0.8));
        p.advance(6, ModeKind::Sync);
        assert_eq!(p.trace().events[1].signal, None);
    }
}
