//! Mode-switch bookkeeping: records a switch trace (when, from, to) so
//! experiments can annotate AUC curves with switch points, and implements
//! the *adaptive* switching controller sketched in the paper's conclusion
//! ("make GBA adaptive to the cluster status" — future work there,
//! implemented here as an extension).

use crate::config::ModeKind;

/// One switch event.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchEvent {
    /// Day index (continual-training time axis).
    pub day: usize,
    pub from: ModeKind,
    pub to: ModeKind,
}

/// Trace of mode switches over a continual run.
#[derive(Clone, Debug, Default)]
pub struct SwitchTrace {
    pub events: Vec<SwitchEvent>,
}

impl SwitchTrace {
    pub fn record(&mut self, day: usize, from: ModeKind, to: ModeKind) {
        self.events.push(SwitchEvent { day, from, to });
    }

    pub fn mode_on_day(&self, initial: ModeKind, day: usize) -> ModeKind {
        let mut mode = initial;
        for e in &self.events {
            if e.day <= day {
                mode = e.to;
            }
        }
        mode
    }
}

/// Adaptive switching controller (paper §6 future work): choose the mode
/// from the observed cluster utilization with hysteresis — synchronous HPC
/// when the cluster is vacant, GBA when it is busy.
#[derive(Clone, Debug)]
pub struct AdaptiveSwitcher {
    /// Switch to GBA above this utilization.
    pub high_watermark: f64,
    /// Switch back to sync below this utilization.
    pub low_watermark: f64,
    current: ModeKind,
}

impl AdaptiveSwitcher {
    pub fn new(initial: ModeKind) -> Self {
        AdaptiveSwitcher { high_watermark: 0.60, low_watermark: 0.40, current: initial }
    }

    pub fn current(&self) -> ModeKind {
        self.current
    }

    /// Feed a utilization observation; returns Some(new_mode) on a switch.
    pub fn observe(&mut self, utilization: f64) -> Option<ModeKind> {
        let next = match self.current {
            ModeKind::Sync if utilization > self.high_watermark => ModeKind::Gba,
            ModeKind::Gba if utilization < self.low_watermark => ModeKind::Sync,
            other => other,
        };
        if next != self.current {
            self.current = next;
            Some(next)
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_resolves_mode_by_day() {
        let mut t = SwitchTrace::default();
        t.record(3, ModeKind::Sync, ModeKind::Gba);
        t.record(7, ModeKind::Gba, ModeKind::Sync);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 0), ModeKind::Sync);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 3), ModeKind::Gba);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 6), ModeKind::Gba);
        assert_eq!(t.mode_on_day(ModeKind::Sync, 9), ModeKind::Sync);
    }

    #[test]
    fn adaptive_hysteresis() {
        let mut a = AdaptiveSwitcher::new(ModeKind::Sync);
        assert_eq!(a.observe(0.5), None); // between watermarks: no switch
        assert_eq!(a.observe(0.7), Some(ModeKind::Gba));
        assert_eq!(a.observe(0.5), None); // hysteresis holds GBA
        assert_eq!(a.observe(0.3), Some(ModeKind::Sync));
        assert_eq!(a.observe(0.3), None);
    }
}
