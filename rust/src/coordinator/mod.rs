//! Training-mode coordination policies — the paper's system contribution.
//!
//! Every distributed training mode in the paper (§5.1) is expressed as a
//! pure state machine over pull/push events, independent of transport and
//! of time. The same policy objects drive
//!
//! * the **threaded PS runtime** (`ps`, `worker`) for real training, and
//! * the **discrete-event cluster simulator** (`sim`) for the 100–800
//!   worker QPS/staleness experiments,
//!
//! which is what makes the policy layer property-testable: invariants are
//! checked on the state machine itself, not on timing-dependent behavior.
//!
//! | mode    | pull gating                        | aggregation trigger     | staleness handling |
//! |---------|------------------------------------|-------------------------|--------------------|
//! | Sync    | one batch per worker per step      | all `N` grads           | none possible      |
//! | Async   | none                               | every grad              | unbounded          |
//! | Hop-BS  | fastest ≤ slowest + b1 (SSP)       | every grad              | bounded by b1      |
//! | BSP     | none                               | every `b2` grads        | unbounded          |
//! | Hop-BW  | one batch per worker per step      | first `N − b3` of cohort| late grads dropped |
//! | GBA     | none (token list)                  | buffer of `M` grads     | decay `f(τ,k)` (Eqn. 1) |

pub mod modes;
pub mod switch;

pub use modes::{make_policy, AsyncPolicy, BspPolicy, GbaPolicy, HopBsPolicy, HopBwPolicy, SyncPolicy};
pub use switch::{AdaptiveSwitcher, ModeEpoch, SwitchEvent, SwitchPlane, SwitchTrace};

use crate::config::ModeKind;

pub type WorkerId = usize;

/// Result of a worker's pull request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PullDecision {
    /// Proceed; attach this token to the computed gradient.
    Token(u64),
    /// Blocked (sync barrier / SSP bound); retry after the next apply.
    Wait,
}

/// What to do with a pushed gradient.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushAction {
    /// Discard (stale cohort — Hop-BW stragglers).
    Drop,
    /// Admit to the gradient buffer; no aggregation yet.
    Buffer,
    /// Admit and flush the buffer now (aggregate + apply).
    FlushNow,
}

/// Per-entry aggregation weights for a flush.
#[derive(Clone, Debug)]
pub struct FlushSpec {
    /// Weight of each buffered gradient; 0.0 = excluded (counted dropped).
    /// GBA's Eqn. (1) is the binary {0,1} case; see `DecayStrategy`.
    pub weights: Vec<f32>,
    /// Divisor for the dense-gradient weighted sum (Algorithm 2 L22:
    /// GBA divides by `N_a = M` regardless of exclusions).
    pub dense_divisor: f32,
}

/// GBA staleness-decay strategies (Eqn. 1 is `Threshold`; the others are
/// the ablations discussed in §4.1 "GBA could employ different staleness
/// decay strategies").
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DecayStrategy {
    /// f = 1 if k − τ ≤ ι else 0 (the paper's Eqn. 1).
    Threshold { iota: u64 },
    /// f = max(0, 1 − (k − τ)/ι): linear fade to zero at ι.
    Linear { iota: u64 },
    /// f = alpha^(k − τ): exponential decay, never fully dropped.
    Exponential { alpha: f32 },
}

impl DecayStrategy {
    /// Weight for a gradient with token `tau` applied at global step `k`.
    pub fn weight(&self, tau: u64, k: u64) -> f32 {
        let s = k.saturating_sub(tau);
        match *self {
            DecayStrategy::Threshold { iota } => {
                if s > iota {
                    0.0
                } else {
                    1.0
                }
            }
            DecayStrategy::Linear { iota } => {
                if s >= iota {
                    0.0
                } else {
                    1.0 - s as f32 / iota as f32
                }
            }
            DecayStrategy::Exponential { alpha } => alpha.powi(s as i32),
        }
    }
}

/// The mode state machine. All methods are called under the PS control
/// lock (threaded runtime) or from the single-threaded simulator.
pub trait ModePolicy: Send {
    fn kind(&self) -> ModeKind;

    /// Worker `w` requests a batch/token.
    fn on_pull(&mut self, w: WorkerId) -> PullDecision;

    /// Gradient with `token` arrives from worker `w`.
    fn on_push(&mut self, w: WorkerId, token: u64) -> PushAction;

    /// Decide aggregation weights for the buffered tokens (called when
    /// `on_push` returned `FlushNow`, or at end-of-data force-flush).
    fn flush_spec(&mut self, tokens: &[u64]) -> FlushSpec;

    /// The flush was applied; the global step advanced.
    fn on_applied(&mut self);

    /// Current global step `k` (number of applied aggregated updates).
    fn global_step(&self) -> u64;

    /// Worker failed/recovered: forget its in-flight state (Appendix B:
    /// "the disappearance of a specific token would not change the
    /// correctness").
    fn on_worker_reset(&mut self, w: WorkerId);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decay_threshold_matches_eqn1() {
        let d = DecayStrategy::Threshold { iota: 3 };
        assert_eq!(d.weight(5, 5), 1.0); // fresh
        assert_eq!(d.weight(2, 5), 1.0); // k - τ = 3 = ι -> keep
        assert_eq!(d.weight(1, 5), 0.0); // k - τ = 4 > ι -> drop
        assert_eq!(d.weight(9, 5), 1.0); // token ahead of k: fresh
    }

    #[test]
    fn decay_linear_fades() {
        let d = DecayStrategy::Linear { iota: 4 };
        assert_eq!(d.weight(10, 10), 1.0);
        assert_eq!(d.weight(8, 10), 0.5);
        assert_eq!(d.weight(6, 10), 0.0);
    }

    #[test]
    fn decay_exponential_never_zero() {
        let d = DecayStrategy::Exponential { alpha: 0.5 };
        assert_eq!(d.weight(10, 10), 1.0);
        assert_eq!(d.weight(9, 10), 0.5);
        assert!(d.weight(0, 10) > 0.0);
    }
}
