//! Expandable hash-table embedding store (the DeepRec-style sparse half of
//! the parameter server).
//!
//! * Rows are created lazily on first lookup with a deterministic per-key
//!   initialization (seeded from the key), so every training mode — and the
//!   native vs PJRT backends — see identical initial embeddings.
//! * Each row carries optimizer slots and per-ID metadata: the global step
//!   of its last update and its update count. Algorithm 2 (lines 19–23)
//!   decays embedding gradients by *per-ID* staleness, which needs exactly
//!   this tag.
//! * The table is sharded `mix64(key) % n_shards`, each shard behind its
//!   own `RwLock` — concurrent worker pulls only contend per shard.

use crate::util::fasthash::U64Map;
use std::sync::RwLock;

use crate::optim::Optimizer;
use crate::runtime::HostTensor;
use crate::util::rng::{mix64, Pcg64};

/// Per-row bookkeeping used by the staleness-decay logic.
#[derive(Clone, Copy, Debug, Default)]
pub struct RowMeta {
    /// Global step at which this row was last updated (Algorithm 2 L19).
    pub last_update_step: u64,
    pub update_count: u32,
}

#[derive(Clone, Debug)]
struct Row {
    vec: Vec<f32>,
    /// Optimizer slots, planar layout (`dim * slots` floats).
    state: Vec<f32>,
    meta: RowMeta,
}

#[derive(Clone, Debug)]
pub struct EmbeddingConfig {
    pub dim: usize,
    /// Std of the N(0, scale^2) lazy initializer.
    pub init_scale: f32,
    pub seed: u64,
    pub shards: usize,
}

impl Default for EmbeddingConfig {
    fn default() -> Self {
        EmbeddingConfig { dim: 16, init_scale: 0.05, seed: 0, shards: 8 }
    }
}

pub struct EmbeddingStore {
    cfg: EmbeddingConfig,
    slots: usize,
    shards: Vec<RwLock<U64Map<Row>>>,
}

impl EmbeddingStore {
    /// `slots`: optimizer state floats per weight (from `Optimizer::slots`).
    pub fn new(cfg: EmbeddingConfig, slots: usize) -> Self {
        let shards = (0..cfg.shards.max(1)).map(|_| RwLock::new(U64Map::default())).collect();
        EmbeddingStore { cfg, slots, shards }
    }

    #[inline]
    fn shard_of(&self, key: u64) -> usize {
        self.shard_index(mix64(key))
    }

    /// The one place internal shard placement is decided; every lookup
    /// path (hashed or not) must route through it so a key can never
    /// materialize in two sub-shards.
    #[inline]
    fn shard_index(&self, hash: u64) -> usize {
        (hash % self.shards.len() as u64) as usize
    }

    fn init_row(&self, key: u64) -> Row {
        let mut rng = Pcg64::new(self.cfg.seed ^ mix64(key), 0xE21B);
        let vec =
            (0..self.cfg.dim).map(|_| rng.normal() as f32 * self.cfg.init_scale).collect();
        Row { vec, state: vec![0.0; self.cfg.dim * self.slots], meta: RowMeta::default() }
    }

    pub fn dim(&self) -> usize {
        self.cfg.dim
    }

    /// Number of materialized rows.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.read().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Gather rows for a flattened key block into an [B, F, D] tensor.
    /// Missing rows are materialized (expandable-vocab semantics).
    pub fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> HostTensor {
        debug_assert_eq!(keys.len(), batch * fields);
        let dim = self.cfg.dim;
        let mut data = vec![0.0f32; keys.len() * dim];
        for (i, &key) in keys.iter().enumerate() {
            self.read_row_into(key, &mut data[i * dim..(i + 1) * dim]);
        }
        HostTensor { shape: vec![batch, fields, dim], data }
    }

    /// Copy one row's vector (materializing it if absent).
    pub fn read_row_into(&self, key: u64, out: &mut [f32]) {
        self.read_row_into_hashed(key, mix64(key), out);
    }

    /// [`read_row_into`](Self::read_row_into) with a pre-computed
    /// `mix64(key)`, for callers that already hashed the key. (The
    /// sharded-PS front used to route and look up on one hash; since
    /// the transport split, routing hashes front-side and the shard
    /// service re-derives the hash here — shipping hashes over the
    /// wire wasn't worth widening the Gather frame.)
    pub fn read_row_into_hashed(&self, key: u64, hash: u64, out: &mut [f32]) {
        debug_assert_eq!(hash, mix64(key));
        let shard = &self.shards[self.shard_index(hash)];
        {
            let guard = shard.read().unwrap();
            if let Some(row) = guard.get(&key) {
                out.copy_from_slice(&row.vec);
                return;
            }
        }
        let mut guard = shard.write().unwrap();
        let row = guard.entry(key).or_insert_with(|| self.init_row(key));
        out.copy_from_slice(&row.vec);
    }

    pub fn row(&self, key: u64) -> Vec<f32> {
        let mut v = vec![0.0; self.cfg.dim];
        self.read_row_into(key, &mut v);
        v
    }

    pub fn meta(&self, key: u64) -> Option<RowMeta> {
        let shard = &self.shards[self.shard_of(key)];
        shard.read().unwrap().get(&key).map(|r| r.meta)
    }

    /// Apply aggregated per-ID gradients at global step `step`.
    ///
    /// `grads`: (key, gradient-sum, contributing-worker-count) triples —
    /// Algorithm 2 L23 divides each ID's gradient by the number of workers
    /// that encountered that ID (not by M).
    pub fn apply_grads(
        &self,
        grads: &[(u64, Vec<f32>, u32)],
        opt: &dyn Optimizer,
        step: u64,
    ) {
        self.apply_grads_threaded(grads, opt, step, 1);
    }

    /// [`apply_grads`](Self::apply_grads), batched by internal
    /// lock-shard: each sub-shard `RwLock` is taken **once per apply**
    /// instead of once per key, and with `threads > 1` the lock-shard
    /// groups are spread over scoped worker threads (each with its own
    /// `scaled` scratch). Within a group, keys apply in arrival order.
    ///
    /// Bit-identical to the per-key loop it replaces: upstream per-key
    /// aggregation means a key appears at most once per apply, and a key
    /// always maps to the same lock-shard, so no two workers ever touch
    /// the same row and per-row float work is independent across rows.
    pub fn apply_grads_threaded(
        &self,
        grads: &[(u64, Vec<f32>, u32)],
        opt: &dyn Optimizer,
        step: u64,
        threads: usize,
    ) {
        if grads.is_empty() {
            return;
        }
        // Group grad indices by lock-shard, preserving arrival order.
        let mut groups: Vec<Vec<usize>> = vec![Vec::new(); self.shards.len()];
        for (i, (key, _, _)) in grads.iter().enumerate() {
            groups[self.shard_of(*key)].push(i);
        }
        let apply_group = |group: &[usize], scaled: &mut [f32]| {
            if group.is_empty() {
                return;
            }
            let shard = &self.shards[self.shard_of(grads[group[0]].0)];
            let mut guard = shard.write().unwrap();
            for &i in group {
                let (key, gsum, count) = &grads[i];
                let row = guard.entry(*key).or_insert_with(|| self.init_row(*key));
                let inv = 1.0 / (*count).max(1) as f32;
                for (s, g) in scaled.iter_mut().zip(gsum) {
                    *s = g * inv;
                }
                opt.apply(&mut row.vec, scaled, &mut row.state, step);
                row.meta.last_update_step = step;
                row.meta.update_count += 1;
            }
        };
        let busy = groups.iter().filter(|g| !g.is_empty()).count();
        let workers = threads.max(1).min(busy.max(1));
        if workers <= 1 {
            let mut scaled = vec![0.0f32; self.cfg.dim];
            for g in &groups {
                apply_group(g, &mut scaled);
            }
        } else {
            // Round-robin the lock-shard groups over `workers` scoped
            // threads; the calling thread takes stripe 0.
            std::thread::scope(|scope| {
                for w in 1..workers {
                    let groups = &groups;
                    let apply_group = &apply_group;
                    scope.spawn(move || {
                        let mut scaled = vec![0.0f32; self.cfg.dim];
                        for g in groups.iter().skip(w).step_by(workers) {
                            apply_group(g, &mut scaled);
                        }
                    });
                }
                let mut scaled = vec![0.0f32; self.cfg.dim];
                for g in groups.iter().step_by(workers) {
                    apply_group(g, &mut scaled);
                }
            });
        }
    }

    /// Iterate all rows (checkpointing). The callback sees
    /// (key, vector, optimizer state, meta).
    pub fn for_each_row(&self, mut f: impl FnMut(u64, &[f32], &[f32], RowMeta)) {
        for shard in &self.shards {
            let guard = shard.read().unwrap();
            for (k, row) in guard.iter() {
                f(*k, &row.vec, &row.state, row.meta);
            }
        }
    }

    /// Bulk-insert a row (checkpoint restore).
    pub fn insert_row(&self, key: u64, vec: Vec<f32>, state: Vec<f32>, meta: RowMeta) {
        assert_eq!(vec.len(), self.cfg.dim);
        assert_eq!(state.len(), self.cfg.dim * self.slots);
        let shard = &self.shards[self.shard_of(key)];
        shard.write().unwrap().insert(key, Row { vec, state, meta });
    }

    /// Re-shape every materialized row's optimizer state for a new
    /// optimizer with `slots` state floats per weight, zeroing it (the
    /// old optimizer's accumulators are meaningless to the new one).
    /// The mode-switch (`SwapPolicy`) path for optimizer-changing
    /// epochs; vectors and metadata are untouched.
    pub fn reset_state(&mut self, slots: usize) {
        self.slots = slots;
        let n = self.cfg.dim * slots;
        for shard in &self.shards {
            let mut guard = shard.write().unwrap();
            for row in guard.values_mut() {
                row.state = vec![0.0; n];
            }
        }
    }

    /// Drop all rows (tests).
    pub fn clear(&self) {
        for shard in &self.shards {
            shard.write().unwrap().clear();
        }
    }

    /// Approximate resident bytes.
    pub fn memory_bytes(&self) -> usize {
        self.len() * (self.cfg.dim * (1 + self.slots) * 4 + 32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adagrad, Sgd};

    fn store(slots: usize) -> EmbeddingStore {
        EmbeddingStore::new(
            EmbeddingConfig { dim: 4, init_scale: 0.1, seed: 9, shards: 4 },
            slots,
        )
    }

    #[test]
    fn lazy_init_is_deterministic() {
        let s1 = store(0);
        let s2 = store(0);
        for key in [1u64, 999, 1 << 50] {
            assert_eq!(s1.row(key), s2.row(key));
        }
        assert_eq!(s1.len(), 3);
    }

    #[test]
    fn different_keys_different_rows() {
        let s = store(0);
        assert_ne!(s.row(1), s.row(2));
    }

    #[test]
    fn gather_shapes_and_content() {
        let s = store(0);
        let keys = vec![10, 11, 12, 10, 11, 13];
        let t = s.gather(&keys, 2, 3);
        assert_eq!(t.shape, vec![2, 3, 4]);
        // Same key gathers the same row.
        assert_eq!(&t.data[0..4], &t.data[12..16]);
        assert_eq!(s.len(), 4);
    }

    #[test]
    fn apply_grads_divides_by_worker_count() {
        let s = store(0);
        let before = s.row(5);
        let opt = Sgd { lr: 1.0 };
        // gradient sum [2,2,2,2] over 2 contributing workers -> step of 1.0
        s.apply_grads(&[(5, vec![2.0; 4], 2)], &opt, 1);
        let after = s.row(5);
        for i in 0..4 {
            assert!((after[i] - (before[i] - 1.0)).abs() < 1e-6);
        }
        let meta = s.meta(5).unwrap();
        assert_eq!(meta.last_update_step, 1);
        assert_eq!(meta.update_count, 1);
    }

    #[test]
    fn optimizer_state_persists_across_updates() {
        let s = store(1);
        let opt = Adagrad::new(0.1);
        let k = 77u64;
        let mut deltas = Vec::new();
        for step in 1..=3 {
            let before = s.row(k);
            s.apply_grads(&[(k, vec![1.0; 4], 1)], &opt, step);
            let after = s.row(k);
            deltas.push((after[0] - before[0]).abs());
        }
        // Accumulator grows -> steps shrink.
        assert!(deltas[1] < deltas[0] && deltas[2] < deltas[1], "{deltas:?}");
    }

    #[test]
    fn concurrent_gather_and_update() {
        use std::sync::Arc;
        let s = Arc::new(store(0));
        let mut handles = Vec::new();
        for t in 0..8 {
            let s = s.clone();
            handles.push(std::thread::spawn(move || {
                let opt = Sgd { lr: 0.01 };
                for i in 0..200u64 {
                    let key = (t * 37 + i) % 64;
                    let _ = s.row(key);
                    s.apply_grads(&[(key, vec![0.1; 4], 1)], &opt, i + 1);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(s.len() <= 64);
    }

    #[test]
    fn checkpoint_roundtrip_via_iteration() {
        let s = store(1);
        let opt = Adagrad::new(0.1);
        for k in 0..20u64 {
            s.apply_grads(&[(k, vec![1.0; 4], 1)], &opt, k + 1);
        }
        let mut rows = Vec::new();
        s.for_each_row(|k, v, st, m| rows.push((k, v.to_vec(), st.to_vec(), m)));
        assert_eq!(rows.len(), 20);
        let s2 = store(1);
        for (k, v, st, m) in rows {
            s2.insert_row(k, v, st, m);
        }
        for k in 0..20u64 {
            assert_eq!(s.row(k), s2.row(k));
            assert_eq!(s.meta(k).unwrap().update_count, s2.meta(k).unwrap().update_count);
        }
    }

    #[test]
    fn reset_state_reshapes_and_zeroes_every_row() {
        let mut s = store(1);
        let opt = Adagrad::new(0.1);
        for k in 0..8u64 {
            s.apply_grads(&[(k, vec![1.0; 4], 1)], &opt, 1);
        }
        let mut any_nonzero = false;
        s.for_each_row(|_, _, st, _| any_nonzero |= st.iter().any(|&x| x != 0.0));
        assert!(any_nonzero, "adagrad accumulators should be live");
        let vec_before = s.row(3);
        let meta_before = s.meta(3).unwrap();
        s.reset_state(2);
        s.for_each_row(|_, _, st, _| {
            assert_eq!(st.len(), 8, "state reshaped to dim * new_slots");
            assert!(st.iter().all(|&x| x == 0.0), "state zeroed");
        });
        // Vectors and metadata survive; inserts now expect the new shape.
        assert_eq!(s.row(3), vec_before);
        assert_eq!(s.meta(3).unwrap().update_count, meta_before.update_count);
        s.insert_row(99, vec![0.0; 4], vec![0.0; 8], RowMeta::default());
    }

    #[test]
    fn memory_accounting_positive() {
        let s = store(2);
        let _ = s.row(1);
        assert!(s.memory_bytes() > 0);
    }

    /// The lock-shard-batched, multi-threaded apply must leave the store
    /// bit-identical to the serial per-key path, for any thread count.
    #[test]
    fn threaded_apply_grads_bit_identical_to_serial() {
        use crate::optim::Adam;
        let opt = Adam::new(0.01);
        // Unique keys per apply (the upstream aggregation invariant),
        // spanning every lock-shard, applied over several steps.
        let grads_at = |step: u64| -> Vec<(u64, Vec<f32>, u32)> {
            (0..257u64)
                .map(|k| {
                    let g: Vec<f32> =
                        (0..4).map(|j| ((k * 31 + j + step) % 17) as f32 * 0.25 - 2.0).collect();
                    (k * 7, g, 1 + (k % 3) as u32)
                })
                .collect()
        };
        let dump = |s: &EmbeddingStore| {
            let mut rows: Vec<(u64, Vec<u32>, Vec<u32>, u64, u32)> = Vec::new();
            s.for_each_row(|k, v, st, m| {
                rows.push((
                    k,
                    v.iter().map(|x| x.to_bits()).collect(),
                    st.iter().map(|x| x.to_bits()).collect(),
                    m.last_update_step,
                    m.update_count,
                ));
            });
            rows.sort_by_key(|r| r.0);
            rows
        };
        let serial = store(2);
        for step in 1..=3 {
            serial.apply_grads(&grads_at(step), &opt, step);
        }
        for threads in [2, 4, 16] {
            let s = store(2);
            for step in 1..=3 {
                s.apply_grads_threaded(&grads_at(step), &opt, step, threads);
            }
            assert_eq!(dump(&serial), dump(&s), "threads={threads} diverged");
        }
    }
}
