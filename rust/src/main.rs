//! `gba-train` — launcher CLI.
//!
//! Subcommands:
//!   experiment <id|all>   regenerate a paper table/figure (DESIGN.md §3)
//!   train                 run continual training from a config
//!   shard-server          serve one PS shard on a TCP socket (the
//!                         multi-process deployment; see docs/DEPLOY.md)
//!   worker                run one training worker as this process,
//!                         dialing a front with [cluster] workers="remote"
//!   serve                 read-only inference front over the live PS
//!                         shards (hot-key cache + batched gathers)
//!   serve-probe           drive Zipfian gather traffic at a serve front
//!                         and report served-QPS latency quantiles
//!   datagen               inspect the synthetic data generator
//!   inspect               dump the AOT artifact manifest
//!
//! (Hand-rolled argument parsing: the build environment has no clap.)

use std::path::PathBuf;

use anyhow::{Context, Result};

use gba::config::{ExperimentConfig, ModeKind, SwitchPolicyKind, TransportKind, WorkerPlane};
use gba::data::DataGen;
use gba::experiments::{self, ExpCtx};
use gba::metrics::report::{fmt_auc, write_result};
use gba::runtime::Manifest;
use gba::util::json::Json;
use gba::transport::serve_shard;
use gba::worker::remote::{run_worker_process, WorkerProcOptions};
use gba::worker::session::{shard_server_spec, SessionOptions, TrainSession};
use gba::worker::BackendKind;

struct Args {
    positional: Vec<String>,
    flags: std::collections::BTreeMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = std::collections::BTreeMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --flag value  or bare --flag (boolean)
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name), Some("true") | Some("1") | Some("yes"))
    }
}

const USAGE: &str = "\
gba-train — GBA (NeurIPS'22) reproduction: tuning-free sync/async switching

USAGE:
  gba-train experiment <id|all> [--out DIR] [--configs DIR] [--quick]
                                 [--backend native|pjrt] [--seed N]
  gba-train train --config FILE --mode <sync|async|hop_bs|bsp|hop_bw|gba>
                  [--days N] [--backend native|pjrt] [--artifacts DIR]
                  [--straggler] [--switch-to MODE] [--switch-day D]
                  [--switch-policy manual|adaptive]   (override [switch]
                                 policy: adaptive watches per-day straggler
                                 telemetry and switches sync<->gba in place,
                                 with remote workers re-handshaking live)
                  [--staleness-policy gba|gap_aware|abs]   (override [train]
                                 staleness_policy: how the control plane
                                 decays stale gradients at the flush point —
                                 gba = the paper's fixed schedule, gap_aware
                                 = penalize by parameter movement since
                                 issue, abs = online-adapted staleness
                                 bound; see docs/STALENESS.md)
                  [--shards N]   (override [ps] n_shards: PS plane width)
                  [--transport inproc|socket|remote]   (override [ps]
                                 transport: shard endpoints in-process,
                                 over TCP, or in shard-server processes)
                  [--shard-addrs HOST:PORT,...]   (connect to remote
                                 shard-servers; implies --transport remote)
                  [--workers inproc|remote]   (override [cluster] workers:
                                 worker loops in-thread or as gba-train
                                 worker processes dialing this front)
                  [--worker-listen ADDR]   (override [cluster] worker_listen)
                  [--obs-listen ADDR] [--obs-trace-dir DIR]   (override
                                 [obs]: /metrics exposition and trace-span
                                 JSONL export; docs/OBSERVABILITY.md)
                  [--out DIR]    (where train.json — per-day stats plus the
                                 run-wide telemetry block — lands;
                                 default results/)
  gba-train shard-server --config FILE --shard-id K [--listen ADDR]
                  [--mode MODE] [--shards N]
                  [--obs-listen ADDR] [--obs-trace-dir DIR]
                  (serve shard K of the PS plane on a listening socket;
                   prints \"shard-server listening on ADDR\" once bound,
                   then the obs metrics address if enabled)
  gba-train worker --config FILE --connect ADDR --worker-id W
                  [--mode MODE] [--fail-prob P] [--batch-sleep-ms T]
                  [--obs-listen ADDR] [--obs-trace-dir DIR]
                  (run worker W's Algorithm-1 loop as this process,
                   against a front started with --workers remote; exits 0
                   when the front ends the session)
  gba-train serve --config FILE [--shard-addrs HOST:PORT,...]
                  [--listen ADDR] [--cache-rows N]
                  [--obs-listen ADDR] [--obs-trace-dir DIR]
                  (serve read-only embedding gathers from the PS shard
                   fleet — the shard-servers keep answering while (and
                   after) a trainer runs against them; prints
                   \"serve front listening on ADDR\" once every shard
                   companion is attached; cache/batching/staleness knobs
                   come from [serve], see docs/DEPLOY.md)
  gba-train serve-probe --config FILE --connect ADDR
                  [--requests N] [--batch B]
                  (replay the generator's Zipfian key traffic against a
                   serve front; prints served QPS and p50/p95/p99 latency)
  gba-train datagen --config FILE [--day D] [--samples N]
  gba-train inspect [--artifacts DIR]

EXPERIMENTS (DESIGN.md §3): fig1 fig2 fig3 fig4 fig6 fig7 fig8 table52
table53 convergence ablation_decay
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprint!("{USAGE}");
        std::process::exit(2);
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let result = match cmd.as_str() {
        "experiment" => cmd_experiment(&args),
        "train" => cmd_train(&args),
        "shard-server" => cmd_shard_server(&args),
        "worker" => cmd_worker(&args),
        "serve" => cmd_serve(&args),
        "serve-probe" => cmd_serve_probe(&args),
        "datagen" => cmd_datagen(&args),
        "inspect" => cmd_inspect(&args),
        "help" | "--help" | "-h" => {
            print!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{USAGE}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Fold the `--obs-listen` / `--obs-trace-dir` CLI overrides into
/// `cfg.obs`, then turn on whichever export surfaces ended up
/// configured. The metrics announcement is one parseable stdout line
/// (`obs metrics listening on ADDR`); `shard-server` calls this *after*
/// its address banner so the banner stays the first line its
/// supervisors parse. Instrumentation itself is always on — with both
/// surfaces off this changes nothing about the run.
fn init_obs(cfg: &mut ExperimentConfig, args: &Args, role: &str) -> Result<()> {
    if let Some(listen) = args.get("obs-listen") {
        cfg.obs.listen = Some(listen.to_string());
    }
    if let Some(dir) = args.get("obs-trace-dir") {
        cfg.obs.trace_dir = Some(dir.to_string());
    }
    if let Some(listen) = &cfg.obs.listen {
        let addr = gba::obs::serve::start(listen)
            .with_context(|| format!("binding obs metrics listener on {listen}"))?;
        // Standard `*_up` liveness gauge, so the exposition is non-empty
        // the moment the listener binds (a freshly booted, idle process
        // has not registered anything else yet).
        gba::obs::global().gauge(&gba::obs::labeled("gba_process_up", "role", role)).set(1.0);
        println!("obs metrics listening on {addr}");
        use std::io::Write;
        std::io::stdout().flush()?;
    }
    if let Some(dir) = &cfg.obs.trace_dir {
        let path = gba::obs::trace::init(dir, role)
            .with_context(|| format!("opening obs trace sink in {dir}"))?;
        eprintln!("obs trace spans -> {}", path.display());
    }
    Ok(())
}

fn cmd_experiment(args: &Args) -> Result<()> {
    let name = args.positional.first().context("experiment id required (or 'all')")?;
    let ctx = ExpCtx {
        out_dir: PathBuf::from(args.get("out").unwrap_or("results")),
        configs_dir: PathBuf::from(args.get("configs").unwrap_or("configs")),
        backend: BackendKind::parse(args.get("backend").unwrap_or("native"))?,
        quick: args.get_bool("quick"),
        seed: args.get("seed").map(|s| s.parse()).transpose()?.unwrap_or(7),
    };
    experiments::run(name, &ctx)
}

fn cmd_train(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let mut cfg = ExperimentConfig::load(config)?;
    if let Some(n) = args.get("shards") {
        cfg.ps.n_shards = n.parse().context("--shards wants a positive integer")?;
        cfg.validate()?;
    }
    if let Some(t) = args.get("transport") {
        cfg.ps.transport = TransportKind::parse(t)?;
    }
    if let Some(addrs) = args.get("shard-addrs") {
        cfg.ps.shard_addrs = addrs
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        cfg.ps.transport = TransportKind::Remote;
    }
    if cfg.ps.transport == TransportKind::Remote {
        cfg.validate()?; // addr count must match the shard count
    }
    if let Some(plane) = args.get("workers") {
        cfg.cluster.workers = WorkerPlane::parse(plane)?;
    }
    if let Some(listen) = args.get("worker-listen") {
        cfg.cluster.worker_listen = listen.to_string();
        cfg.validate()?;
    }
    if let Some(policy) = args.get("switch-policy") {
        cfg.switch.policy = SwitchPolicyKind::parse(policy)?;
    }
    if let Some(policy) = args.get("staleness-policy") {
        cfg.train.staleness.policy = gba::staleness::StalenessPolicyKind::parse(policy)?;
        cfg.validate()?;
    }
    init_obs(&mut cfg, args, "trainer")?;
    let task_name = cfg.name.clone();
    let kind = ModeKind::parse(args.get("mode").unwrap_or("gba"))?;
    let days: usize = args
        .get("days")
        .map(|s| s.parse())
        .transpose()?
        .unwrap_or(cfg.data.days_base + cfg.data.days_eval - 1);
    let switch_to = args.get("switch-to").map(ModeKind::parse).transpose()?;
    // A switch that switch_mode would reject at the switch day is fully
    // decidable here — fail before day 0, not hours into training.
    if let Some(to) = switch_to {
        anyhow::ensure!(
            cfg.has_mode(to),
            "--switch-to {}: the config does not define [mode.{}]",
            to.as_str(),
            to.as_str()
        );
        anyhow::ensure!(
            cfg.switch.policy != SwitchPolicyKind::Adaptive
                || matches!(to, ModeKind::Sync | ModeKind::Gba),
            "--switch-to {} is incompatible with --switch-policy adaptive (the controller \
             drives sync <-> gba only); use --switch-policy manual",
            to.as_str()
        );
    }
    let switch_day: usize =
        args.get("switch-day").map(|s| s.parse()).transpose()?.unwrap_or(days / 2);
    let opts = SessionOptions {
        backend: BackendKind::parse(args.get("backend").unwrap_or("native"))?,
        artifacts_dir: PathBuf::from(args.get("artifacts").unwrap_or("artifacts")),
        straggler: args.get_bool("straggler"),
        ..SessionOptions::default()
    };

    println!(
        "task {} | mode {} | G_sync = {} | M = {} | ps shards = {} ({}) | workers {} | backend {:?}",
        cfg.name,
        kind.paper_name(),
        cfg.global_batch_sync(),
        cfg.gba_m_effective(),
        cfg.ps.n_shards,
        cfg.ps.transport.as_str(),
        cfg.cluster.workers.as_str(),
        opts.backend
    );
    let n_workers = cfg.mode(kind).workers;
    let mut session = TrainSession::new(cfg, kind, opts)?;
    if let Some(addr) = session.worker_addr() {
        // One parseable line, mirroring the shard-server banner: process
        // supervisors (and tests) scrape the bound address from it.
        println!("worker front listening on {addr} (waiting for {n_workers} workers)");
        use std::io::Write;
        std::io::stdout().flush()?;
    }
    let mut day_rows: Vec<Json> = Vec::new();
    for d in 0..days {
        if let Some(to) = switch_to {
            if d == switch_day {
                println!(
                    "--- switching {} -> {} (tuning-free, in place) ---",
                    session.kind.paper_name(),
                    to.paper_name()
                );
                session.switch_mode(to)?;
            }
        }
        let stats = session.train_day(d)?;
        let auc = session.eval_auc(d + 1)?;
        println!(
            "day {d} [{} e{}]: auc(day{}) = {}  qps = {:.0}  steps = {}  dropped = {}  \
             reissued = {}  stale(mean/max) = {:.2}/{}  straggler = {:.2}",
            session.kind.as_str(),
            session.mode_epoch(),
            d + 1,
            fmt_auc(auc),
            stats.qps,
            stats.counters.global_steps,
            stats.counters.dropped_batches,
            stats.reissued(),
            stats.counters.dense_staleness.mean(),
            stats.counters.dense_staleness.max(),
            stats.straggler_signal(),
        );
        day_rows.push(
            Json::obj()
                .set("day", d)
                .set("mode", session.kind.as_str())
                .set("epoch", session.mode_epoch())
                .set("auc", auc)
                .set("qps", stats.qps)
                .set("global_steps", stats.counters.global_steps)
                .set("batch_latency_med", stats.batch_latency_med)
                .set("batch_latency_p95", stats.batch_latency_p95)
                .set("straggler_signal", stats.straggler_signal()),
        );
        // Adaptive policy: let the switch plane read the day's straggler
        // telemetry and advance the mode epoch if the watermarks say so
        // (remote workers re-handshake inside switch_mode).
        if session.is_adaptive() {
            if let Some(to) = session.observe_day(&stats)? {
                println!(
                    "--- adaptive switch -> {} (epoch {}, straggler signal {:.2}) ---",
                    to.paper_name(),
                    session.mode_epoch(),
                    stats.straggler_signal()
                );
            }
        }
    }
    // Run metrics: the switch trace, one parseable line per event,
    // annotated with the straggler signal that drove adaptive switches.
    let mut switch_events = Vec::new();
    for e in &session.switch_trace().events {
        match e.signal {
            Some(s) => println!(
                "switch-trace: day {} {} -> {} (signal {s:.2})",
                e.day,
                e.from.as_str(),
                e.to.as_str()
            ),
            None => {
                println!("switch-trace: day {} {} -> {}", e.day, e.from.as_str(), e.to.as_str())
            }
        }
        switch_events.push(
            Json::obj()
                .set("day", e.day)
                .set("from", e.from.as_str())
                .set("to", e.to.as_str())
                .set("signal", e.signal.map_or(Json::Null, Json::from)),
        );
    }
    // The run-wide telemetry block: this process's registry (worker
    // batch-latency quantiles live here), every shard process's registry
    // via the ObsScrape RPC, and the annotated switch trace.
    let reg = gba::obs::global();
    let batch = reg.histogram("gba_worker_batch_seconds", gba::obs::Histogram::latency_bounds());
    let shard_scrapes: Vec<Json> = session
        .ps()
        .obs_scrape()
        .into_iter()
        .enumerate()
        .map(|(s, entries)| {
            Json::obj().set("shard", s).set("metrics", gba::obs::snapshot_to_json(&entries))
        })
        .collect();
    let telemetry = Json::obj()
        .set(
            "worker_batch_seconds",
            Json::obj()
                .set("count", batch.count())
                .set("p50", batch.quantile(0.50))
                .set("p95", batch.quantile(0.95))
                .set("p99", batch.quantile(0.99)),
        )
        .set("switch_events", Json::Arr(switch_events))
        .set("registry", gba::obs::snapshot_to_json(&reg.snapshot()))
        .set("shards", Json::Arr(shard_scrapes));
    let out_dir = PathBuf::from(args.get("out").unwrap_or("results"));
    write_result(
        &out_dir,
        "train",
        &Json::obj()
            .set("task", task_name)
            .set("mode", kind.as_str())
            .set("days", Json::Arr(day_rows))
            .set("telemetry", telemetry),
    )?;
    // Clean end of training: remote workers get the SessionOver
    // farewell and exit 0. Error paths skip this, so workers exit
    // nonzero when the front fails — restart policies see both.
    session.shutdown_workers();
    Ok(())
}

/// Run one PS shard as this process: bind, announce the bound address
/// on stdout (exactly one line — process supervisors and the
/// `process_shards` test parse it), then serve codec RPCs forever,
/// accepting a fresh connection (with a fresh shard, state installed by
/// the front) whenever the previous one drops. See docs/DEPLOY.md for
/// the multi-host launch recipe.
fn cmd_shard_server(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let mut cfg = ExperimentConfig::load(config)?;
    // The server role ignores the front-side transport/address config —
    // the shared file typically carries `transport = "remote"` plus the
    // addr list, and a `--shards` override must not trip the
    // addr-count-vs-n_shards validation rule that only binds the front.
    cfg.ps.transport = TransportKind::InProc;
    cfg.ps.shard_addrs.clear();
    if let Some(n) = args.get("shards") {
        cfg.ps.n_shards = n.parse().context("--shards wants a positive integer")?;
        cfg.validate()?;
    }
    let shard_id: usize = args
        .get("shard-id")
        .context("--shard-id K required")?
        .parse()
        .context("--shard-id wants a shard index")?;
    anyhow::ensure!(
        shard_id < cfg.ps.n_shards,
        "--shard-id {shard_id} out of range for {} shards (override with --shards)",
        cfg.ps.n_shards
    );
    // The mode fixes the optimizer pair this shard applies with; it must
    // match the front's --mode (Table 5.1 pairs optimizers with modes).
    let kind = ModeKind::parse(args.get("mode").unwrap_or("gba"))?;
    let (spec, init) = shard_server_spec(&cfg, kind, shard_id);
    let listen = args.get("listen").unwrap_or("127.0.0.1:0");
    let listener = std::net::TcpListener::bind(listen)
        .with_context(|| format!("binding shard-server listener on {listen}"))?;
    let addr = listener.local_addr()?;
    println!("shard-server listening on {addr} (shard {shard_id}/{}, task {})",
        cfg.ps.n_shards, cfg.name);
    use std::io::Write;
    std::io::stdout().flush()?;
    // After the banner: supervisors and tests parse the first stdout
    // line as the shard address, so the obs announcement comes second.
    init_obs(&mut cfg, args, &format!("shard{shard_id}"))?;
    eprintln!(
        "shard {shard_id}: mode {} | {} dense ranges | emb dim {} | serving forever",
        kind.as_str(),
        spec.ranges.len(),
        cfg.model.emb_dim
    );
    serve_shard(listener, spec, &init).context("shard-server accept loop failed")?;
    Ok(())
}

/// Run one training worker as this process: dial the front announced by
/// `gba-train train --workers remote`, handshake, then serve days until
/// the front closes the session. The config file and `--mode` must
/// match the front's — the `Hello` handshake pins the shape-critical
/// keys, docs/DEPLOY.md documents the rest of the operator contract.
fn cmd_worker(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let mut cfg = ExperimentConfig::load(config)?;
    let addr = args.get("connect").context("--connect ADDR required")?;
    let worker_id: usize = args
        .get("worker-id")
        .context("--worker-id W required")?
        .parse()
        .context("--worker-id wants a worker index")?;
    init_obs(&mut cfg, args, &format!("worker{worker_id}"))?;
    let kind = ModeKind::parse(args.get("mode").unwrap_or("gba"))?;
    let opts = WorkerProcOptions {
        fail_prob: args.get("fail-prob").map(|s| s.parse()).transpose()?.unwrap_or(0.0),
        batch_sleep_ms: args
            .get("batch-sleep-ms")
            .map(|s| s.parse())
            .transpose()?
            .unwrap_or(0.0),
        ..WorkerProcOptions::default()
    };
    let days = run_worker_process(&cfg, kind, worker_id, addr, opts)?;
    eprintln!("worker {worker_id}: session over after {days} day(s)");
    Ok(())
}

/// Run the read-only serving front as this process: attach a read
/// companion to every PS shard-server, then answer worker-vocabulary
/// gathers (hot-key cache + batched snapshot fetches) forever. The
/// shard fleet keeps serving while a trainer applies into it — that is
/// the point — and after the trainer exits, so `serve` works against a
/// quiesced fleet too. The banner prints only once every companion is
/// attached, so "listening" means "ready to answer".
fn cmd_serve(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let mut cfg = ExperimentConfig::load(config)?;
    if let Some(addrs) = args.get("shard-addrs") {
        cfg.ps.shard_addrs = addrs
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
        cfg.ps.transport = TransportKind::Remote;
        cfg.validate()?;
    }
    anyhow::ensure!(
        !cfg.ps.shard_addrs.is_empty(),
        "serve needs the shard fleet's addresses: set [ps] shard_addrs \
         (with transport = \"remote\") or pass --shard-addrs"
    );
    if let Some(listen) = args.get("listen") {
        cfg.serve.listen = listen.to_string();
    }
    if let Some(rows) = args.get("cache-rows") {
        cfg.serve.cache_rows = rows.parse().context("--cache-rows wants an integer")?;
    }
    cfg.validate()?;

    let deadline = std::time::Duration::from_millis(cfg.ps.connect_deadline_ms);
    let shards = gba::serve::RemoteReadShards::connect(
        &cfg.ps.shard_addrs,
        cfg.model.emb_dim,
        deadline,
    )
    .context("attaching read companions to the PS shard fleet")?;
    let n_shards = cfg.ps.shard_addrs.len();
    let front = std::sync::Arc::new(gba::serve::ServeFront::new(
        Box::new(shards),
        cfg.serve.clone(),
    ));
    let listener = std::net::TcpListener::bind(&cfg.serve.listen)
        .with_context(|| format!("binding serve listener on {}", cfg.serve.listen))?;
    let addr = gba::serve::serve_listener(front, listener)?;
    // One parseable line, same contract as the shard-server banner: the
    // first stdout line is the bound address.
    println!(
        "serve front listening on {addr} ({n_shards} shards, cache {} rows, \
         window {} us, max-stale {} ms)",
        cfg.serve.cache_rows, cfg.serve.batch_window_us, cfg.serve.max_stale_ms
    );
    use std::io::Write;
    std::io::stdout().flush()?;
    init_obs(&mut cfg, args, "serve")?;
    eprintln!("serve: task {} | emb dim {} | serving forever", cfg.name, cfg.model.emb_dim);
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

/// Drive the generator's Zipfian key traffic at a serve front and
/// report served-QPS latency quantiles — the online half of the
/// Table 5.2 throughput story (the offline half is bench_table52_qps).
fn cmd_serve_probe(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let cfg = ExperimentConfig::load(config)?;
    let addr = args.get("connect").context("--connect ADDR required")?;
    let requests: usize =
        args.get("requests").map(|s| s.parse()).transpose()?.unwrap_or(2000);
    let batch: usize = args.get("batch").map(|s| s.parse()).transpose()?.unwrap_or(8);
    anyhow::ensure!(requests > 0 && batch > 0, "--requests and --batch must be positive");
    let fields = cfg.model.fields;

    // The generator's own samples ARE the serving key distribution:
    // per-field Zipfian ids over the ids the trainer actually touched.
    let gen = DataGen::new(&cfg.model, &cfg.data, cfg.seed);
    let mut client =
        gba::serve::ServeClient::connect(addr, std::time::Duration::from_secs(20))?;
    let mut keys = Vec::with_capacity(batch * fields);
    // Warm the connection (and the front's cache head) outside the clock.
    keys.extend(gen.sample(0, 0).keys.iter().copied());
    for _ in 1..batch {
        keys.extend(gen.sample(0, 0).keys.iter().copied());
    }
    client.gather(&keys, batch, fields)?;

    let mut lat_ns: Vec<f64> = Vec::with_capacity(requests);
    let t0 = std::time::Instant::now();
    for r in 0..requests {
        keys.clear();
        for b in 0..batch {
            let j = (r * batch + b) % cfg.data.samples_per_day.max(1);
            keys.extend(gen.sample(0, j).keys.iter().copied());
        }
        let s = std::time::Instant::now();
        let t = client.gather(&keys, batch, fields)?;
        lat_ns.push(s.elapsed().as_nanos() as f64);
        anyhow::ensure!(
            t.shape == vec![batch, fields, cfg.model.emb_dim],
            "serve returned shape {:?}",
            t.shape
        );
    }
    let wall = t0.elapsed().as_secs_f64();
    lat_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let ms = |p: f64| gba::util::stats::percentile_sorted(&lat_ns, p) / 1e6;
    println!(
        "serve-probe: {requests} requests x {batch}x{fields} keys | qps {:.0} | \
         latency p50 {:.3} ms p95 {:.3} ms p99 {:.3} ms",
        requests as f64 / wall,
        ms(50.0),
        ms(95.0),
        ms(99.0)
    );
    Ok(())
}

fn cmd_datagen(args: &Args) -> Result<()> {
    let config = args.get("config").context("--config FILE required")?;
    let cfg = ExperimentConfig::load(config)?;
    let day: usize = args.get("day").map(|s| s.parse()).transpose()?.unwrap_or(0);
    let samples: usize = args.get("samples").map(|s| s.parse()).transpose()?.unwrap_or(8);
    let gen = DataGen::new(&cfg.model, &cfg.data, cfg.seed);
    println!("task {} day {day}: first {samples} samples", cfg.name);
    let mut pos = 0usize;
    for j in 0..samples {
        let s = gen.sample(day, j);
        pos += (s.label > 0.5) as usize;
        println!("  #{j}: label={} keys={:?}", s.label, &s.keys[..s.keys.len().min(6)]);
    }
    let n = 4096.min(cfg.data.samples_per_day);
    let ctr = (0..n).filter(|&j| gen.sample(day, j).label > 0.5).count() as f64 / n as f64;
    println!("shown positives: {pos}/{samples}; day CTR over {n} samples: {ctr:.3}");
    let stats = gba::data::stats::id_occurrence_stats(&gen, day, 256, 32);
    println!(
        "id stats over 32x256 batches: {} distinct ids, {:.1}% in <=10 batches",
        stats.distinct_ids,
        100.0 * stats.cdf_small[9]
    );
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let dir = args.get("artifacts").unwrap_or("artifacts");
    let m = Manifest::load(dir)?;
    println!("artifacts at {dir} (jax {}):", m.jax_version);
    for (name, (dims, batches)) in &m.variants {
        println!(
            "  variant {name}: F={} D={} H=({}, {}) mlp_in={} batches={batches:?}",
            dims.fields, dims.emb_dim, dims.hidden1, dims.hidden2, dims.mlp_in
        );
    }
    for a in &m.artifacts {
        println!(
            "  {} [{} b{}] <- {} ({} inputs)",
            a.file,
            a.variant,
            a.batch,
            a.function,
            a.inputs.len()
        );
    }
    Ok(())
}
