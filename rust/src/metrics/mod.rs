//! Training metrics: AUC (the paper's accuracy metric), QPS meters (the
//! efficiency metric), gradient-staleness statistics and drop counters
//! (Table 5.3), and tabular/JSON reporting.

pub mod report;

use crate::util::stats::Running;

/// Exact ROC-AUC with tie handling (average ranks). O(n log n).
///
/// Returns 0.5 for degenerate inputs (single class) — matches how the
/// paper reports a diverged model ("AUC decreases to 0.5", Fig. 2).
pub fn auc(scores: &[f32], labels: &[f32]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let n = scores.len();
    if n == 0 {
        return 0.5;
    }
    let mut idx: Vec<usize> = (0..n).collect();
    idx.sort_by(|&a, &b| scores[a].partial_cmp(&scores[b]).unwrap_or(std::cmp::Ordering::Equal));
    let (mut n_pos, mut n_neg) = (0u64, 0u64);
    for &l in labels {
        if l > 0.5 {
            n_pos += 1;
        } else {
            n_neg += 1;
        }
    }
    if n_pos == 0 || n_neg == 0 {
        return 0.5;
    }
    // Sum of positive ranks with average rank for ties.
    let mut rank_sum = 0.0f64;
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg_rank = (i + j) as f64 / 2.0 + 1.0;
        for &k in &idx[i..=j] {
            if labels[k] > 0.5 {
                rank_sum += avg_rank;
            }
        }
        i = j + 1;
    }
    (rank_sum - n_pos as f64 * (n_pos as f64 + 1.0) / 2.0) / (n_pos as f64 * n_neg as f64)
}

/// Sample-throughput series over (possibly virtual) time.
#[derive(Clone, Debug, Default)]
pub struct RateSeries {
    /// (time-seconds, samples-completed-at-that-instant)
    points: Vec<(f64, u64)>,
}

impl RateSeries {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, t_sec: f64, samples: u64) {
        self.points.push((t_sec, samples));
    }

    pub fn total_samples(&self) -> u64 {
        self.points.iter().map(|&(_, s)| s).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn duration(&self) -> f64 {
        if self.points.is_empty() {
            return 0.0;
        }
        let t0 = self.points.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let t1 = self.points.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
        (t1 - t0).max(0.0)
    }

    /// Mean QPS over the whole series.
    ///
    /// A series with fewer than two distinct instants has
    /// `duration() == 0`: a single point carries no rate information,
    /// so this deliberately reports 0.0 rather than dividing by zero
    /// (or inventing a time base). Real runs record one point per
    /// global step, so the edge only appears in truncated/quick runs —
    /// callers that must distinguish "no data" from "one instant" can
    /// check `is_empty()` / `total_samples()`.
    pub fn mean_qps(&self) -> f64 {
        let d = self.duration();
        if d <= 0.0 {
            return 0.0;
        }
        self.total_samples() as f64 / d
    }

    /// QPS per fixed window; returns (window-center-time, qps).
    pub fn windowed_qps(&self, width_sec: f64) -> Vec<(f64, f64)> {
        if self.points.is_empty() || width_sec <= 0.0 {
            return vec![];
        }
        let t0 = self.points.iter().map(|p| p.0).fold(f64::INFINITY, f64::min);
        let t1 = self.points.iter().map(|p| p.0).fold(f64::NEG_INFINITY, f64::max);
        let nw = (((t1 - t0) / width_sec).ceil() as usize).max(1);
        let mut sums = vec![0u64; nw];
        for &(t, s) in &self.points {
            let w = (((t - t0) / width_sec) as usize).min(nw - 1);
            sums[w] += s;
        }
        sums.iter()
            .enumerate()
            .map(|(w, &s)| (t0 + (w as f64 + 0.5) * width_sec, s as f64 / width_sec))
            .collect()
    }

    /// Mean ± std of windowed QPS (the "±" columns of Table 5.2).
    pub fn qps_mean_std(&self, width_sec: f64) -> (f64, f64) {
        let ws = self.windowed_qps(width_sec);
        if ws.is_empty() {
            return (0.0, 0.0);
        }
        let xs: Vec<f64> = ws.iter().map(|&(_, q)| q).collect();
        (crate::util::stats::mean(&xs), crate::util::stats::std(&xs))
    }
}

/// Gradient-staleness statistics (Table 5.3 columns).
#[derive(Clone, Debug, Default)]
pub struct StalenessStats {
    run: Running,
    max: u64,
}

impl StalenessStats {
    pub fn new() -> Self {
        Self::default()
    }
    pub fn record(&mut self, staleness: u64) {
        self.run.push(staleness as f64);
        self.max = self.max.max(staleness);
    }
    pub fn mean(&self) -> f64 {
        self.run.mean()
    }
    pub fn max(&self) -> u64 {
        self.max
    }
    pub fn count(&self) -> u64 {
        self.run.count()
    }
    pub fn merge(&mut self, other: &StalenessStats) {
        self.run.merge(&other.run);
        self.max = self.max.max(other.max);
    }
}

/// Counters a training run accumulates (owner lives on the PS).
#[derive(Clone, Debug, Default)]
pub struct TrainCounters {
    /// Batches whose gradients were discarded (Hop-BW drops, GBA decay).
    pub dropped_batches: u64,
    /// Batch indices re-issued after their claiming worker was reset
    /// (the claim died with the worker; the batch goes back on the data
    /// list so end-of-day coverage stays complete).
    pub reissued_batches: u64,
    /// Gradients applied to parameters.
    pub applied_gradients: u64,
    /// Global steps (aggregated updates).
    pub global_steps: u64,
    /// Samples trained (excluding drops).
    pub samples_trained: u64,
    pub dense_staleness: StalenessStats,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn auc_perfect_and_inverted() {
        let labels = [0.0, 0.0, 1.0, 1.0];
        assert_eq!(auc(&[0.1, 0.2, 0.8, 0.9], &labels), 1.0);
        assert_eq!(auc(&[0.9, 0.8, 0.2, 0.1], &labels), 0.0);
    }

    #[test]
    fn auc_random_is_half() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seeded(1);
        let n = 20_000;
        let scores: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
        let labels: Vec<f32> = (0..n).map(|_| if rng.bernoulli(0.3) { 1.0 } else { 0.0 }).collect();
        let a = auc(&scores, &labels);
        assert!((a - 0.5).abs() < 0.02, "auc={a}");
    }

    #[test]
    fn auc_ties_averaged() {
        // all scores equal -> AUC 0.5 exactly
        let a = auc(&[0.7; 6], &[1.0, 0.0, 1.0, 0.0, 1.0, 0.0]);
        assert!((a - 0.5).abs() < 1e-12);
    }

    #[test]
    fn auc_degenerate_single_class() {
        assert_eq!(auc(&[0.1, 0.9], &[1.0, 1.0]), 0.5);
        assert_eq!(auc(&[], &[]), 0.5);
    }

    #[test]
    fn auc_known_value() {
        // pos {0.8, 0.4}, neg {0.6, 0.2}: won pairs = 3 of 4
        let a = auc(&[0.8, 0.4, 0.6, 0.2], &[1.0, 1.0, 0.0, 0.0]);
        assert!((a - 0.75).abs() < 1e-12);
    }

    #[test]
    fn rate_series_windows() {
        let mut r = RateSeries::new();
        for i in 0..100 {
            r.record(i as f64 * 0.1, 50);
        }
        assert_eq!(r.total_samples(), 5000);
        let (mean, std) = r.qps_mean_std(1.0);
        assert!((mean - 500.0).abs() < 55.0, "mean={mean}");
        assert!(std < 200.0);
        assert!((r.mean_qps() - 5000.0 / 9.9).abs() < 1.0);
    }

    #[test]
    fn rate_series_degenerate_single_point() {
        // No points: no rate, and no window stats.
        let empty = RateSeries::new();
        assert!(empty.is_empty());
        assert_eq!(empty.mean_qps(), 0.0);
        assert_eq!(empty.qps_mean_std(1.0), (0.0, 0.0));
        // One instant: duration is 0, so the mean rate is pinned to the
        // documented 0.0 fallback (not a division by zero, not +inf) —
        // but the samples are still counted and windowed stats still
        // see the one window.
        let mut one = RateSeries::new();
        one.record(3.0, 500);
        assert_eq!(one.duration(), 0.0);
        assert_eq!(one.total_samples(), 500);
        assert_eq!(one.mean_qps(), 0.0, "single instant carries no rate information");
        assert!(one.mean_qps().is_finite());
        let (mean, _) = one.qps_mean_std(1.0);
        assert_eq!(mean, 500.0, "windowed stats treat the instant as one window");
        // Two coincident instants are still zero-duration.
        one.record(3.0, 100);
        assert_eq!(one.duration(), 0.0);
        assert_eq!(one.mean_qps(), 0.0);
    }

    #[test]
    fn staleness_stats() {
        let mut s = StalenessStats::new();
        for v in [0, 1, 2, 11] {
            s.record(v);
        }
        assert_eq!(s.max(), 11);
        assert!((s.mean() - 3.5).abs() < 1e-12);
        let mut t = StalenessStats::new();
        t.record(20);
        s.merge(&t);
        assert_eq!(s.max(), 20);
        assert_eq!(s.count(), 5);
    }
}
