//! Tabular + JSON experiment reporting. Every experiment driver prints a
//! table shaped like the paper's and writes the same rows to
//! `results/<experiment>.json` for downstream tooling.

use std::path::Path;

use anyhow::Result;

use crate::util::json::Json;

/// Simple aligned-column table.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::new();
            for i in 0..ncol {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<w$}", cells[i], w = widths[i]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncol - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.render());
    }

    pub fn to_json(&self) -> Json {
        let rows: Vec<Json> = self
            .rows
            .iter()
            .map(|r| {
                let mut obj = Json::obj();
                for (h, c) in self.headers.iter().zip(r) {
                    obj = obj.set(h, c.as_str());
                }
                obj
            })
            .collect();
        Json::obj().set("title", self.title.as_str()).set("rows", Json::Arr(rows))
    }
}

/// Write an experiment result document under `out_dir`.
pub fn write_result(out_dir: impl AsRef<Path>, name: &str, doc: &Json) -> Result<()> {
    let dir = out_dir.as_ref();
    std::fs::create_dir_all(dir)?;
    let path = dir.join(format!("{name}.json"));
    std::fs::write(&path, doc.to_string_pretty())?;
    Ok(())
}

/// Format an AUC the way the paper prints it (4 decimals).
pub fn fmt_auc(a: f64) -> String {
    format!("{a:.4}")
}

/// Format "mean(±std)" QPS in K-units like Table 5.2.
pub fn fmt_qps_k(mean: f64, std: f64) -> String {
    format!("{:.0}K(±{:.0}K)", mean / 1e3, std / 1e3)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("Demo", &["mode", "auc"]);
        t.row(vec!["sync".into(), "0.7864".into()]);
        t.row(vec!["gba".into(), "0.7866".into()]);
        let r = t.render();
        assert!(r.contains("== Demo =="));
        assert!(r.contains("sync  0.7864"));
        let j = t.to_json();
        assert_eq!(j.get("rows").unwrap().idx(1).unwrap().get("mode").unwrap().as_str(), Some("gba"));
    }

    #[test]
    #[should_panic]
    fn row_width_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn write_result_roundtrip() {
        let dir = std::env::temp_dir().join("gba_report_test");
        let doc = Json::obj().set("x", 1i64);
        write_result(&dir, "unit", &doc).unwrap();
        let text = std::fs::read_to_string(dir.join("unit.json")).unwrap();
        assert!(text.contains("\"x\""));
    }

    #[test]
    fn formatting_helpers() {
        assert_eq!(fmt_auc(0.78639), "0.7864");
        assert_eq!(fmt_qps_k(3_253_000.0, 84_000.0), "3253K(±84K)");
    }
}
