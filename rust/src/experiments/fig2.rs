//! Fig. 2 — the *sudden drop*: switching training mode naively (sync ↔
//! async) with either side's tuned hyper-parameter set degrades AUC,
//! motivating the tuning-free approach.
//!
//! Set 𝕊 = the sync-tuned pair (Adam, lr); set 𝔸 = the async-tuned pair
//! (Adagrad, lr_async). Training runs half the days in the source mode,
//! switches, and evaluates per day. GBA (same global batch, set 𝕊) is
//! included to show the contrast.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::{ExperimentConfig, ModeKind};
use crate::metrics::report::{fmt_auc, write_result, Table};
use crate::util::json::Json;
use crate::worker::session::{SessionOptions, TrainSession};

/// Force the async-family optimizer/lr to the sync set (emulates "switch
/// with set S").
fn with_set_s(cfg: &ExperimentConfig) -> ExperimentConfig {
    let mut c = cfg.clone();
    c.train.optimizer_async = c.train.optimizer;
    c.train.lr_async = c.train.lr;
    c
}

fn arm(
    cfg: &ExperimentConfig,
    from: ModeKind,
    to: Option<ModeKind>,
    days_each: usize,
) -> Result<(Vec<f64>, Vec<Json>)> {
    let mut s = TrainSession::new(cfg.clone(), from, SessionOptions::default())?;
    let mut aucs = Vec::new();
    for d in 0..days_each {
        s.train_day(d)?;
        aucs.push(s.eval_auc(d + 1)?);
    }
    if let Some(to) = to {
        // In-place switch: the session records the event on its own
        // SwitchTrace, which we emit so the figure can annotate the
        // switch point instead of hard-coding `days_each`.
        s.switch_mode(to)?;
    }
    for d in days_each..2 * days_each {
        s.train_day(d)?;
        aucs.push(s.eval_auc(d + 1)?);
    }
    let events = s
        .switch_trace()
        .events
        .iter()
        .map(|e| {
            Json::obj()
                .set("day", e.day)
                .set("from", e.from.as_str())
                .set("to", e.to.as_str())
                .set("signal", e.signal.map_or(Json::Null, Json::from))
        })
        .collect();
    Ok((aucs, events))
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    // Criteo: "few parameters, fast convergence" — the paper's Fig. 2 task.
    let mut cfg = common::load_task(ctx, "criteo")?;
    if ctx.quick {
        common::quicken(&mut cfg);
    } else {
        cfg.data.samples_per_day = cfg.data.samples_per_day.min(16384);
    }
    let days_each = if ctx.quick { 1 } else { 2 };

    let arms: Vec<(&str, (Vec<f64>, Vec<Json>))> = vec![
        ("sync (no switch)", arm(&cfg, ModeKind::Sync, None, days_each)?),
        ("sync -> async, set A", arm(&cfg, ModeKind::Sync, Some(ModeKind::Async), days_each)?),
        (
            "sync -> async, set S",
            arm(&with_set_s(&cfg), ModeKind::Sync, Some(ModeKind::Async), days_each)?,
        ),
        ("sync -> GBA (tuning-free)", arm(&cfg, ModeKind::Sync, Some(ModeKind::Gba), days_each)?),
        ("async -> sync, set A kept", arm(&cfg, ModeKind::Async, Some(ModeKind::Sync), days_each)?),
        ("GBA -> sync (tuning-free)", arm(&cfg, ModeKind::Gba, Some(ModeKind::Sync), days_each)?),
    ];

    let mut headers = vec!["arm".to_string()];
    for d in 0..2 * days_each {
        headers.push(format!("day{}", d + 1));
    }
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new("Fig. 2 — AUC around a mid-run mode switch (criteo task)", &hrefs);
    let mut jrows = Vec::new();
    for (name, (aucs, events)) in &arms {
        let mut row = vec![name.to_string()];
        row.extend(aucs.iter().map(|a| fmt_auc(*a)));
        table.row(row);
        jrows.push(
            Json::obj()
                .set("arm", *name)
                .set("auc", aucs.clone())
                .set("switch_trace", Json::Arr(events.clone())),
        );
    }
    table.print();

    // Shape checks: naive switches dip relative to the un-switched arm at
    // the first post-switch eval; the GBA switch does not.
    let base = arms[0].1 .0[days_each];
    let naive_a = arms[1].1 .0[days_each];
    let gba = arms[3].1 .0[days_each];
    println!(
        "\nfirst post-switch AUC: baseline {:.4}, sync->async(setA) {:.4} (drop {:+.4}), \
         sync->GBA {:.4} (drop {:+.4})",
        base,
        naive_a,
        naive_a - base,
        gba,
        gba - base
    );
    write_result(
        &ctx.out_dir,
        "fig2",
        &Json::obj()
            .set("days_each", days_each)
            .set("arms", Json::Arr(jrows))
            // All six arms run in-process, so the global registry is the
            // run-wide telemetry: per-RPC counters, batch-latency
            // quantiles, and the switch counters accumulated above.
            .set("telemetry", crate::obs::snapshot_to_json(&crate::obs::global().snapshot())),
    )?;
    Ok(())
}
