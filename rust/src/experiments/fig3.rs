//! Fig. 3 — distribution of gradient L2 norms vs the aggregated batch
//! size (Insight 1: the aggregated/global batch size determines the mean
//! and variance of the gradient distribution; BSP at the sync global batch
//! matches sync's distribution).

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::ModeKind;
use crate::metrics::report::{write_result, Table};
use crate::util::json::Json;
use crate::util::stats;
use crate::worker::session::{SessionOptions, TrainSession};

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut cfg = common::load_task(ctx, "private")?;
    cfg.data.samples_per_day = if ctx.quick { 16384 } else { 32768 };
    cfg.train.eval_samples = 1024; // eval unused here

    let sync_mode = cfg.mode(ModeKind::Sync);
    let g_sync = sync_mode.workers * sync_mode.local_batch;
    let b_local = cfg.mode(ModeKind::Bsp).local_batch;
    let target_norms = if ctx.quick { 24 } else { 96 };

    // BSP with aggregation counts giving aggregated batches around G_sync.
    let bsp_aggs: Vec<usize> =
        vec![(g_sync / b_local / 4).max(1), g_sync / b_local, (g_sync / b_local) * 4];

    let mut table = Table::new(
        "Fig. 3 — L2 norm of aggregated dense gradients vs aggregated batch size",
        &["config", "agg. batch", "mean ||g||", "std ||g||", "n"],
    );
    let mut jrows = Vec::new();

    let mut collect = |label: String, kind: ModeKind, agg_override: Option<usize>| -> Result<(f64, f64)> {
        let mut c = cfg.clone();
        if let Some(b2) = agg_override {
            for (k, m) in c.modes.iter_mut() {
                if *k == ModeKind::Bsp {
                    m.aggregate = b2;
                }
            }
        }
        let agg_batch = match kind {
            ModeKind::Sync => g_sync,
            ModeKind::Bsp => agg_override.unwrap() * b_local,
            _ => g_sync,
        };
        // Enough days to see ~target_norms applies.
        let applies_per_day = (c.data.samples_per_day / agg_batch).max(1);
        let days = (target_norms / applies_per_day).clamp(1, 24);
        c.data.days_base = days + 1;
        c.data.days_eval = 1;
        let s = TrainSession::new(c.clone(), kind, SessionOptions::default())?;
        s.ps().collect_grad_norms(true);
        let mut norms = Vec::new();
        for d in 0..days {
            s.train_day(d)?;
            norms.extend(s.ps().take_grad_norms());
        }
        let (m, sd) = (stats::mean(&norms), stats::std(&norms));
        table.row(vec![
            label.clone(),
            agg_batch.to_string(),
            format!("{m:.4}"),
            format!("{sd:.4}"),
            norms.len().to_string(),
        ]);
        jrows.push(
            Json::obj()
                .set("config", label)
                .set("agg_batch", agg_batch)
                .set("mean_norm", m)
                .set("std_norm", sd)
                .set("norms_head", norms.iter().take(200).cloned().collect::<Vec<f64>>()),
        );
        Ok((m, sd))
    };

    let (sync_mean, _) = collect(format!("Sync (G={g_sync})"), ModeKind::Sync, None)?;
    let mut bsp_at_g = (0.0, 0.0);
    for &b2 in &bsp_aggs {
        let r = collect(format!("BSP-{}", b2 * b_local), ModeKind::Bsp, Some(b2))?;
        if b2 * b_local == g_sync {
            bsp_at_g = r;
        }
    }
    table.print();
    println!(
        "\nBSP at the sync global batch: mean ||g|| = {:.4} vs sync {:.4} \
         (paper: distributions coincide when aggregation sizes match)",
        bsp_at_g.0, sync_mean
    );
    write_result(&ctx.out_dir, "fig3", &Json::obj().set("rows", Json::Arr(jrows)))?;
    Ok(())
}
