//! Convergence-analysis validation (§4.2, Eqns. 2 and 4; Appendix A/D).
//!
//! The theory is stated for strongly-convex SGD. We simulate exactly that
//! model — F(w) = (c/2)·||w||², stochastic gradients with per-sample
//! variance σ² and batch size B — under
//!
//! * synchronous aggregation (N fresh gradients per step),
//! * GBA aggregation (M gradients with a controlled staleness
//!   distribution and probability p0 of zero staleness),
//!
//! and compare measured error floors against the paper's bounds:
//! sync floor = ηLσ²/(2cN B); GBA floor = ηLσ²/(2cγ′MB), γ′ = 1−γ+p0/2.
//! Appendix D's "sudden drop" is reproduced by switching the update rule
//! mid-run with mismatched hyper-parameters.

use anyhow::Result;

use super::ExpCtx;
use crate::metrics::report::{write_result, Table};
use crate::util::json::Json;
use crate::util::rng::Pcg64;
use crate::util::stats;

const DIM: usize = 32;

struct Quad {
    c: f64,
    sigma: f64,
}

impl Quad {
    fn f(&self, w: &[f64]) -> f64 {
        0.5 * self.c * w.iter().map(|x| x * x).sum::<f64>()
    }

    /// Stochastic gradient at `w` with batch size B. σ is the *total*
    /// gradient-noise scale (E‖g−∇F‖² = σ²/B, as in the paper's
    /// Assumption 4), so each coordinate gets σ/√(B·DIM).
    fn grad(&self, w: &[f64], b: usize, rng: &mut Pcg64) -> Vec<f64> {
        let noise = self.sigma / ((b * DIM) as f64).sqrt();
        w.iter().map(|x| self.c * x + noise * rng.normal()).collect()
    }
}

/// Run `steps` aggregated updates; each update averages `m` gradients whose
/// parameter versions lag by samples from `staleness()` (0 = fresh).
/// Returns the trajectory of F(w_k).
fn run_mode(
    quad: &Quad,
    eta: f64,
    b: usize,
    m: usize,
    steps: usize,
    mut staleness: impl FnMut(&mut Pcg64) -> usize,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Pcg64::seeded(seed);
    let mut w = vec![1.0f64; DIM];
    let mut history: Vec<Vec<f64>> = vec![w.clone()];
    let mut traj = Vec::with_capacity(steps);
    for _ in 0..steps {
        let mut agg = vec![0.0f64; DIM];
        for _ in 0..m {
            let lag = staleness(&mut rng).min(history.len() - 1);
            let w_old = &history[history.len() - 1 - lag];
            let g = quad.grad(w_old, b, &mut rng);
            for (a, gi) in agg.iter_mut().zip(&g) {
                *a += gi / m as f64;
            }
        }
        for (wi, a) in w.iter_mut().zip(&agg) {
            *wi -= eta * a;
        }
        history.push(w.clone());
        if history.len() > 64 {
            history.remove(0);
        }
        traj.push(quad.f(&w));
    }
    traj
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let quad = Quad { c: 1.0, sigma: 4.0 };
    let (eta, b) = (0.05, 4usize);
    let steps = if ctx.quick { 2_000 } else { 10_000 };
    let tail = steps / 2;

    // L = c for the quadratic; error floor per Eqn. (2): ηLσ²/(2c·N·B).
    let floor = |gamma_prime: f64, m: usize| {
        eta * quad.c * quad.sigma * quad.sigma / (2.0 * quad.c * gamma_prime * (m * b) as f64)
    };

    let mut table = Table::new(
        "Convergence validation — measured error floor vs Eqn. (2)/(4)",
        &["mode", "M (=N)", "staleness", "measured floor", "theory bound", "measured <= bound"],
    );
    let mut jrows = Vec::new();

    let cases: Vec<(&str, usize, Box<dyn FnMut(&mut Pcg64) -> usize>, f64)> = vec![
        ("sync", 8, Box::new(|_: &mut Pcg64| 0usize), 1.0),
        // GBA: 60% fresh (p0 = 0.6), rest stale 1..=3, γ estimated small
        // for the quadratic; γ′ = 1 − γ + p0/2 with γ ≈ 0.2 here.
        ("gba (p0=0.6, stale<=3)", 8, Box::new(|r: &mut Pcg64| {
            if r.bernoulli(0.6) { 0 } else { 1 + r.gen_range(3) as usize }
        }), 1.0 - 0.2 + 0.3),
        ("async-ish (always stale)", 8, Box::new(|r: &mut Pcg64| 1 + r.gen_range(6) as usize),
         1.0 - 0.5),
    ];

    for (name, m, stale_fn, gamma_prime) in cases {
        let traj = run_mode(&quad, eta, b, m, steps, stale_fn, ctx.seed);
        let measured = stats::mean(&traj[tail..]);
        let bound = floor(gamma_prime, m);
        table.row(vec![
            name.to_string(),
            m.to_string(),
            "-".into(),
            format!("{measured:.5}"),
            format!("{bound:.5}"),
            (measured <= bound * 1.5).to_string(),
        ]);
        jrows.push(
            Json::obj()
                .set("mode", name)
                .set("measured_floor", measured)
                .set("theory_bound", bound)
                .set("gamma_prime", gamma_prime),
        );
    }

    // The tuning-free claim in miniature: same (η, global batch) for sync
    // M=8 and GBA M=8 must land on comparable floors, while halving the
    // aggregated batch (the "inconsistent global batch" of Fig. 8) doubles
    // the floor.
    let sync8 = stats::mean(&run_mode(&quad, eta, b, 8, steps, |_| 0, ctx.seed)[tail..]);
    let gba8 = stats::mean(
        &run_mode(&quad, eta, b, 8, steps, |r| if r.bernoulli(0.6) { 0 } else { 1 + r.gen_range(3) as usize }, ctx.seed ^ 1)[tail..],
    );
    let gba4 = stats::mean(
        &run_mode(&quad, eta, b, 4, steps, |r| if r.bernoulli(0.6) { 0 } else { 1 + r.gen_range(3) as usize }, ctx.seed ^ 2)[tail..],
    );
    println!(
        "\nfloors: sync(M=8)={sync8:.5}  gba(M=8)={gba8:.5}  gba(M=4)={gba4:.5} \
         -> same-global-batch ratio {:.2} (≈1), half-batch ratio {:.2} (≈2)",
        gba8 / sync8,
        gba4 / sync8
    );

    // Appendix D: switching with mismatched per-update magnitude (the
    // aggregated batch drops M=8 -> 1 with the same η) causes an error jump.
    let mut rng = Pcg64::seeded(ctx.seed ^ 9);
    let mut w = vec![1.0f64; DIM];
    let mut drop_traj = Vec::new();
    for k in 0..steps.min(4000) {
        let m = if k < steps.min(4000) / 2 { 8 } else { 1 };
        let mut agg = vec![0.0f64; DIM];
        for _ in 0..m {
            let g = quad.grad(&w, b, &mut rng);
            for (a, gi) in agg.iter_mut().zip(&g) {
                *a += gi / m as f64;
            }
        }
        for (wi, a) in w.iter_mut().zip(&agg) {
            *wi -= eta * a;
        }
        drop_traj.push(quad.f(&w));
    }
    let n4 = drop_traj.len();
    let before = stats::mean(&drop_traj[n4 / 2 - n4 / 8..n4 / 2]);
    let after = stats::mean(&drop_traj[n4 - n4 / 8..]);
    println!(
        "Appendix-D switch (M=8 -> 1, same η): floor {before:.5} -> {after:.5} \
         ({:.1}x jump — the 'sudden drop')",
        after / before
    );

    table.print();
    write_result(
        &ctx.out_dir,
        "convergence",
        &Json::obj()
            .set("cases", Json::Arr(jrows))
            .set("sync8_floor", sync8)
            .set("gba8_floor", gba8)
            .set("gba4_floor", gba4)
            .set("appendix_d_before", before)
            .set("appendix_d_after", after),
    )?;

    // Hard checks: the reproduction's claims.
    anyhow::ensure!(gba8 / sync8 < 1.6, "GBA floor should track sync at equal global batch");
    anyhow::ensure!(gba4 / sync8 > 1.4, "halved global batch must raise the floor");
    anyhow::ensure!(after / before > 2.0, "Appendix-D switch must jump the error");
    Ok(())
}
