//! Table 5.3 — fine-grained analysis on the Private task across cluster
//! periods: local QPS (Async vs GBA), AUC (Sync vs GBA), dropped batches
//! (Hop-BW vs GBA), and average (max) dense-gradient staleness
//! (Hop-BS vs GBA vs BSP).
//!
//! QPS / drops / staleness come from the discrete-event simulator at three
//! periods of the load trace (the paper repeats the experiment "during
//! different periods of a day"); AUC comes from real training with the
//! straggler model injected.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::ModeKind;
use crate::metrics::report::{fmt_auc, write_result, Table};
use crate::sim::simulate_mode;
use crate::util::json::Json;
use crate::worker::session::{SessionOptions, TrainSession};

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let cfg = common::load_task(ctx, "private")?;
    let periods: &[(&str, f64)] = &[("peak 15:00", 15.0), ("evening 20:00", 20.0), ("night 04:00", 4.0)];
    let dur = if ctx.quick { 60.0 } else { 180.0 };

    let mut table = Table::new(
        "Table 5.3 — fine-grained analysis (Private task)",
        &[
            "period",
            "localQPS Async.",
            "localQPS GBA",
            "AUC Sync.",
            "AUC GBA",
            "#drop Hop-BW",
            "#drop GBA",
            "stale Hop-BS",
            "stale GBA",
            "stale BSP",
        ],
    );
    let mut jrows = Vec::new();
    for &(label, hour) in periods {
        let start = hour * 3600.0;
        let sim = |kind: ModeKind| simulate_mode(&cfg, kind, start, dur, ctx.seed ^ hour as u64);
        let s_async = sim(ModeKind::Async);
        let s_gba = sim(ModeKind::Gba);
        let s_bw = sim(ModeKind::HopBw);
        let s_bs = sim(ModeKind::HopBs);
        let s_bsp = sim(ModeKind::Bsp);

        // AUC: real short training run with stragglers at this period.
        let mut c = cfg.clone();
        if ctx.quick {
            common::quicken(&mut c);
        } else {
            c.data.days_base = 2;
            c.data.days_eval = 1;
        }
        c.cluster.base_compute_ms = 0.5; // keep wall time sane
        let auc_of = |kind: ModeKind| -> Result<f64> {
            let opts = SessionOptions {
                straggler: true,
                start_sec: start,
                ..SessionOptions::default()
            };
            let s = TrainSession::new(c.clone(), kind, opts)?;
            for d in 0..c.data.days_base {
                s.train_day(d)?;
            }
            s.eval_auc(c.data.days_base)
        };
        let auc_sync = auc_of(ModeKind::Sync)?;
        let auc_gba = auc_of(ModeKind::Gba)?;

        let fmt_stale = |o: &crate::sim::SimOutcome| {
            format!("{:.2} ({})", o.staleness.mean(), o.staleness.max())
        };
        table.row(vec![
            label.to_string(),
            format!("{:.0}", s_async.local_qps_mean),
            format!("{:.0}", s_gba.local_qps_mean),
            fmt_auc(auc_sync),
            fmt_auc(auc_gba),
            s_bw.dropped_batches.to_string(),
            s_gba.dropped_batches.to_string(),
            fmt_stale(&s_bs),
            fmt_stale(&s_gba),
            fmt_stale(&s_bsp),
        ]);
        jrows.push(
            Json::obj()
                .set("period", label)
                .set("local_qps_async", s_async.local_qps_mean)
                .set("local_qps_gba", s_gba.local_qps_mean)
                .set("auc_sync", auc_sync)
                .set("auc_gba", auc_gba)
                .set("drops_hop_bw", s_bw.dropped_batches)
                .set("drops_gba", s_gba.dropped_batches)
                .set("stale_hop_bs_mean", s_bs.staleness.mean())
                .set("stale_hop_bs_max", s_bs.staleness.max())
                .set("stale_gba_mean", s_gba.staleness.mean())
                .set("stale_gba_max", s_gba.staleness.max())
                .set("stale_bsp_mean", s_bsp.staleness.mean())
                .set("stale_bsp_max", s_bsp.staleness.max()),
        );
    }
    table.print();
    println!(
        "\n(expect: GBA local QPS ~ Async.; GBA drops << Hop-BW; GBA staleness \
         between Hop-BS and BSP; AUC stable — the paper's Table 5.3 shape)"
    );
    write_result(&ctx.out_dir, "table53", &Json::obj().set("rows", Json::Arr(jrows)))?;
    Ok(())
}
