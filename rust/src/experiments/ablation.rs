//! Ablation (extension beyond the paper's Eqn. 1): staleness-decay
//! strategies and the tolerance ι. §4.1 notes "GBA could employ different
//! staleness decay strategies"; this driver compares them under an
//! artificially noisy cluster so staleness actually occurs.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::cluster::StragglerModel;
use crate::config::ModeKind;
use crate::coordinator::modes::GbaPolicy;
use crate::coordinator::DecayStrategy;
use crate::metrics::report::{write_result, Table};
use crate::sim::{simulate, SimParams};
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let cfg = common::load_task(ctx, "private")?;
    let mode = cfg.mode(ModeKind::Gba);
    let m = cfg.gba_m_effective();

    // Sim half: drops + staleness per strategy under peak-hour stragglers.
    let strategies: Vec<(String, DecayStrategy)> = vec![
        ("threshold ι=0".into(), DecayStrategy::Threshold { iota: 0 }),
        ("threshold ι=2".into(), DecayStrategy::Threshold { iota: 2 }),
        ("threshold ι=4 (paper)".into(), DecayStrategy::Threshold { iota: 4 }),
        ("threshold ι=16".into(), DecayStrategy::Threshold { iota: 16 }),
        ("linear ι=4".into(), DecayStrategy::Linear { iota: 4 }),
        ("exponential α=0.7".into(), DecayStrategy::Exponential { alpha: 0.7 }),
    ];
    let mut table = Table::new(
        "Ablation — GBA staleness-decay strategies (sim, peak hour)",
        &["strategy", "steps", "dropped", "kept stale mean", "kept stale max"],
    );
    let mut jrows = Vec::new();
    for (name, decay) in &strategies {
        let compute = StragglerModel::new(&cfg.cluster, mode.workers, ctx.seed);
        let params = SimParams {
            workers: mode.workers,
            local_batch: mode.local_batch,
            compute,
            ps_apply_ms: cfg.cluster.ps_apply_ms,
            n_shards: cfg.ps.n_shards,
            apply_threads: cfg.ps.apply_threads,
            wire_ms: SimParams::wire_ms_of(&cfg),
            start_sec: 15.0 * 3600.0,
            duration_sec: if ctx.quick { 60.0 } else { 180.0 },
            seed: ctx.seed,
        };
        let out = simulate(&params, Box::new(GbaPolicy::new(m, *decay)));
        table.row(vec![
            name.clone(),
            out.global_steps.to_string(),
            out.dropped_batches.to_string(),
            format!("{:.3}", out.staleness.mean()),
            out.staleness.max().to_string(),
        ]);
        jrows.push(
            Json::obj()
                .set("strategy", name.as_str())
                .set("steps", out.global_steps)
                .set("dropped", out.dropped_batches)
                .set("stale_mean", out.staleness.mean())
                .set("stale_max", out.staleness.max()),
        );
    }
    table.print();
    println!(
        "\n(threshold ι=0 drops every late gradient; exponential never drops \
         but down-weights — the paper's Eqn. 1 is the threshold row)"
    );
    write_result(&ctx.out_dir, "ablation_decay", &Json::obj().set("rows", Json::Arr(jrows)))?;
    Ok(())
}
