//! Ablation (extension beyond the paper's Eqn. 1): staleness-decay
//! strategies and the tolerance ι. §4.1 notes "GBA could employ different
//! staleness decay strategies"; this driver compares them under an
//! artificially noisy cluster so staleness actually occurs.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::cluster::StragglerModel;
use crate::config::ModeKind;
use crate::coordinator::modes::GbaPolicy;
use crate::coordinator::DecayStrategy;
use crate::metrics::report::{write_result, Table};
use crate::sim::{simulate, simulate_with_staleness, SimParams};
use crate::staleness::{make_staleness, StalenessConfig, StalenessPolicyKind};
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let cfg = common::load_task(ctx, "private")?;
    let mode = cfg.mode(ModeKind::Gba);
    let m = cfg.gba_m_effective();

    // Sim half: drops + staleness per strategy under peak-hour stragglers.
    let strategies: Vec<(String, DecayStrategy)> = vec![
        ("threshold ι=0".into(), DecayStrategy::Threshold { iota: 0 }),
        ("threshold ι=2".into(), DecayStrategy::Threshold { iota: 2 }),
        ("threshold ι=4 (paper)".into(), DecayStrategy::Threshold { iota: 4 }),
        ("threshold ι=16".into(), DecayStrategy::Threshold { iota: 16 }),
        ("linear ι=4".into(), DecayStrategy::Linear { iota: 4 }),
        ("exponential α=0.7".into(), DecayStrategy::Exponential { alpha: 0.7 }),
    ];
    let mut table = Table::new(
        "Ablation — GBA staleness-decay strategies (sim, peak hour)",
        &["strategy", "steps", "dropped", "kept stale mean", "kept stale max"],
    );
    let mut jrows = Vec::new();
    for (name, decay) in &strategies {
        let compute = StragglerModel::new(&cfg.cluster, mode.workers, ctx.seed);
        let params = SimParams {
            workers: mode.workers,
            local_batch: mode.local_batch,
            compute,
            ps_apply_ms: cfg.cluster.ps_apply_ms,
            n_shards: cfg.ps.n_shards,
            apply_threads: cfg.ps.apply_threads,
            wire_ms: SimParams::wire_ms_of(&cfg),
            start_sec: 15.0 * 3600.0,
            duration_sec: if ctx.quick { 60.0 } else { 180.0 },
            seed: ctx.seed,
        };
        let out = simulate(&params, Box::new(GbaPolicy::new(m, *decay)));
        table.row(vec![
            name.clone(),
            out.global_steps.to_string(),
            out.dropped_batches.to_string(),
            format!("{:.3}", out.staleness.mean()),
            out.staleness.max().to_string(),
        ]);
        jrows.push(
            Json::obj()
                .set("strategy", name.as_str())
                .set("steps", out.global_steps)
                .set("dropped", out.dropped_batches)
                .set("stale_mean", out.staleness.mean())
                .set("stale_max", out.staleness.max()),
        );
    }
    table.print();
    println!(
        "\n(threshold ι=0 drops every late gradient; exponential never drops \
         but down-weights — the paper's Eqn. 1 is the threshold row)"
    );

    // Staleness-policy sweep under a straggler storm: GBA's fixed decay
    // vs. gap_aware vs. abs through the `rust/src/staleness/` seam,
    // under the spike-trace storm of `examples/straggler_storm.rs`
    // (severe lognormal heterogeneity at the spike hour, so deep
    // staleness actually occurs). The sim has no loss surface;
    // gradient utilization (kept fraction) and the kept-staleness
    // distribution are the convergence proxies — see docs/STALENESS.md
    // for how to read them.
    let storm_cluster = crate::config::ClusterConfig {
        trace: "spike".into(),
        base_compute_ms: 8.0,
        hetero_sigma: 0.5,
        ps_apply_ms: 0.5,
        wire_ms: 0.0,
        workers: crate::config::WorkerPlane::InProc,
        worker_listen: String::new(),
    };
    let storm_workers = 16usize;
    let storm_batch = 256usize;
    let mut storm_table = Table::new(
        "Ablation — staleness policies under a straggler storm (sim, spike hour)",
        &["policy", "steps", "kept", "dropped", "kept_frac", "stale mean", "stale max"],
    );
    let mut storm_rows = Vec::new();
    for kind in StalenessPolicyKind::ALL {
        let scfg = StalenessConfig { policy: kind, ..StalenessConfig::default() };
        let compute = StragglerModel::new(&storm_cluster, storm_workers, ctx.seed);
        let params = SimParams {
            workers: storm_workers,
            local_batch: storm_batch,
            compute,
            ps_apply_ms: storm_cluster.ps_apply_ms,
            n_shards: cfg.ps.n_shards,
            apply_threads: cfg.ps.apply_threads,
            wire_ms: 0.0,
            // The spike trace peaks mid-day; simulate through the spike.
            start_sec: 12.0 * 3600.0,
            duration_sec: if ctx.quick { 60.0 } else { 120.0 },
            seed: ctx.seed,
        };
        let out = simulate_with_staleness(
            &params,
            Box::new(GbaPolicy::with_iota(storm_workers, 4)),
            make_staleness(&scfg),
        );
        let kept = out.staleness.count();
        let total = kept + out.dropped_batches;
        let kept_frac = if total > 0 { kept as f64 / total as f64 } else { 0.0 };
        storm_table.row(vec![
            kind.as_str().to_string(),
            out.global_steps.to_string(),
            kept.to_string(),
            out.dropped_batches.to_string(),
            format!("{kept_frac:.3}"),
            format!("{:.3}", out.staleness.mean()),
            out.staleness.max().to_string(),
        ]);
        storm_rows.push(
            Json::obj()
                .set("policy", kind.as_str())
                .set("steps", out.global_steps)
                .set("kept", kept)
                .set("dropped", out.dropped_batches)
                .set("kept_frac", kept_frac)
                .set("stale_mean", out.staleness.mean())
                .set("stale_max", out.staleness.max())
                .set("samples", out.samples_done),
        );
    }
    storm_table.print();
    println!(
        "\n(kept_frac is the convergence proxy: the fraction of pushed \
         gradients that survived the decay and actually moved the model)"
    );

    write_result(
        &ctx.out_dir,
        "ablation_decay",
        &Json::obj()
            .set("rows", Json::Arr(jrows))
            .set("storm_rows", Json::Arr(storm_rows)),
    )?;
    Ok(())
}
