//! Table 5.2 — global QPS (mean ± std) of the six training modes on the
//! three tasks, under the shared-cluster load trace.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::ModeKind;
use crate::metrics::report::{fmt_qps_k, write_result, Table};
use crate::sim::simulate_mode;
use crate::util::json::Json;
use crate::util::stats;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    // Sample several windows spread over the day (the paper's ± spread
    // comes from the varying cluster state).
    let windows: Vec<f64> = if ctx.quick {
        vec![4.0, 15.0]
    } else {
        vec![2.0, 6.0, 10.0, 13.0, 15.0, 18.0, 21.0]
    };
    let dur = if ctx.quick { 60.0 } else { 120.0 };

    let mut table = Table::new(
        "Table 5.2 — global QPS of the compared training modes",
        &["task", "Sync.", "Async.", "Hop-BS", "BSP", "Hop-BW", "GBA"],
    );
    let mut doc = Json::obj();
    for (short, cfg) in common::load_all_tasks(ctx)? {
        let mut cells = vec![short.to_string()];
        let mut jtask = Json::obj();
        for kind in ModeKind::ALL {
            if !cfg.has_mode(kind) {
                cells.push("-".into());
                continue;
            }
            let qps: Vec<f64> = windows
                .iter()
                .map(|&h| {
                    simulate_mode(&cfg, kind, h * 3600.0, dur, ctx.seed ^ (h as u64)).global_qps()
                })
                .collect();
            let (m, s) = (stats::mean(&qps), stats::std(&qps));
            cells.push(fmt_qps_k(m, s));
            jtask = jtask.set(
                kind.as_str(),
                Json::obj().set("mean_qps", m).set("std_qps", s).set("windows", qps.clone()),
            );
        }
        table.row(cells);
        doc = doc.set(short, jtask);
    }
    table.print();

    // Paper's headline: GBA ~= Async >> Sync; Hop-BS struggles with slow
    // workers; Hop-BW in between.
    println!("\n(expect: GBA within a few % of Async.; Sync slowest; Hop-BS < BSP)");
    write_result(&ctx.out_dir, "table52", &doc.set("table", table.to_json()))?;
    Ok(())
}
