//! Fig. 1 — normalized QPS of four training modes across a day of shared-
//! cluster load (CPU utilization), on the YouTubeDNN-like task.
//!
//! Modes: Sync (AR), Async (PS), GBA, and a local-all-reduce baseline
//! (SwarmAdam/Prague-like), modelled as `g` independent synchronous islands
//! of N/g workers whose throughputs add — the throughput-side behaviour of
//! decentralized local AR (its accuracy problems are why the paper rejects
//! it; see §2).

use anyhow::Result;

use super::{common, ExpCtx};
use crate::cluster::{LoadTrace, StragglerModel};
use crate::config::ModeKind;
use crate::coordinator::modes::{make_policy, SyncPolicy};
use crate::metrics::report::{write_result, Table};
use crate::sim::{simulate, SimParams};
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let cfg = common::load_task(ctx, "private")?;
    let hours: Vec<f64> =
        if ctx.quick { vec![4.0, 10.0, 15.0, 22.0] } else { (0..24).map(|h| h as f64).collect() };
    let window = if ctx.quick { 60.0 } else { 180.0 };

    let trace = LoadTrace::from_name(&cfg.cluster.trace);
    let mut rows: Vec<(f64, f64, f64, f64, f64, f64)> = Vec::new(); // h, util, sync, async, local_ar, gba
    for &h in &hours {
        let start = h * 3600.0;
        let util = trace.utilization(start);
        let mut qps = std::collections::BTreeMap::new();
        for kind in [ModeKind::Sync, ModeKind::Async, ModeKind::Gba] {
            let mode = cfg.mode(kind);
            let compute = StragglerModel::new(&cfg.cluster, mode.workers, ctx.seed);
            let params = SimParams {
                workers: mode.workers,
                local_batch: mode.local_batch,
                compute,
                ps_apply_ms: cfg.cluster.ps_apply_ms,
                n_shards: cfg.ps.n_shards,
                apply_threads: cfg.ps.apply_threads,
                wire_ms: SimParams::wire_ms_of(&cfg),
                start_sec: start,
                duration_sec: window,
                seed: ctx.seed ^ (h as u64),
            };
            let out = simulate(&params, make_policy(kind, &mode, cfg.gba_m()));
            qps.insert(kind, out.global_qps());
        }
        // local all-reduce: 4 sync islands, throughputs add.
        let sync_mode = cfg.mode(ModeKind::Sync);
        let groups = 4usize;
        let per_group = (sync_mode.workers / groups).max(1);
        let mut local_ar = 0.0;
        for g in 0..groups {
            let compute = StragglerModel::new(&cfg.cluster, per_group, ctx.seed ^ (g as u64) << 3);
            let params = SimParams {
                workers: per_group,
                local_batch: sync_mode.local_batch,
                compute,
                ps_apply_ms: cfg.cluster.ps_apply_ms,
                n_shards: cfg.ps.n_shards,
                apply_threads: cfg.ps.apply_threads,
                wire_ms: SimParams::wire_ms_of(&cfg),
                start_sec: start,
                duration_sec: window,
                seed: ctx.seed ^ (h as u64) ^ (g as u64) << 8,
            };
            local_ar += simulate(&params, Box::new(SyncPolicy::new(per_group))).global_qps();
        }
        rows.push((
            h,
            util,
            qps[&ModeKind::Sync],
            qps[&ModeKind::Async],
            local_ar,
            qps[&ModeKind::Gba],
        ));
    }

    // Normalize each mode by its own max (as the paper does).
    let maxes = rows.iter().fold([0.0f64; 4], |m, r| {
        [m[0].max(r.2), m[1].max(r.3), m[2].max(r.4), m[3].max(r.5)]
    });
    let mut table = Table::new(
        "Fig. 1 — normalized QPS over a day (YouTubeDNN task, shared cluster)",
        &["hour", "cpu util", "Sync.", "Async.", "LocalAR", "GBA"],
    );
    let mut series = Vec::new();
    for (h, util, s, a, l, g) in &rows {
        table.row(vec![
            format!("{h:02.0}:00"),
            format!("{:.2}", util),
            format!("{:.2}", s / maxes[0]),
            format!("{:.2}", a / maxes[1]),
            format!("{:.2}", l / maxes[2]),
            format!("{:.2}", g / maxes[3]),
        ]);
        series.push(
            Json::obj()
                .set("hour", *h)
                .set("util", *util)
                .set("sync_qps", *s)
                .set("async_qps", *a)
                .set("local_ar_qps", *l)
                .set("gba_qps", *g),
        );
    }
    table.print();

    // Headline checks (paper's Observation 1): at peak load async/GBA
    // sustain much higher QPS than sync; when vacant they are comparable.
    let peak = rows.iter().max_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    let vacant = rows.iter().min_by(|a, b| a.1.total_cmp(&b.1)).unwrap();
    println!(
        "\npeak-load async/sync = {:.2}x, gba/sync = {:.2}x; vacant async/sync = {:.2}x",
        peak.3 / peak.2,
        peak.5 / peak.2,
        vacant.3 / vacant.2
    );

    write_result(
        &ctx.out_dir,
        "fig1",
        &Json::obj().set("series", Json::Arr(series)).set("table", table.to_json()),
    )?;
    Ok(())
}
