//! Shared helpers for experiment drivers: task-config loading (with
//! embedded fallbacks so drivers run from any cwd) and quick-mode scaling.

use anyhow::Result;

use super::ExpCtx;
use crate::config::ExperimentConfig;

/// The three tasks of Table 5.1.
pub const TASKS: &[(&str, &str)] = &[
    ("criteo", "criteo_deepfm.toml"),
    ("alimama", "alimama_dien.toml"),
    ("private", "private_youtubednn.toml"),
];

const EMBEDDED: &[(&str, &str)] = &[
    ("criteo", include_str!("../../../configs/criteo_deepfm.toml")),
    ("alimama", include_str!("../../../configs/alimama_dien.toml")),
    ("private", include_str!("../../../configs/private_youtubednn.toml")),
];

/// Load a task config by short name, preferring `<configs_dir>/<file>`,
/// falling back to the embedded copy.
pub fn load_task(ctx: &ExpCtx, short: &str) -> Result<ExperimentConfig> {
    let file = TASKS
        .iter()
        .find(|(s, _)| *s == short)
        .map(|(_, f)| *f)
        .ok_or_else(|| anyhow::anyhow!("unknown task '{short}'"))?;
    let path = ctx.configs_dir.join(file);
    let mut cfg = if path.exists() {
        ExperimentConfig::load(&path)?
    } else {
        let text = EMBEDDED.iter().find(|(s, _)| *s == short).unwrap().1;
        ExperimentConfig::from_toml(text)?
    };
    if ctx.quick {
        quicken(&mut cfg);
    }
    Ok(cfg)
}

/// Shrink a config for smoke runs: fewer days, fewer samples. Preserves
/// the global-batch invariants (batch sizes and worker counts untouched).
pub fn quicken(cfg: &mut ExperimentConfig) {
    cfg.data.days_base = cfg.data.days_base.min(2);
    cfg.data.days_eval = cfg.data.days_eval.min(2);
    cfg.data.samples_per_day = cfg.data.samples_per_day.min(8192);
    cfg.train.eval_samples = cfg.train.eval_samples.min(4096);
}

/// All three tasks (order of Table 5.1).
pub fn load_all_tasks(ctx: &ExpCtx) -> Result<Vec<(&'static str, ExperimentConfig)>> {
    TASKS.iter().map(|(s, _)| Ok((*s, load_task(ctx, s)?))).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embedded_configs_parse_and_validate() {
        let ctx = ExpCtx { configs_dir: "/nonexistent".into(), ..ExpCtx::default() };
        for (short, _) in TASKS {
            let cfg = load_task(&ctx, short).unwrap();
            assert!(cfg.gba_m() >= 2, "{short}: M = {}", cfg.gba_m());
        }
    }

    #[test]
    fn quick_mode_shrinks() {
        let ctx = ExpCtx { configs_dir: "/nonexistent".into(), quick: true, ..ExpCtx::default() };
        let cfg = load_task(&ctx, "criteo").unwrap();
        assert!(cfg.data.samples_per_day <= 8192);
        assert!(cfg.data.days_base <= 2);
    }
}
