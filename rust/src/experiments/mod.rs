//! Experiment drivers — one per table/figure of the paper's evaluation
//! (see DESIGN.md §3 for the experiment index). Every driver prints a
//! paper-shaped table and writes `results/<id>.json`.

pub mod ablation;
pub mod common;
pub mod convergence;
pub mod fig1;
pub mod fig2;
pub mod fig3;
pub mod fig4;
pub mod fig6;
pub mod fig7;
pub mod fig8;
pub mod table52;
pub mod table53;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::worker::BackendKind;

/// Shared driver context.
#[derive(Clone)]
pub struct ExpCtx {
    pub out_dir: PathBuf,
    pub configs_dir: PathBuf,
    pub backend: BackendKind,
    /// Reduced days/samples for smoke runs.
    pub quick: bool,
    pub seed: u64,
}

impl Default for ExpCtx {
    fn default() -> Self {
        ExpCtx {
            out_dir: PathBuf::from("results"),
            configs_dir: PathBuf::from("configs"),
            backend: BackendKind::Native,
            quick: false,
            seed: 7,
        }
    }
}

/// All experiment ids, in suggested execution order (cheap sims first).
pub const ALL: &[&str] = &[
    "fig4", "fig1", "table52", "fig7", "table53", "convergence", "fig3", "fig2", "fig8",
    "ablation_decay", "fig6",
];

/// Run one experiment (or "all").
pub fn run(name: &str, ctx: &ExpCtx) -> Result<()> {
    match name {
        "all" => {
            for n in ALL {
                println!("\n################ experiment {n} ################");
                run(n, ctx)?;
            }
            Ok(())
        }
        "fig1" => fig1::run(ctx),
        "fig2" => fig2::run(ctx),
        "fig3" => fig3::run(ctx),
        "fig4" => fig4::run(ctx),
        "fig6" => fig6::run(ctx),
        "fig7" => fig7::run(ctx),
        "fig8" => fig8::run(ctx),
        "table52" => table52::run(ctx),
        "table53" => table53::run(ctx),
        "convergence" => convergence::run(ctx),
        "ablation_decay" => ablation::run(ctx),
        other => bail!("unknown experiment '{other}' (one of {ALL:?} or 'all')"),
    }
}
