//! Fig. 6 / Tables 6.1–6.8 — the paper's headline experiment.
//!
//! For each task:
//! * (a–c): train a base model synchronously over the base days, then
//!   switch to every compared mode and continue the continual protocol
//!   (train day d, evaluate day d+1) over the eval days (Tables 6.1–6.3).
//! * (d–f): train a base model in every compared mode, then switch each to
//!   synchronous training for the eval days (Tables 6.5–6.7).
//! * (g–h): the per-day AUC deltas between GBA and the other modes
//!   (Tables 6.4 and 6.8).

use std::collections::BTreeMap;

use anyhow::Result;

use super::{common, ExpCtx};
use crate::checkpoint::Checkpoint;
use crate::config::{ExperimentConfig, ModeKind};
use crate::metrics::report::{fmt_auc, write_result, Table};
use crate::util::json::Json;
use crate::worker::session::{SessionOptions, TrainSession};

/// Mode order as the paper's tables print it.
const COLS: [ModeKind; 6] =
    [ModeKind::Sync, ModeKind::Gba, ModeKind::HopBw, ModeKind::HopBs, ModeKind::Bsp, ModeKind::Async];

fn train_base(cfg: &ExperimentConfig, kind: ModeKind) -> Result<Checkpoint> {
    let s = TrainSession::new(cfg.clone(), kind, SessionOptions::default())?;
    for d in 0..cfg.data.days_base {
        s.train_day(d)?;
    }
    Ok(s.checkpoint())
}

/// Continue in `kind` from `ckpt` over the eval days; per-day AUCs.
fn eval_arm(cfg: &ExperimentConfig, kind: ModeKind, ckpt: &Checkpoint) -> Result<Vec<f64>> {
    let s = TrainSession::from_checkpoint(cfg.clone(), kind, SessionOptions::default(), ckpt)?;
    let mut aucs = Vec::new();
    let d0 = cfg.data.days_base;
    for d in d0..d0 + cfg.data.days_eval {
        s.train_day(d)?;
        aucs.push(s.eval_auc(d + 1)?);
    }
    Ok(aucs)
}

fn print_task_table(
    title: &str,
    days0: usize,
    per_mode: &BTreeMap<ModeKind, Vec<f64>>,
) -> (Table, Json) {
    let mut headers = vec!["Day".to_string()];
    headers.extend(COLS.iter().map(|k| k.paper_name().to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &hrefs);
    let n_days = per_mode.values().next().map(|v| v.len()).unwrap_or(0);
    for i in 0..n_days {
        let mut row = vec![format!("{}", days0 + i + 1)];
        for k in COLS {
            row.push(per_mode.get(&k).map(|v| fmt_auc(v[i])).unwrap_or_else(|| "-".into()));
        }
        table.row(row);
    }
    // Average row.
    let mut avg_row = vec!["Avg.".to_string()];
    let mut javg = Json::obj();
    for k in COLS {
        if let Some(v) = per_mode.get(&k) {
            let avg = v.iter().sum::<f64>() / v.len() as f64;
            avg_row.push(fmt_auc(avg));
            javg = javg.set(k.as_str(), avg);
        } else {
            avg_row.push("-".into());
        }
    }
    table.row(avg_row);
    table.print();
    println!();
    let mut jmode = Json::obj();
    for (k, v) in per_mode {
        jmode = jmode.set(k.as_str(), v.clone());
    }
    (table, Json::obj().set("per_day", jmode).set("avg", javg))
}

/// Table 6.4 / 6.8 shape: GBA-minus-mode deltas on first/last/avg day.
fn delta_table(title: &str, all: &BTreeMap<&str, BTreeMap<ModeKind, Vec<f64>>>) -> (Table, Json) {
    let mut headers = vec!["".to_string()];
    headers.extend(COLS.iter().filter(|k| **k != ModeKind::Gba).map(|k| k.paper_name().to_string()));
    let hrefs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(title, &hrefs);
    let mut jd = Json::obj();
    for (label, pick) in [("1st day", 0usize), ("last day", usize::MAX), ("Average", usize::MAX - 1)]
    {
        let mut row = vec![label.to_string()];
        let mut jrow = Json::obj();
        for k in COLS.iter().filter(|k| **k != ModeKind::Gba) {
            // mean over tasks of (mode AUC - GBA AUC) at the chosen day
            let mut deltas = Vec::new();
            for per_mode in all.values() {
                let (Some(gba), Some(other)) = (per_mode.get(&ModeKind::Gba), per_mode.get(k))
                else {
                    continue;
                };
                let idx = |v: &Vec<f64>| match pick {
                    0 => v[0],
                    usize::MAX => *v.last().unwrap(),
                    _ => v.iter().sum::<f64>() / v.len() as f64,
                };
                deltas.push(idx(other) - idx(gba));
            }
            let d = deltas.iter().sum::<f64>() / deltas.len().max(1) as f64;
            row.push(format!("{d:+.4}"));
            jrow = jrow.set(k.as_str(), d);
        }
        table.row(row);
        jd = jd.set(label, jrow);
    }
    table.print();
    println!();
    (table, jd)
}

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut doc = Json::obj();
    let mut from_sync_all: BTreeMap<&str, BTreeMap<ModeKind, Vec<f64>>> = BTreeMap::new();
    let mut to_sync_all: BTreeMap<&str, BTreeMap<ModeKind, Vec<f64>>> = BTreeMap::new();

    for (short, cfg) in common::load_all_tasks(ctx)? {
        // ---- (a-c): base trained sync, switch to each mode -------------
        let base_sync = train_base(&cfg, ModeKind::Sync)?;
        let mut from_sync: BTreeMap<ModeKind, Vec<f64>> = BTreeMap::new();
        for kind in COLS {
            if !cfg.has_mode(kind) {
                continue;
            }
            from_sync.insert(kind, eval_arm(&cfg, kind, &base_sync)?);
        }
        let (_t, j) = print_task_table(
            &format!("Table 6.x — {short}: inherit sync base, switch to mode"),
            cfg.data.days_base,
            &from_sync,
        );
        doc = doc.set(&format!("{short}_from_sync"), j);
        from_sync_all.insert(short, from_sync);

        // ---- (d-f): base trained in each mode, switch to sync ----------
        let mut to_sync: BTreeMap<ModeKind, Vec<f64>> = BTreeMap::new();
        for kind in COLS {
            if !cfg.has_mode(kind) {
                continue;
            }
            let base = if kind == ModeKind::Sync {
                base_sync.clone()
            } else {
                train_base(&cfg, kind)?
            };
            to_sync.insert(kind, eval_arm(&cfg, ModeKind::Sync, &base)?);
        }
        let (_t, j) = print_task_table(
            &format!("Table 6.x — {short}: base trained per mode, switch to sync"),
            cfg.data.days_base,
            &to_sync,
        );
        doc = doc.set(&format!("{short}_to_sync"), j);
        to_sync_all.insert(short, to_sync);
    }

    let (_t, j) = delta_table(
        "Table 6.4 — avg AUC delta vs GBA across tasks (from sync)",
        &from_sync_all,
    );
    doc = doc.set("table64_deltas_from_sync", j);
    let (_t, j) =
        delta_table("Table 6.8 — avg AUC delta vs GBA across tasks (to sync)", &to_sync_all);
    doc = doc.set("table68_deltas_to_sync", j);

    write_result(&ctx.out_dir, "fig6", &doc)?;
    Ok(())
}
