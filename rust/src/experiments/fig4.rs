//! Fig. 4 — the skewed distribution of ID occurrences across batches,
//! which underlies Insight 2 (embedding parameters see far fewer updates
//! than dense parameters, hence tolerate staleness better).

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::ModeKind;
use crate::data::{stats::id_occurrence_stats, DataGen};
use crate::metrics::report::{write_result, Table};
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut table = Table::new(
        "Fig. 4 — ID occurrences across batches (per task)",
        &["task", "batches", "distinct IDs", "top-1 ID in % of batches", "IDs in <=10 batches", "mean update ratio vs dense"],
    );
    let mut doc = Json::obj();
    for (short, cfg) in common::load_all_tasks(ctx)? {
        let gen = DataGen::new(&cfg.model, &cfg.data, cfg.seed);
        let bsz = cfg.mode(ModeKind::Gba).local_batch;
        let n_batches = gen.batches_per_day(bsz).min(if ctx.quick { 32 } else { 128 });
        let stats = id_occurrence_stats(&gen, 0, bsz, n_batches);
        table.row(vec![
            short.to_string(),
            n_batches.to_string(),
            stats.distinct_ids.to_string(),
            format!("{:.1}%", 100.0 * stats.batches_per_id[0] as f64 / n_batches as f64),
            format!("{:.1}%", 100.0 * stats.cdf_small[9]),
            format!("{:.4}", stats.mean_update_ratio),
        ]);
        // Head of the occurrence curve for plotting (rank vs batch count).
        let head: Vec<Json> = stats
            .batches_per_id
            .iter()
            .take(200)
            .map(|&c| Json::from(c as u64))
            .collect();
        doc = doc.set(
            short,
            Json::obj()
                .set("n_batches", n_batches)
                .set("distinct_ids", stats.distinct_ids)
                .set("cdf_le_k", stats.cdf_small.clone())
                .set("mean_update_ratio", stats.mean_update_ratio)
                .set("occurrences_head", Json::Arr(head)),
        );
    }
    table.print();
    write_result(&ctx.out_dir, "fig4", &doc.set("table", table.to_json()))?;
    Ok(())
}
