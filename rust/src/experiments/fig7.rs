//! Fig. 7 — GBA scale-out: vary the number of workers with the global
//! batch size fixed (local batch co-varies). The paper reports a steady
//! AUC (absolute difference < 1e-4 between worker counts... we report the
//! spread) and a near-linear QPS boost.
//!
//! Two halves:
//! * QPS at paper scale (100–800 workers) on the discrete-event simulator.
//! * AUC at proportionally scaled-down worker counts with *real* training
//!   (native backend), global batch held exactly constant.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::cluster::StragglerModel;
use crate::config::ModeKind;
use crate::coordinator::modes::GbaPolicy;
use crate::metrics::report::{fmt_auc, write_result, Table};
use crate::sim::{simulate, SimParams};
use crate::util::json::Json;
use crate::worker::session::{SessionOptions, TrainSession};

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let cfg = common::load_task(ctx, "private")?;

    // ---- QPS half: paper-scale worker counts on the simulator ----------
    let paper_workers = [100usize, 200, 400, 800];
    let paper_global_batch = 400 * 1000; // paper: 400 workers x 1K local
    let mut qps_table = Table::new(
        "Fig. 7 (QPS) — GBA scale-out at fixed global batch (sim, paper scale)",
        &["workers", "local batch", "global QPS", "steps/s"],
    );
    let mut jqps = Vec::new();
    for &n in &paper_workers {
        let local = paper_global_batch / n;
        let m = n; // N_a = M (paper's §4.1 choice)
        let compute = StragglerModel::new(&cfg.cluster, n, ctx.seed);
        let params = SimParams {
            workers: n,
            local_batch: local,
            compute,
            ps_apply_ms: cfg.cluster.ps_apply_ms,
            n_shards: cfg.ps.n_shards,
            apply_threads: cfg.ps.apply_threads,
            wire_ms: SimParams::wire_ms_of(&cfg),
            start_sec: 10.0 * 3600.0,
            duration_sec: if ctx.quick { 30.0 } else { 120.0 },
            seed: ctx.seed ^ n as u64,
        };
        let out = simulate(&params, Box::new(GbaPolicy::with_iota(m, 4)));
        qps_table.row(vec![
            n.to_string(),
            local.to_string(),
            format!("{:.0}", out.global_qps()),
            format!("{:.2}", out.global_steps as f64 / params.duration_sec),
        ]);
        jqps.push(
            Json::obj()
                .set("workers", n)
                .set("local_batch", local)
                .set("qps", out.global_qps())
                .set("steps", out.global_steps),
        );
    }
    qps_table.print();

    // ---- AUC half: real training, G fixed, workers scaled --------------
    // Inherit a common sync-trained base (the paper's protocol), then run
    // GBA with different worker counts at the *same* global batch.
    let mut c0 = cfg.clone();
    if ctx.quick {
        common::quicken(&mut c0);
    } else {
        c0.data.days_base = c0.data.days_base.min(3);
        c0.data.days_eval = c0.data.days_eval.min(2);
    }
    let base_session = TrainSession::new(c0.clone(), ModeKind::Sync, SessionOptions::default())?;
    for d in 0..c0.data.days_base {
        base_session.train_day(d)?;
    }
    let ckpt = base_session.checkpoint();

    let sync = c0.mode(ModeKind::Sync);
    let g = sync.workers * sync.local_batch;
    let worker_counts: &[usize] = if ctx.quick { &[8, 16] } else { &[4, 8, 16, 32] };
    let mut auc_table = Table::new(
        "Fig. 7 (AUC) — real GBA training from a common base, global batch fixed",
        &["workers", "local batch", "M", "AUC avg", "wall sec/day"],
    );
    let mut jauc = Vec::new();
    let mut aucs = Vec::new();
    for &n in worker_counts {
        let local = g / n;
        let mut c = c0.clone();
        // Patch the GBA mode entry: workers n, local batch G/n.
        for (kind, mode) in c.modes.iter_mut() {
            if *kind == ModeKind::Gba {
                mode.workers = n;
                mode.local_batch = local;
                mode.m_override = None;
            }
        }
        c.validate()?;
        let s = TrainSession::from_checkpoint(c.clone(), ModeKind::Gba, SessionOptions::default(), &ckpt)?;
        let mut day_aucs = Vec::new();
        let mut wall = 0.0;
        for d in c0.data.days_base..c0.data.days_base + c0.data.days_eval {
            let stats = s.train_day(d)?;
            wall += stats.wall_sec;
            day_aucs.push(s.eval_auc(d + 1)?);
        }
        let auc = day_aucs.iter().sum::<f64>() / day_aucs.len() as f64;
        aucs.push(auc);
        auc_table.row(vec![
            n.to_string(),
            local.to_string(),
            c.gba_m().to_string(),
            fmt_auc(auc),
            format!("{:.2}", wall / c0.data.days_eval as f64),
        ]);
        jauc.push(
            Json::obj()
                .set("workers", n)
                .set("local_batch", local)
                .set("auc", auc)
                .set("auc_per_day", day_aucs.clone()),
        );
    }
    auc_table.print();
    let spread = aucs.iter().cloned().fold(f64::NEG_INFINITY, f64::max)
        - aucs.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\nAUC spread across worker counts: {spread:.5} (paper: < 1e-4 steady state)");

    // ---- PS shard scale-out: real training, sharded parameter plane ----
    // Same GBA day from the common base on n_shards ∈ {1, 2, 4, 8}; the
    // control plane makes results shard-invariant, so this sweep reports
    // the *systems* axis: throughput plus per-shard load and dense-lock
    // contention.
    let shard_counts: &[usize] = if ctx.quick { &[1, 4] } else { &[1, 2, 4, 8] };
    let mut shard_table = Table::new(
        "Fig. 7 (shards) — GBA on a sharded PS plane (real training)",
        &["shards", "QPS", "steps", "max/mean shard keys", "pull stall (ms)"],
    );
    let mut jshard = Vec::new();
    for &n_shards in shard_counts {
        let mut c = c0.clone();
        c.ps.n_shards = n_shards;
        let s = TrainSession::from_checkpoint(c, ModeKind::Gba, SessionOptions::default(), &ckpt)?;
        let stats = s.train_day(c0.data.days_base)?;
        let shards = s.ps().shard_stats();
        let keys: Vec<u64> = shards.iter().map(|x| x.emb_keys_applied).collect();
        let mean_keys = keys.iter().sum::<u64>() as f64 / keys.len() as f64;
        let max_keys = keys.iter().copied().max().unwrap_or(0) as f64;
        let imbalance = if mean_keys > 0.0 { max_keys / mean_keys } else { 1.0 };
        // Contention metric: time parameter pulls spent stalled behind
        // applies. Shards shrink the apply critical section, so this
        // should fall as n_shards grows.
        let pull_stall_ms = s.ps().pull_stall_ns() as f64 / 1e6;
        let apply_ms_max = shards
            .iter()
            .map(|x| x.apply_ns as f64 / 1e6)
            .fold(0.0f64, f64::max);
        shard_table.row(vec![
            n_shards.to_string(),
            format!("{:.0}", stats.qps),
            stats.counters.global_steps.to_string(),
            format!("{imbalance:.2}x"),
            format!("{pull_stall_ms:.2}"),
        ]);
        jshard.push(
            Json::obj()
                .set("n_shards", n_shards)
                .set("qps", stats.qps)
                .set("steps", stats.counters.global_steps)
                .set("emb_key_imbalance", imbalance)
                .set("pull_stall_ms", pull_stall_ms)
                .set("apply_ms_slowest_shard", apply_ms_max),
        );
    }
    shard_table.print();

    write_result(
        &ctx.out_dir,
        "fig7",
        &Json::obj()
            .set("qps_scaleout", Json::Arr(jqps))
            .set("auc_fixed_global_batch", Json::Arr(jauc))
            .set("auc_spread", spread)
            .set("shard_scaleout", Json::Arr(jshard)),
    )?;
    Ok(())
}
