//! Fig. 8 — fixing the workers and varying GBA's local batch size, so the
//! global batch *diverges* from the sync global batch. The paper shows the
//! AUC degrades (or at least fails to reach the tuned optimum) whenever
//! G_a ≠ G_s — the evidence that keeping the global batch is what makes
//! switching tuning-free.

use anyhow::Result;

use super::{common, ExpCtx};
use crate::config::ModeKind;
use crate::metrics::report::{fmt_auc, write_result, Table};
use crate::worker::session::{SessionOptions, TrainSession};
use crate::util::json::Json;

pub fn run(ctx: &ExpCtx) -> Result<()> {
    let mut cfg = common::load_task(ctx, "private")?;
    common::quicken(&mut cfg);
    if !ctx.quick {
        cfg.data.days_base = 2;
        cfg.data.days_eval = 2;
        cfg.data.samples_per_day = 16384;
    }

    let sync = cfg.mode(ModeKind::Sync);
    let g_sync = sync.workers * sync.local_batch;
    let gba_workers = cfg.mode(ModeKind::Gba).workers;

    // Base model from sync training (the inherit-and-switch protocol).
    let base_session = TrainSession::new(cfg.clone(), ModeKind::Sync, SessionOptions::default())?;
    for d in 0..cfg.data.days_base {
        base_session.train_day(d)?;
    }
    let ckpt = base_session.checkpoint();

    let batches: &[usize] = if ctx.quick { &[128, 256, 512] } else { &[64, 128, 256, 512] };
    let mut table = Table::new(
        "Fig. 8 — AUC vs GBA local batch at fixed workers (global batch varies)",
        &["local batch", "global batch", "== sync G?", "AUC min", "AUC max", "AUC avg"],
    );
    let mut jrows = Vec::new();
    for &b in batches {
        let mut c = cfg.clone();
        // Paper setting: M is pinned to the (fixed) worker count, so the
        // actual global batch G_a = workers * B_a varies with B_a.
        for (k, m) in c.modes.iter_mut() {
            if *k == ModeKind::Gba {
                m.local_batch = b;
                m.workers = gba_workers;
                m.m_override = Some(gba_workers);
            }
        }
        c.validate()?;
        let s = TrainSession::from_checkpoint(c.clone(), ModeKind::Gba, SessionOptions::default(), &ckpt)?;
        let mut aucs = Vec::new();
        for d in cfg.data.days_base..cfg.data.days_base + cfg.data.days_eval {
            s.train_day(d)?;
            aucs.push(s.eval_auc(d + 1)?);
        }
        let g_a = c.gba_m_effective() * b;
        let (mn, mx) =
            aucs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(a, z), &x| (a.min(x), z.max(x)));
        let avg = aucs.iter().sum::<f64>() / aucs.len() as f64;
        table.row(vec![
            b.to_string(),
            g_a.to_string(),
            (g_a == g_sync).to_string(),
            fmt_auc(mn),
            fmt_auc(mx),
            fmt_auc(avg),
        ]);
        jrows.push(
            Json::obj()
                .set("local_batch", b)
                .set("global_batch", g_a)
                .set("matches_sync", g_a == g_sync)
                .set("auc", aucs.clone()),
        );
    }
    table.print();
    println!("\n(paper: the matched global batch reaches the best AUC without tuning)");
    write_result(&ctx.out_dir, "fig8", &Json::obj().set("rows", Json::Arr(jrows)).set("g_sync", g_sync))?;
    Ok(())
}
