//! Worker runtime (Algorithm 1): pull → generate/download batch → gather
//! embeddings → compute fwd/bwd → pre-reduce per-ID gradients →
//! non-blocking push. Plus the compute-backend abstraction.
//!
//! The worker plane speaks the wire codec's vocabulary directly: the
//! [`GradPush`] it builds and the [`PullReply`] it consumes *are* the
//! frame structs defined in [`crate::transport::codec`] — there is no
//! worker-local gradient or pull type to convert through. Since the
//! remote-worker refactor the PS itself sits behind the [`PsClient`]
//! trait: [`run_worker`] is written exactly once against it and drives
//! both the in-process front ([`ShardedPs`](crate::shard::ShardedPs),
//! any shard count/transport) and the wire-backed client a
//! `gba-train worker` process holds ([`remote::FrontClient`]) — the
//! deployment shape of the paper's Figure 2, where every worker is its
//! own machine.

pub mod remote;
pub mod session;

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::cluster::StragglerModel;
use crate::coordinator::WorkerId;
use crate::data::DataGen;
use crate::model::NativeModel;
use crate::ps::reduce_emb_grads;
use crate::transport::codec::{GradPush, PullReply};
use crate::runtime::{EngineHandle, HostTensor, TrainOut};
use crate::util::rng::Pcg64;

/// The worker's view of the parameter-server plane: the five verbs of
/// Algorithm 1. The in-process implementation is infallible by
/// construction (every method wraps an inherent `ShardedPs` call); for
/// the wire-backed client an `Err` means the front is gone, which ends
/// the worker's day.
pub trait PsClient {
    /// Claim the next batch; parks while the mode's gate is closed, so
    /// `PullReply::Wait` is never returned.
    fn pull_blocking(&self, w: WorkerId) -> Result<PullReply>;
    /// Push a gradient (never parks waiting for other workers).
    fn push(&self, grad: GradPush) -> Result<()>;
    /// Forget this worker's in-flight claim (Appendix B).
    fn worker_reset(&self, w: WorkerId) -> Result<()>;
    /// Snapshot of the dense parameters.
    fn dense_params(&self) -> Result<Vec<HostTensor>>;
    /// Gather embedding rows for a flattened key block.
    fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> Result<HostTensor>;
}

// (Inherent methods win resolution over same-named trait methods, so
// these delegations cannot recurse.)
impl PsClient for crate::shard::ShardedPs {
    fn pull_blocking(&self, w: WorkerId) -> Result<PullReply> {
        Ok(crate::shard::ShardedPs::pull_blocking(self, w))
    }

    fn push(&self, grad: GradPush) -> Result<()> {
        crate::shard::ShardedPs::push(self, grad);
        Ok(())
    }

    fn worker_reset(&self, w: WorkerId) -> Result<()> {
        crate::shard::ShardedPs::worker_reset(self, w);
        Ok(())
    }

    fn dense_params(&self) -> Result<Vec<HostTensor>> {
        Ok(crate::shard::ShardedPs::dense_params(self))
    }

    fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> Result<HostTensor> {
        Ok(crate::shard::ShardedPs::gather(self, keys, batch, fields))
    }
}

/// Seed of a worker's per-day RNG stream. One definition shared by the
/// in-thread session and the remote `gba-train worker` process — both
/// sides must derive identical streams from the same config file for
/// the worker planes to be bit-identical.
pub fn worker_day_seed(cfg_seed: u64, day: usize) -> u64 {
    cfg_seed ^ ((day as u64) << 8)
}

/// Which engine executes the model (identical numerics — pinned by the
/// `train_integration` test).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust fwd/bwd (`model::NativeModel`) — default for experiments.
    Native,
    /// AOT HLO artifacts via PJRT — the production path.
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            _ => anyhow::bail!("unknown backend '{s}' (native|pjrt)"),
        }
    }
}

/// A compute backend instance shared by all workers of a session.
pub enum Backend {
    Native(NativeModel),
    Pjrt(EngineHandle),
}

impl Backend {
    pub fn train_step(
        &self,
        batch: usize,
        emb: &HostTensor,
        params: &[HostTensor],
        labels: &[f32],
    ) -> Result<TrainOut> {
        match self {
            Backend::Native(m) => Ok(m.train_step(emb, params, labels)),
            Backend::Pjrt(h) => h.train_step(batch, emb.clone(), params.to_vec(), labels.to_vec()),
        }
    }

    pub fn predict(
        &self,
        batch: usize,
        emb: &HostTensor,
        params: &[HostTensor],
    ) -> Result<Vec<f32>> {
        match self {
            Backend::Native(m) => Ok(m.predict(emb, params)),
            Backend::Pjrt(h) => h.predict(batch, emb.clone(), params.to_vec()),
        }
    }
}

/// Per-worker runtime parameters.
#[derive(Clone)]
pub struct WorkerParams {
    pub id: usize,
    pub local_batch: usize,
    /// Injected compute-time model (None = run at full speed).
    pub straggler: Option<Arc<StragglerModel>>,
    /// Virtual time-of-day at session start (secs) for the load trace.
    pub start_sec: f64,
    /// Probability of a simulated crash per batch (failure injection).
    pub fail_prob: f64,
    /// Fixed extra compute time per batch (ms) — a deterministic slow-
    /// worker injection, independent of the traced straggler model.
    pub batch_sleep_ms: f64,
    pub seed: u64,
}

/// What a worker reports after a day of training.
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub batches: u64,
    pub samples: u64,
    pub failures: u64,
    /// Wall seconds spent in compute+sleep (excludes barrier waits).
    pub busy_sec: f64,
}

/// Run one worker until the PS data list is exhausted (Algorithm 1).
/// This is the *only* implementation of the worker loop: generic over
/// [`PsClient`], it drives in-thread workers against the front directly
/// and remote `gba-train worker` processes over the wire, unchanged.
pub fn run_worker<C: PsClient + ?Sized>(
    ps: &C,
    gen: &DataGen,
    backend: &Backend,
    wp: &WorkerParams,
) -> Result<WorkerStats> {
    let mut stats = WorkerStats::default();
    let mut rng = Pcg64::new(wp.seed, wp.id as u64 + 1000);
    let t0 = Instant::now();
    loop {
        let item = match ps.pull_blocking(wp.id)? {
            PullReply::Work(item) => item,
            PullReply::EndOfData => break,
            PullReply::Wait => unreachable!("pull_blocking resolves waits"),
        };

        // Failure injection: lose the claim (and its token) mid-flight.
        if wp.fail_prob > 0.0 && rng.bernoulli(wp.fail_prob) {
            ps.worker_reset(wp.id)?;
            stats.failures += 1;
            continue;
        }

        let busy_start = Instant::now();
        // "Download" + pack the batch (deterministic generation).
        let batch = gen.batch_by_index(item.day, item.batch_index, wp.local_batch);
        // Pull parameters: dense snapshot + embedding gather.
        let params = ps.dense_params()?;
        let emb = ps.gather(&batch.keys, wp.local_batch, batch.fields)?;
        // Compute fwd/bwd.
        let out = backend.train_step(wp.local_batch, &emb, &params, &batch.labels)?;
        // Straggler model: emulate the shared-cluster compute time.
        if let Some(m) = &wp.straggler {
            let t_virtual = wp.start_sec + t0.elapsed().as_secs_f64();
            let ms = m.compute_ms_batch(wp.id, t_virtual, wp.local_batch, &mut rng);
            std::thread::sleep(std::time::Duration::from_secs_f64(ms / 1000.0));
        }
        if wp.batch_sleep_ms > 0.0 {
            std::thread::sleep(std::time::Duration::from_secs_f64(wp.batch_sleep_ms / 1000.0));
        }
        // Pre-reduce per-ID embedding gradients, then push (non-blocking
        // from the worker's perspective: push never parks this thread).
        let emb_grads = reduce_emb_grads(&batch.keys, &out.d_emb);
        ps.push(GradPush {
            worker: wp.id,
            token: item.token,
            dense: out.d_dense,
            emb: emb_grads,
            n_samples: wp.local_batch,
            loss: out.loss,
        })?;
        stats.batches += 1;
        stats.samples += wp.local_batch as u64;
        stats.busy_sec += busy_start.elapsed().as_secs_f64();
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;
    use crate::coordinator::modes::GbaPolicy;
    use crate::embedding::EmbeddingConfig;
    use crate::optim::Sgd;
    use crate::ps::PsServer;
    use crate::runtime::VariantDims;

    fn tiny_cfg() -> ExperimentConfig {
        ExperimentConfig::from_toml(
            r#"
name = "worker-test"
seed = 1
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 16
hidden2 = 8
vocab_size = 500
zipf_s = 1.1
[data]
days_base = 1
days_eval = 1
samples_per_day = 512
teacher_seed = 3
[train]
optimizer = "sgd"
optimizer_async = "sgd"
lr = 0.1
[mode.sync]
workers = 2
local_batch = 32
[mode.gba]
workers = 4
local_batch = 16
iota = 3
"#,
        )
        .unwrap()
    }

    #[test]
    fn workers_train_a_day_gba() {
        let cfg = tiny_cfg();
        let dims = VariantDims {
            fields: 4,
            emb_dim: 4,
            hidden1: 16,
            hidden2: 8,
            mlp_in: 20,
        };
        let native = NativeModel::new(dims);
        let ps = Arc::new(PsServer::new(
            dims,
            native.init_params(cfg.seed),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 2, shards: 4 },
            Box::new(Sgd { lr: 0.1 }),
            Box::new(Sgd { lr: 0.1 }),
            Box::new(GbaPolicy::with_iota(cfg.gba_m(), 3)),
        ));
        let gen = Arc::new(DataGen::new(&cfg.model, &cfg.data, cfg.seed));
        let backend = Arc::new(Backend::Native(native));
        let mode = cfg.mode(crate::config::ModeKind::Gba);
        let n_batches = gen.batches_per_day(mode.local_batch);
        ps.set_day(0, n_batches);

        let mut handles = Vec::new();
        for w in 0..mode.workers {
            let (ps, gen, backend) = (ps.clone(), gen.clone(), backend.clone());
            let wp = WorkerParams {
                id: w,
                local_batch: mode.local_batch,
                straggler: None,
                start_sec: 0.0,
                fail_prob: 0.0,
                batch_sleep_ms: 0.0,
                seed: 9,
            };
            handles.push(std::thread::spawn(move || {
                run_worker(ps.as_ref(), &gen, &backend, &wp).unwrap()
            }));
        }
        let stats: Vec<WorkerStats> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ps.flush_partial();

        let total_batches: u64 = stats.iter().map(|s| s.batches).sum();
        assert_eq!(total_batches as usize, n_batches);
        let c = ps.counters();
        // Every batch's gradient was either applied or dropped; none lost.
        assert_eq!(c.applied_gradients + c.dropped_batches, n_batches as u64);
        assert!(c.global_steps >= (n_batches / cfg.gba_m()) as u64);
        assert!(ps.quiescent());
        // Training actually moved the dense parameters.
        let p = ps.dense_params();
        assert!(p[0].data.iter().any(|&x| x != 0.0) || p[1].data.iter().any(|&x| x != 0.0));
    }

    #[test]
    fn failure_injection_does_not_deadlock_sync() {
        use crate::coordinator::modes::SyncPolicy;
        let dims = VariantDims { fields: 4, emb_dim: 4, hidden1: 16, hidden2: 8, mlp_in: 20 };
        let cfg = tiny_cfg();
        let native = NativeModel::new(dims);
        let ps = Arc::new(PsServer::new(
            dims,
            native.init_params(1),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 2, shards: 4 },
            Box::new(Sgd { lr: 0.1 }),
            Box::new(Sgd { lr: 0.1 }),
            Box::new(SyncPolicy::new(2)),
        ));
        let gen = Arc::new(DataGen::new(&cfg.model, &cfg.data, cfg.seed));
        let backend = Arc::new(Backend::Native(native));
        ps.set_day(0, 16);
        let mut handles = Vec::new();
        for w in 0..2 {
            let (ps, gen, backend) = (ps.clone(), gen.clone(), backend.clone());
            let wp = WorkerParams {
                id: w,
                local_batch: 32,
                straggler: None,
                start_sec: 0.0,
                fail_prob: 0.2,
                batch_sleep_ms: 0.0,
                seed: 5,
            };
            handles.push(std::thread::spawn(move || {
                run_worker(ps.as_ref(), &gen, &backend, &wp).unwrap()
            }));
        }
        let stats: Vec<WorkerStats> = handles.into_iter().map(|h| h.join().unwrap()).collect();
        ps.flush_partial();
        assert!(stats.iter().any(|s| s.failures > 0), "no failures injected");
        assert!(ps.quiescent());
    }
}
