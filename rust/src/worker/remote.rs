//! The remote worker plane, worker side: what a `gba-train worker`
//! process runs.
//!
//! [`FrontClient`] is the wire-backed [`PsClient`]: each of the five
//! Algorithm-1 verbs is one request/reply exchange with the front's
//! [`WorkerFront`](crate::transport::WorkerFront) over the length-
//! prefixed codec, so [`run_worker`] drives it exactly as it drives the
//! in-process front — there is no second worker loop. Around the verbs
//! sits the session protocol: a connect-time `Hello` identity/shape
//! handshake, then `BeginDay` → train → `EndOfDay` until the front
//! answers a `BeginDay` with the `SessionOver` farewell (a clean exit);
//! an abrupt connection loss means the front crashed and is an error.
//!
//! Everything the worker derives locally — the data stream, the model
//! dims, the per-day RNG seed — comes from the *same config file* the
//! front reads; the `Hello` pins the shape-critical keys and the rest
//! is the operator contract documented in docs/DEPLOY.md.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{ExperimentConfig, ModeKind};
use crate::coordinator::WorkerId;
use crate::data::DataGen;
use crate::model::NativeModel;
use crate::obs;
use crate::runtime::HostTensor;
use crate::transport::codec::{GradPush, PullReply, WireMsg, WorkerReply, WorkerRequest};
use crate::transport::{connect_retry, Conn, SocketConn, WorkerShape, RECONNECT_DEADLINE};
use crate::worker::session::dims_of;
use crate::worker::{run_worker, worker_day_seed, Backend, PsClient, WorkerParams, WorkerStats};

/// What the front answered a `BeginDay` with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum NextStep {
    /// Train this day.
    Day(usize),
    /// The session advanced its mode epoch: re-derive the shape for
    /// `kind` and re-handshake before asking for a day again.
    Switch { epoch: u64, kind: ModeKind },
    /// Clean end of the session.
    Over,
}

/// The worker process's connection to the front: a [`PsClient`] over
/// the wire plus the session frames around it.
pub struct FrontClient {
    conn: Mutex<SocketConn>,
}

impl FrontClient {
    /// Dial the front, retrying with backoff up to `deadline` (the
    /// front may still be binding when the worker launches).
    pub fn connect(addr: &str, deadline: Duration) -> Result<FrontClient> {
        let conn = connect_retry(addr, deadline)
            .with_context(|| format!("no worker front reachable at {addr} within {deadline:?}"))?;
        Ok(FrontClient { conn: Mutex::new(conn) })
    }

    /// One request/reply exchange (the slot lock enforces alternation).
    /// Every call lands in the worker-side per-RPC latency histogram,
    /// labeled by the request kind.
    fn call(&self, req: WorkerRequest) -> Result<WorkerReply> {
        let kind = req.kind_name();
        let t0 = Instant::now();
        let mut conn = self.conn.lock().unwrap();
        conn.send(WireMsg::WorkerReq(req)).map_err(|e| anyhow::anyhow!("front send: {e}"))?;
        let reply = match conn.recv() {
            Ok(WireMsg::WorkerRep(r)) => Ok(r),
            Ok(other) => bail!("front protocol: expected a worker reply, got {other:?}"),
            Err(e) => bail!("front connection lost: {e}"),
        };
        obs::global()
            .histogram(
                &obs::labeled("gba_front_rpc_seconds", "rpc", kind),
                obs::Histogram::latency_bounds(),
            )
            .record(t0.elapsed().as_secs_f64());
        reply
    }

    fn expect_ok(&self, req: WorkerRequest, what: &str) -> Result<()> {
        match self.call(req)? {
            WorkerReply::Ok => Ok(()),
            other => bail!("front protocol: expected Ok to {what}, got {other:?}"),
        }
    }

    /// The identity/shape handshake; the declared shape comes from the
    /// same [`WorkerShape::of`] the front checks against. The front
    /// hangs up instead of acking when we disagree with its config —
    /// surfaced here as a connection error with the front's log holding
    /// the reason.
    pub fn hello(&self, worker: WorkerId, cfg: &ExperimentConfig, kind: ModeKind) -> Result<()> {
        self.expect_ok(WorkerShape::of(cfg, kind).hello(worker), "Hello")
            .context("front rejected the Hello handshake (front/worker config or mode disagree?)")
    }

    /// Ask for the next day. Three clean outcomes: a day to train
    /// ([`NextStep::Day`]), a mode switch to re-handshake
    /// ([`NextStep::Switch`] — the session advanced its mode epoch; the
    /// worker must re-derive its shape and call
    /// [`switch_epoch`](Self::switch_epoch) before asking again), or
    /// the `SessionOver` farewell ([`NextStep::Over`] — the worker
    /// exits cleanly). An abrupt connection loss is an `Err` (and a
    /// nonzero process exit): the front crashed, and a supervisor
    /// should restart us, not read "session over".
    pub fn begin_day(&self) -> Result<NextStep> {
        let mut conn = self.conn.lock().unwrap();
        conn.send(WireMsg::WorkerReq(WorkerRequest::BeginDay))
            .map_err(|e| anyhow::anyhow!("front lost asking for a day (front crashed?): {e}"))?;
        match conn.recv() {
            Ok(WireMsg::WorkerRep(WorkerReply::Day { day })) => Ok(NextStep::Day(day as usize)),
            Ok(WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode })) => {
                Ok(NextStep::Switch { epoch, kind: mode })
            }
            Ok(WireMsg::WorkerRep(WorkerReply::SessionOver)) => Ok(NextStep::Over),
            Ok(other) => {
                bail!("front protocol: expected Day, Switch or SessionOver, got {other:?}")
            }
            Err(e) => bail!("front lost waiting for a day (front crashed?): {e}"),
        }
    }

    /// The worker half of the mode re-handshake: declare the shape this
    /// worker re-derived from its own config file for the announced
    /// mode, and wait for the front's `Epoch` confirmation. The front
    /// hangs up instead of confirming when the declaration disagrees
    /// with its config — the same loud-failure contract as `Hello`.
    pub fn switch_epoch(
        &self,
        epoch: u64,
        worker: WorkerId,
        cfg: &ExperimentConfig,
        kind: ModeKind,
    ) -> Result<()> {
        let shape = WorkerShape::of(cfg, kind);
        let req = WorkerRequest::SwitchMode {
            epoch,
            worker: worker as u64,
            workers: shape.workers as u64,
            local_batch: shape.local_batch,
            fields: shape.fields,
            emb_dim: shape.emb_dim,
            seed: shape.seed,
            samples_per_day: shape.samples_per_day,
        };
        match self.call(req).with_context(|| {
            format!(
                "front rejected the epoch-{epoch} re-handshake to mode {} \
                 (front/worker config files disagree?)",
                kind.as_str()
            )
        })? {
            WorkerReply::Epoch { epoch: e } if e == epoch => Ok(()),
            other => bail!("front protocol: expected Epoch {epoch}, got {other:?}"),
        }
    }

    /// Report the day's stats back to the front.
    pub fn end_of_day(&self, stats: &WorkerStats) -> Result<()> {
        self.expect_ok(
            WorkerRequest::EndOfDay {
                batches: stats.batches,
                samples: stats.samples,
                failures: stats.failures,
                busy_sec: stats.busy_sec,
            },
            "EndOfDay",
        )
    }
}

impl PsClient for FrontClient {
    fn pull_blocking(&self, w: WorkerId) -> Result<PullReply> {
        match self.call(WorkerRequest::Pull { worker: w as u64 })? {
            WorkerReply::Pull(r) => Ok(r),
            other => bail!("front protocol: expected Pull reply, got {other:?}"),
        }
    }

    fn push(&self, grad: GradPush) -> Result<()> {
        // A gradient push starts a trace: the fresh id rides the frame
        // header to the front, whose serving thread carries it into the
        // shard applies — one id correlates worker → front → shard.
        obs::trace::set_current(obs::trace::next_id());
        obs::trace::span(
            "worker_push",
            crate::util::json::Json::obj().set("worker", grad.worker).set("token", grad.token),
        );
        self.expect_ok(WorkerRequest::Push(grad), "Push")
    }

    fn worker_reset(&self, w: WorkerId) -> Result<()> {
        self.expect_ok(WorkerRequest::Reset { worker: w as u64 }, "Reset")
    }

    fn dense_params(&self) -> Result<Vec<HostTensor>> {
        match self.call(WorkerRequest::DenseParams)? {
            WorkerReply::Dense(ts) => Ok(ts),
            other => bail!("front protocol: expected Dense reply, got {other:?}"),
        }
    }

    fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> Result<HostTensor> {
        let req = WorkerRequest::Gather {
            keys: keys.to_vec(),
            batch: batch as u64,
            fields: fields as u64,
        };
        match self.call(req)? {
            WorkerReply::Emb(t) => Ok(t),
            other => bail!("front protocol: expected Emb reply, got {other:?}"),
        }
    }
}

/// Extra knobs of the `gba-train worker` subcommand.
#[derive(Clone, Copy, Debug)]
pub struct WorkerProcOptions {
    /// Per-batch simulated crash probability (failure injection).
    pub fail_prob: f64,
    /// Fixed extra compute per batch (ms) — deterministic slow worker.
    pub batch_sleep_ms: f64,
    /// How long to keep dialing the front before giving up.
    pub connect_deadline: Duration,
}

impl Default for WorkerProcOptions {
    fn default() -> Self {
        WorkerProcOptions {
            fail_prob: 0.0,
            batch_sleep_ms: 0.0,
            connect_deadline: RECONNECT_DEADLINE,
        }
    }
}

/// The whole life of a `gba-train worker` process: dial, handshake,
/// then `BeginDay` → [`run_worker`] → `EndOfDay` until the front closes
/// the session. Returns the number of days served.
pub fn run_worker_process(
    cfg: &ExperimentConfig,
    kind: ModeKind,
    worker_id: WorkerId,
    addr: &str,
    opts: WorkerProcOptions,
) -> Result<u64> {
    let mut kind = kind;
    let mut mode = cfg.mode(kind);
    anyhow::ensure!(
        worker_id < mode.workers,
        "--worker-id {worker_id} out of range for {} {} workers",
        mode.workers,
        kind.as_str()
    );
    let client = FrontClient::connect(addr, opts.connect_deadline)?;
    client.hello(worker_id, cfg, kind)?;
    eprintln!(
        "worker {worker_id}: connected to front {addr} (task {}, mode {})",
        cfg.name,
        kind.as_str()
    );

    let dims = dims_of(cfg);
    let gen = DataGen::new(&cfg.model, &cfg.data, cfg.seed);
    let backend = Backend::Native(NativeModel::new(dims));
    let mut days = 0u64;
    loop {
        match client.begin_day()? {
            NextStep::Over => break,
            NextStep::Day(day) => {
                let wp = WorkerParams {
                    id: worker_id,
                    local_batch: mode.local_batch,
                    straggler: None,
                    start_sec: 0.0,
                    fail_prob: opts.fail_prob,
                    batch_sleep_ms: opts.batch_sleep_ms,
                    seed: worker_day_seed(cfg.seed, day),
                };
                let stats = run_worker(&client, &gen, &backend, &wp)?;
                eprintln!(
                    "worker {worker_id}: day {day} done ({} batches, {} samples, {} failures)",
                    stats.batches, stats.samples, stats.failures
                );
                client.end_of_day(&stats)?;
                days += 1;
            }
            NextStep::Switch { epoch, kind: to } => {
                // The session advanced its mode epoch in place: survive
                // the switch by re-deriving our shape from the *same
                // config file* at the new mode and re-handshaking. A
                // config that does not define the mode is the loud
                // failure, not a panic.
                anyhow::ensure!(
                    cfg.has_mode(to),
                    "front switched to mode {} which this worker's config does not define",
                    to.as_str()
                );
                client.switch_epoch(epoch, worker_id, cfg, to)?;
                kind = to;
                mode = cfg.mode(kind);
                eprintln!(
                    "worker {worker_id}: switched to mode {} (epoch {epoch}, \
                     local batch {})",
                    kind.as_str(),
                    mode.local_batch
                );
            }
        }
    }
    Ok(days)
}
