//! Training session: the launcher-level object tying config, data, PS,
//! policy, backend and workers together. Implements the paper's continual
//! protocol (train day d, evaluate day d+1) and the *switch* operation
//! (inherit parameters, change mode — §5.2 / Fig. 6).

use std::path::PathBuf;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::cluster::StragglerModel;
use crate::config::{ExperimentConfig, ModeKind, WorkerPlane};
use crate::coordinator::modes::make_policy;
use crate::data::DataGen;
use crate::embedding::EmbeddingConfig;
use crate::metrics::{auc, TrainCounters};
use crate::model::NativeModel;
use crate::optim::make_optimizer;
use crate::ps::PsServer;
use crate::runtime::{EnginePool, Manifest, VariantDims};
use crate::shard::{PsBuild, ShardRouter};
use crate::transport::{
    RowRecord, ShardSpawnSpec, WorkerFront, WorkerShape, WORKER_ACCEPT_DEADLINE,
};
use crate::worker::{
    run_worker, worker_day_seed, Backend, BackendKind, WorkerParams, WorkerStats,
};

/// Options beyond the config file.
#[derive(Clone)]
pub struct SessionOptions {
    pub backend: BackendKind,
    /// Artifacts directory (PJRT backend only).
    pub artifacts_dir: PathBuf,
    /// Inject the cluster straggler model into worker compute.
    pub straggler: bool,
    /// Virtual time-of-day at session start (secs), for the load trace.
    pub start_sec: f64,
    pub fail_prob: f64,
    /// PJRT engine threads.
    pub engine_threads: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            straggler: false,
            start_sec: 0.0,
            fail_prob: 0.0,
            engine_threads: 2,
        }
    }
}

/// Per-day training statistics.
#[derive(Clone, Debug)]
pub struct DayStats {
    pub day: usize,
    pub wall_sec: f64,
    pub samples: u64,
    pub qps: f64,
    pub counters: TrainCounters,
    pub failures: u64,
    /// Mean local (per-worker) QPS.
    pub local_qps: f64,
}

pub struct TrainSession {
    pub cfg: ExperimentConfig,
    pub kind: ModeKind,
    pub dims: VariantDims,
    gen: Arc<DataGen>,
    ps: Arc<PsServer>,
    backend: Arc<Backend>,
    /// Kept alive while the PJRT backend is in use.
    _engine: Option<EnginePool>,
    opts: SessionOptions,
    straggler: Option<Arc<StragglerModel>>,
    /// The remote worker plane's accept/serve half (`[cluster] workers
    /// = "remote"` only): bound at session build so operators and tests
    /// can learn the address before launching `gba-train worker`
    /// processes; workers are admitted lazily at the first `train_day`.
    worker_front: Option<WorkerFront>,
}

/// Model dimensions a config describes.
pub fn dims_of(cfg: &ExperimentConfig) -> VariantDims {
    VariantDims {
        fields: cfg.model.fields,
        emb_dim: cfg.model.emb_dim,
        hidden1: cfg.model.hidden1,
        hidden2: cfg.model.hidden2,
        mlp_in: cfg.model.mlp_in(),
    }
}

/// (optimizer kind, lr) the paper assigns to a mode (Table 5.1).
fn optim_for(cfg: &ExperimentConfig, kind: ModeKind) -> (crate::config::OptimKind, f64) {
    if kind.is_fully_async() {
        (cfg.train.optimizer_async, cfg.train.lr_async)
    } else {
        (cfg.train.optimizer, cfg.train.lr)
    }
}

/// The embedding-table config a session derives from `cfg`. Public
/// because a `shard-server` process must derive the *same* table (same
/// key-seeded init) from the same config file, or lazily-materialized
/// rows would diverge between in-process and remote runs.
pub fn emb_cfg_of(cfg: &ExperimentConfig) -> EmbeddingConfig {
    EmbeddingConfig {
        dim: cfg.model.emb_dim,
        init_scale: 0.05,
        seed: cfg.seed ^ 0xE0B,
        shards: 16,
    }
}

/// Everything a `gba-train shard-server` process needs to serve shard
/// `shard_id` of the PS plane that a front built from the same config
/// will expect: the dense range partition (must agree with the front's
/// router), the embedding config, the mode's optimizer pair, and the
/// config-seeded initial parameters. The front still installs its own
/// checkpoint over the wire on every connect — the spec only fixes the
/// *shape* (and the lazy-init seed) both sides must share.
pub fn shard_server_spec(
    cfg: &ExperimentConfig,
    kind: ModeKind,
    shard_id: usize,
) -> (ShardSpawnSpec, Vec<crate::runtime::HostTensor>) {
    assert!(shard_id < cfg.ps.n_shards, "shard id {} of {} shards", shard_id, cfg.ps.n_shards);
    let dims = dims_of(cfg);
    let init = NativeModel::new(dims).init_params(cfg.seed);
    let (okind, lr) = optim_for(cfg, kind);
    let router = ShardRouter::new(cfg.ps.n_shards);
    let spec = ShardSpawnSpec {
        index: shard_id,
        ranges: init.iter().map(|t| router.dense_range(shard_id, t.numel())).collect(),
        emb_cfg: emb_cfg_of(cfg),
        opt_dense: make_optimizer(okind, lr),
        opt_emb: make_optimizer(okind, lr),
        addr: None,
    };
    (spec, init)
}

impl TrainSession {
    pub fn new(cfg: ExperimentConfig, kind: ModeKind, opts: SessionOptions) -> Result<Self> {
        let dims = dims_of(&cfg);
        let native = NativeModel::new(dims);
        let init = native.init_params(cfg.seed);
        Self::build(cfg, kind, opts, init, None, 0)
    }

    /// Inherit a checkpoint (the paper's switching protocol).
    pub fn from_checkpoint(
        cfg: ExperimentConfig,
        kind: ModeKind,
        opts: SessionOptions,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        Self::build(cfg, kind, opts, ckpt.dense.clone(), Some(ckpt), ckpt.global_step)
    }

    fn build(
        cfg: ExperimentConfig,
        kind: ModeKind,
        opts: SessionOptions,
        init_dense: Vec<crate::runtime::HostTensor>,
        ckpt: Option<&Checkpoint>,
        _step0: u64,
    ) -> Result<Self> {
        let dims = dims_of(&cfg);
        let mode = cfg.mode(kind);
        let (okind, lr) = optim_for(&cfg, kind);
        let policy = make_policy(kind, &mode, cfg.gba_m_effective());
        let ps = Arc::new(
            PsBuild {
                dims,
                init_params: init_dense,
                emb_cfg: emb_cfg_of(&cfg),
                opt_dense: make_optimizer(okind, lr),
                opt_emb: make_optimizer(okind, lr),
                policy,
                n_shards: cfg.ps.n_shards,
                transport: cfg.ps.transport,
                shard_addrs: cfg.ps.shard_addrs.clone(),
                connect_deadline: Some(Duration::from_millis(cfg.ps.connect_deadline_ms)),
            }
            // An unreachable shard-server is an `Err` here (and a clean
            // nonzero exit from `gba-train train`), not a panic.
            .try_build()
            .context("building the PS plane")?,
        );
        ps.set_journal_spill_bytes(cfg.ps.journal_spill_bytes);
        if let Some(ckpt) = ckpt {
            // One bulk InsertRows frame per shard — the restore path that
            // stays tractable when the shards sit across a wire.
            let emb_slots = make_optimizer(okind, lr).slots();
            let rows: Vec<RowRecord> = ckpt
                .emb_rows
                .iter()
                .map(|(key, vec, meta)| {
                    (*key, vec.clone(), vec![0.0; vec.len() * emb_slots], *meta)
                })
                .collect();
            ps.insert_emb_rows(rows);
        }
        let gen = Arc::new(DataGen::new(&cfg.model, &cfg.data, cfg.seed));

        let (backend, engine) = match opts.backend {
            BackendKind::Native => (Backend::Native(NativeModel::new(dims)), None),
            BackendKind::Pjrt => {
                let manifest = Manifest::load(&opts.artifacts_dir)?;
                let mdims = manifest.dims(&cfg.model.variant)?;
                anyhow::ensure!(
                    mdims == dims,
                    "config model dims {dims:?} != artifact dims {mdims:?}"
                );
                anyhow::ensure!(
                    manifest.batches(&cfg.model.variant)?.contains(&mode.local_batch),
                    "no artifact for local batch {} of variant {}",
                    mode.local_batch,
                    cfg.model.variant
                );
                let pool = EnginePool::start(&manifest, &cfg.model.variant, opts.engine_threads)
                    .context("starting PJRT engine pool")?;
                (Backend::Pjrt(pool.handle()), Some(pool))
            }
        };
        let straggler = opts
            .straggler
            .then(|| Arc::new(StragglerModel::new(&cfg.cluster, mode.workers, cfg.seed ^ 0x57)));
        let worker_front = match cfg.cluster.workers {
            WorkerPlane::InProc => None,
            WorkerPlane::Remote => {
                // Worker-side injections live in the worker processes
                // (`gba-train worker --fail-prob/--batch-sleep-ms`);
                // accepting these session options here would silently
                // run a straggler/failure experiment with no injection.
                anyhow::ensure!(
                    !opts.straggler && opts.fail_prob == 0.0 && opts.start_sec == 0.0,
                    "--straggler / fail_prob / start_sec are in-thread worker options; \
                     with [cluster] workers = \"remote\" pass the equivalent flags to the \
                     gba-train worker processes instead"
                );
                Some(
                    WorkerFront::bind(&cfg.cluster.worker_listen, WorkerShape::of(&cfg, kind))
                        .context("binding the worker front")?,
                )
            }
        };
        Ok(TrainSession {
            cfg,
            kind,
            dims,
            gen,
            ps,
            backend: Arc::new(backend),
            _engine: engine,
            opts,
            straggler,
            worker_front,
        })
    }

    pub fn ps(&self) -> &PsServer {
        &self.ps
    }

    pub fn gen(&self) -> &DataGen {
        &self.gen
    }

    /// Where remote `gba-train worker` processes connect (`[cluster]
    /// workers = "remote"` only).
    pub fn worker_addr(&self) -> Option<String> {
        self.worker_front.as_ref().map(|f| f.addr().to_string())
    }

    /// Training finished successfully: send remote workers the
    /// `SessionOver` farewell so they exit 0. Not called on error paths
    /// (and deliberately not on drop) — workers seeing an abrupt close
    /// exit nonzero, telling a supervisor the run failed. No-op for the
    /// in-thread plane.
    pub fn shutdown_workers(&self) {
        if let Some(front) = &self.worker_front {
            front.shutdown();
        }
    }

    /// Train on one day of data; returns the day's statistics.
    ///
    /// The worker plane is a config dispatch: in-thread loops
    /// (`[cluster] workers = "inproc"`, the default) or remote
    /// `gba-train worker` processes served over the wire (`"remote"`).
    /// Both planes drive the identical `run_worker` body against the
    /// token-control plane, so the resulting parameters, rows and
    /// counters are bit-for-bit identical on the same schedule.
    pub fn train_day(&self, day: usize) -> Result<DayStats> {
        let mode = self.cfg.mode(self.kind);
        let n_batches = self.gen.batches_per_day(mode.local_batch);
        self.ps.reset_counters();
        self.ps.set_day(day, n_batches);
        let t0 = Instant::now();
        let stats: Vec<WorkerStats> = match &self.worker_front {
            None => {
                let mut handles = Vec::new();
                for w in 0..mode.workers {
                    let ps = self.ps.clone();
                    let gen = self.gen.clone();
                    let backend = self.backend.clone();
                    let wp = WorkerParams {
                        id: w,
                        local_batch: mode.local_batch,
                        straggler: self.straggler.clone(),
                        start_sec: self.opts.start_sec,
                        fail_prob: self.opts.fail_prob,
                        batch_sleep_ms: 0.0,
                        seed: worker_day_seed(self.cfg.seed, day),
                    };
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("worker-{w}"))
                            .spawn(move || run_worker(ps.as_ref(), &gen, &backend, &wp))?,
                    );
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect::<Result<_>>()?
            }
            Some(front) => {
                // First day: wait for the full complement. Later days:
                // admit any replacement hellos and continue on the
                // survivors. Then stream the day over the wire — the
                // token-control plane is driven unchanged, by serving
                // threads instead of worker threads.
                front.admit_for_day(WORKER_ACCEPT_DEADLINE)?;
                front.run_day(day, &self.ps)?
            }
        };
        let mut samples = 0u64;
        let mut failures = 0u64;
        let mut busy = 0.0f64;
        for s in &stats {
            samples += s.samples;
            failures += s.failures;
            busy += s.busy_sec;
        }
        // Drain: apply any partial buffer left at end-of-day.
        self.ps.flush_partial();
        let wall = t0.elapsed().as_secs_f64();
        let counters = self.ps.counters();
        if self.worker_front.is_some() {
            // Conservation audit: every issued batch must have resolved
            // as applied, dropped, or a reclaimed claim. A shortfall
            // means the worker fleet died mid-day and part of the data
            // list was never trained — that is a failed day, not a
            // quiet DayStats. (In-thread workers can't die silently:
            // their panics and Errs propagate through the joins above.)
            let resolved =
                counters.applied_gradients + counters.dropped_batches + failures;
            anyhow::ensure!(
                resolved == n_batches as u64,
                "day {day} incomplete: {resolved} of {n_batches} batches resolved — \
                 worker processes died mid-day with no survivors to finish the data list"
            );
        }
        Ok(DayStats {
            day,
            wall_sec: wall,
            samples,
            qps: samples as f64 / wall.max(1e-9),
            local_qps: samples as f64 / busy.max(1e-9) / mode.workers as f64,
            counters,
            failures,
        })
    }

    /// AUC over `n` eval samples of `day` (the paper's next-day protocol:
    /// call with `day = trained_day + 1`).
    pub fn eval_auc(&self, day: usize) -> Result<f64> {
        let n = self.cfg.train.eval_samples;
        let bsz = self.cfg.train.eval_batch;
        let params = self.ps.dense_params();
        let mut scores = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let n_batches = (n / bsz).max(1);
        for b in 0..n_batches {
            let batch = self.gen.batch_by_index(day, b, bsz);
            let emb = self.ps.gather(&batch.keys, bsz, batch.fields);
            let logits = self.backend.predict(bsz, &emb, &params)?;
            scores.extend_from_slice(&logits);
            labels.extend_from_slice(&batch.labels);
        }
        Ok(auc(&scores, &labels))
    }

    /// In-memory checkpoint of the current parameters.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint::from_ps(self.dims, &self.ps)
    }

    /// Switch the training mode, inheriting all parameters (the paper's
    /// tuning-free switch: same hyper-parameters, new coordination).
    /// Optimizer slots reset — exactly what checkpoint-inherit does.
    pub fn switch_mode(&mut self, kind: ModeKind) -> Result<()> {
        // Remote workers hold the *old* mode's shape (local batch,
        // worker count) from their own launch flags; carrying their
        // connections into a new mode would train silently wrong
        // batches. Until workers learn to re-handshake on switch
        // (ROADMAP follow-up), the switch requires in-thread workers.
        anyhow::ensure!(
            self.worker_front.is_none(),
            "switch_mode is not supported with [cluster] workers = \"remote\": restart \
             the session and the worker processes in mode '{}'",
            kind.as_str()
        );
        let ckpt = self.checkpoint();
        let new = TrainSession::from_checkpoint(
            self.cfg.clone(),
            kind,
            self.opts.clone(),
            &ckpt,
        )?;
        *self = new;
        Ok(())
    }

    /// Train `days`, evaluating on the subsequent day after each (the
    /// paper's continual protocol). Returns (day, AUC-on-day+1) pairs.
    pub fn run_continual(&self, days: std::ops::Range<usize>) -> Result<Vec<(usize, f64, DayStats)>> {
        let mut out = Vec::new();
        for d in days {
            let stats = self.train_day(d)?;
            let a = self.eval_auc(d + 1)?;
            out.push((d, a, stats));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::from_toml(
            r#"
name = "session-test"
seed = 11
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 32
hidden2 = 16
vocab_size = 2000
zipf_s = 1.1
[data]
days_base = 2
days_eval = 1
samples_per_day = 4096
teacher_seed = 3
label_noise = 0.02
[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 2048
[mode.sync]
workers = 4
local_batch = 64
[mode.async]
workers = 8
local_batch = 16
[mode.gba]
workers = 8
local_batch = 32
iota = 3
[mode.hop_bs]
workers = 8
local_batch = 32
bound = 2
[mode.bsp]
workers = 8
local_batch = 32
aggregate = 8
[mode.hop_bw]
workers = 4
local_batch = 64
backup = 1
"#,
        )
        .unwrap()
    }

    #[test]
    fn sync_training_improves_auc() {
        let s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        let before = s.eval_auc(1).unwrap();
        s.train_day(0).unwrap();
        let after = s.eval_auc(1).unwrap();
        assert!(after > before + 0.05, "auc {before} -> {after}");
        assert!(after > 0.6, "auc after one day = {after}");
    }

    #[test]
    fn gba_training_improves_auc_and_matches_global_batch() {
        let c = cfg();
        let m = c.gba_m();
        assert_eq!(m, 8); // 4*64 / 32
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        let stats = s.train_day(0).unwrap();
        // steps ≈ batches / M
        let batches = stats.counters.applied_gradients + stats.counters.dropped_batches;
        assert!(stats.counters.global_steps >= batches / m as u64);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "gba auc = {a}");
    }

    #[test]
    fn switch_sync_to_gba_keeps_accuracy() {
        let mut s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        s.train_day(0).unwrap();
        let before = s.eval_auc(1).unwrap();
        s.switch_mode(ModeKind::Gba).unwrap();
        let inherited = s.eval_auc(1).unwrap();
        // Inheriting parameters must preserve eval exactly (same params).
        assert!((inherited - before).abs() < 1e-9);
        s.train_day(1).unwrap();
        let after = s.eval_auc(2).unwrap();
        assert!(after > before - 0.05, "switch degraded: {before} -> {after}");
    }

    #[test]
    fn sharded_ps_session_trains() {
        let mut c = cfg();
        c.ps.n_shards = 4;
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        assert_eq!(s.ps().n_shards(), 4);
        let stats = s.train_day(0).unwrap();
        assert!(stats.counters.global_steps > 0);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "sharded gba auc = {a}");
    }

    #[test]
    fn socket_transport_session_trains() {
        let mut c = cfg();
        c.ps.n_shards = 2;
        c.ps.transport = crate::config::TransportKind::Socket;
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        assert_eq!(s.ps().transport(), crate::config::TransportKind::Socket);
        let stats = s.train_day(0).unwrap();
        assert!(stats.counters.global_steps > 0);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "socket gba auc = {a}");
    }

    #[test]
    fn all_modes_run_a_day() {
        for kind in crate::config::ModeKind::ALL {
            let s = TrainSession::new(cfg(), kind, SessionOptions::default()).unwrap();
            let stats = s.train_day(0).unwrap();
            assert!(stats.counters.global_steps > 0, "{kind:?} made no steps");
            let a = s.eval_auc(1).unwrap();
            assert!(a > 0.52, "{kind:?} auc = {a}");
        }
    }
}
