//! Training session: the launcher-level object tying config, data, PS,
//! policy, backend and workers together. Implements the paper's continual
//! protocol (train day d, evaluate day d+1) and the *switch* operation
//! (inherit parameters, change mode — §5.2 / Fig. 6).
//!
//! # In-place switching
//!
//! `switch_mode` advances a mode epoch *in place* instead of rebuilding
//! the session: the [`SwitchPlane`] owns the mode as a sequence of
//! epochs, the shard plane swaps its coordination policy (draining any
//! buffered gradients under the old one) and — only when the epoch
//! changes the optimizer pair (async ↔ the rest, Table 5.1) — its
//! optimizers, and remote `gba-train worker` processes survive the
//! switch through the wire-level `SwitchMode`/`Epoch` re-handshake.
//! Dense parameters, embedding rows and (across same-pair switches)
//! optimizer slots are inherited untouched — the paper's tuning-free
//! switch with nothing torn down around it.

use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::checkpoint::Checkpoint;
use crate::cluster::StragglerModel;
use crate::config::{ExperimentConfig, ModeKind, SwitchPolicyKind, WorkerPlane};
use crate::coordinator::modes::make_policy;
use crate::coordinator::{SwitchPlane, SwitchTrace};
use crate::data::DataGen;
use crate::embedding::EmbeddingConfig;
use crate::metrics::{auc, TrainCounters};
use crate::model::NativeModel;
use crate::obs;
use crate::optim::make_optimizer;
use crate::ps::PsServer;
use crate::runtime::{EnginePool, Manifest, VariantDims};
use crate::shard::{PsBuild, ShardRouter};
use crate::transport::{
    RowRecord, ShardSpawnSpec, WorkerFront, WorkerShape, WORKER_ACCEPT_DEADLINE,
};
use crate::util::stats::percentile;
use crate::worker::{
    run_worker, worker_day_seed, Backend, BackendKind, WorkerParams, WorkerStats,
};

/// Options beyond the config file.
#[derive(Clone)]
pub struct SessionOptions {
    pub backend: BackendKind,
    /// Artifacts directory (PJRT backend only).
    pub artifacts_dir: PathBuf,
    /// Inject the cluster straggler model into worker compute.
    pub straggler: bool,
    /// Virtual time-of-day at session start (secs), for the load trace.
    pub start_sec: f64,
    pub fail_prob: f64,
    /// PJRT engine threads.
    pub engine_threads: usize,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            straggler: false,
            start_sec: 0.0,
            fail_prob: 0.0,
            engine_threads: 2,
        }
    }
}

/// Per-day training statistics.
#[derive(Clone, Debug)]
pub struct DayStats {
    pub day: usize,
    pub wall_sec: f64,
    pub samples: u64,
    pub qps: f64,
    pub counters: TrainCounters,
    pub failures: u64,
    /// Mean local (per-worker) QPS.
    pub local_qps: f64,
    /// p95 across workers of mean per-batch latency (busy seconds per
    /// batch) — the straggler telemetry the adaptive switcher watches.
    pub batch_latency_p95: f64,
    /// Median across workers of mean per-batch latency.
    pub batch_latency_med: f64,
}

impl DayStats {
    /// Batch indices re-issued after a worker reset reclaimed their
    /// claim — the day's coverage stayed complete despite those
    /// workers. (A view over the counters, not a second copy.)
    pub fn reissued(&self) -> u64 {
        self.counters.reissued_batches
    }

    /// Straggler signal in [0, 1): 0 for a homogeneous fleet, → 1 as
    /// the p95 worker falls ever further behind the median. This is
    /// what feeds `AdaptiveSwitcher::observe` between days.
    pub fn straggler_signal(&self) -> f64 {
        if self.batch_latency_p95 <= 0.0 {
            return 0.0;
        }
        (1.0 - self.batch_latency_med / self.batch_latency_p95).max(0.0)
    }
}

pub struct TrainSession {
    pub cfg: ExperimentConfig,
    pub kind: ModeKind,
    pub dims: VariantDims,
    gen: Arc<DataGen>,
    ps: Arc<PsServer>,
    backend: Arc<Backend>,
    /// Kept alive while the PJRT backend is in use.
    _engine: Option<EnginePool>,
    opts: SessionOptions,
    straggler: Option<Arc<StragglerModel>>,
    /// The remote worker plane's accept/serve half (`[cluster] workers
    /// = "remote"` only): bound at session build so operators and tests
    /// can learn the address before launching `gba-train worker`
    /// processes; workers are admitted lazily at the first `train_day`.
    worker_front: Option<WorkerFront>,
    /// Owns the mode as a sequence of epochs, records the switch trace,
    /// and (under `[switch] policy = "adaptive"`) proposes switches
    /// from the per-day straggler telemetry.
    switch: SwitchPlane,
    /// `last trained day + 1` — where a switch lands on the continual
    /// time axis (atomic: `train_day` takes `&self`).
    next_day: AtomicUsize,
}

/// Model dimensions a config describes.
pub fn dims_of(cfg: &ExperimentConfig) -> VariantDims {
    VariantDims {
        fields: cfg.model.fields,
        emb_dim: cfg.model.emb_dim,
        hidden1: cfg.model.hidden1,
        hidden2: cfg.model.hidden2,
        mlp_in: cfg.model.mlp_in(),
    }
}

/// (optimizer kind, lr) the paper assigns to a mode (Table 5.1).
fn optim_for(cfg: &ExperimentConfig, kind: ModeKind) -> (crate::config::OptimKind, f64) {
    if kind.is_fully_async() {
        (cfg.train.optimizer_async, cfg.train.lr_async)
    } else {
        (cfg.train.optimizer, cfg.train.lr)
    }
}

/// The embedding-table config a session derives from `cfg`. Public
/// because a `shard-server` process must derive the *same* table (same
/// key-seeded init) from the same config file, or lazily-materialized
/// rows would diverge between in-process and remote runs.
pub fn emb_cfg_of(cfg: &ExperimentConfig) -> EmbeddingConfig {
    EmbeddingConfig {
        dim: cfg.model.emb_dim,
        init_scale: 0.05,
        seed: cfg.seed ^ 0xE0B,
        shards: 16,
    }
}

/// Everything a `gba-train shard-server` process needs to serve shard
/// `shard_id` of the PS plane that a front built from the same config
/// will expect: the dense range partition (must agree with the front's
/// router), the embedding config, the mode's optimizer pair, and the
/// config-seeded initial parameters. The front still installs its own
/// checkpoint over the wire on every connect — the spec only fixes the
/// *shape* (and the lazy-init seed) both sides must share.
pub fn shard_server_spec(
    cfg: &ExperimentConfig,
    kind: ModeKind,
    shard_id: usize,
) -> (ShardSpawnSpec, Vec<crate::runtime::HostTensor>) {
    assert!(shard_id < cfg.ps.n_shards, "shard id {} of {} shards", shard_id, cfg.ps.n_shards);
    let dims = dims_of(cfg);
    let init = NativeModel::new(dims).init_params(cfg.seed);
    let (okind, lr) = optim_for(cfg, kind);
    let router = ShardRouter::new(cfg.ps.n_shards);
    let spec = ShardSpawnSpec {
        index: shard_id,
        ranges: init.iter().map(|t| router.dense_range(shard_id, t.numel())).collect(),
        emb_cfg: emb_cfg_of(cfg),
        opt_dense: make_optimizer(okind, lr),
        opt_emb: make_optimizer(okind, lr),
        addr: None,
        apply_threads: cfg.ps.apply_threads,
    };
    (spec, init)
}

impl TrainSession {
    pub fn new(cfg: ExperimentConfig, kind: ModeKind, opts: SessionOptions) -> Result<Self> {
        let dims = dims_of(&cfg);
        let native = NativeModel::new(dims);
        let init = native.init_params(cfg.seed);
        Self::build(cfg, kind, opts, init, None, 0)
    }

    /// Inherit a checkpoint (the paper's switching protocol).
    pub fn from_checkpoint(
        cfg: ExperimentConfig,
        kind: ModeKind,
        opts: SessionOptions,
        ckpt: &Checkpoint,
    ) -> Result<Self> {
        Self::build(cfg, kind, opts, ckpt.dense.clone(), Some(ckpt), ckpt.global_step)
    }

    fn build(
        cfg: ExperimentConfig,
        kind: ModeKind,
        opts: SessionOptions,
        init_dense: Vec<crate::runtime::HostTensor>,
        ckpt: Option<&Checkpoint>,
        _step0: u64,
    ) -> Result<Self> {
        let dims = dims_of(&cfg);
        let mode = cfg.mode(kind);
        let (okind, lr) = optim_for(&cfg, kind);
        let policy = make_policy(kind, &mode, cfg.gba_m_effective());
        let ps = Arc::new(
            PsBuild {
                dims,
                init_params: init_dense,
                emb_cfg: emb_cfg_of(&cfg),
                opt_dense: make_optimizer(okind, lr),
                opt_emb: make_optimizer(okind, lr),
                policy,
                n_shards: cfg.ps.n_shards,
                transport: cfg.ps.transport,
                shard_addrs: cfg.ps.shard_addrs.clone(),
                connect_deadline: Some(Duration::from_millis(cfg.ps.connect_deadline_ms)),
                apply_threads: cfg.ps.apply_threads,
            }
            // An unreachable shard-server is an `Err` here (and a clean
            // nonzero exit from `gba-train train`), not a panic.
            .try_build()
            .context("building the PS plane")?,
        );
        ps.set_journal_spill_bytes(cfg.ps.journal_spill_bytes);
        // Install the configured staleness-decay policy before any token
        // is issued (the default `gba` is a no-op and costs nothing).
        ps.set_staleness_policy(crate::staleness::make_staleness(&cfg.train.staleness));
        if let Some(ckpt) = ckpt {
            // One bulk InsertRows frame per shard — the restore path that
            // stays tractable when the shards sit across a wire.
            let emb_slots = make_optimizer(okind, lr).slots();
            let rows: Vec<RowRecord> = ckpt
                .emb_rows
                .iter()
                .map(|(key, vec, meta)| {
                    (*key, vec.clone(), vec![0.0; vec.len() * emb_slots], *meta)
                })
                .collect();
            ps.insert_emb_rows(rows);
        }
        let gen = Arc::new(DataGen::new(&cfg.model, &cfg.data, cfg.seed));

        let (backend, engine) = match opts.backend {
            BackendKind::Native => (Backend::Native(NativeModel::new(dims)), None),
            BackendKind::Pjrt => {
                let manifest = Manifest::load(&opts.artifacts_dir)?;
                let mdims = manifest.dims(&cfg.model.variant)?;
                anyhow::ensure!(
                    mdims == dims,
                    "config model dims {dims:?} != artifact dims {mdims:?}"
                );
                anyhow::ensure!(
                    manifest.batches(&cfg.model.variant)?.contains(&mode.local_batch),
                    "no artifact for local batch {} of variant {}",
                    mode.local_batch,
                    cfg.model.variant
                );
                let pool = EnginePool::start(&manifest, &cfg.model.variant, opts.engine_threads)
                    .context("starting PJRT engine pool")?;
                (Backend::Pjrt(pool.handle()), Some(pool))
            }
        };
        let straggler = opts
            .straggler
            .then(|| Arc::new(StragglerModel::new(&cfg.cluster, mode.workers, cfg.seed ^ 0x57)));
        let worker_front = match cfg.cluster.workers {
            WorkerPlane::InProc => None,
            WorkerPlane::Remote => {
                // Worker-side injections live in the worker processes
                // (`gba-train worker --fail-prob/--batch-sleep-ms`);
                // accepting these session options here would silently
                // run a straggler/failure experiment with no injection.
                anyhow::ensure!(
                    !opts.straggler && opts.fail_prob == 0.0 && opts.start_sec == 0.0,
                    "--straggler / fail_prob / start_sec are in-thread worker options; \
                     with [cluster] workers = \"remote\" pass the equivalent flags to the \
                     gba-train worker processes instead"
                );
                Some(
                    WorkerFront::bind(&cfg.cluster.worker_listen, WorkerShape::of(&cfg, kind))
                        .context("binding the worker front")?,
                )
            }
        };
        let switch = match cfg.switch.policy {
            SwitchPolicyKind::Manual => SwitchPlane::manual(kind),
            SwitchPolicyKind::Adaptive => {
                // The controller drives the sync ↔ GBA pair (the
                // paper's switch); from any other launch mode it would
                // never fire — reject instead of silently running a
                // manual session the operator believes is adaptive.
                anyhow::ensure!(
                    matches!(kind, ModeKind::Sync | ModeKind::Gba),
                    "[switch] policy = \"adaptive\" drives sync <-> gba switches; \
                     launch in one of those modes (got '{}')",
                    kind.as_str()
                );
                SwitchPlane::adaptive(
                    kind,
                    cfg.switch.high_watermark,
                    cfg.switch.low_watermark,
                )
            }
        };
        Ok(TrainSession {
            cfg,
            kind,
            dims,
            gen,
            ps,
            backend: Arc::new(backend),
            _engine: engine,
            opts,
            straggler,
            worker_front,
            switch,
            next_day: AtomicUsize::new(0),
        })
    }

    pub fn ps(&self) -> &PsServer {
        &self.ps
    }

    pub fn gen(&self) -> &DataGen {
        &self.gen
    }

    /// Where remote `gba-train worker` processes connect (`[cluster]
    /// workers = "remote"` only).
    pub fn worker_addr(&self) -> Option<String> {
        self.worker_front.as_ref().map(|f| f.addr().to_string())
    }

    /// Training finished successfully: send remote workers the
    /// `SessionOver` farewell so they exit 0. Not called on error paths
    /// (and deliberately not on drop) — workers seeing an abrupt close
    /// exit nonzero, telling a supervisor the run failed. No-op for the
    /// in-thread plane.
    pub fn shutdown_workers(&self) {
        if let Some(front) = &self.worker_front {
            front.shutdown();
        }
    }

    /// Train on one day of data; returns the day's statistics.
    ///
    /// The worker plane is a config dispatch: in-thread loops
    /// (`[cluster] workers = "inproc"`, the default) or remote
    /// `gba-train worker` processes served over the wire (`"remote"`).
    /// Both planes drive the identical `run_worker` body against the
    /// token-control plane, so the resulting parameters, rows and
    /// counters are bit-for-bit identical on the same schedule.
    pub fn train_day(&self, day: usize) -> Result<DayStats> {
        let mode = self.cfg.mode(self.kind);
        let n_batches = self.gen.batches_per_day(mode.local_batch);
        self.ps.reset_counters();
        self.ps.set_day(day, n_batches);
        let t0 = Instant::now();
        let stats: Vec<WorkerStats> = match &self.worker_front {
            None => {
                let mut handles = Vec::new();
                for w in 0..mode.workers {
                    let ps = self.ps.clone();
                    let gen = self.gen.clone();
                    let backend = self.backend.clone();
                    let wp = WorkerParams {
                        id: w,
                        local_batch: mode.local_batch,
                        straggler: self.straggler.clone(),
                        start_sec: self.opts.start_sec,
                        fail_prob: self.opts.fail_prob,
                        batch_sleep_ms: 0.0,
                        seed: worker_day_seed(self.cfg.seed, day),
                    };
                    handles.push(
                        std::thread::Builder::new()
                            .name(format!("worker-{w}"))
                            .spawn(move || {
                                // A worker that aborts (Err or panic)
                                // between pull and push dies holding a
                                // claim; since day-end now *waits out*
                                // outstanding claims (so late reclaims
                                // can re-issue), an unreleased claim
                                // would park the survivors forever
                                // instead of surfacing the abort. The
                                // guard reclaims it on any abnormal
                                // exit — a no-op when no claim is held.
                                struct ReclaimOnAbort<'a> {
                                    ps: &'a PsServer,
                                    id: usize,
                                    armed: bool,
                                }
                                impl Drop for ReclaimOnAbort<'_> {
                                    fn drop(&mut self) {
                                        if self.armed {
                                            self.ps.worker_reset(self.id);
                                        }
                                    }
                                }
                                let mut guard =
                                    ReclaimOnAbort { ps: ps.as_ref(), id: w, armed: true };
                                let out = run_worker(ps.as_ref(), &gen, &backend, &wp);
                                if out.is_ok() {
                                    guard.armed = false;
                                }
                                out
                            })?,
                    );
                }
                handles
                    .into_iter()
                    .map(|h| h.join().expect("worker panicked"))
                    .collect::<Result<_>>()?
            }
            Some(front) => {
                // First day: wait for the full complement. Later days:
                // admit any replacement hellos and continue on the
                // survivors. Then stream the day over the wire — the
                // token-control plane is driven unchanged, by serving
                // threads instead of worker threads.
                front.admit_for_day(WORKER_ACCEPT_DEADLINE)?;
                front.run_day(day, &self.ps)?
            }
        };
        let mut samples = 0u64;
        let mut failures = 0u64;
        let mut busy = 0.0f64;
        for s in &stats {
            samples += s.samples;
            failures += s.failures;
            busy += s.busy_sec;
        }
        // Drain: apply any partial buffer left at end-of-day.
        self.ps.flush_partial();
        let wall = t0.elapsed().as_secs_f64();
        let counters = self.ps.counters();
        if self.worker_front.is_some() {
            // Conservation audit: every batch of the data list must have
            // resolved as applied or dropped — a reclaimed claim is
            // *re-issued* (and the replacement resolution is counted),
            // so even a day with failures covers the whole list. A
            // shortfall means the worker fleet died mid-day with
            // re-issued batches nobody was left to train — that is a
            // failed day, not a quiet DayStats. (In-thread workers
            // can't die silently: their panics and Errs propagate
            // through the joins above.)
            let resolved = counters.applied_gradients + counters.dropped_batches;
            anyhow::ensure!(
                resolved == n_batches as u64,
                "day {day} incomplete: {resolved} of {n_batches} batches resolved — \
                 worker processes died mid-day with no survivors to finish the data list"
            );
        }
        // Straggler telemetry: per-worker mean batch latency, p95 vs.
        // median across the fleet (workers that trained nothing — died
        // at day start — contribute no latency sample).
        let lat: Vec<f64> = stats
            .iter()
            .filter(|s| s.batches > 0)
            .map(|s| s.busy_sec / s.batches as f64)
            .collect();
        let (p95, med) = if lat.is_empty() {
            (0.0, 0.0)
        } else {
            (percentile(&lat, 95.0), percentile(&lat, 50.0))
        };
        // Observability: each worker's mean batch latency is one
        // histogram sample (the scrape-side quantiles then mirror the
        // fleet spread the switcher watches), and the day's reissue/drop
        // resolutions accumulate into run-total counters.
        let reg = obs::global();
        let batch_hist =
            reg.histogram("gba_worker_batch_seconds", obs::Histogram::latency_bounds());
        for &l in &lat {
            batch_hist.record(l);
        }
        reg.counter("gba_batches_reissued_total").add(counters.reissued_batches);
        reg.counter("gba_batches_dropped_total").add(counters.dropped_batches);
        self.next_day.store(day + 1, Ordering::Relaxed);
        let stats = DayStats {
            day,
            wall_sec: wall,
            samples,
            qps: samples as f64 / wall.max(1e-9),
            local_qps: samples as f64 / busy.max(1e-9) / mode.workers as f64,
            counters,
            failures,
            batch_latency_p95: p95,
            batch_latency_med: med,
        };
        reg.gauge("gba_straggler_signal").set(stats.straggler_signal());
        Ok(stats)
    }

    /// AUC over `n` eval samples of `day` (the paper's next-day protocol:
    /// call with `day = trained_day + 1`).
    pub fn eval_auc(&self, day: usize) -> Result<f64> {
        let n = self.cfg.train.eval_samples;
        let bsz = self.cfg.train.eval_batch;
        let params = self.ps.dense_params();
        let mut scores = Vec::with_capacity(n);
        let mut labels = Vec::with_capacity(n);
        let n_batches = (n / bsz).max(1);
        for b in 0..n_batches {
            let batch = self.gen.batch_by_index(day, b, bsz);
            let emb = self.ps.gather(&batch.keys, bsz, batch.fields);
            let logits = self.backend.predict(bsz, &emb, &params)?;
            scores.extend_from_slice(&logits);
            labels.extend_from_slice(&batch.labels);
        }
        Ok(auc(&scores, &labels))
    }

    /// In-memory checkpoint of the current parameters.
    pub fn checkpoint(&self) -> Checkpoint {
        Checkpoint::from_ps(self.dims, &self.ps)
    }

    /// Switch the training mode **in place**, inheriting all parameters
    /// (the paper's tuning-free switch: same hyper-parameters, new
    /// coordination). Nothing is rebuilt:
    ///
    /// 1. remote `gba-train worker` processes re-derive their
    ///    [`WorkerShape`] for the new mode through the wire-level
    ///    `SwitchMode`/`Epoch` re-handshake between days — the switch
    ///    works on the one topology where it matters, and a worker that
    ///    dies or disagrees fails the switch before any state changed;
    /// 2. the [`SwitchPlane`] advances the mode epoch (recording the
    ///    [`SwitchTrace`] event at the next training day);
    /// 3. the shard plane's `ControlPlane::swap_policy` drains any
    ///    buffered gradients under the *old* policy and installs the
    ///    new one — identical behavior on in-process and remote shards,
    ///    since the flush travels the normal `Apply` path;
    /// 4. only when the new epoch changes the optimizer pair (async ↔
    ///    the rest, Table 5.1) the shards swap optimizers over the
    ///    journaled `SwapPolicy` RPC, resetting slot state; a same-pair
    ///    switch (sync ↔ GBA, the paper's headline case) preserves the
    ///    optimizer slots — a *stronger* inherit than checkpoint
    ///    restore, which zeroed them.
    ///
    /// A same-mode switch is a no-op. Must be called between days (the
    /// continual protocol's switch point): the epoch boundary then
    /// holds no in-flight tokens, and in-flight gradients of the old
    /// epoch are flushed, not carried over.
    pub fn switch_mode(&mut self, kind: ModeKind) -> Result<()> {
        self.switch_mode_with_signal(kind, None)
    }

    /// [`switch_mode`](Self::switch_mode), annotating the recorded
    /// [`SwitchEvent`](crate::coordinator::SwitchEvent) with the
    /// straggler signal that drove the decision (adaptive switches
    /// only; manual switches record `None`).
    fn switch_mode_with_signal(&mut self, kind: ModeKind, signal: Option<f64>) -> Result<()> {
        if kind == self.kind {
            return Ok(());
        }
        anyhow::ensure!(
            self.cfg.has_mode(kind),
            "cannot switch to mode {}: the config does not define [mode.{}]",
            kind.as_str(),
            kind.as_str()
        );
        // Under the adaptive policy a manual switch out of the sync/gba
        // pair would strand the controller (it only drives those two):
        // every later storm would silently propose nothing — the exact
        // failure the build-time launch-mode guard rejects. Reject the
        // target here for the same reason.
        anyhow::ensure!(
            !self.switch.is_adaptive() || matches!(kind, ModeKind::Sync | ModeKind::Gba),
            "[switch] policy = \"adaptive\" drives sync <-> gba switches; switching to \
             '{}' would silently disable the controller (use --switch-policy manual)",
            kind.as_str()
        );
        let mode = self.cfg.mode(kind);
        // PJRT executes AOT artifacts per (variant, batch): refuse a
        // switch whose local batch has no artifact *before* touching
        // any state, not at the first train step of the new epoch.
        if self.opts.backend == BackendKind::Pjrt {
            let manifest = Manifest::load(&self.opts.artifacts_dir)?;
            anyhow::ensure!(
                manifest.batches(&self.cfg.model.variant)?.contains(&mode.local_batch),
                "no artifact for local batch {} of variant {} (mode {})",
                mode.local_batch,
                self.cfg.model.variant,
                kind.as_str()
            );
        }
        let day = self.next_day.load(Ordering::Relaxed);

        // Worker plane first: remote processes re-handshake (in-thread
        // loops just pick the new mode up from `cfg.mode(self.kind)`
        // next day). Running this *before* any state changes means a
        // worker that dies or disagrees mid-re-handshake fails the
        // switch with the session's own state untouched — the epoch
        // boundary holds no in-flight tokens, so nothing leaks.
        let epoch = self.switch.epoch() + 1;
        if let Some(front) = &self.worker_front {
            front
                .begin_epoch(epoch, kind, WorkerShape::of(&self.cfg, kind))
                .with_context(|| {
                    format!("switching the remote worker plane to {}", kind.as_str())
                })?;
        }
        let advanced = self.switch.advance_with_signal(day, kind, signal);
        debug_assert_eq!(advanced, epoch);
        obs::global().counter("gba_mode_switches_total").inc();

        // Shard plane: drain buffered gradients under the old policy,
        // install the new one; swap optimizers only when the pair
        // actually changes (Table 5.1: only the async family differs).
        let (old_okind, old_lr) = optim_for(&self.cfg, self.kind);
        let (new_okind, new_lr) = optim_for(&self.cfg, kind);
        self.ps.switch_policy(make_policy(kind, &mode, self.cfg.gba_m_effective()));
        if (old_okind, old_lr) != (new_okind, new_lr) {
            self.ps.swap_optimizer(new_okind, new_lr, true);
        }
        // The straggler model is shaped by the mode's worker count.
        if self.straggler.is_some() {
            self.straggler = Some(Arc::new(StragglerModel::new(
                &self.cfg.cluster,
                mode.workers,
                self.cfg.seed ^ 0x57,
            )));
        }
        self.kind = kind;
        Ok(())
    }

    /// The switch trace accumulated so far (every epoch advance, manual
    /// or adaptive) — emitted into run metrics by the launcher and the
    /// switching experiments.
    pub fn switch_trace(&self) -> &SwitchTrace {
        self.switch.trace()
    }

    /// Current mode epoch id (0 = the launch mode).
    pub fn mode_epoch(&self) -> u64 {
        self.switch.epoch()
    }

    /// Whether the session decides switches itself (`[switch] policy =
    /// "adaptive"`).
    pub fn is_adaptive(&self) -> bool {
        self.switch.is_adaptive()
    }

    /// Feed one finished day's telemetry to the adaptive switcher and
    /// perform the switch it proposes, if any. Call between days (after
    /// `train_day`); returns the new mode when a switch happened. A
    /// no-op (always `Ok(None)`) under `[switch] policy = "manual"`.
    pub fn observe_day(&mut self, stats: &DayStats) -> Result<Option<ModeKind>> {
        let signal = stats.straggler_signal();
        // Second controller signal: the staleness policy's normalized
        // parameter gap at the last flush, squashed to [0, 1) on the
        // same scale as the straggler signal. 0 under the default `gba`
        // policy, so manual and gba runs behave exactly as before.
        let raw_gap = self.ps.staleness_gap();
        let gap_signal = raw_gap / (raw_gap + 1.0);
        let combined = signal.max(gap_signal);
        match self.switch.observe_signals(signal, gap_signal) {
            None => Ok(None),
            Some(to) => {
                self.switch_mode_with_signal(to, Some(combined))?;
                Ok(Some(to))
            }
        }
    }

    /// Train `days`, evaluating on the subsequent day after each (the
    /// paper's continual protocol). Returns (day, AUC-on-day+1) pairs.
    pub fn run_continual(&self, days: std::ops::Range<usize>) -> Result<Vec<(usize, f64, DayStats)>> {
        let mut out = Vec::new();
        for d in days {
            let stats = self.train_day(d)?;
            let a = self.eval_auc(d + 1)?;
            out.push((d, a, stats));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> ExperimentConfig {
        ExperimentConfig::from_toml(
            r#"
name = "session-test"
seed = 11
[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 32
hidden2 = 16
vocab_size = 2000
zipf_s = 1.1
[data]
days_base = 2
days_eval = 1
samples_per_day = 4096
teacher_seed = 3
label_noise = 0.02
[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.01
lr_async = 0.05
eval_batch = 256
eval_samples = 2048
[mode.sync]
workers = 4
local_batch = 64
[mode.async]
workers = 8
local_batch = 16
[mode.gba]
workers = 8
local_batch = 32
iota = 3
[mode.hop_bs]
workers = 8
local_batch = 32
bound = 2
[mode.bsp]
workers = 8
local_batch = 32
aggregate = 8
[mode.hop_bw]
workers = 4
local_batch = 64
backup = 1
"#,
        )
        .unwrap()
    }

    #[test]
    fn sync_training_improves_auc() {
        let s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        let before = s.eval_auc(1).unwrap();
        s.train_day(0).unwrap();
        let after = s.eval_auc(1).unwrap();
        assert!(after > before + 0.05, "auc {before} -> {after}");
        assert!(after > 0.6, "auc after one day = {after}");
    }

    #[test]
    fn gba_training_improves_auc_and_matches_global_batch() {
        let c = cfg();
        let m = c.gba_m();
        assert_eq!(m, 8); // 4*64 / 32
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        let stats = s.train_day(0).unwrap();
        // steps ≈ batches / M
        let batches = stats.counters.applied_gradients + stats.counters.dropped_batches;
        assert!(stats.counters.global_steps >= batches / m as u64);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "gba auc = {a}");
    }

    #[test]
    fn switch_sync_to_gba_keeps_accuracy() {
        let mut s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        s.train_day(0).unwrap();
        let before = s.eval_auc(1).unwrap();
        s.switch_mode(ModeKind::Gba).unwrap();
        let inherited = s.eval_auc(1).unwrap();
        // Inheriting parameters must preserve eval exactly (same params).
        assert!((inherited - before).abs() < 1e-9);
        s.train_day(1).unwrap();
        let after = s.eval_auc(2).unwrap();
        assert!(after > before - 0.05, "switch degraded: {before} -> {after}");
    }

    /// The in-place switch: parameters AND optimizer slots survive a
    /// same-pair switch (sync → GBA both run Adam at `lr`), the epoch
    /// advances, and the trace lands on the next training day.
    #[test]
    fn inplace_switch_inherits_slots_and_records_trace() {
        let mut s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        s.train_day(0).unwrap();
        let params = s.ps().dense_params();
        let slots = s.ps().dense_slots();
        assert!(slots.iter().any(|t| t.iter().any(|&x| x != 0.0)), "Adam slots live");
        s.switch_mode(ModeKind::Gba).unwrap();
        assert_eq!(s.kind, ModeKind::Gba);
        assert_eq!(s.mode_epoch(), 1);
        assert_eq!(s.ps().mode(), ModeKind::Gba, "control plane swapped in place");
        assert_eq!(s.ps().dense_params(), params, "parameters inherited");
        assert_eq!(s.ps().dense_slots(), slots, "same-pair switch keeps optimizer slots");
        let trace = s.switch_trace();
        assert_eq!(trace.events.len(), 1);
        assert_eq!(
            (trace.events[0].day, trace.events[0].from, trace.events[0].to),
            (1, ModeKind::Sync, ModeKind::Gba)
        );
        // Same-mode switch is a no-op: no event, no epoch.
        s.switch_mode(ModeKind::Gba).unwrap();
        assert_eq!(s.mode_epoch(), 1);
        assert_eq!(s.switch_trace().events.len(), 1);
        // And the new epoch trains.
        let stats = s.train_day(1).unwrap();
        assert!(stats.counters.global_steps > 0);
    }

    /// Switching into the async family swaps the optimizer pair on the
    /// shards (Adam → Adagrad, `lr_async`) and resets slot state; the
    /// parameters themselves are inherited untouched.
    #[test]
    fn switch_to_async_swaps_optimizer_and_resets_slots() {
        let mut s = TrainSession::new(cfg(), ModeKind::Sync, SessionOptions::default()).unwrap();
        s.train_day(0).unwrap();
        let params = s.ps().dense_params();
        let adam_slots = s.ps().dense_slots();
        s.switch_mode(ModeKind::Async).unwrap();
        assert_eq!(s.ps().dense_params(), params, "parameters inherited");
        let ada_slots = s.ps().dense_slots();
        for (t, slot) in ada_slots.iter().enumerate() {
            assert_eq!(slot.len(), adam_slots[t].len() / 2, "Adagrad: 1 slot vs Adam's 2");
            assert!(slot.iter().all(|&x| x == 0.0), "cross-pair switch resets state");
        }
        let stats = s.train_day(1).unwrap();
        assert!(stats.counters.global_steps > 0, "async epoch trains");
        // And back: another in-place swap, back to Adam shapes.
        s.switch_mode(ModeKind::Sync).unwrap();
        assert_eq!(s.mode_epoch(), 2);
        let stats = s.train_day(2).unwrap();
        assert!(stats.counters.global_steps > 0);
    }

    /// The adaptive plane switches the live session from day telemetry:
    /// a straggler-heavy day proposes GBA, a calm one proposes sync.
    #[test]
    fn adaptive_policy_switches_from_day_telemetry() {
        let mut c = cfg();
        c.switch.policy = crate::config::SwitchPolicyKind::Adaptive;
        let mut s = TrainSession::new(c, ModeKind::Sync, SessionOptions::default()).unwrap();
        assert!(s.is_adaptive());
        let day = |p95: f64, med: f64| DayStats {
            day: 0,
            wall_sec: 1.0,
            samples: 0,
            qps: 0.0,
            counters: TrainCounters::default(),
            failures: 0,
            local_qps: 0.0,
            batch_latency_p95: p95,
            batch_latency_med: med,
        };
        // Storm: p95 10× median → signal 0.9 > high watermark.
        assert_eq!(s.observe_day(&day(0.1, 0.01)).unwrap(), Some(ModeKind::Gba));
        assert_eq!(s.kind, ModeKind::Gba);
        // Still stormy: hysteresis holds GBA.
        assert_eq!(s.observe_day(&day(0.1, 0.05)).unwrap(), None);
        // Calm fleet → signal 0.1 < low watermark → back to sync.
        assert_eq!(s.observe_day(&day(0.1, 0.09)).unwrap(), Some(ModeKind::Sync));
        assert_eq!(s.switch_trace().events.len(), 2);
        // A manual switch out of the sync/gba pair would strand the
        // controller — rejected, and the session state is untouched.
        assert!(s.switch_mode(ModeKind::Async).is_err());
        assert_eq!(s.kind, ModeKind::Sync);
        assert_eq!(s.switch_trace().events.len(), 2);
    }

    /// Adaptive policy from a mode the controller cannot drive is a
    /// build-time error, not a silent manual session.
    #[test]
    fn adaptive_policy_rejects_non_switchable_launch_mode() {
        let mut c = cfg();
        c.switch.policy = crate::config::SwitchPolicyKind::Adaptive;
        let err = TrainSession::new(c, ModeKind::Async, SessionOptions::default())
            .err()
            .expect("async + adaptive must be rejected");
        assert!(format!("{err:#}").contains("adaptive"), "unhelpful error: {err:#}");
    }

    #[test]
    fn sharded_ps_session_trains() {
        let mut c = cfg();
        c.ps.n_shards = 4;
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        assert_eq!(s.ps().n_shards(), 4);
        let stats = s.train_day(0).unwrap();
        assert!(stats.counters.global_steps > 0);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "sharded gba auc = {a}");
    }

    #[test]
    fn socket_transport_session_trains() {
        let mut c = cfg();
        c.ps.n_shards = 2;
        c.ps.transport = crate::config::TransportKind::Socket;
        let s = TrainSession::new(c, ModeKind::Gba, SessionOptions::default()).unwrap();
        assert_eq!(s.ps().transport(), crate::config::TransportKind::Socket);
        let stats = s.train_day(0).unwrap();
        assert!(stats.counters.global_steps > 0);
        let a = s.eval_auc(1).unwrap();
        assert!(a > 0.6, "socket gba auc = {a}");
    }

    #[test]
    fn all_modes_run_a_day() {
        for kind in crate::config::ModeKind::ALL {
            let s = TrainSession::new(cfg(), kind, SessionOptions::default()).unwrap();
            let stats = s.train_day(0).unwrap();
            assert!(stats.counters.global_steps > 0, "{kind:?} made no steps");
            let a = s.eval_auc(1).unwrap();
            assert!(a > 0.52, "{kind:?} auc = {a}");
        }
    }
}
