//! Native (pure-Rust) implementation of the L2 model — forward and
//! backward — mirroring `python/compile/model.py` bit-for-bit in structure.
//!
//! Two jobs:
//! 1. **Fast compute backend** for the accuracy experiments (Fig. 2/3/6/7/8):
//!    the models in this reproduction are small enough that FFI+PJRT
//!    overhead dominates, so experiments default to this path. The PJRT
//!    backend is the production path; an integration test pins the two
//!    to the same numerics.
//! 2. **Independent oracle** for the AOT pipeline: any disagreement
//!    between this implementation and the artifact indicates a lowering
//!    or layout bug.

use crate::runtime::{HostTensor, TrainOut, VariantDims};
use crate::util::rng::Pcg64;

/// Row-major matmul out[m,n] = a[m,k] @ b[k,n]  (+= when `acc`).
fn matmul(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    out.fill(0.0);
    // 4-row register blocking: each pass over a row of `b` feeds four
    // output rows, quartering the b-matrix memory traffic (b is the
    // largest operand and is re-streamed per output row otherwise).
    let mut i = 0;
    while i + 4 <= m {
        let (o01, o23) = out[i * n..(i + 4) * n].split_at_mut(2 * n);
        let (o0, o1) = o01.split_at_mut(n);
        let (o2, o3) = o23.split_at_mut(n);
        for p in 0..k {
            let a0 = a[i * k + p];
            let a1 = a[(i + 1) * k + p];
            let a2 = a[(i + 2) * k + p];
            let a3 = a[(i + 3) * k + p];
            if a0 == 0.0 && a1 == 0.0 && a2 == 0.0 && a3 == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for j in 0..n {
                let bv = brow[j];
                o0[j] += a0 * bv;
                o1[j] += a1 * bv;
                o2[j] += a2 * bv;
                o3[j] += a3 * bv;
            }
        }
        i += 4;
    }
    while i < m {
        let orow = &mut out[i * n..(i + 1) * n];
        for p in 0..k {
            let av = a[i * k + p];
            if av == 0.0 {
                continue;
            }
            let brow = &b[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
        i += 1;
    }
}

/// out[m,n] = a[m,k] @ b[n,k]^T
///
/// The inner product is split over 8 independent accumulators so the
/// compiler can vectorize the reduction (float adds are not associative,
/// so a single-accumulator loop defeats auto-vectorization).
fn matmul_bt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = [0.0f32; 8];
            let chunks = k / 8;
            for c in 0..chunks {
                let base = c * 8;
                for l in 0..8 {
                    acc[l] += arow[base + l] * brow[base + l];
                }
            }
            let mut tail = 0.0f32;
            for p in chunks * 8..k {
                tail += arow[p] * brow[p];
            }
            out[i * n + j] = acc.iter().sum::<f32>() + tail;
        }
    }
}

/// out[k,n] = a[m,k]^T @ b[m,n]
fn matmul_at(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    out.fill(0.0);
    // 4-row blocking over the summation index: four (a,b) row pairs
    // accumulate into `out` per pass, quartering the out-matrix traffic
    // (out is k x n and is the streamed operand here).
    let mut i = 0;
    while i + 4 <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let b0 = &b[i * n..(i + 1) * n];
        let b1 = &b[(i + 1) * n..(i + 2) * n];
        let b2 = &b[(i + 2) * n..(i + 3) * n];
        let b3 = &b[(i + 3) * n..(i + 4) * n];
        for p in 0..k {
            let (v0, v1, v2, v3) = (a0[p], a1[p], a2[p], a3[p]);
            if v0 == 0.0 && v1 == 0.0 && v2 == 0.0 && v3 == 0.0 {
                continue;
            }
            let orow = &mut out[p * n..(p + 1) * n];
            for j in 0..n {
                orow[j] += v0 * b0[j] + v1 * b1[j] + v2 * b2[j] + v3 * b3[j];
            }
        }
        i += 4;
    }
    while i < m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for p in 0..k {
            let av = arow[p];
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[p * n..(p + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
        i += 1;
    }
}

#[inline]
fn sigmoid(z: f32) -> f32 {
    1.0 / (1.0 + (-z).exp())
}

/// Forward-pass intermediates kept for the backward pass.
struct Residuals {
    h0: Vec<f32>,   // [B, IN] concat(x, fm)
    a1: Vec<f32>,   // [B, H1] post-ReLU
    a2: Vec<f32>,   // [B, H2] post-ReLU
    s: Vec<f32>,    // [B, D] field sums (FM residual)
    logits: Vec<f32>,
}

#[derive(Clone, Debug)]
pub struct NativeModel {
    pub dims: VariantDims,
}

impl NativeModel {
    pub fn new(dims: VariantDims) -> Self {
        NativeModel { dims }
    }

    /// He-initialized dense parameters (weights N(0, 2/fan_in), zero bias).
    /// Same *scheme* as the python side; exact values come from this RNG,
    /// so tests that cross-check PJRT vs native pass parameters explicitly.
    pub fn init_params(&self, seed: u64) -> Vec<HostTensor> {
        let mut rng = Pcg64::new(seed, 0x9a17);
        self.dims
            .param_shapes()
            .into_iter()
            .map(|shape| {
                if shape.len() == 2 {
                    let scale = (2.0 / shape[0] as f64).sqrt();
                    let n: usize = shape.iter().product();
                    let data =
                        (0..n).map(|_| (rng.normal() * scale) as f32).collect::<Vec<_>>();
                    HostTensor { shape, data }
                } else {
                    HostTensor::zeros(shape)
                }
            })
            .collect()
    }

    fn forward_full(&self, emb: &HostTensor, params: &[HostTensor]) -> Residuals {
        let d = &self.dims;
        let b = emb.shape[0];
        let (f, dim) = (d.fields, d.emb_dim);
        debug_assert_eq!(emb.shape, vec![b, f, dim]);
        let xin = f * dim;
        let h0w = d.mlp_in;

        // h0 = concat(flatten(emb), fm)
        let mut h0 = vec![0.0f32; b * h0w];
        let mut s = vec![0.0f32; b * dim];
        for i in 0..b {
            let erow = &emb.data[i * xin..(i + 1) * xin];
            h0[i * h0w..i * h0w + xin].copy_from_slice(erow);
            let srow = &mut s[i * dim..(i + 1) * dim];
            for fi in 0..f {
                for di in 0..dim {
                    srow[di] += erow[fi * dim + di];
                }
            }
            // fm = 0.5 * (s^2 - sum e^2)
            let fmrow = &mut h0[i * h0w + xin..(i + 1) * h0w];
            for di in 0..dim {
                let mut sq = 0.0;
                for fi in 0..f {
                    let e = erow[fi * dim + di];
                    sq += e * e;
                }
                fmrow[di] = 0.5 * (srow[di] * srow[di] - sq);
            }
        }

        let (w1, b1, w2, b2, w3, b3) =
            (&params[0], &params[1], &params[2], &params[3], &params[4], &params[5]);
        let (h1, h2) = (d.hidden1, d.hidden2);

        let mut a1 = vec![0.0f32; b * h1];
        matmul(&h0, &w1.data, &mut a1, b, h0w, h1);
        for i in 0..b {
            for j in 0..h1 {
                a1[i * h1 + j] = (a1[i * h1 + j] + b1.data[j]).max(0.0);
            }
        }
        let mut a2 = vec![0.0f32; b * h2];
        matmul(&a1, &w2.data, &mut a2, b, h1, h2);
        for i in 0..b {
            for j in 0..h2 {
                a2[i * h2 + j] = (a2[i * h2 + j] + b2.data[j]).max(0.0);
            }
        }
        let mut logits = vec![0.0f32; b];
        for i in 0..b {
            let mut acc = b3.data[0];
            for j in 0..h2 {
                acc += a2[i * h2 + j] * w3.data[j];
            }
            logits[i] = acc;
        }
        Residuals { h0, a1, a2, s, logits }
    }

    /// Inference logits.
    pub fn predict(&self, emb: &HostTensor, params: &[HostTensor]) -> Vec<f32> {
        self.forward_full(emb, params).logits
    }

    /// Mean BCE loss + gradients — mirrors the AOT `train_step` signature.
    pub fn train_step(
        &self,
        emb: &HostTensor,
        params: &[HostTensor],
        labels: &[f32],
    ) -> TrainOut {
        let d = &self.dims;
        let b = emb.shape[0];
        debug_assert_eq!(labels.len(), b);
        let (f, dim) = (d.fields, d.emb_dim);
        let xin = f * dim;
        let h0w = d.mlp_in;
        let (h1, h2) = (d.hidden1, d.hidden2);
        let res = self.forward_full(emb, params);
        let (w1, w2, w3) = (&params[0], &params[2], &params[4]);

        // loss = mean(max(z,0) - z*y + log1p(exp(-|z|)))
        let mut loss = 0.0f64;
        for i in 0..b {
            let z = res.logits[i];
            let y = labels[i];
            loss += (z.max(0.0) - z * y + (-z.abs()).exp().ln_1p()) as f64;
        }
        let loss = (loss / b as f64) as f32;

        // dz3[i] = (sigmoid(z) - y) / B
        let invb = 1.0 / b as f32;
        let dz3: Vec<f32> =
            (0..b).map(|i| (sigmoid(res.logits[i]) - labels[i]) * invb).collect();

        // layer 3: w3 [H2,1]
        let mut dw3 = vec![0.0f32; h2];
        let mut db3 = 0.0f32;
        let mut da2 = vec![0.0f32; b * h2];
        for i in 0..b {
            db3 += dz3[i];
            for j in 0..h2 {
                dw3[j] += res.a2[i * h2 + j] * dz3[i];
                da2[i * h2 + j] = dz3[i] * w3.data[j];
            }
        }
        // relu mask
        let mut dz2 = da2;
        for (dz, a) in dz2.iter_mut().zip(&res.a2) {
            if *a <= 0.0 {
                *dz = 0.0;
            }
        }
        let mut dw2 = vec![0.0f32; h1 * h2];
        matmul_at(&res.a1, &dz2, &mut dw2, b, h1, h2);
        let mut db2 = vec![0.0f32; h2];
        for i in 0..b {
            for j in 0..h2 {
                db2[j] += dz2[i * h2 + j];
            }
        }
        let mut da1 = vec![0.0f32; b * h1];
        matmul_bt(&dz2, &w2.data, &mut da1, b, h2, h1);
        let mut dz1 = da1;
        for (dz, a) in dz1.iter_mut().zip(&res.a1) {
            if *a <= 0.0 {
                *dz = 0.0;
            }
        }
        let mut dw1 = vec![0.0f32; h0w * h1];
        matmul_at(&res.h0, &dz1, &mut dw1, b, h0w, h1);
        let mut db1 = vec![0.0f32; h1];
        for i in 0..b {
            for j in 0..h1 {
                db1[j] += dz1[i * h1 + j];
            }
        }
        let mut dh0 = vec![0.0f32; b * h0w];
        matmul_bt(&dz1, &w1.data, &mut dh0, b, h1, h0w);

        // demb = dx + dfm * (s - e)
        let mut demb = vec![0.0f32; b * xin];
        for i in 0..b {
            let erow = &emb.data[i * xin..(i + 1) * xin];
            let dxrow = &dh0[i * h0w..i * h0w + xin];
            let dfmrow = &dh0[i * h0w + xin..(i + 1) * h0w];
            let srow = &res.s[i * dim..(i + 1) * dim];
            let drow = &mut demb[i * xin..(i + 1) * xin];
            for fi in 0..f {
                for di in 0..dim {
                    let idx = fi * dim + di;
                    drow[idx] = dxrow[idx] + dfmrow[di] * (srow[di] - erow[idx]);
                }
            }
        }

        TrainOut {
            loss,
            logits: res.logits,
            d_emb: HostTensor { shape: vec![b, f, dim], data: demb },
            d_dense: vec![
                HostTensor { shape: vec![h0w, h1], data: dw1 },
                HostTensor { shape: vec![h1], data: db1 },
                HostTensor { shape: vec![h1, h2], data: dw2 },
                HostTensor { shape: vec![h2], data: db2 },
                HostTensor { shape: vec![h2, 1], data: dw3 },
                HostTensor { shape: vec![1], data: vec![db3] },
            ],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> VariantDims {
        VariantDims { fields: 3, emb_dim: 4, hidden1: 8, hidden2: 5, mlp_in: 16 }
    }

    fn rand_tensor(rng: &mut Pcg64, shape: Vec<usize>, scale: f32) -> HostTensor {
        let n: usize = shape.iter().product();
        HostTensor { shape, data: (0..n).map(|_| (rng.next_f32() * 2.0 - 1.0) * scale).collect() }
    }

    fn setup() -> (NativeModel, HostTensor, Vec<HostTensor>, Vec<f32>) {
        let m = NativeModel::new(dims());
        let mut rng = Pcg64::seeded(3);
        let b = 6;
        let emb = rand_tensor(&mut rng, vec![b, 3, 4], 0.4);
        let params = m.init_params(1);
        let labels: Vec<f32> = (0..b).map(|i| (i % 2) as f32).collect();
        (m, emb, params, labels)
    }

    #[test]
    fn loss_matches_manual_bce() {
        let (m, emb, params, labels) = setup();
        let out = m.train_step(&emb, &params, &labels);
        let mut want = 0.0f64;
        for (z, y) in out.logits.iter().zip(&labels) {
            let p = sigmoid(*z) as f64;
            want += -(*y as f64) * p.ln() - (1.0 - *y as f64) * (1.0 - p).ln();
        }
        want /= labels.len() as f64;
        assert!((out.loss as f64 - want).abs() < 1e-5, "{} vs {want}", out.loss);
    }

    #[test]
    fn gradcheck_dense_params() {
        let (m, emb, params, labels) = setup();
        let out = m.train_step(&emb, &params, &labels);
        let eps = 1e-3f32;
        // spot-check a handful of coordinates in every param tensor
        for (pi, p) in params.iter().enumerate() {
            let idxs: Vec<usize> =
                (0..p.data.len()).step_by((p.data.len() / 5).max(1)).take(5).collect();
            for &i in &idxs {
                let mut plus = params.clone();
                plus[pi].data[i] += eps;
                let lp = m.train_step(&emb, &plus, &labels).loss;
                let mut minus = params.clone();
                minus[pi].data[i] -= eps;
                let lm = m.train_step(&emb, &minus, &labels).loss;
                let fd = (lp - lm) / (2.0 * eps);
                let an = out.d_dense[pi].data[i];
                assert!(
                    (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                    "param {pi} idx {i}: fd={fd} analytic={an}"
                );
            }
        }
    }

    #[test]
    fn gradcheck_embeddings() {
        let (m, emb, params, labels) = setup();
        let out = m.train_step(&emb, &params, &labels);
        let eps = 1e-3f32;
        for i in (0..emb.data.len()).step_by(7) {
            let mut plus = emb.clone();
            plus.data[i] += eps;
            let lp = m.train_step(&plus, &params, &labels).loss;
            let mut minus = emb.clone();
            minus.data[i] -= eps;
            let lm = m.train_step(&minus, &params, &labels).loss;
            let fd = (lp - lm) / (2.0 * eps);
            let an = out.d_emb.data[i];
            assert!(
                (fd - an).abs() < 2e-3 + 0.05 * fd.abs().max(an.abs()),
                "emb idx {i}: fd={fd} analytic={an}"
            );
        }
    }

    #[test]
    fn sgd_steps_reduce_loss() {
        let (m, emb, mut params, labels) = setup();
        let first = m.train_step(&emb, &params, &labels).loss;
        let mut last = first;
        for _ in 0..100 {
            let out = m.train_step(&emb, &params, &labels);
            for (p, g) in params.iter_mut().zip(&out.d_dense) {
                p.axpy(-0.3, g);
            }
            last = out.loss;
        }
        assert!(last < first * 0.6, "{first} -> {last}");
    }

    #[test]
    fn predict_matches_train_logits() {
        let (m, emb, params, labels) = setup();
        let out = m.train_step(&emb, &params, &labels);
        let logits = m.predict(&emb, &params);
        assert_eq!(logits, out.logits);
    }

    #[test]
    fn single_field_fm_is_zero() {
        let d = VariantDims { fields: 1, emb_dim: 4, hidden1: 4, hidden2: 3, mlp_in: 8 };
        let m = NativeModel::new(d);
        let mut rng = Pcg64::seeded(5);
        let emb = rand_tensor(&mut rng, vec![2, 1, 4], 1.0);
        let params = m.init_params(0);
        let res = m.forward_full(&emb, &params);
        // fm part of h0 (last emb_dim columns) must be zero
        for i in 0..2 {
            for di in 0..4 {
                assert!(res.h0[i * 8 + 4 + di].abs() < 1e-6);
            }
        }
    }
}
