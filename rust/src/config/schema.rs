//! Config structs and TOML binding.

use anyhow::{bail, Context, Result};

use crate::staleness::{StalenessConfig, StalenessPolicyKind};
use crate::util::toml::TomlDoc;

/// The six training modes evaluated in the paper (Table 5.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum ModeKind {
    Sync,
    Async,
    HopBs,
    Bsp,
    HopBw,
    Gba,
}

impl ModeKind {
    pub const ALL: [ModeKind; 6] =
        [ModeKind::Sync, ModeKind::Async, ModeKind::HopBs, ModeKind::Bsp, ModeKind::HopBw, ModeKind::Gba];

    pub fn as_str(&self) -> &'static str {
        match self {
            ModeKind::Sync => "sync",
            ModeKind::Async => "async",
            ModeKind::HopBs => "hop_bs",
            ModeKind::Bsp => "bsp",
            ModeKind::HopBw => "hop_bw",
            ModeKind::Gba => "gba",
        }
    }

    /// Display name as the paper prints it.
    pub fn paper_name(&self) -> &'static str {
        match self {
            ModeKind::Sync => "Sync.",
            ModeKind::Async => "Async.",
            ModeKind::HopBs => "Hop-BS",
            ModeKind::Bsp => "BSP",
            ModeKind::HopBw => "Hop-BW",
            ModeKind::Gba => "GBA",
        }
    }

    pub fn parse(s: &str) -> Result<ModeKind> {
        Ok(match s {
            "sync" => ModeKind::Sync,
            "async" => ModeKind::Async,
            "hop_bs" | "hop-bs" => ModeKind::HopBs,
            "bsp" => ModeKind::Bsp,
            "hop_bw" | "hop-bw" => ModeKind::HopBw,
            "gba" => ModeKind::Gba,
            _ => bail!("unknown mode '{s}'"),
        })
    }

    /// Asynchronous-family modes use the async optimizer/lr pair
    /// (Table 5.1: Adagrad for Async., Adam for the rest).
    pub fn is_fully_async(&self) -> bool {
        matches!(self, ModeKind::Async)
    }

    /// Stable one-byte encoding for the wire (the worker-plane mode
    /// re-handshake announces the new epoch's mode in a frame).
    pub fn wire_id(&self) -> u8 {
        match self {
            ModeKind::Sync => 0,
            ModeKind::Async => 1,
            ModeKind::HopBs => 2,
            ModeKind::Bsp => 3,
            ModeKind::HopBw => 4,
            ModeKind::Gba => 5,
        }
    }

    pub fn from_wire(id: u8) -> Result<ModeKind> {
        Ok(match id {
            0 => ModeKind::Sync,
            1 => ModeKind::Async,
            2 => ModeKind::HopBs,
            3 => ModeKind::Bsp,
            4 => ModeKind::HopBw,
            5 => ModeKind::Gba,
            _ => bail!("unknown mode wire id {id}"),
        })
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptimKind {
    Sgd,
    Adagrad,
    Adam,
}

impl OptimKind {
    pub fn parse(s: &str) -> Result<OptimKind> {
        Ok(match s {
            "sgd" => OptimKind::Sgd,
            "adagrad" => OptimKind::Adagrad,
            "adam" => OptimKind::Adam,
            _ => bail!("unknown optimizer '{s}'"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            OptimKind::Sgd => "sgd",
            OptimKind::Adagrad => "adagrad",
            OptimKind::Adam => "adam",
        }
    }

    /// Stable one-byte encoding for the wire (the `SwapPolicy` shard RPC
    /// carries the mode epoch's optimizer kind).
    pub fn wire_id(&self) -> u8 {
        match self {
            OptimKind::Sgd => 0,
            OptimKind::Adagrad => 1,
            OptimKind::Adam => 2,
        }
    }

    pub fn from_wire(id: u8) -> Result<OptimKind> {
        Ok(match id {
            0 => OptimKind::Sgd,
            1 => OptimKind::Adagrad,
            2 => OptimKind::Adam,
            _ => bail!("unknown optimizer wire id {id}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct ModelConfig {
    /// AOT variant name in artifacts/manifest.json (PJRT backend).
    pub variant: String,
    pub fields: usize,
    pub emb_dim: usize,
    pub hidden1: usize,
    pub hidden2: usize,
    /// Per-field ID space for the synthetic generator (hash-expandable at
    /// the store level; this bounds the generator, not the table).
    pub vocab_size: u64,
    /// Zipf exponent of the ID popularity distribution (Fig. 4).
    pub zipf_s: f64,
}

impl ModelConfig {
    pub fn mlp_in(&self) -> usize {
        self.fields * self.emb_dim + self.emb_dim
    }
}

#[derive(Clone, Debug)]
pub struct DataConfig {
    pub days_base: usize,
    pub days_eval: usize,
    pub samples_per_day: usize,
    pub teacher_seed: u64,
    /// Probability a label is flipped (bounds achievable AUC below 1).
    pub label_noise: f64,
    /// Per-day teacher drift magnitude (continual-learning signal).
    pub drift: f64,
}

#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// Optimizer for sync / semi-sync modes (Table 5.1: Adam).
    pub optimizer: OptimKind,
    /// Optimizer for fully-async mode (Table 5.1: Adagrad).
    pub optimizer_async: OptimKind,
    pub lr: f64,
    pub lr_async: f64,
    pub eval_batch: usize,
    /// Samples evaluated per AUC measurement.
    pub eval_samples: usize,
    /// Staleness-decay policy at the control plane's flush point
    /// (`[train] staleness_policy` + per-policy knobs; default `gba`,
    /// the paper's fixed decay, bit-identical to pre-seam training).
    pub staleness: StalenessConfig,
}

#[derive(Clone, Copy, Debug)]
pub struct ModeConfig {
    pub workers: usize,
    pub local_batch: usize,
    /// GBA: staleness tolerance ι (Eqn. 1).
    pub iota: u64,
    /// Hop-BS: staleness bound b1.
    pub bound: u64,
    /// BSP: aggregation count b2.
    pub aggregate: usize,
    /// Hop-BW: dropped (backup) gradients per step b3.
    pub backup: usize,
    /// GBA: explicit buffer capacity M. Default (None) derives
    /// M = G_s / B_a per §4.1; Fig. 8 sets M = workers to let the global
    /// batch diverge from the sync global batch.
    pub m_override: Option<usize>,
}

impl Default for ModeConfig {
    fn default() -> Self {
        ModeConfig {
            workers: 1,
            local_batch: 1,
            iota: 3,
            bound: 2,
            aggregate: 1,
            backup: 0,
            m_override: None,
        }
    }
}

/// How the PS front reaches its shard services (`[ps] transport`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process endpoints over `util/chan` duplex pairs (default).
    InProc,
    /// Localhost TCP endpoints framed through the versioned binary
    /// codec, with the service still a thread of this process.
    /// Bit-for-bit identical results to `InProc` (pinned by
    /// `tests/shard_invariance.rs`); the stepping stone to `Remote`.
    Socket,
    /// Shards are *separate OS processes*: each endpoint is a TCP
    /// connection to a `gba-train shard-server` listening at the
    /// matching `[ps] shard_addrs` entry. Same codec and service loop
    /// as `Socket`, so results stay bit-for-bit identical; the
    /// supervisor recovers a dropped peer by reconnecting and replaying
    /// its journal instead of respawning a thread.
    Remote,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        Ok(match s {
            "inproc" => TransportKind::InProc,
            "socket" => TransportKind::Socket,
            "remote" => TransportKind::Remote,
            _ => bail!("unknown transport '{s}' (inproc|socket|remote)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Socket => "socket",
            TransportKind::Remote => "remote",
        }
    }
}

/// Where the Algorithm-1 worker loops run (`[cluster] workers`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerPlane {
    /// Worker loops are threads of the trainer front (default).
    InProc,
    /// Workers are *separate OS processes* (`gba-train worker`), dialing
    /// the front at `[cluster] worker_listen` and driving the identical
    /// `run_worker` loop over the wire codec. Results are bit-for-bit
    /// identical to in-thread workers (pinned by
    /// `tests/process_workers.rs`).
    Remote,
}

impl WorkerPlane {
    pub fn parse(s: &str) -> Result<WorkerPlane> {
        Ok(match s {
            "inproc" => WorkerPlane::InProc,
            "remote" => WorkerPlane::Remote,
            _ => bail!("unknown worker plane '{s}' (inproc|remote)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            WorkerPlane::InProc => "inproc",
            WorkerPlane::Remote => "remote",
        }
    }
}

/// Who decides when the session switches training modes (`[switch]
/// policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SwitchPolicyKind {
    /// Switches happen only when the operator asks (`--switch-to` /
    /// explicit `switch_mode` calls). The default.
    Manual,
    /// The session's `SwitchPlane` watches per-day straggler telemetry
    /// (per-worker batch-latency p95 vs. median from `DayStats`) and
    /// advances the mode epoch itself: GBA when the cluster turns
    /// straggler-heavy, back to sync when it clears — the paper's
    /// "adaptive to the cluster status" direction (§6) made live.
    Adaptive,
}

impl SwitchPolicyKind {
    pub fn parse(s: &str) -> Result<SwitchPolicyKind> {
        Ok(match s {
            "manual" => SwitchPolicyKind::Manual,
            "adaptive" => SwitchPolicyKind::Adaptive,
            _ => bail!("unknown switch policy '{s}' (manual|adaptive)"),
        })
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            SwitchPolicyKind::Manual => "manual",
            SwitchPolicyKind::Adaptive => "adaptive",
        }
    }
}

/// Live mode-switch control (`[switch]` table).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SwitchConfig {
    pub policy: SwitchPolicyKind,
    /// Adaptive: switch sync → GBA when the straggler signal
    /// (1 − median/p95 of per-worker batch latency) rises above this.
    pub high_watermark: f64,
    /// Adaptive: switch GBA → sync when the signal falls below this
    /// (hysteresis: `low < high` keeps the controller from flapping).
    pub low_watermark: f64,
}

impl Default for SwitchConfig {
    fn default() -> Self {
        SwitchConfig {
            policy: SwitchPolicyKind::Manual,
            high_watermark: 0.60,
            low_watermark: 0.40,
        }
    }
}

/// Observability export surfaces (`[obs]` table).
///
/// Instrumentation itself is always on — the atomic counters never
/// touch training arithmetic, so the bit-identity pins hold regardless.
/// These knobs only enable the *export* surfaces; the default (both
/// `None`) serves and writes nothing.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ObsConfig {
    /// `host:port` for the per-process `/metrics` exposition listener
    /// (`host:0` picks a free port; each process prints its bound
    /// address). `None` serves nothing.
    pub listen: Option<String>,
    /// Directory for trace-span JSONL streams; each process appends to
    /// `<dir>/<role>-<pid>.jsonl`. `None` writes nothing.
    pub trace_dir: Option<String>,
}

/// Online serving plane (`[serve]` table) — knobs for `gba-train
/// serve`, the read-only inference front.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServeConfig {
    /// `host:port` the serving front's gather listener binds
    /// (`host:0` picks a free port; the process prints the bound
    /// address).
    pub listen: String,
    /// Hot-key cache capacity in embedding rows across all cache
    /// shards. 0 disables caching entirely — every request is served
    /// from a snapshot-consistent PS fetch.
    pub cache_rows: usize,
    /// Lock shards the cache is split across (bounds contention, not
    /// capacity).
    pub cache_shards: usize,
    /// Request-batching collection window (µs): concurrent cache
    /// misses arriving within one window coalesce into a single
    /// cross-shard gather round. 0 fetches immediately (no window).
    pub batch_window_us: u64,
    /// Staleness bound (ms) for cache-served rows: the front drains
    /// the shards' invalidation logs at least this often, so a cached
    /// row lags a landed training apply by at most this long. 0 polls
    /// before every request (freshest, most poll traffic).
    pub max_stale_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            cache_rows: 65_536,
            cache_shards: 16,
            batch_window_us: 100,
            max_stale_ms: 50,
        }
    }
}

/// Parameter-server plane shape (`[ps]` table).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PsConfig {
    /// Number of PS shards: dense range partitions + consistent-hash
    /// slices of the embedding keyspace. 1 reproduces the seed
    /// single-server behavior bit-for-bit.
    pub n_shards: usize,
    /// Shard endpoint transport.
    pub transport: TransportKind,
    /// `host:port` of each `shard-server` process, index-aligned with
    /// the shard ids. Required (length == `n_shards`) when `transport =
    /// "remote"`; ignored otherwise.
    pub shard_addrs: Vec<String>,
    /// In-memory cap (bytes, approximate) on each shard's mutating-
    /// request journal; past it the journal spills to a temp file on
    /// disk so the checkpoint cadence can stretch without memory
    /// growth. 0 (the default) never spills.
    pub journal_spill_bytes: usize,
    /// How long (ms) the front keeps dialing a `shard-server` address —
    /// at session build and when recovering a dropped peer — before the
    /// shard is declared unreachable. At build the failure surfaces as
    /// `Err` from `TrainSession::new`; mid-training it is fatal.
    pub connect_deadline_ms: u64,
    /// Worker threads one shard fans a single apply across: the dense
    /// sweep splits every tensor's index range, the embedding pass
    /// splits by internal lock-shard. Bit-identical to 1 at any value
    /// (elementwise updates on disjoint rows/ranges). 1 disables the
    /// fan-out.
    pub apply_threads: usize,
}

impl Default for PsConfig {
    fn default() -> Self {
        PsConfig {
            n_shards: 1,
            transport: TransportKind::InProc,
            shard_addrs: Vec::new(),
            journal_spill_bytes: 0,
            connect_deadline_ms: 20_000,
            apply_threads: 1,
        }
    }
}

#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Load-trace shape: "diurnal" | "flat" | "spike".
    pub trace: String,
    /// Mean compute time of one local batch on an unloaded worker (ms).
    pub base_compute_ms: f64,
    /// Lognormal sigma of worker heterogeneity.
    pub hetero_sigma: f64,
    /// PS time to apply one aggregated update (ms).
    pub ps_apply_ms: f64,
    /// Per-flush serialization + framing cost when shards sit behind a
    /// socket transport (ms); the simulator adds it to the apply cost
    /// when `[ps] transport = "socket"`.
    pub wire_ms: f64,
    /// Worker plane: in-thread loops or remote `gba-train worker`
    /// processes.
    pub workers: WorkerPlane,
    /// Address the front's worker service listens on (`Remote` plane
    /// only). `host:0` picks a free port; the front prints the bound
    /// address.
    pub worker_listen: String,
}

#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    pub name: String,
    pub seed: u64,
    pub model: ModelConfig,
    pub data: DataConfig,
    pub train: TrainConfig,
    pub modes: Vec<(ModeKind, ModeConfig)>,
    pub cluster: ClusterConfig,
    pub ps: PsConfig,
    pub switch: SwitchConfig,
    pub obs: ObsConfig,
    pub serve: ServeConfig,
}

impl ExperimentConfig {
    pub fn mode(&self, kind: ModeKind) -> ModeConfig {
        self.modes
            .iter()
            .find(|(k, _)| *k == kind)
            .map(|(_, c)| *c)
            .unwrap_or_else(|| panic!("mode {kind:?} not configured"))
    }

    pub fn has_mode(&self, kind: ModeKind) -> bool {
        self.modes.iter().any(|(k, _)| *k == kind)
    }

    /// G_s = B_s × N_s (§4.1).
    pub fn global_batch_sync(&self) -> usize {
        let m = self.mode(ModeKind::Sync);
        m.workers * m.local_batch
    }

    /// M = G_s / B_a — the gradient-buffer capacity (§4.1).
    pub fn gba_m(&self) -> usize {
        self.global_batch_sync() / self.mode(ModeKind::Gba).local_batch
    }

    /// Effective M honoring an explicit `m` override (Fig. 8).
    pub fn gba_m_effective(&self) -> usize {
        let gba = self.mode(ModeKind::Gba);
        gba.m_override.unwrap_or_else(|| self.global_batch_sync() / gba.local_batch)
    }

    pub(crate) fn from_doc(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let req_str =
            |k: &str| -> Result<String> { Ok(doc.get_str(k).with_context(|| format!("missing {k}"))?.to_string()) };
        let req_usize =
            |k: &str| -> Result<usize> { doc.get_usize(k).with_context(|| format!("missing {k}")) };
        let req_f64 =
            |k: &str| -> Result<f64> { doc.get_f64(k).with_context(|| format!("missing {k}")) };

        let model = ModelConfig {
            variant: req_str("model.variant")?,
            fields: req_usize("model.fields")?,
            emb_dim: req_usize("model.emb_dim")?,
            hidden1: req_usize("model.hidden1")?,
            hidden2: req_usize("model.hidden2")?,
            vocab_size: req_usize("model.vocab_size")? as u64,
            zipf_s: req_f64("model.zipf_s")?,
        };
        let data = DataConfig {
            days_base: req_usize("data.days_base")?,
            days_eval: req_usize("data.days_eval")?,
            samples_per_day: req_usize("data.samples_per_day")?,
            teacher_seed: req_usize("data.teacher_seed")? as u64,
            label_noise: doc.get_f64("data.label_noise").unwrap_or(0.05),
            drift: doc.get_f64("data.drift").unwrap_or(0.0),
        };
        let train = TrainConfig {
            optimizer: OptimKind::parse(&req_str("train.optimizer")?)?,
            optimizer_async: OptimKind::parse(&req_str("train.optimizer_async")?)?,
            lr: req_f64("train.lr")?,
            lr_async: doc.get_f64("train.lr_async").unwrap_or(req_f64("train.lr")?),
            eval_batch: doc.get_usize("train.eval_batch").unwrap_or(256),
            eval_samples: doc.get_usize("train.eval_samples").unwrap_or(10_000),
            // Absent keys default (gba = zero behavior change); malformed
            // keys error — a "gap_aware" run that silently fell back to
            // the fixed decay would invalidate the whole ablation.
            staleness: {
                let d = StalenessConfig::default();
                StalenessConfig {
                    policy: match doc.get("train.staleness_policy") {
                        None => d.policy,
                        Some(v) => StalenessPolicyKind::parse(
                            v.as_str().context("train.staleness_policy must be a string")?,
                        )?,
                    },
                    gap_scale: match doc.get("train.gap_scale") {
                        None => d.gap_scale,
                        Some(v) => v.as_f64().context("train.gap_scale must be a number")?,
                    },
                    abs_bound_min: match doc.get("train.abs_bound_min") {
                        None => d.abs_bound_min,
                        Some(v) => v
                            .as_usize()
                            .context("train.abs_bound_min must be a non-negative integer")?
                            as u64,
                    },
                    abs_bound_max: match doc.get("train.abs_bound_max") {
                        None => d.abs_bound_max,
                        Some(v) => v
                            .as_usize()
                            .context("train.abs_bound_max must be a non-negative integer")?
                            as u64,
                    },
                    abs_adapt_rate: match doc.get("train.abs_adapt_rate") {
                        None => d.abs_adapt_rate,
                        Some(v) => {
                            v.as_f64().context("train.abs_adapt_rate must be a number")?
                        }
                    },
                }
            },
        };
        let mut modes = Vec::new();
        for kind in ModeKind::ALL {
            let pfx = format!("mode.{}", kind.as_str());
            if !doc.has_table(&pfx) {
                continue;
            }
            let g = |k: &str| doc.get_usize(&format!("{pfx}.{k}"));
            let cfg = ModeConfig {
                workers: g("workers").with_context(|| format!("{pfx}.workers"))?,
                local_batch: g("local_batch").with_context(|| format!("{pfx}.local_batch"))?,
                iota: g("iota").unwrap_or(3) as u64,
                bound: g("bound").unwrap_or(2) as u64,
                aggregate: g("aggregate").unwrap_or(1),
                backup: g("backup").unwrap_or(0),
                m_override: g("m"),
            };
            modes.push((kind, cfg));
        }
        let cluster = ClusterConfig {
            trace: doc.get_str("cluster.trace").unwrap_or("diurnal").to_string(),
            base_compute_ms: doc.get_f64("cluster.base_compute_ms").unwrap_or(2.0),
            hetero_sigma: doc.get_f64("cluster.hetero_sigma").unwrap_or(0.3),
            ps_apply_ms: doc.get_f64("cluster.ps_apply_ms").unwrap_or(0.5),
            wire_ms: doc.get_f64("cluster.wire_ms").unwrap_or(0.0),
            // A malformed worker plane must error, not silently fall
            // back to in-thread workers (same rule as [ps] below).
            workers: match doc.get("cluster.workers") {
                None => WorkerPlane::InProc,
                Some(v) => WorkerPlane::parse(
                    v.as_str().context("cluster.workers must be a string")?,
                )?,
            },
            worker_listen: match doc.get("cluster.worker_listen") {
                None => "127.0.0.1:0".to_string(),
                Some(v) => v
                    .as_str()
                    .context("cluster.worker_listen must be a \"host:port\" string")?
                    .to_string(),
            },
        };
        // Absent [ps] defaults to one in-process shard; a *malformed*
        // value must error, not silently fall back (a "4-shard" or
        // "socket" run that quietly ran the default would invalidate
        // every scale-out result).
        let ps = PsConfig {
            n_shards: match doc.get("ps.n_shards") {
                None => 1,
                Some(v) => v
                    .as_usize()
                    .context("ps.n_shards must be a non-negative integer")?,
            },
            transport: match doc.get("ps.transport") {
                None => TransportKind::InProc,
                Some(v) => TransportKind::parse(
                    v.as_str().context("ps.transport must be a string")?,
                )?,
            },
            shard_addrs: match doc.get("ps.shard_addrs") {
                None => Vec::new(),
                Some(v) => v
                    .as_arr()
                    .context("ps.shard_addrs must be an array of \"host:port\" strings")?
                    .iter()
                    .map(|e| {
                        e.as_str()
                            .map(str::to_string)
                            .context("ps.shard_addrs entries must be strings")
                    })
                    .collect::<Result<_>>()?,
            },
            journal_spill_bytes: match doc.get("ps.journal_spill_bytes") {
                None => 0,
                Some(v) => v
                    .as_usize()
                    .context("ps.journal_spill_bytes must be a non-negative integer")?,
            },
            connect_deadline_ms: match doc.get("ps.connect_deadline_ms") {
                None => 20_000,
                Some(v) => v
                    .as_usize()
                    .context("ps.connect_deadline_ms must be a positive integer")?
                    as u64,
            },
            apply_threads: match doc.get("ps.apply_threads") {
                None => 1,
                Some(v) => v
                    .as_usize()
                    .context("ps.apply_threads must be a positive integer")?,
            },
        };
        // Same rule as [ps]/[cluster]: absent keys default, malformed
        // keys error (a run that silently fell back to "manual" would
        // invalidate an adaptive-switching experiment).
        let defaults = SwitchConfig::default();
        let switch = SwitchConfig {
            policy: match doc.get("switch.policy") {
                None => defaults.policy,
                Some(v) => SwitchPolicyKind::parse(
                    v.as_str().context("switch.policy must be a string")?,
                )?,
            },
            high_watermark: match doc.get("switch.high_watermark") {
                None => defaults.high_watermark,
                Some(v) => v.as_f64().context("switch.high_watermark must be a number")?,
            },
            low_watermark: match doc.get("switch.low_watermark") {
                None => defaults.low_watermark,
                Some(v) => v.as_f64().context("switch.low_watermark must be a number")?,
            },
        };
        // Absent [obs] keys leave both export surfaces off; malformed
        // keys error (an "observed" run that silently served nothing
        // would be debugged for the wrong reason).
        let obs = ObsConfig {
            listen: match doc.get("obs.listen") {
                None => None,
                Some(v) => Some(
                    v.as_str()
                        .context("obs.listen must be a \"host:port\" string")?
                        .to_string(),
                ),
            },
            trace_dir: match doc.get("obs.trace_dir") {
                None => None,
                Some(v) => Some(
                    v.as_str().context("obs.trace_dir must be a directory string")?.to_string(),
                ),
            },
        };
        // Same rule again for [serve]: absent keys take the defaults,
        // malformed keys error (a serve front that silently ran with a
        // default cache would invalidate a hit-rate measurement).
        let serve_defaults = ServeConfig::default();
        let serve = ServeConfig {
            listen: match doc.get("serve.listen") {
                None => serve_defaults.listen,
                Some(v) => v
                    .as_str()
                    .context("serve.listen must be a \"host:port\" string")?
                    .to_string(),
            },
            cache_rows: match doc.get("serve.cache_rows") {
                None => serve_defaults.cache_rows,
                Some(v) => v
                    .as_usize()
                    .context("serve.cache_rows must be a non-negative integer")?,
            },
            cache_shards: match doc.get("serve.cache_shards") {
                None => serve_defaults.cache_shards,
                Some(v) => v
                    .as_usize()
                    .context("serve.cache_shards must be a positive integer")?,
            },
            batch_window_us: match doc.get("serve.batch_window_us") {
                None => serve_defaults.batch_window_us,
                Some(v) => v
                    .as_usize()
                    .context("serve.batch_window_us must be a non-negative integer")?
                    as u64,
            },
            max_stale_ms: match doc.get("serve.max_stale_ms") {
                None => serve_defaults.max_stale_ms,
                Some(v) => v
                    .as_usize()
                    .context("serve.max_stale_ms must be a non-negative integer")?
                    as u64,
            },
        };
        Ok(ExperimentConfig {
            name: req_str("name")?,
            seed: req_usize("seed")? as u64,
            model,
            data,
            train,
            modes,
            cluster,
            ps,
            switch,
            obs,
            serve,
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.model.fields == 0 || self.model.emb_dim == 0 {
            bail!("model dims must be positive");
        }
        for need in [ModeKind::Sync, ModeKind::Gba] {
            if !self.has_mode(need) {
                bail!("config must define [mode.{}]", need.as_str());
            }
        }
        for (kind, m) in &self.modes {
            if m.workers == 0 || m.local_batch == 0 {
                bail!("mode {} needs workers/local_batch > 0", kind.as_str());
            }
        }
        let gs = self.global_batch_sync();
        let gba = self.mode(ModeKind::Gba);
        if gba.m_override.is_none() && gs % gba.local_batch != 0 {
            bail!(
                "GBA local batch {} must divide the sync global batch {gs} \
                 (M = Gs/Ba must be integral, §4.1)",
                gba.local_batch
            );
        }
        // Paper: N_a = M avoids intrinsic staleness; warn-level check only.
        if !(0.0..=0.5).contains(&self.data.label_noise) {
            bail!("label_noise must be in [0, 0.5]");
        }
        if self.model.zipf_s <= 0.0 {
            bail!("zipf_s must be positive");
        }
        if self.ps.n_shards == 0 || self.ps.n_shards > 256 {
            bail!("ps.n_shards must be in [1, 256], got {}", self.ps.n_shards);
        }
        // The remote transport needs one shard-server address per shard;
        // a count mismatch would silently train against the wrong plane
        // shape, so it is rejected here, not discovered at connect time.
        if self.ps.transport == TransportKind::Remote
            && self.ps.shard_addrs.len() != self.ps.n_shards
        {
            bail!(
                "ps.transport = \"remote\" needs exactly n_shards shard_addrs \
                 ({} configured for {} shards)",
                self.ps.shard_addrs.len(),
                self.ps.n_shards
            );
        }
        if self.ps.transport != TransportKind::Remote && !self.ps.shard_addrs.is_empty() {
            bail!("ps.shard_addrs is only meaningful with ps.transport = \"remote\"");
        }
        if self.ps.connect_deadline_ms == 0 {
            bail!("ps.connect_deadline_ms must be positive");
        }
        if self.ps.apply_threads == 0 || self.ps.apply_threads > 64 {
            bail!("ps.apply_threads must be in [1, 64], got {}", self.ps.apply_threads);
        }
        if self.cluster.workers == WorkerPlane::Remote && self.cluster.worker_listen.is_empty() {
            bail!("cluster.workers = \"remote\" needs a cluster.worker_listen address");
        }
        if self.obs.listen.as_deref() == Some("") {
            bail!("obs.listen must be a \"host:port\" address, not empty");
        }
        if self.obs.trace_dir.as_deref() == Some("") {
            bail!("obs.trace_dir must be a directory path, not empty");
        }
        if self.serve.listen.is_empty() {
            bail!("serve.listen must be a \"host:port\" address, not empty");
        }
        if self.serve.cache_shards == 0 || self.serve.cache_shards > 1024 {
            bail!("serve.cache_shards must be in [1, 1024], got {}", self.serve.cache_shards);
        }
        if self.serve.batch_window_us > 1_000_000 {
            bail!(
                "serve.batch_window_us must be at most 1000000 (1 s), got {} \
                 — the window adds directly to every miss's serve latency",
                self.serve.batch_window_us
            );
        }
        let st = &self.train.staleness;
        if !(st.gap_scale > 0.0) || !st.gap_scale.is_finite() {
            bail!("train.gap_scale must be a positive finite number, got {}", st.gap_scale);
        }
        if st.abs_bound_min > st.abs_bound_max {
            bail!(
                "train.abs_bound_min ({}) must not exceed train.abs_bound_max ({}) \
                 — the pair is the adaptive bound's clamp window",
                st.abs_bound_min,
                st.abs_bound_max
            );
        }
        if !(st.abs_adapt_rate > 0.0 && st.abs_adapt_rate <= 1.0) {
            bail!(
                "train.abs_adapt_rate must be in (0, 1], got {} \
                 — it is the EMA rate of the observed-staleness statistics",
                st.abs_adapt_rate
            );
        }
        let sw = &self.switch;
        if !(0.0..=1.0).contains(&sw.low_watermark) || !(0.0..=1.0).contains(&sw.high_watermark) {
            bail!("switch watermarks must be in [0, 1]");
        }
        if sw.low_watermark >= sw.high_watermark {
            bail!(
                "switch.low_watermark ({}) must be below switch.high_watermark ({}) \
                 — the gap is the adaptive controller's hysteresis band",
                sw.low_watermark,
                sw.high_watermark
            );
        }
        Ok(())
    }
}
