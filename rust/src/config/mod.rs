//! Typed configuration system.
//!
//! Experiments are driven by TOML files under `configs/` (one per paper
//! task, mirroring Table 5.1). A config fully determines a run: model
//! hyper-shapes (validated against `artifacts/manifest.json` when the PJRT
//! backend is used), synthetic-data parameters, per-mode worker counts and
//! batch sizes, optimizer/lr pairs and cluster-simulation parameters.

mod schema;

pub use schema::*;

use anyhow::{Context, Result};
use std::path::Path;

use crate::util::toml;

impl ExperimentConfig {
    /// Load and validate a config file.
    pub fn load(path: impl AsRef<Path>) -> Result<ExperimentConfig> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading config {}", path.display()))?;
        let doc = toml::parse(&text).with_context(|| format!("parsing {}", path.display()))?;
        let cfg = Self::from_doc(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Parse from an in-memory TOML string (tests, embedded defaults).
    pub fn from_toml(text: &str) -> Result<ExperimentConfig> {
        let doc = toml::parse(text)?;
        let cfg = Self::from_doc(&doc)?;
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
name = "unit-test-task"
seed = 42

[model]
variant = "tiny"
fields = 4
emb_dim = 4
hidden1 = 32
hidden2 = 16
vocab_size = 10000
zipf_s = 1.1

[data]
days_base = 2
days_eval = 2
samples_per_day = 5000
teacher_seed = 7
label_noise = 0.05
drift = 0.02

[train]
optimizer = "adam"
optimizer_async = "adagrad"
lr = 0.001
lr_async = 0.002
eval_batch = 256
eval_samples = 2000

[mode.sync]
workers = 4
local_batch = 64

[mode.async]
workers = 8
local_batch = 16

[mode.gba]
workers = 8
local_batch = 32
iota = 3

[mode.hop_bs]
workers = 8
local_batch = 32
bound = 2

[mode.bsp]
workers = 8
local_batch = 32
aggregate = 8

[mode.hop_bw]
workers = 8
local_batch = 32
backup = 2

[cluster]
trace = "diurnal"
base_compute_ms = 2.0
hetero_sigma = 0.3
ps_apply_ms = 0.5
"#;

    #[test]
    fn parses_and_validates() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.name, "unit-test-task");
        assert_eq!(cfg.model.fields, 4);
        assert_eq!(cfg.mode(ModeKind::Sync).workers, 4);
        assert_eq!(cfg.mode(ModeKind::Gba).iota, 3);
        // Global batch consistency: sync 4*64 == gba 8*32*... M = 256/32 = 8
        assert_eq!(cfg.global_batch_sync(), 256);
        assert_eq!(cfg.gba_m(), 8);
    }

    #[test]
    fn gba_m_must_divide() {
        let bad = SAMPLE.replace("local_batch = 32\niota = 3", "local_batch = 48\niota = 3");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn unknown_optimizer_rejected() {
        let bad = SAMPLE.replace("optimizer = \"adam\"", "optimizer = \"lamb\"");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn missing_mode_rejected() {
        let bad = SAMPLE.replace("[mode.sync]", "[mode_sync_typo]");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn ps_shards_default_parse_and_bounds() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.ps.n_shards, 1, "[ps] absent defaults to one shard");
        let sharded = format!("{SAMPLE}\n[ps]\nn_shards = 8\n");
        assert_eq!(ExperimentConfig::from_toml(&sharded).unwrap().ps.n_shards, 8);
        let bad = format!("{SAMPLE}\n[ps]\nn_shards = 0\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn ps_transport_default_parse_and_reject() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.ps.transport, TransportKind::InProc, "absent [ps] defaults to inproc");
        let sock = format!("{SAMPLE}\n[ps]\nn_shards = 2\ntransport = \"socket\"\n");
        assert_eq!(
            ExperimentConfig::from_toml(&sock).unwrap().ps.transport,
            TransportKind::Socket
        );
        let bad = format!("{SAMPLE}\n[ps]\ntransport = \"carrier-pigeon\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let not_str = format!("{SAMPLE}\n[ps]\ntransport = 3\n");
        assert!(ExperimentConfig::from_toml(&not_str).is_err());
    }

    #[test]
    fn ps_remote_transport_requires_matching_addrs() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert!(cfg.ps.shard_addrs.is_empty());
        assert_eq!(cfg.ps.journal_spill_bytes, 0, "journal spill defaults off");
        let good = format!(
            "{SAMPLE}\n[ps]\nn_shards = 2\ntransport = \"remote\"\n\
             shard_addrs = [\"127.0.0.1:7001\", \"127.0.0.1:7002\"]\n"
        );
        let cfg = ExperimentConfig::from_toml(&good).unwrap();
        assert_eq!(cfg.ps.transport, TransportKind::Remote);
        assert_eq!(cfg.ps.shard_addrs, vec!["127.0.0.1:7001", "127.0.0.1:7002"]);
        // Address count must equal the shard count.
        let short = format!(
            "{SAMPLE}\n[ps]\nn_shards = 2\ntransport = \"remote\"\n\
             shard_addrs = [\"127.0.0.1:7001\"]\n"
        );
        assert!(ExperimentConfig::from_toml(&short).is_err());
        // Addresses without the remote transport are a config bug.
        let stray = format!(
            "{SAMPLE}\n[ps]\nn_shards = 1\nshard_addrs = [\"127.0.0.1:7001\"]\n"
        );
        assert!(ExperimentConfig::from_toml(&stray).is_err());
        // Non-string entries are rejected.
        let bad = format!(
            "{SAMPLE}\n[ps]\nn_shards = 1\ntransport = \"remote\"\nshard_addrs = [7001]\n"
        );
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn ps_journal_spill_bytes_parses() {
        let spilled = format!("{SAMPLE}\n[ps]\nn_shards = 2\njournal_spill_bytes = 4096\n");
        assert_eq!(
            ExperimentConfig::from_toml(&spilled).unwrap().ps.journal_spill_bytes,
            4096
        );
        let bad = format!("{SAMPLE}\n[ps]\njournal_spill_bytes = \"lots\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn ps_apply_threads_parses_defaults_and_rejects() {
        assert_eq!(ExperimentConfig::from_toml(SAMPLE).unwrap().ps.apply_threads, 1);
        let threaded = format!("{SAMPLE}\n[ps]\nn_shards = 2\napply_threads = 8\n");
        assert_eq!(ExperimentConfig::from_toml(&threaded).unwrap().ps.apply_threads, 8);
        let zero = format!("{SAMPLE}\n[ps]\napply_threads = 0\n");
        assert!(ExperimentConfig::from_toml(&zero).is_err(), "0 threads rejected");
        let huge = format!("{SAMPLE}\n[ps]\napply_threads = 65\n");
        assert!(ExperimentConfig::from_toml(&huge).is_err(), "over-cap rejected");
        let bad = format!("{SAMPLE}\n[ps]\napply_threads = \"many\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err(), "malformed rejected");
    }

    #[test]
    fn cluster_workers_plane_default_parse_and_reject() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.cluster.workers, WorkerPlane::InProc, "absent defaults to inproc");
        assert_eq!(cfg.cluster.worker_listen, "127.0.0.1:0");
        let remote = SAMPLE.replace(
            "trace = \"diurnal\"",
            "trace = \"diurnal\"\nworkers = \"remote\"\nworker_listen = \"127.0.0.1:7100\"",
        );
        let cfg = ExperimentConfig::from_toml(&remote).unwrap();
        assert_eq!(cfg.cluster.workers, WorkerPlane::Remote);
        assert_eq!(cfg.cluster.worker_listen, "127.0.0.1:7100");
        let bad = SAMPLE.replace("trace = \"diurnal\"", "trace = \"diurnal\"\nworkers = \"threads\"");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        let not_str = SAMPLE.replace("trace = \"diurnal\"", "trace = \"diurnal\"\nworkers = 4");
        assert!(ExperimentConfig::from_toml(&not_str).is_err());
    }

    #[test]
    fn ps_connect_deadline_parses_with_default_and_rejects_zero() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.ps.connect_deadline_ms, 20_000);
        let short = format!("{SAMPLE}\n[ps]\nn_shards = 2\nconnect_deadline_ms = 500\n");
        assert_eq!(ExperimentConfig::from_toml(&short).unwrap().ps.connect_deadline_ms, 500);
        let zero = format!("{SAMPLE}\n[ps]\nconnect_deadline_ms = 0\n");
        assert!(ExperimentConfig::from_toml(&zero).is_err());
        let bad = format!("{SAMPLE}\n[ps]\nconnect_deadline_ms = \"soon\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
    }

    #[test]
    fn cluster_wire_ms_parses_with_default() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.cluster.wire_ms, 0.0);
        let wired = SAMPLE.replace("ps_apply_ms = 0.5", "ps_apply_ms = 0.5\nwire_ms = 0.2");
        assert_eq!(ExperimentConfig::from_toml(&wired).unwrap().cluster.wire_ms, 0.2);
    }

    #[test]
    fn obs_config_defaults_parse_and_reject() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.obs.listen, None, "absent [obs] exports nothing");
        assert_eq!(cfg.obs.trace_dir, None);
        let on = format!(
            "{SAMPLE}\n[obs]\nlisten = \"127.0.0.1:0\"\ntrace_dir = \"/tmp/gba-trace\"\n"
        );
        let cfg = ExperimentConfig::from_toml(&on).unwrap();
        assert_eq!(cfg.obs.listen.as_deref(), Some("127.0.0.1:0"));
        assert_eq!(cfg.obs.trace_dir.as_deref(), Some("/tmp/gba-trace"));
        // Malformed values error instead of silently exporting nothing.
        let not_str = format!("{SAMPLE}\n[obs]\nlisten = 9100\n");
        assert!(ExperimentConfig::from_toml(&not_str).is_err());
        let empty = format!("{SAMPLE}\n[obs]\nlisten = \"\"\n");
        assert!(ExperimentConfig::from_toml(&empty).is_err());
    }

    #[test]
    fn mode_kind_roundtrip() {
        for k in ModeKind::ALL {
            assert_eq!(ModeKind::parse(k.as_str()).unwrap(), k);
            assert_eq!(ModeKind::from_wire(k.wire_id()).unwrap(), k);
        }
        assert!(ModeKind::parse("nope").is_err());
        assert!(ModeKind::from_wire(250).is_err());
    }

    #[test]
    fn switch_config_defaults_parse_and_watermark_validation() {
        let cfg = ExperimentConfig::from_toml(SAMPLE).unwrap();
        assert_eq!(cfg.switch.policy, SwitchPolicyKind::Manual, "absent [switch] is manual");
        assert_eq!(cfg.switch.high_watermark, 0.60);
        assert_eq!(cfg.switch.low_watermark, 0.40);
        let adaptive = format!(
            "{SAMPLE}\n[switch]\npolicy = \"adaptive\"\nhigh_watermark = 0.7\nlow_watermark = 0.2\n"
        );
        let cfg = ExperimentConfig::from_toml(&adaptive).unwrap();
        assert_eq!(cfg.switch.policy, SwitchPolicyKind::Adaptive);
        assert_eq!(cfg.switch.high_watermark, 0.7);
        assert_eq!(cfg.switch.low_watermark, 0.2);
        // A malformed policy errors instead of silently running manual.
        let bad = format!("{SAMPLE}\n[switch]\npolicy = \"vibes\"\n");
        assert!(ExperimentConfig::from_toml(&bad).is_err());
        // Watermarks must leave a hysteresis band (low < high) in [0, 1].
        let inverted = format!("{SAMPLE}\n[switch]\nhigh_watermark = 0.3\nlow_watermark = 0.5\n");
        assert!(ExperimentConfig::from_toml(&inverted).is_err());
        let out_of_range = format!("{SAMPLE}\n[switch]\nhigh_watermark = 1.5\n");
        assert!(ExperimentConfig::from_toml(&out_of_range).is_err());
        // A malformed watermark errors too — silently running the
        // default 0.60 would invalidate the experiment just as badly.
        let not_a_number = format!("{SAMPLE}\n[switch]\nhigh_watermark = \"high\"\n");
        assert!(ExperimentConfig::from_toml(&not_a_number).is_err());
    }
}
