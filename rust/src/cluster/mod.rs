//! Shared-cluster model: diurnal load traces and straggler behavior.
//!
//! Substitutes the paper's production cluster (Fig. 1: CPU utilization over
//! a day, and the resulting QPS of each training mode). What matters for
//! reproducing the *shape* of Fig. 1 / Table 5.2 is the relative speed
//! distribution across workers and time:
//!
//! * a **diurnal utilization curve** u(t) ∈ [0,1] (vacant at night, busy in
//!   the day),
//! * **per-worker heterogeneity** (lognormal speed factors — some machines
//!   are just slower),
//! * **transient stragglers** whose frequency and severity grow with
//!   utilization (co-located workloads steal CPU).
//!
//! Synchronous training is bound by `max` over workers per step; fully
//! asynchronous modes by the *sum of rates* — exactly the gap the paper's
//! Observation 1 describes.

use crate::config::ClusterConfig;
use crate::util::rng::Pcg64;

pub const DAY_SECS: f64 = 86_400.0;

/// Cluster-wide utilization over time.
#[derive(Clone, Debug)]
pub enum LoadTrace {
    /// Constant utilization.
    Flat(f64),
    /// Fig. 1-shaped day: low ~04:00, peak ~15:00 (+ second evening bump).
    Diurnal,
    /// Flat `base` with a heavy spike in [start, end) (examples).
    Spike { base: f64, level: f64, start_sec: f64, end_sec: f64 },
}

impl LoadTrace {
    pub fn from_name(name: &str) -> LoadTrace {
        match name {
            "flat" => LoadTrace::Flat(0.5),
            "spike" => LoadTrace::Spike {
                base: 0.3,
                level: 0.9,
                start_sec: 8.0 * 3600.0,
                end_sec: 16.0 * 3600.0,
            },
            _ => LoadTrace::Diurnal,
        }
    }

    /// Utilization in [0, 1] at time-of-day `t_sec` (wraps at 24h).
    pub fn utilization(&self, t_sec: f64) -> f64 {
        match *self {
            LoadTrace::Flat(u) => u.clamp(0.0, 1.0),
            LoadTrace::Spike { base, level, start_sec, end_sec } => {
                let t = t_sec.rem_euclid(DAY_SECS);
                if t >= start_sec && t < end_sec {
                    level.clamp(0.0, 1.0)
                } else {
                    base.clamp(0.0, 1.0)
                }
            }
            LoadTrace::Diurnal => {
                let t = t_sec.rem_euclid(DAY_SECS) / DAY_SECS; // [0,1)
                // Main daytime hump peaking ~15:00 plus a smaller evening
                // bump ~21:00; trough ~04:30. Mirrors Fig. 1's CPU curve.
                let main = (std::f64::consts::TAU * (t - 0.625)).cos(); // peak 15:00
                let evening = 0.35 * (2.0 * std::f64::consts::TAU * (t - 0.875)).cos();
                (0.52 + 0.30 * main + 0.08 * evening).clamp(0.05, 0.98)
            }
        }
    }
}

/// Per-worker compute-time model.
#[derive(Clone, Debug)]
pub struct StragglerModel {
    pub trace: LoadTrace,
    /// Mean ms for one local batch on an unloaded, average worker.
    pub base_ms: f64,
    /// Static per-worker speed factors (lognormal(0, sigma)).
    factors: Vec<f64>,
    /// Jitter sigma for per-batch lognormal noise.
    jitter_sigma: f64,
}

impl StragglerModel {
    pub fn new(cfg: &ClusterConfig, n_workers: usize, seed: u64) -> Self {
        let mut rng = Pcg64::new(seed, 0xC1u64);
        let factors =
            (0..n_workers).map(|_| rng.lognormal(0.0, cfg.hetero_sigma)).collect();
        StragglerModel {
            trace: LoadTrace::from_name(&cfg.trace),
            base_ms: cfg.base_compute_ms,
            factors,
            jitter_sigma: 0.15,
        }
    }

    /// Deterministic constant-time model (tests).
    pub fn constant(base_ms: f64, n_workers: usize) -> Self {
        StragglerModel {
            trace: LoadTrace::Flat(0.0),
            base_ms,
            factors: vec![1.0; n_workers],
            jitter_sigma: 0.0,
        }
    }

    pub fn n_workers(&self) -> usize {
        self.factors.len()
    }

    /// Slowdown multiplier implied by utilization: at u→1 a worker competes
    /// with co-located jobs for cycles. Calibrated so u=0.2 ≈ 1.1x and
    /// u=0.9 ≈ 4x.
    fn load_multiplier(u: f64) -> f64 {
        1.0 / (1.15 - u).clamp(0.08, 1.15) * 1.05
    }

    /// Reference local batch: `base_ms` is the cost of one batch of this
    /// size; other batch sizes scale linearly (compute-bound workers).
    pub const REF_BATCH: usize = 256;

    /// Compute time (ms) for a batch of `batch` samples on worker `w`.
    pub fn compute_ms_batch(
        &self,
        w: usize,
        t_sec: f64,
        batch: usize,
        rng: &mut Pcg64,
    ) -> f64 {
        self.compute_ms(w, t_sec, rng) * batch as f64 / Self::REF_BATCH as f64
    }

    /// Compute time (ms) for worker `w` starting a reference-sized batch at
    /// time-of-day `t_sec`. Uses `rng` for the per-batch jitter and
    /// transient straggler tail.
    pub fn compute_ms(&self, w: usize, t_sec: f64, rng: &mut Pcg64) -> f64 {
        let u = self.trace.utilization(t_sec);
        let mut ms = self.base_ms * self.factors[w % self.factors.len()] * Self::load_multiplier(u);
        if self.jitter_sigma > 0.0 {
            ms *= rng.lognormal(0.0, self.jitter_sigma);
            // Transient straggler: probability and severity grow with load.
            let p_tail = 0.01 + 0.10 * u;
            if rng.bernoulli(p_tail) {
                ms *= 2.0 + 8.0 * u * rng.next_f64();
            }
        }
        ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diurnal_shape() {
        let t = LoadTrace::Diurnal;
        let night = t.utilization(4.5 * 3600.0);
        let peak = t.utilization(15.0 * 3600.0);
        assert!(night < 0.4, "night={night}");
        assert!(peak > 0.7, "peak={peak}");
        // wraps across days
        assert!((t.utilization(0.0) - t.utilization(DAY_SECS)).abs() < 1e-9);
    }

    #[test]
    fn spike_trace_window() {
        let t = LoadTrace::from_name("spike");
        assert!(t.utilization(7.0 * 3600.0) < 0.4);
        assert!(t.utilization(12.0 * 3600.0) > 0.8);
    }

    #[test]
    fn compute_time_grows_with_load() {
        let cfg = ClusterConfig {
            trace: "diurnal".into(),
            base_compute_ms: 10.0,
            hetero_sigma: 0.0,
            ps_apply_ms: 0.5,
            wire_ms: 0.0,
            workers: crate::config::WorkerPlane::InProc,
            worker_listen: String::new(),
        };
        let m = StragglerModel::new(&cfg, 4, 1);
        let mut rng = Pcg64::seeded(2);
        let night: f64 =
            (0..500).map(|_| m.compute_ms(0, 4.5 * 3600.0, &mut rng)).sum::<f64>() / 500.0;
        let peak: f64 =
            (0..500).map(|_| m.compute_ms(0, 15.0 * 3600.0, &mut rng)).sum::<f64>() / 500.0;
        assert!(peak > night * 1.8, "night={night} peak={peak}");
    }

    #[test]
    fn heterogeneity_spreads_workers() {
        let cfg = ClusterConfig {
            trace: "flat".into(),
            base_compute_ms: 10.0,
            hetero_sigma: 0.5,
            ps_apply_ms: 0.5,
            wire_ms: 0.0,
            workers: crate::config::WorkerPlane::InProc,
            worker_listen: String::new(),
        };
        let m = StragglerModel::new(&cfg, 64, 7);
        let mut rng = Pcg64::seeded(3);
        let times: Vec<f64> = (0..64).map(|w| m.compute_ms(w, 0.0, &mut rng)).collect();
        let fastest = times.iter().cloned().fold(f64::INFINITY, f64::min);
        let slowest = times.iter().cloned().fold(0.0, f64::max);
        assert!(slowest / fastest > 1.5);
    }

    #[test]
    fn constant_model_is_constant() {
        let m = StragglerModel::constant(5.0, 2);
        let mut rng = Pcg64::seeded(4);
        for w in 0..2 {
            for t in [0.0, 3600.0, 50_000.0] {
                let ms = m.compute_ms(w, t, &mut rng);
                assert!((ms - 5.0 * StragglerModel::load_multiplier(0.0)).abs() < 1e-9, "{ms}");
            }
        }
    }
}
