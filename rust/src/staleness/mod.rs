//! Pluggable staleness policies — the decay layer of GBA's token
//! control, extracted behind a trait so the *reweighting* of buffered
//! gradients is swappable independently of the mode state machine.
//!
//! The [`ModePolicy`](crate::coordinator::ModePolicy) decides *which*
//! gradients enter a flush and hands back per-entry weights
//! (`flush_spec`); a [`StalenessPolicy`] then gets one chance to rescale
//! those weights before aggregation. Three implementations:
//!
//! * **`gba`** — the paper's fixed decay, untouched. `reweight` is a
//!   strict no-op, so the default path produces bit-identical weights to
//!   every pre-seam release (pinned by `tests/policy_properties.rs` and
//!   the shard invariance suites).
//! * **`gap_aware`** — Gap-Aware (arXiv 1909.10802): penalize a stale
//!   gradient by how far the parameters have *moved* since its worker
//!   pulled, not by how many steps elapsed. The control plane snapshots
//!   a cumulative dense-update-norm clock per token at issue time; at
//!   flush the gap is the clock distance, normalized by the mean
//!   per-step update norm so it reads as "staleness in units of actual
//!   parameter movement". Weight: `w / (1 + gap_scale · gap)` — monotone
//!   non-increasing in the gap, 1.0 at gap 0.
//! * **`abs`** — adaptive staleness bound (arXiv 2301.08895): a
//!   threshold like Eqn. 1, but the bound tightens/loosens online from
//!   the observed staleness histogram (EMA mean + 2σ), clamped to the
//!   configured `[abs_bound_min, abs_bound_max]` window.
//!
//! Every policy's weights stay in `[0, 1]` (they only ever *scale* the
//! mode policy's weights, which are themselves in `[0, 1]`).

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Which staleness policy a run decays with (`[train] staleness_policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StalenessPolicyKind {
    /// The paper's fixed decay — identity over the mode policy's weights.
    Gba,
    /// Gap-Aware: penalize by parameter movement since issue.
    GapAware,
    /// Adaptive staleness bound from the observed histogram.
    Abs,
}

impl StalenessPolicyKind {
    pub const ALL: [StalenessPolicyKind; 3] =
        [StalenessPolicyKind::Gba, StalenessPolicyKind::GapAware, StalenessPolicyKind::Abs];

    pub fn as_str(&self) -> &'static str {
        match self {
            StalenessPolicyKind::Gba => "gba",
            StalenessPolicyKind::GapAware => "gap_aware",
            StalenessPolicyKind::Abs => "abs",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "gba" => StalenessPolicyKind::Gba,
            "gap_aware" => StalenessPolicyKind::GapAware,
            "abs" => StalenessPolicyKind::Abs,
            other => bail!("unknown staleness policy '{other}' (gba | gap_aware | abs)"),
        })
    }
}

/// Per-policy knobs, threaded from `[train]` (see docs/STALENESS.md).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct StalenessConfig {
    pub policy: StalenessPolicyKind,
    /// `gap_aware`: strength of the gap penalty (weight is
    /// `w / (1 + gap_scale · gap)`); must be > 0.
    pub gap_scale: f64,
    /// `abs`: hard clamp window for the adaptive bound.
    pub abs_bound_min: u64,
    pub abs_bound_max: u64,
    /// `abs`: EMA rate for the observed-staleness statistics, in (0, 1].
    pub abs_adapt_rate: f64,
}

impl Default for StalenessConfig {
    fn default() -> Self {
        StalenessConfig {
            policy: StalenessPolicyKind::Gba,
            gap_scale: 1.0,
            abs_bound_min: 1,
            abs_bound_max: 16,
            abs_adapt_rate: 0.1,
        }
    }
}

/// The staleness-decay seam. All methods are called under the control
/// lock (threaded runtime) or from the single-threaded simulator, in a
/// fixed order: `on_issue` at every token issue, `reweight` once per
/// flush admission, `on_update_norm` once per completed apply (only
/// when [`needs_norm`](Self::needs_norm) is true).
pub trait StalenessPolicy: Send {
    fn kind(&self) -> StalenessPolicyKind;

    /// A token was issued to some worker: snapshot whatever issue-time
    /// state the policy compares against at flush.
    fn on_issue(&mut self, _token: u64) {}

    /// Whether the policy needs the aggregated dense-gradient norm fed
    /// back after each apply (the control plane forces norm collection
    /// on the flush jobs when true).
    fn needs_norm(&self) -> bool {
        false
    }

    /// The apply for a flush landed with aggregated dense-update norm
    /// `norm` — the policy's clock of actual parameter movement.
    fn on_update_norm(&mut self, _norm: f64) {}

    /// Rescale the mode policy's flush weights in place. `k` is the
    /// global step at admission, `tokens[i]` the token of entry `i`.
    /// Implementations must keep every weight in `[0, 1]` and must not
    /// raise a weight above its incoming value.
    fn reweight(&mut self, k: u64, tokens: &[u64], weights: &mut [f32]);

    /// Mean normalized gap observed at the most recent `reweight` —
    /// the second adaptive-switcher signal and the `gba_staleness_gap`
    /// gauge. 0.0 for policies without a gap notion.
    fn last_gap(&self) -> f64 {
        0.0
    }

    /// Current adaptive bound (the `gba_staleness_bound` gauge);
    /// `None` for policies without one.
    fn current_bound(&self) -> Option<f64> {
        None
    }
}

/// Build a policy from config.
pub fn make_staleness(cfg: &StalenessConfig) -> Box<dyn StalenessPolicy> {
    match cfg.policy {
        StalenessPolicyKind::Gba => Box::new(GbaStaleness),
        StalenessPolicyKind::GapAware => Box::new(GapAwareStaleness::new(cfg.gap_scale)),
        StalenessPolicyKind::Abs => Box::new(AbsStaleness::new(
            cfg.abs_bound_min,
            cfg.abs_bound_max,
            cfg.abs_adapt_rate,
        )),
    }
}

/// The default: the mode policy's own decay (GBA Eqn. 1 / the
/// `DecayStrategy` ablations) stands unmodified. This must stay a
/// strict no-op — the bit-identity of every `staleness_policy = "gba"`
/// run with pre-seam training depends on it.
pub struct GbaStaleness;

impl StalenessPolicy for GbaStaleness {
    fn kind(&self) -> StalenessPolicyKind {
        StalenessPolicyKind::Gba
    }

    fn reweight(&mut self, _k: u64, _tokens: &[u64], _weights: &mut [f32]) {}
}

/// How many steps behind the flush step an issue-time snapshot is kept
/// before pruning. Far larger than any decay window that could still
/// admit the token; a pruned (ancient) token reads as gap 0, which only
/// *raises* its weight back toward the mode policy's — harmless, since
/// such tokens are decayed out by the mode policy anyway.
const SNAP_KEEP_STEPS: u64 = 256;

/// Gap-Aware staleness (arXiv 1909.10802). Tracks a cumulative clock of
/// applied dense-update norms; each token snapshots the clock at issue,
/// and at flush the gap is the clock distance normalized by the mean
/// per-step update norm.
pub struct GapAwareStaleness {
    gap_scale: f64,
    /// Cumulative sum of applied update norms (the movement clock).
    cum: f64,
    /// Running mean of per-apply update norms (the normalizer).
    norm_mean: f64,
    norm_count: u64,
    /// Issue-time clock snapshot per token (first issue wins: GBA issues
    /// each token M times back-to-back, so the first is the cohort's
    /// base).
    snaps: BTreeMap<u64, f64>,
    last_gap: f64,
}

impl GapAwareStaleness {
    pub fn new(gap_scale: f64) -> Self {
        GapAwareStaleness {
            gap_scale,
            cum: 0.0,
            norm_mean: 0.0,
            norm_count: 0,
            snaps: BTreeMap::new(),
            last_gap: 0.0,
        }
    }

    /// Normalized gap for a token: movement since issue, in units of the
    /// mean per-step update norm. Unknown tokens (pruned, or issued
    /// before this policy was installed) read as gap 0.
    fn gap_of(&self, token: u64) -> f64 {
        let base = self.snaps.get(&token).copied().unwrap_or(self.cum);
        let denom = if self.norm_count == 0 { 1.0 } else { self.norm_mean.max(1e-12) };
        (self.cum - base).max(0.0) / denom
    }
}

impl StalenessPolicy for GapAwareStaleness {
    fn kind(&self) -> StalenessPolicyKind {
        StalenessPolicyKind::GapAware
    }

    fn on_issue(&mut self, token: u64) {
        self.snaps.entry(token).or_insert(self.cum);
    }

    fn needs_norm(&self) -> bool {
        true
    }

    fn on_update_norm(&mut self, norm: f64) {
        let norm = if norm.is_finite() { norm.max(0.0) } else { 0.0 };
        self.cum += norm;
        self.norm_count += 1;
        self.norm_mean += (norm - self.norm_mean) / self.norm_count as f64;
    }

    fn reweight(&mut self, k: u64, tokens: &[u64], weights: &mut [f32]) {
        let mut gap_sum = 0.0f64;
        for (&tok, w) in tokens.iter().zip(weights.iter_mut()) {
            let gap = self.gap_of(tok);
            gap_sum += gap;
            let scaled = *w as f64 / (1.0 + self.gap_scale * gap);
            *w = scaled as f32;
        }
        if !tokens.is_empty() {
            self.last_gap = gap_sum / tokens.len() as f64;
        }
        // Prune snapshots no decay window can still admit.
        let keep_from = k.saturating_sub(SNAP_KEEP_STEPS);
        self.snaps = self.snaps.split_off(&keep_from);
    }

    fn last_gap(&self) -> f64 {
        self.last_gap
    }
}

/// Adaptive staleness bound (arXiv 2301.08895): a threshold decay whose
/// tolerance follows the observed staleness distribution — EMA mean plus
/// two EMA standard deviations, clamped to the configured window. A
/// quiet cluster tightens the bound toward `min` (outliers dropped
/// aggressively); a straggler storm loosens it toward `max` so the
/// system keeps absorbing late-but-useful gradients.
pub struct AbsStaleness {
    min: u64,
    max: u64,
    adapt_rate: f64,
    /// EMA of observed staleness and of its square (for the σ term).
    ema_mean: f64,
    ema_sq: f64,
    seen: bool,
    bound: f64,
}

impl AbsStaleness {
    pub fn new(min: u64, max: u64, adapt_rate: f64) -> Self {
        assert!(min <= max, "abs bound window inverted");
        AbsStaleness {
            min,
            max,
            adapt_rate,
            ema_mean: 0.0,
            ema_sq: 0.0,
            seen: false,
            // Start wide open: no histogram yet, no grounds to drop.
            bound: max as f64,
        }
    }

    fn clamp(&self, b: f64) -> f64 {
        b.clamp(self.min as f64, self.max as f64)
    }
}

impl StalenessPolicy for AbsStaleness {
    fn kind(&self) -> StalenessPolicyKind {
        StalenessPolicyKind::Abs
    }

    fn reweight(&mut self, k: u64, tokens: &[u64], weights: &mut [f32]) {
        // Fold this flush's staleness observations into the histogram
        // statistics, then re-derive the bound and gate with it.
        for &tok in tokens {
            let s = k.saturating_sub(tok) as f64;
            if !self.seen {
                self.ema_mean = s;
                self.ema_sq = s * s;
                self.seen = true;
            } else {
                self.ema_mean += self.adapt_rate * (s - self.ema_mean);
                self.ema_sq += self.adapt_rate * (s * s - self.ema_sq);
            }
        }
        if self.seen {
            let var = (self.ema_sq - self.ema_mean * self.ema_mean).max(0.0);
            self.bound = self.clamp(self.ema_mean + 2.0 * var.sqrt());
        }
        for (&tok, w) in tokens.iter().zip(weights.iter_mut()) {
            let s = k.saturating_sub(tok) as f64;
            if s > self.bound {
                *w = 0.0;
            }
        }
    }

    fn current_bound(&self) -> Option<f64> {
        Some(self.bound)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip_and_reject() {
        for k in StalenessPolicyKind::ALL {
            assert_eq!(StalenessPolicyKind::parse(k.as_str()).unwrap(), k);
        }
        assert!(StalenessPolicyKind::parse("lru").is_err());
    }

    #[test]
    fn gba_reweight_is_bitwise_identity() {
        let mut p = GbaStaleness;
        let tokens = [0u64, 3, 7, 7];
        let original = vec![1.0f32, 0.25, 0.0, 0.6180339887];
        let mut weights = original.clone();
        p.on_issue(7);
        p.reweight(9, &tokens, &mut weights);
        for (a, b) in original.iter().zip(&weights) {
            assert_eq!(a.to_bits(), b.to_bits(), "gba staleness must not touch a single bit");
        }
        assert_eq!(p.last_gap(), 0.0);
        assert_eq!(p.current_bound(), None);
        assert!(!p.needs_norm());
    }

    #[test]
    fn gap_aware_fresh_token_keeps_full_weight() {
        let mut p = GapAwareStaleness::new(1.0);
        p.on_issue(5);
        // No movement between issue and flush: gap 0, weight untouched.
        let mut w = vec![1.0f32];
        p.reweight(5, &[5], &mut w);
        assert_eq!(w[0], 1.0);
        assert_eq!(p.last_gap(), 0.0);
    }

    #[test]
    fn gap_aware_weight_monotone_in_gap() {
        // Same token flushed after increasing amounts of movement must
        // get a non-increasing weight.
        let mut prev = f32::INFINITY;
        for moved_steps in 0..10 {
            let mut p = GapAwareStaleness::new(1.0);
            p.on_issue(0);
            for _ in 0..moved_steps {
                p.on_update_norm(2.0);
            }
            let mut w = vec![1.0f32];
            p.reweight(moved_steps, &[0], &mut w);
            assert!((0.0..=1.0).contains(&w[0]));
            assert!(w[0] <= prev, "gap_aware not monotone at {moved_steps} steps");
            prev = w[0];
        }
    }

    #[test]
    fn gap_aware_normalizes_by_mean_update_norm() {
        // Two policies seeing the same *relative* movement (3 steps of
        // uniform updates) must agree on the gap regardless of scale.
        let mut small = GapAwareStaleness::new(1.0);
        let mut large = GapAwareStaleness::new(1.0);
        small.on_issue(0);
        large.on_issue(0);
        for _ in 0..3 {
            small.on_update_norm(0.01);
            large.on_update_norm(100.0);
        }
        let (mut ws, mut wl) = (vec![1.0f32], vec![1.0f32]);
        small.reweight(3, &[0], &mut ws);
        large.reweight(3, &[0], &mut wl);
        assert!((small.last_gap() - large.last_gap()).abs() < 1e-9);
        assert!((ws[0] - wl[0]).abs() < 1e-6);
        // Three mean steps of movement -> gap ~3.
        assert!((small.last_gap() - 3.0).abs() < 1e-9, "gap = {}", small.last_gap());
    }

    #[test]
    fn gap_aware_prunes_ancient_snapshots() {
        let mut p = GapAwareStaleness::new(1.0);
        for t in 0..5u64 {
            p.on_issue(t);
        }
        let mut w = vec![1.0f32];
        p.reweight(SNAP_KEEP_STEPS + 100, &[SNAP_KEEP_STEPS + 100], &mut w);
        assert!(p.snaps.is_empty(), "ancient snapshots must be pruned");
    }

    #[test]
    fn abs_bound_stays_clamped_under_hostile_feeds() {
        let mut p = AbsStaleness::new(2, 8, 0.5);
        // Quiet cluster: staleness 0 everywhere drives the bound to min.
        for _ in 0..50 {
            let mut w = vec![1.0f32; 4];
            p.reweight(100, &[100, 100, 100, 100], &mut w);
        }
        assert_eq!(p.current_bound(), Some(2.0), "quiet cluster tightens to min");
        // Storm: enormous staleness drives it to max, never past.
        for _ in 0..50 {
            let mut w = vec![1.0f32; 2];
            p.reweight(10_000, &[0, 1], &mut w);
        }
        assert_eq!(p.current_bound(), Some(8.0), "storm loosens to max, clamped");
    }

    #[test]
    fn abs_gates_by_the_adaptive_bound() {
        let mut p = AbsStaleness::new(0, 4, 1.0);
        // One flush of fresh grads pins the bound at the floor …
        let mut w = vec![1.0f32; 3];
        p.reweight(10, &[10, 10, 10], &mut w);
        assert!(w.iter().all(|&x| x == 1.0));
        let floor = p.current_bound().unwrap();
        assert!(floor <= 4.0);
        // … so a very stale grad in the next flush is zeroed while the
        // fresh one survives.
        let mut w = vec![1.0f32, 1.0];
        p.reweight(100, &[0, 100], &mut w);
        assert_eq!(w[0], 0.0, "staleness 100 must exceed a bound clamped to <= 4");
        assert_eq!(w[1], 1.0);
    }

    #[test]
    fn abs_never_raises_a_weight() {
        let mut p = AbsStaleness::new(1, 16, 0.1);
        let mut w = vec![0.25f32, 0.0, 1.0];
        p.reweight(3, &[3, 2, 3], &mut w);
        assert!(w[0] <= 0.25 && w[1] == 0.0 && w[2] <= 1.0);
    }

    #[test]
    fn factory_builds_the_configured_policy() {
        let mut cfg = StalenessConfig::default();
        assert_eq!(make_staleness(&cfg).kind(), StalenessPolicyKind::Gba);
        cfg.policy = StalenessPolicyKind::GapAware;
        assert_eq!(make_staleness(&cfg).kind(), StalenessPolicyKind::GapAware);
        cfg.policy = StalenessPolicyKind::Abs;
        let p = make_staleness(&cfg);
        assert_eq!(p.kind(), StalenessPolicyKind::Abs);
        let b = p.current_bound().unwrap();
        assert!((cfg.abs_bound_min as f64..=cfg.abs_bound_max as f64).contains(&b));
    }
}
