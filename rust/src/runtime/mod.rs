//! PJRT runtime: load AOT HLO-text artifacts and execute them from the
//! Rust hot path (python never runs at train time).

pub mod engine;
pub mod manifest;
pub mod tensor;

pub use engine::{EngineHandle, EnginePool, TrainOut};
pub use manifest::{ArtifactEntry, Manifest, TensorSpec, VariantDims};
pub use tensor::HostTensor;
