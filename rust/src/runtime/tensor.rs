//! Host-side tensor: the framework-internal value type crossing the
//! worker <-> PJRT boundary (and used by the native compute backend).

use anyhow::{bail, Result};

/// Dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Self> {
        let numel: usize = shape.iter().product();
        if numel != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, numel, data.len());
        }
        Ok(HostTensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let numel = shape.iter().product();
        HostTensor { shape, data: vec![0.0; numel] }
    }

    pub fn scalar(x: f32) -> Self {
        HostTensor { shape: vec![], data: vec![x] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    /// L2 norm of the flattened tensor (used by Fig. 3 gradient stats).
    pub fn l2_norm(&self) -> f64 {
        self.data.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt()
    }

    /// Elementwise in-place axpy: self += alpha * other.
    pub fn axpy(&mut self, alpha: f32, other: &HostTensor) {
        debug_assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += alpha * b;
        }
    }

    /// In-place scale.
    pub fn scale(&mut self, alpha: f32) {
        for a in &mut self.data {
            *a *= alpha;
        }
    }

    /// Convert to an XLA literal.
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let lit = xla::Literal::vec1(&self.data);
        if self.shape.is_empty() {
            // scalar: keep as rank-1 then reshape to rank-0
            Ok(lit.reshape(&[])?)
        } else {
            let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Read back from an XLA literal with known shape.
    pub fn from_literal(lit: &xla::Literal, shape: Vec<usize>) -> Result<Self> {
        let data = lit.to_vec::<f32>()?;
        HostTensor::new(shape, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shape_checked() {
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn l2_norm() {
        let t = HostTensor::new(vec![4], vec![1.0, -2.0, 2.0, 0.0]).unwrap();
        assert!((t.l2_norm() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn axpy_scale() {
        let mut a = HostTensor::zeros(vec![3]);
        let b = HostTensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        a.axpy(2.0, &b);
        assert_eq!(a.data, vec![2.0, 4.0, 6.0]);
        a.scale(0.5);
        assert_eq!(a.data, vec![1.0, 2.0, 3.0]);
    }
}
