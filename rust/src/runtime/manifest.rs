//! Typed view of `artifacts/manifest.json` (emitted by `python -m
//! compile.aot`). The manifest is the contract between the build-time
//! python pipeline and the runtime: artifact file names, input signatures
//! (positional names/shapes/dtypes) and model hyper-shapes per variant.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{self, Json};

/// One positional tensor of an artifact signature.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One compiled artifact (a `train_step` or `predict` HLO module).
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub function: String,
    pub variant: String,
    pub batch: usize,
    pub file: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<String>,
    pub hlo_sha256: String,
}

/// Model hyper-shapes for a variant (must match `python/compile/model.py`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct VariantDims {
    pub fields: usize,
    pub emb_dim: usize,
    pub hidden1: usize,
    pub hidden2: usize,
    pub mlp_in: usize,
}

impl VariantDims {
    /// Dense parameter shapes in the positional order of `train_step`
    /// (w1, b1, w2, b2, w3, b3) — mirrors `ModelDims.param_shapes()`.
    pub fn param_shapes(&self) -> Vec<Vec<usize>> {
        vec![
            vec![self.mlp_in, self.hidden1],
            vec![self.hidden1],
            vec![self.hidden1, self.hidden2],
            vec![self.hidden2],
            vec![self.hidden2, 1],
            vec![1],
        ]
    }

    pub fn dense_param_count(&self) -> usize {
        self.param_shapes().iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub jax_version: String,
    pub variants: BTreeMap<String, (VariantDims, Vec<usize>)>,
    pub artifacts: Vec<ArtifactEntry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let root = json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(dir, &root)
    }

    fn from_json(dir: PathBuf, root: &Json) -> Result<Manifest> {
        if root.get("interchange").and_then(Json::as_str) != Some("hlo-text") {
            bail!("manifest interchange format is not hlo-text");
        }
        let jax_version =
            root.get("jax_version").and_then(Json::as_str).unwrap_or("unknown").to_string();

        let mut variants = BTreeMap::new();
        let vmap = root.get("variants").and_then(Json::as_obj).context("manifest.variants")?;
        for (name, v) in vmap {
            let u = |k: &str| -> Result<usize> {
                v.get(k).and_then(Json::as_usize).with_context(|| format!("variants.{name}.{k}"))
            };
            let dims = VariantDims {
                fields: u("fields")?,
                emb_dim: u("emb_dim")?,
                hidden1: u("hidden1")?,
                hidden2: u("hidden2")?,
                mlp_in: u("mlp_in")?,
            };
            // Cross-check the python-computed mlp_in.
            if dims.mlp_in != dims.fields * dims.emb_dim + dims.emb_dim {
                bail!("variant {name}: inconsistent mlp_in {}", dims.mlp_in);
            }
            let batches = v
                .get("batches")
                .and_then(Json::as_arr)
                .context("batches")?
                .iter()
                .map(|b| b.as_usize().context("batch"))
                .collect::<Result<Vec<_>>>()?;
            variants.insert(name.clone(), (dims, batches));
        }

        let mut artifacts = Vec::new();
        for a in root.get("artifacts").and_then(Json::as_arr).context("manifest.artifacts")? {
            let s = |k: &str| -> Result<String> {
                Ok(a.get(k).and_then(Json::as_str).with_context(|| format!("artifact.{k}"))?.to_string())
            };
            let mut inputs = Vec::new();
            for i in a.get("inputs").and_then(Json::as_arr).context("artifact.inputs")? {
                inputs.push(TensorSpec {
                    name: i.get("name").and_then(Json::as_str).context("input.name")?.to_string(),
                    shape: i
                        .get("shape")
                        .and_then(Json::as_arr)
                        .context("input.shape")?
                        .iter()
                        .map(|d| d.as_usize().context("dim"))
                        .collect::<Result<Vec<_>>>()?,
                    dtype: i.get("dtype").and_then(Json::as_str).unwrap_or("float32").to_string(),
                });
            }
            let outputs = a
                .get("outputs")
                .and_then(Json::as_arr)
                .context("artifact.outputs")?
                .iter()
                .map(|o| Ok(o.as_str().context("output")?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            artifacts.push(ArtifactEntry {
                function: s("function")?,
                variant: s("variant")?,
                batch: a.get("batch").and_then(Json::as_usize).context("artifact.batch")?,
                file: s("file")?,
                inputs,
                outputs,
                hlo_sha256: s("hlo_sha256").unwrap_or_default(),
            });
        }
        Ok(Manifest { dir, jax_version, variants, artifacts })
    }

    pub fn dims(&self, variant: &str) -> Result<VariantDims> {
        Ok(self.variants.get(variant).with_context(|| format!("unknown variant {variant}"))?.0)
    }

    pub fn batches(&self, variant: &str) -> Result<&[usize]> {
        Ok(&self.variants.get(variant).with_context(|| format!("unknown variant {variant}"))?.1)
    }

    /// Find an artifact by function + variant + batch.
    pub fn find(&self, function: &str, variant: &str, batch: usize) -> Result<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.function == function && a.variant == variant && a.batch == batch)
            .with_context(|| format!("no artifact {function}/{variant}/b{batch}"))
    }

    pub fn artifact_path(&self, entry: &ArtifactEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_manifest() -> Json {
        json::parse(
            r#"{
              "format": 1, "jax_version": "0.8.2", "interchange": "hlo-text",
              "variants": {"tiny": {"fields": 4, "emb_dim": 4, "hidden1": 32,
                                     "hidden2": 16, "mlp_in": 20, "batches": [8, 32]}},
              "artifacts": [
                {"function": "train_step", "variant": "tiny", "batch": 8,
                 "file": "train_step_tiny_b8.hlo.txt",
                 "inputs": [{"name": "emb", "shape": [8, 4, 4], "dtype": "float32"}],
                 "outputs": ["loss"], "hlo_sha256": "x"}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_sample() {
        let m = Manifest::from_json(PathBuf::from("/tmp"), &sample_manifest()).unwrap();
        assert_eq!(m.dims("tiny").unwrap().fields, 4);
        assert_eq!(m.batches("tiny").unwrap(), &[8, 32]);
        let a = m.find("train_step", "tiny", 8).unwrap();
        assert_eq!(a.inputs[0].shape, vec![8, 4, 4]);
        assert_eq!(a.inputs[0].numel(), 128);
        assert!(m.find("predict", "tiny", 8).is_err());
    }

    #[test]
    fn rejects_bad_mlp_in() {
        let mut j = sample_manifest();
        if let Json::Obj(ref mut root) = j {
            if let Some(Json::Obj(vs)) = root.get_mut("variants") {
                if let Some(Json::Obj(t)) = vs.get_mut("tiny") {
                    t.insert("mlp_in".into(), Json::Num(99.0));
                }
            }
        }
        assert!(Manifest::from_json(PathBuf::from("/tmp"), &j).is_err());
    }

    #[test]
    fn param_shapes_order() {
        let d = VariantDims { fields: 4, emb_dim: 4, hidden1: 32, hidden2: 16, mlp_in: 20 };
        let shapes = d.param_shapes();
        assert_eq!(shapes[0], vec![20, 32]);
        assert_eq!(shapes[5], vec![1]);
        assert_eq!(d.dense_param_count(), 20 * 32 + 32 + 32 * 16 + 16 + 16 + 1);
    }
}
