//! PJRT execution engine: loads the AOT HLO-text artifacts and serves
//! `train_step` / `predict` calls to worker threads.
//!
//! The `xla` crate's wrappers hold non-atomic `Rc` internals, so PJRT
//! objects must stay on the thread that created them. The engine therefore
//! runs N service threads, each owning its own `PjRtClient` and compiled
//! executables; callers talk to the pool through an MPMC request channel
//! and get replies on per-request oneshot channels. This mirrors the
//! paper's deployment: each physical worker owns a private compute stream.

use std::collections::BTreeMap;
use std::sync::mpsc as std_mpsc;
use std::thread::JoinHandle;

use anyhow::{anyhow, bail, Context, Result};

use super::manifest::Manifest;
use super::tensor::HostTensor;
use crate::util::chan;

/// Output of one `train_step` execution.
#[derive(Clone, Debug)]
pub struct TrainOut {
    pub loss: f32,
    pub logits: Vec<f32>,
    /// [B, F, D] per-sample embedding gradients.
    pub d_emb: HostTensor,
    /// Dense gradients in param order (dw1, db1, dw2, db2, dw3, db3).
    pub d_dense: Vec<HostTensor>,
}

enum Request {
    Train {
        batch: usize,
        emb: HostTensor,
        params: Vec<HostTensor>,
        labels: Vec<f32>,
        reply: std_mpsc::Sender<Result<TrainOut>>,
    },
    Predict {
        batch: usize,
        emb: HostTensor,
        params: Vec<HostTensor>,
        reply: std_mpsc::Sender<Result<Vec<f32>>>,
    },
}

/// Cloneable handle used by workers to submit compute.
#[derive(Clone)]
pub struct EngineHandle {
    tx: chan::Sender<Request>,
}

impl EngineHandle {
    /// Blocking train-step execution on any free engine thread.
    pub fn train_step(
        &self,
        batch: usize,
        emb: HostTensor,
        params: Vec<HostTensor>,
        labels: Vec<f32>,
    ) -> Result<TrainOut> {
        let (rtx, rrx) = std_mpsc::channel();
        self.tx
            .send(Request::Train { batch, emb, params, labels, reply: rtx })
            .map_err(|_| anyhow!("engine pool shut down"))?;
        rrx.recv().context("engine thread dropped reply")?
    }

    /// Blocking inference execution.
    pub fn predict(
        &self,
        batch: usize,
        emb: HostTensor,
        params: Vec<HostTensor>,
    ) -> Result<Vec<f32>> {
        let (rtx, rrx) = std_mpsc::channel();
        self.tx
            .send(Request::Predict { batch, emb, params, reply: rtx })
            .map_err(|_| anyhow!("engine pool shut down"))?;
        rrx.recv().context("engine thread dropped reply")?
    }
}

/// Pool of PJRT service threads for one model variant.
pub struct EnginePool {
    tx: chan::Sender<Request>,
    threads: Vec<JoinHandle<()>>,
}

impl EnginePool {
    /// Start `n_threads` engines for `variant`, compiling every batch-size
    /// specialization listed in the manifest. Blocks until all threads have
    /// compiled (or reports the first failure).
    pub fn start(manifest: &Manifest, variant: &str, n_threads: usize) -> Result<EnginePool> {
        let (tx, rx) = chan::unbounded::<Request>();
        let (ready_tx, ready_rx) = std_mpsc::channel::<Result<()>>();
        let mut threads = Vec::new();
        for tid in 0..n_threads.max(1) {
            let rx = rx.clone();
            let ready = ready_tx.clone();
            let manifest = manifest.clone();
            let variant = variant.to_string();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("xla-engine-{tid}"))
                    .spawn(move || engine_thread(manifest, variant, rx, ready))
                    .context("spawning engine thread")?,
            );
        }
        drop(ready_tx);
        for _ in 0..threads.len() {
            ready_rx.recv().context("engine thread died during startup")??;
        }
        Ok(EnginePool { tx, threads })
    }

    pub fn handle(&self) -> EngineHandle {
        EngineHandle { tx: self.tx.clone() }
    }

    /// Shut down: close the queue and join the threads.
    pub fn shutdown(mut self) {
        self.tx.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

impl Drop for EnginePool {
    fn drop(&mut self) {
        self.tx.close();
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    /// Output element counts (for shape bookkeeping on read-back).
    emb_shape: Vec<usize>,
    param_shapes: Vec<Vec<usize>>,
    batch: usize,
}

fn engine_thread(
    manifest: Manifest,
    variant: String,
    rx: chan::Receiver<Request>,
    ready: std_mpsc::Sender<Result<()>>,
) {
    let setup = || -> Result<(BTreeMap<usize, Compiled>, BTreeMap<usize, Compiled>)> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let dims = manifest.dims(&variant)?;
        let mut train = BTreeMap::new();
        let mut predict = BTreeMap::new();
        for &batch in manifest.batches(&variant)? {
            for (function, map) in
                [("train_step", &mut train), ("predict", &mut predict)]
            {
                let entry = manifest.find(function, &variant, batch)?;
                let path = manifest.artifact_path(entry);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .map_err(|e| anyhow!("loading {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe =
                    client.compile(&comp).map_err(|e| anyhow!("compiling {function}: {e:?}"))?;
                map.insert(
                    batch,
                    Compiled {
                        exe,
                        emb_shape: vec![batch, dims.fields, dims.emb_dim],
                        param_shapes: dims.param_shapes(),
                        batch,
                    },
                );
            }
        }
        Ok((train, predict))
    };

    let (train, predict) = match setup() {
        Ok(x) => {
            let _ = ready.send(Ok(()));
            x
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };

    while let Ok(req) = rx.recv() {
        match req {
            Request::Train { batch, emb, params, labels, reply } => {
                let res = run_train(&train, batch, &emb, &params, &labels);
                let _ = reply.send(res);
            }
            Request::Predict { batch, emb, params, reply } => {
                let res = run_predict(&predict, batch, &emb, &params);
                let _ = reply.send(res);
            }
        }
    }
}

fn build_args(
    emb: &HostTensor,
    params: &[HostTensor],
    labels: Option<&[f32]>,
) -> Result<Vec<xla::Literal>> {
    let mut args = Vec::with_capacity(params.len() + 2);
    args.push(emb.to_literal()?);
    for p in params {
        args.push(p.to_literal()?);
    }
    if let Some(labels) = labels {
        args.push(xla::Literal::vec1(labels));
    }
    Ok(args)
}

fn run_train(
    compiled: &BTreeMap<usize, Compiled>,
    batch: usize,
    emb: &HostTensor,
    params: &[HostTensor],
    labels: &[f32],
) -> Result<TrainOut> {
    let c = compiled
        .get(&batch)
        .with_context(|| format!("no train_step artifact for batch {batch}"))?;
    if emb.shape != c.emb_shape {
        bail!("emb shape {:?} != artifact shape {:?}", emb.shape, c.emb_shape);
    }
    if labels.len() != c.batch {
        bail!("labels len {} != batch {}", labels.len(), c.batch);
    }
    let args = build_args(emb, params, Some(labels))?;
    let result = c
        .exe
        .execute::<xla::Literal>(&args)
        .map_err(|e| anyhow!("execute train_step: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
    // Lowered with return_tuple=True: (loss, logits, d_emb, dw1..db3).
    let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
    if parts.len() != 9 {
        bail!("train_step returned {} outputs, want 9", parts.len());
    }
    let loss = parts[0].to_vec::<f32>().map_err(|e| anyhow!("loss: {e:?}"))?[0];
    let logits = parts[1].to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))?;
    let d_emb = HostTensor::from_literal(&parts[2], c.emb_shape.clone())?;
    let mut d_dense = Vec::with_capacity(6);
    for (i, shape) in c.param_shapes.iter().enumerate() {
        d_dense.push(HostTensor::from_literal(&parts[3 + i], shape.clone())?);
    }
    Ok(TrainOut { loss, logits, d_emb, d_dense })
}

fn run_predict(
    compiled: &BTreeMap<usize, Compiled>,
    batch: usize,
    emb: &HostTensor,
    params: &[HostTensor],
) -> Result<Vec<f32>> {
    let c = compiled
        .get(&batch)
        .with_context(|| format!("no predict artifact for batch {batch}"))?;
    if emb.shape != c.emb_shape {
        bail!("emb shape {:?} != artifact shape {:?}", emb.shape, c.emb_shape);
    }
    let args = build_args(emb, params, None)?;
    let result = c
        .exe
        .execute::<xla::Literal>(&args)
        .map_err(|e| anyhow!("execute predict: {e:?}"))?[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("to_literal_sync: {e:?}"))?;
    let logits = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
    logits.to_vec::<f32>().map_err(|e| anyhow!("logits: {e:?}"))
}
