//! # gba-train
//!
//! Reproduction of **"GBA: A Tuning-free Approach to Switch between
//! Synchronous and Asynchronous Training for Recommendation Models"**
//! (Su, Zhang, et al., NeurIPS 2022) as a three-layer Rust + JAX + Pallas
//! framework:
//!
//! * **Layer 3 (this crate)** — a *sharded* parameter-server training
//!   plane ([`shard`]) whose shard-global control plane implements GBA's
//!   token-control mechanism plus five baseline modes (Sync, Async,
//!   Hop-BS, BSP, Hop-BW), an expandable hash-table embedding store
//!   partitioned by consistent hashing, sparse/dense optimizers, a
//!   threaded worker runtime, a discrete-event cluster simulator, metrics
//!   and experiment drivers.
//! * **Layer 2 (python/compile/model.py)** — the recommendation model
//!   (DeepFM/YouTubeDNN-family CTR tower) fwd/bwd in JAX, AOT-lowered to
//!   HLO text.
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels for the compute
//!   hot-spots (FM interaction, fused matmul+bias+ReLU, BCE loss).
//!
//! Python never runs on the training path: artifacts are compiled once by
//! `make artifacts`, then loaded via PJRT ([`runtime`]).
//!
//! See `DESIGN.md` for the full system inventory and experiment index, and
//! `EXPERIMENTS.md` for paper-vs-measured results.

pub mod checkpoint;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod embedding;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod optim;
pub mod ps;
pub mod runtime;
pub mod serve;
pub mod shard;
pub mod sim;
pub mod staleness;
pub mod transport;
pub mod util;
pub mod worker;
