//! The online serving plane: a read-only inference front over the
//! sharded PS.
//!
//! The paper's models exist to answer pull traffic — GBA trains them on
//! a parameter server precisely so the *same* sharded store can serve
//! inference lookups while training continues. [`ServeFront`] is that
//! read path:
//!
//! * **Hot-key cache.** Recommendation key traffic is Zipfian (Fig. 4),
//!   so a small sharded map in front of the PS absorbs most lookups.
//!   Training applies invalidate it through the shards' bounded
//!   invalidation logs (`ReadInvalidations`), polled at most every
//!   `[serve] max_stale_ms` — a cache-served row lags a landed apply by
//!   at most that bound, never longer.
//! * **Batched cross-shard gathers.** Concurrent requests coalesce
//!   their cache misses into one *round*: a `[serve] batch_window_us`
//!   collection window, then one `GatherAt` RPC per involved PS shard
//!   for the union of missed keys, instead of a per-request fan-out.
//! * **Snapshot-consistent reads.** `GatherAt` reads under each shard's
//!   apply seqlock and reports the step the rows are consistent at; the
//!   round retries the fan-out until every involved shard reports the
//!   *same* step. A fetched row block therefore never observes a
//!   half-applied global batch (pinned bit-identical under concurrent
//!   applies by `tests/serve_plane.rs`). With the cache disabled
//!   (`cache_rows = 0`) every served gather is such a block.
//!
//! The front runs against either a live in-process [`ShardedPs`] (reads
//! go over the supervisor's read slots, overlapping training applies —
//! PR 7's companion-connection seam) or, via [`RemoteReadShards`],
//! read-only companion connections to remote `shard-server` processes.
//! [`serve_listener`] exposes it over the worker-plane wire vocabulary
//! (`WorkerRequest::Gather` → `WorkerReply::Emb`), so any `PsClient`
//! gather client can speak to it unchanged.

use std::collections::{HashMap, HashSet};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::config::ServeConfig;
use crate::obs::{self, Histogram};
use crate::runtime::HostTensor;
use crate::shard::{ShardRouter, ShardedPs};
use crate::transport::codec::{
    self, CodecError, ShardReply, ShardRequest, WireMsg, WorkerReply, WorkerRequest,
};
use crate::transport::endpoint::{rpc, Conn, SocketConn};
use crate::transport::remote::connect_retry;
use crate::util::rng::mix64;

/// Fan-out retry budget for one snapshot round: how many times the
/// round re-issues its per-shard `GatherAt`s waiting for every shard to
/// report the same step. Flushes apply to all shards back-to-back under
/// the front's snapshot lock, so disagreement windows are micro-scale;
/// the budget only trips if training wedges mid-flush.
const SNAPSHOT_RETRIES: usize = 1000;

/// Pause between snapshot retry attempts.
const SNAPSHOT_RETRY_PAUSE: Duration = Duration::from_micros(100);

/// A read-only door into a live sharded PS — the seam that lets one
/// [`ServeFront`] run over an in-process [`ShardedPs`] (tests, benches,
/// single-box deploys) or remote companion connections
/// ([`RemoteReadShards`]) with identical semantics.
pub trait ReadShards: Send + Sync {
    fn n_shards(&self) -> usize;
    fn emb_dim(&self) -> usize;
    /// One read-only RPC against shard `s`. Must route only verbs
    /// `try_handle_read` accepts; a mutating verb is a caller bug.
    fn read_call(&self, s: usize, req: ShardRequest) -> Result<ShardReply>;
}

impl ReadShards for Arc<ShardedPs> {
    fn n_shards(&self) -> usize {
        ShardedPs::n_shards(self)
    }

    fn emb_dim(&self) -> usize {
        ShardedPs::emb_dim(self)
    }

    fn read_call(&self, s: usize, req: ShardRequest) -> Result<ShardReply> {
        Ok(ShardedPs::read_call(self, s, req))
    }
}

/// Read-only companion connections to remote `shard-server` processes:
/// one socket per shard, attached with the `ReadHello` handshake — the
/// same read plane a training front's gathers overlap applies on, so a
/// serve process shares shards with a live trainer by construction.
pub struct RemoteReadShards {
    conns: Vec<Mutex<SocketConn>>,
    emb_dim: usize,
}

impl RemoteReadShards {
    /// Dial every shard address and complete the read-companion
    /// handshake, retrying each until `deadline`. A shard-server only
    /// accepts a companion once a *primary* (training) connection has
    /// established the serving generation — so against a fleet that has
    /// never trained, this fails with instructions rather than hanging
    /// forever.
    pub fn connect(addrs: &[String], emb_dim: usize, deadline: Duration) -> Result<Self> {
        let t0 = Instant::now();
        let mut conns = Vec::with_capacity(addrs.len());
        for (s, addr) in addrs.iter().enumerate() {
            loop {
                let remaining = deadline.saturating_sub(t0.elapsed());
                let mut conn = connect_retry(addr, remaining)
                    .with_context(|| format!("shard {s}: nothing listening on {addr}"))?;
                match rpc(&mut conn, ShardRequest::ReadHello { shard: s as u64 }) {
                    Ok(ShardReply::Ok) => {
                        conns.push(Mutex::new(conn));
                        break;
                    }
                    // The server drops a companion that arrives before
                    // any primary has attached — keep dialing until the
                    // trainer shows up or the deadline says it won't.
                    Ok(other) => bail!("shard {s}: unexpected ReadHello reply: {other:?}"),
                    Err(_) if t0.elapsed() < deadline => {
                        std::thread::sleep(Duration::from_millis(50));
                    }
                    Err(e) => bail!(
                        "shard {s} at {addr} refused the read companion ({e}); \
                         a shard-server only serves reads once a trainer has \
                         attached — start (or run) training against this fleet first"
                    ),
                }
            }
        }
        Ok(RemoteReadShards { conns, emb_dim })
    }
}

impl ReadShards for RemoteReadShards {
    fn n_shards(&self) -> usize {
        self.conns.len()
    }

    fn emb_dim(&self) -> usize {
        self.emb_dim
    }

    fn read_call(&self, s: usize, req: ShardRequest) -> Result<ShardReply> {
        let mut conn = self.conns[s].lock().unwrap();
        rpc(&mut *conn, req).map_err(|e| anyhow!("shard {s} read RPC failed: {e}"))
    }
}

/// Instance-local serving counters. Mirrored into the process obs
/// registry as `gba_serve_*`; kept local too so tests and the bench can
/// assert on *this* front's traffic regardless of what else the process
/// is doing.
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub cache_hits: AtomicU64,
    pub cache_misses: AtomicU64,
    pub cache_evictions: AtomicU64,
    /// Batched fetch rounds executed (one per leader, not per request).
    pub rounds: AtomicU64,
    /// Extra fan-out attempts spent waiting for all shards to agree.
    pub snapshot_retries: AtomicU64,
}

/// Point-in-time copy of [`ServeStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeStatsSnapshot {
    pub requests: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_evictions: u64,
    pub rounds: u64,
    pub snapshot_retries: u64,
}

/// One completed fetch round: the union of missed keys, resolved at one
/// consistent step across every involved shard.
struct RoundResult {
    step: u64,
    rows: HashMap<u64, Vec<f32>>,
}

/// Leader/follower state for request-window batching.
struct RoundState {
    /// Id of the round currently collecting keys.
    round: u64,
    /// Union of cache-missed keys awaiting the next fetch.
    keys: Vec<u64>,
    /// A leader is inside the collection window or the fan-out.
    leader_running: bool,
    /// Latest completed round and its result.
    last: Option<(u64, Arc<RoundResult>)>,
    /// Highest round that failed (its keys were drained but never
    /// served); contributors at or below it must error out.
    failed: Option<u64>,
}

/// Cache-invalidation cursors, one per PS shard, plus the poll clock.
struct InvalCursors {
    last_poll: Option<Instant>,
    since: Vec<u64>,
}

/// One cached row plus its clock reference bit.
struct CacheEntry {
    row: Vec<f32>,
    /// Set on every hit; cleared when the clock hand sweeps past. A row
    /// survives eviction as long as it is re-referenced between sweeps.
    referenced: bool,
}

/// One lock-shard of the hot-key cache: a clock (second-chance) ring.
/// Full slots evict exactly one victim — the first un-referenced row at
/// or after the hand — so a Zipfian head that keeps getting hits is
/// never dumped wholesale the way the old flush-on-full scheme did.
#[derive(Default)]
struct CacheShard {
    rows: HashMap<u64, CacheEntry>,
    /// Ring of cached keys in insertion-slot order. Invalidation removes
    /// from `rows` only, leaving a stale ring slot the clock hand reuses
    /// for free on its next pass.
    ring: Vec<u64>,
    hand: usize,
}

impl CacheShard {
    fn get(&mut self, key: u64) -> Option<Vec<f32>> {
        let e = self.rows.get_mut(&key)?;
        e.referenced = true;
        Some(e.row.clone())
    }

    /// Insert `row`, evicting at most one victim. Returns the number of
    /// live rows evicted (0 or 1).
    fn put(&mut self, key: u64, row: Vec<f32>, cap: usize) -> u64 {
        if let Some(e) = self.rows.get_mut(&key) {
            e.row = row;
            e.referenced = true;
            return 0;
        }
        // Fresh inserts start un-referenced: a one-shot churn key never
        // earns its bit, so the clock evicts it before any row that was
        // hit since the hand's last pass.
        let entry = CacheEntry { row, referenced: false };
        if self.ring.len() < cap {
            self.ring.push(key);
            self.rows.insert(key, entry);
            return 0;
        }
        // Clock sweep. Bounded: pass 1 may clear every reference bit,
        // so by 2·len + 1 inspections a victim (or stale slot) is found.
        for _ in 0..(2 * self.ring.len() + 1) {
            let slot = self.hand;
            self.hand = (self.hand + 1) % self.ring.len();
            let victim = self.ring[slot];
            match self.rows.get_mut(&victim) {
                // Stale slot: its key was invalidated out of `rows`
                // already. Reuse it — nothing live is evicted.
                None => {
                    self.ring[slot] = key;
                    self.rows.insert(key, entry);
                    return 0;
                }
                Some(e) if e.referenced => {
                    // Second chance: spare it, clear the bit, move on.
                    e.referenced = false;
                }
                Some(_) => {
                    self.rows.remove(&victim);
                    self.ring[slot] = key;
                    self.rows.insert(key, entry);
                    return 1;
                }
            }
        }
        unreachable!("clock sweep found no victim in a full ring");
    }

    fn clear(&mut self) -> u64 {
        let dropped = self.rows.len() as u64;
        self.rows.clear();
        self.ring.clear();
        self.hand = 0;
        dropped
    }
}

/// The serving front. Shared across connection threads behind an
/// [`Arc`]; every public method takes `&self`.
pub struct ServeFront {
    shards: Box<dyn ReadShards>,
    router: ShardRouter,
    dim: usize,
    cfg: ServeConfig,
    /// Sharded hot-key cache: `mix64(key) % cache_shards` picks the
    /// slice. Empty when `cache_rows = 0` (caching disabled). Each
    /// slice holds at most `cache_rows / cache_shards` rows under clock
    /// (second-chance) eviction, so the Zipfian head survives cold-key
    /// churn instead of being flushed wholesale on every overflow.
    cache: Vec<Mutex<CacheShard>>,
    cache_rows_per_shard: usize,
    batch: Mutex<RoundState>,
    batch_cv: Condvar,
    inval: Mutex<InvalCursors>,
    pub stats: ServeStats,
    latency_hist: Arc<Histogram>,
}

impl ServeFront {
    pub fn new(shards: Box<dyn ReadShards>, cfg: ServeConfig) -> Self {
        let n = shards.n_shards();
        let dim = shards.emb_dim();
        let cache_shards = if cfg.cache_rows == 0 { 0 } else { cfg.cache_shards.max(1) };
        let cache = (0..cache_shards).map(|_| Mutex::new(CacheShard::default())).collect();
        let reg = obs::global();
        for name in [
            "gba_serve_requests_total",
            "gba_serve_cache_hits_total",
            "gba_serve_cache_misses_total",
            "gba_serve_cache_evictions_total",
            "gba_serve_rounds_total",
            "gba_serve_snapshot_retries_total",
        ] {
            // Materialize the family at 0 so /metrics shows it pre-traffic.
            reg.counter(name);
        }
        ServeFront {
            router: ShardRouter::new(n),
            dim,
            cache_rows_per_shard: if cache_shards == 0 {
                0
            } else {
                (cfg.cache_rows / cache_shards).max(1)
            },
            cache,
            batch: Mutex::new(RoundState {
                round: 0,
                keys: Vec::new(),
                leader_running: false,
                last: None,
                failed: None,
            }),
            batch_cv: Condvar::new(),
            inval: Mutex::new(InvalCursors { last_poll: None, since: vec![0; n] }),
            stats: ServeStats::default(),
            latency_hist: reg
                .histogram("gba_serve_latency_seconds", Histogram::latency_bounds()),
            shards,
            cfg,
        }
    }

    pub fn emb_dim(&self) -> usize {
        self.dim
    }

    pub fn stats_snapshot(&self) -> ServeStatsSnapshot {
        ServeStatsSnapshot {
            requests: self.stats.requests.load(Ordering::Relaxed),
            cache_hits: self.stats.cache_hits.load(Ordering::Relaxed),
            cache_misses: self.stats.cache_misses.load(Ordering::Relaxed),
            cache_evictions: self.stats.cache_evictions.load(Ordering::Relaxed),
            rounds: self.stats.rounds.load(Ordering::Relaxed),
            snapshot_retries: self.stats.snapshot_retries.load(Ordering::Relaxed),
        }
    }

    fn count(&self, local: &AtomicU64, name: &'static str, by: u64) {
        if by == 0 {
            return;
        }
        local.fetch_add(by, Ordering::Relaxed);
        obs::global().counter(name).add(by);
    }

    /// Serve one gather: `keys` (one per `[batch, fields]` slot, dups
    /// allowed) → a `[batch, fields, dim]` tensor, exactly the
    /// [`ShardedPs::gather`] contract. Rows come from the hot cache
    /// when present (staleness ≤ `max_stale_ms` behind the live PS) and
    /// otherwise from one snapshot-consistent batched fetch round.
    pub fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> Result<HostTensor> {
        let t0 = Instant::now();
        self.count(&self.stats.requests, "gba_serve_requests_total", 1);
        self.maintain_cache()?;

        let dim = self.dim;
        let mut data = vec![0.0f32; keys.len() * dim];
        // Resolve from cache first; collect the distinct misses.
        let mut miss: Vec<u64> = Vec::new();
        let mut miss_at: Vec<usize> = Vec::new();
        let (mut hits, mut misses) = (0u64, 0u64);
        for (i, &key) in keys.iter().enumerate() {
            match self.cache_get(key) {
                Some(row) => {
                    data[i * dim..(i + 1) * dim].copy_from_slice(&row);
                    hits += 1;
                }
                None => {
                    miss.push(key);
                    miss_at.push(i);
                    misses += 1;
                }
            }
        }
        self.count(&self.stats.cache_hits, "gba_serve_cache_hits_total", hits);
        self.count(&self.stats.cache_misses, "gba_serve_cache_misses_total", misses);

        if !miss.is_empty() {
            let round = self.fetch_batched(&miss)?;
            for (&key, &i) in miss.iter().zip(&miss_at) {
                let row = round
                    .rows
                    .get(&key)
                    .ok_or_else(|| anyhow!("fetch round missing key {key}"))?;
                data[i * dim..(i + 1) * dim].copy_from_slice(row);
                self.cache_put(key, row.clone());
            }
        }
        self.latency_hist.record(t0.elapsed().as_secs_f64());
        Ok(HostTensor { shape: vec![batch, fields, dim], data })
    }

    fn cache_slot(&self, key: u64) -> Option<&Mutex<CacheShard>> {
        if self.cache.is_empty() {
            return None;
        }
        Some(&self.cache[(mix64(key) % self.cache.len() as u64) as usize])
    }

    fn cache_get(&self, key: u64) -> Option<Vec<f32>> {
        self.cache_slot(key)?.lock().unwrap().get(key)
    }

    fn cache_put(&self, key: u64, row: Vec<f32>) {
        let Some(slot) = self.cache_slot(key) else { return };
        let evicted = slot.lock().unwrap().put(key, row, self.cache_rows_per_shard);
        self.count(&self.stats.cache_evictions, "gba_serve_cache_evictions_total", evicted);
    }

    /// Drain the shards' invalidation logs if the staleness budget is
    /// up, evicting every cached row a training apply has touched since
    /// the last poll. `max_stale_ms = 0` polls before every request.
    fn maintain_cache(&self) -> Result<()> {
        if self.cache.is_empty() {
            return Ok(());
        }
        let mut cur = self.inval.lock().unwrap();
        let due = match cur.last_poll {
            None => true,
            Some(t) => t.elapsed() >= Duration::from_millis(self.cfg.max_stale_ms),
        };
        if !due {
            return Ok(());
        }
        for s in 0..self.shards.n_shards() {
            let since = cur.since[s];
            match self.shards.read_call(s, ShardRequest::ReadInvalidations { since })? {
                ShardReply::Invalidations { upto, full, keys } => {
                    if full {
                        let mut dropped = 0u64;
                        for slot in &self.cache {
                            dropped += slot.lock().unwrap().clear();
                        }
                        self.count(
                            &self.stats.cache_evictions,
                            "gba_serve_cache_evictions_total",
                            dropped,
                        );
                    } else {
                        let mut dropped = 0u64;
                        for key in keys {
                            if let Some(slot) = self.cache_slot(key) {
                                // Remove the row only; the ring slot
                                // goes stale and the clock hand reuses
                                // it on its next pass.
                                if slot.lock().unwrap().rows.remove(&key).is_some() {
                                    dropped += 1;
                                }
                            }
                        }
                        self.count(
                            &self.stats.cache_evictions,
                            "gba_serve_cache_evictions_total",
                            dropped,
                        );
                    }
                    cur.since[s] = upto;
                }
                other => bail!("shard {s}: expected Invalidations, got {other:?}"),
            }
        }
        cur.last_poll = Some(Instant::now());
        Ok(())
    }

    /// Join (or lead) the current batching round for `miss` and return
    /// its result once the round's fan-out completes. The leader sleeps
    /// out the collection window, drains the union of every concurrent
    /// request's misses, and runs one snapshot fan-out for all of them;
    /// followers block on the round's completion.
    fn fetch_batched(&self, miss: &[u64]) -> Result<Arc<RoundResult>> {
        let mut st = self.batch.lock().unwrap();
        st.keys.extend_from_slice(miss);
        let my_round = st.round;
        loop {
            if let Some((r, res)) = &st.last {
                if *r >= my_round {
                    return Ok(res.clone());
                }
            }
            if let Some(f) = st.failed {
                if f >= my_round {
                    bail!("batched fetch round {my_round} failed (leader error)");
                }
            }
            if !st.leader_running {
                st.leader_running = true;
                drop(st);
                if self.cfg.batch_window_us > 0 {
                    std::thread::sleep(Duration::from_micros(self.cfg.batch_window_us));
                }
                let (keys, round) = {
                    let mut st = self.batch.lock().unwrap();
                    let keys = std::mem::take(&mut st.keys);
                    let round = st.round;
                    st.round += 1;
                    (keys, round)
                };
                let fetched = self.fetch_now(&keys);
                st = self.batch.lock().unwrap();
                st.leader_running = false;
                match fetched {
                    Ok(res) => {
                        let res = Arc::new(res);
                        st.last = Some((round, res.clone()));
                        self.batch_cv.notify_all();
                        debug_assert!(round >= my_round);
                        return Ok(res);
                    }
                    Err(e) => {
                        st.failed = Some(st.failed.map_or(round, |f| f.max(round)));
                        self.batch_cv.notify_all();
                        return Err(e);
                    }
                }
            }
            st = self.batch_cv.wait(st).unwrap();
        }
    }

    /// One snapshot fan-out: group `keys` by owning PS shard, issue the
    /// per-shard `GatherAt`s concurrently, and retry the whole round
    /// until every involved shard reports the same applied step.
    fn fetch_now(&self, keys: &[u64]) -> Result<RoundResult> {
        self.count(&self.stats.rounds, "gba_serve_rounds_total", 1);
        if keys.is_empty() {
            return Ok(RoundResult { step: 0, rows: HashMap::new() });
        }
        let n = self.shards.n_shards();
        let mut groups: Vec<Vec<u64>> = vec![Vec::new(); n];
        let mut seen: HashSet<u64> = HashSet::with_capacity(keys.len());
        for &key in keys {
            if seen.insert(key) {
                groups[self.router.shard_of_hash(mix64(key))].push(key);
            }
        }
        let involved: Vec<usize> = (0..n).filter(|&s| !groups[s].is_empty()).collect();
        let dim = self.dim;
        for attempt in 0..SNAPSHOT_RETRIES {
            if attempt > 0 {
                self.count(&self.stats.snapshot_retries, "gba_serve_snapshot_retries_total", 1);
                std::thread::sleep(SNAPSHOT_RETRY_PAUSE);
            }
            // Concurrent fan-out: each involved shard answers on its own
            // connection/read slot, so the round's latency is the max,
            // not the sum, of the per-shard gathers.
            let mut replies: Vec<(usize, Result<(u64, Vec<f32>)>)> = Vec::new();
            std::thread::scope(|scope| {
                let handles: Vec<_> = involved
                    .iter()
                    .map(|&s| {
                        let skeys = &groups[s];
                        scope.spawn(move || {
                            let reply = self
                                .shards
                                .read_call(s, ShardRequest::GatherAt { keys: skeys.clone() })?;
                            match reply {
                                ShardReply::RowsAt { step, dim: rdim, data } => {
                                    debug_assert_eq!(rdim as usize, dim);
                                    Ok((step, data))
                                }
                                other => bail!("shard {s}: expected RowsAt, got {other:?}"),
                            }
                        })
                    })
                    .collect();
                for (&s, h) in involved.iter().zip(handles) {
                    replies.push((s, h.join().expect("gather fan-out thread panicked")));
                }
            });
            let mut parts: Vec<(usize, u64, Vec<f32>)> = Vec::with_capacity(replies.len());
            for (s, r) in replies {
                let (step, data) = r?;
                parts.push((s, step, data));
            }
            let step0 = parts.first().map(|p| p.1).unwrap_or(0);
            if parts.iter().all(|p| p.1 == step0) {
                let mut rows = HashMap::with_capacity(seen.len());
                for (s, _, data) in parts {
                    for (j, &key) in groups[s].iter().enumerate() {
                        rows.insert(key, data[j * dim..(j + 1) * dim].to_vec());
                    }
                }
                return Ok(RoundResult { step: step0, rows });
            }
        }
        bail!(
            "no cross-shard snapshot after {SNAPSHOT_RETRIES} attempts — \
             shards never agreed on an applied step (training wedged mid-flush?)"
        )
    }
}

/// Serve the front over TCP: accept loop, one thread per connection,
/// speaking the worker-plane gather vocabulary — a connection sends
/// `WorkerRequest::Gather { keys, batch, fields }` frames and receives
/// `WorkerReply::Emb` tensors. Any other verb closes the connection
/// (this plane is read-only by construction). Returns the bound
/// address; the accept loop runs on a background thread for the life of
/// the process.
pub fn serve_listener(front: Arc<ServeFront>, listener: TcpListener) -> std::io::Result<SocketAddr> {
    let addr = listener.local_addr()?;
    std::thread::Builder::new().name("serve-accept".into()).spawn(move || {
        for stream in listener.incoming() {
            let Ok(stream) = stream else { continue };
            let front = front.clone();
            let _ = std::thread::Builder::new()
                .name("serve-conn".into())
                .spawn(move || serve_conn(front, stream));
        }
    })?;
    Ok(addr)
}

fn serve_conn(front: Arc<ServeFront>, stream: TcpStream) {
    let mut conn = SocketConn::new(stream);
    loop {
        match conn.recv() {
            Ok(WireMsg::WorkerReq(WorkerRequest::Gather { keys, batch, fields })) => {
                let t = match front.gather(&keys, batch as usize, fields as usize) {
                    Ok(t) => t,
                    Err(e) => {
                        eprintln!("serve: gather failed: {e:#}");
                        return;
                    }
                };
                if conn.send(WireMsg::WorkerRep(WorkerReply::Emb(t))).is_err() {
                    return;
                }
            }
            Ok(_) => {
                eprintln!("serve: non-gather frame on a serving connection; closing it");
                return;
            }
            Err(CodecError::Closed) => return,
            Err(e) => {
                eprintln!("serve: connection error: {e}");
                return;
            }
        }
    }
}

/// Client half of [`serve_listener`]'s protocol — what `serve-probe`
/// and the served-QPS bench drive: one blocking gather per call.
pub struct ServeClient {
    conn: SocketConn,
}

impl ServeClient {
    pub fn connect(addr: &str, deadline: Duration) -> Result<Self> {
        let conn = connect_retry(addr, deadline)
            .with_context(|| format!("no serve front listening on {addr}"))?;
        Ok(ServeClient { conn })
    }

    pub fn gather(&mut self, keys: &[u64], batch: usize, fields: usize) -> Result<HostTensor> {
        self.conn
            .send(WireMsg::WorkerReq(WorkerRequest::Gather {
                keys: keys.to_vec(),
                batch: batch as u64,
                fields: fields as u64,
            }))
            .map_err(|e| anyhow!("serve send failed: {e}"))?;
        match self.conn.recv().map_err(|e| anyhow!("serve recv failed: {e}"))? {
            WireMsg::WorkerRep(WorkerReply::Emb(t)) => Ok(t),
            other => bail!("serve protocol: expected Emb, got {:?}", codec::wire_kind(&other)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// In-memory [`ReadShards`]: every key's row is `key + 1000·step`
    /// in all components, so a served value pins exactly which step the
    /// row was read at. Invalidation keys are staged per shard.
    struct MockShards {
        n: usize,
        dim: usize,
        step: AtomicU64,
        gather_calls: AtomicU64,
        pending_inval: Mutex<Vec<Vec<u64>>>,
    }

    impl MockShards {
        fn new(n: usize, dim: usize) -> Self {
            MockShards {
                n,
                dim,
                step: AtomicU64::new(0),
                gather_calls: AtomicU64::new(0),
                pending_inval: Mutex::new(vec![Vec::new(); n]),
            }
        }

        fn row_value(key: u64, step: u64) -> f32 {
            (key + 1000 * step) as f32
        }

        /// Advance the training step and stage the touched keys in
        /// shard 0's invalidation log (eviction is by key, so which
        /// shard reports it doesn't matter).
        fn apply(&self, keys: &[u64]) {
            self.step.fetch_add(1, Ordering::Relaxed);
            self.pending_inval.lock().unwrap()[0].extend_from_slice(keys);
        }
    }

    impl ReadShards for Arc<MockShards> {
        fn n_shards(&self) -> usize {
            self.n
        }

        fn emb_dim(&self) -> usize {
            self.dim
        }

        fn read_call(&self, s: usize, req: ShardRequest) -> Result<ShardReply> {
            match req {
                ShardRequest::GatherAt { keys } => {
                    self.gather_calls.fetch_add(1, Ordering::Relaxed);
                    let step = self.step.load(Ordering::Relaxed);
                    let mut data = vec![0.0f32; keys.len() * self.dim];
                    for (i, &key) in keys.iter().enumerate() {
                        data[i * self.dim..(i + 1) * self.dim]
                            .fill(MockShards::row_value(key, step));
                    }
                    Ok(ShardReply::RowsAt { step, dim: self.dim as u64, data })
                }
                ShardRequest::ReadInvalidations { .. } => {
                    let keys = std::mem::take(&mut self.pending_inval.lock().unwrap()[s]);
                    Ok(ShardReply::Invalidations {
                        upto: self.step.load(Ordering::Relaxed),
                        full: false,
                        keys,
                    })
                }
                other => bail!("mock: unexpected read verb {other:?}"),
            }
        }
    }

    fn cfg(cache_rows: usize) -> ServeConfig {
        ServeConfig {
            listen: "127.0.0.1:0".to_string(),
            cache_rows,
            cache_shards: 4,
            batch_window_us: 0,
            max_stale_ms: 0, // poll invalidations before every request
        }
    }

    fn front_over(mock: &Arc<MockShards>, cache_rows: usize) -> ServeFront {
        ServeFront::new(Box::new(mock.clone()), cfg(cache_rows))
    }

    #[test]
    fn cache_hits_skip_the_ps_and_invalidation_evicts() {
        let mock = Arc::new(MockShards::new(2, 3));
        let front = front_over(&mock, 1024);

        let t = front.gather(&[1, 2, 3], 1, 3).unwrap();
        assert_eq!(t.shape, vec![1, 3, 3]);
        for (i, key) in [1u64, 2, 3].into_iter().enumerate() {
            assert_eq!(t.data[i * 3..(i + 1) * 3], [MockShards::row_value(key, 0); 3]);
        }

        // Same keys again: all hits, no new PS gathers.
        let calls_before = mock.gather_calls.load(Ordering::Relaxed);
        let t2 = front.gather(&[1, 2, 3], 1, 3).unwrap();
        assert_eq!(t2.data, t.data);
        assert_eq!(mock.gather_calls.load(Ordering::Relaxed), calls_before);
        let s = front.stats_snapshot();
        assert_eq!(s.cache_hits, 3);
        assert_eq!(s.cache_misses, 3);

        // A training apply touching key 2 must evict it: the next
        // gather re-fetches key 2 at the new step while 1 and 3 are
        // still served from cache at the old value.
        mock.apply(&[2]);
        let t3 = front.gather(&[1, 2, 3], 1, 3).unwrap();
        assert_eq!(t3.data[0..3], [MockShards::row_value(1, 0); 3]);
        assert_eq!(t3.data[3..6], [MockShards::row_value(2, 1); 3]);
        assert_eq!(t3.data[6..9], [MockShards::row_value(3, 0); 3]);
        assert!(front.stats_snapshot().cache_evictions >= 1);
    }

    #[test]
    fn cache_rows_zero_disables_caching() {
        let mock = Arc::new(MockShards::new(2, 2));
        let front = front_over(&mock, 0);
        front.gather(&[7, 8], 1, 2).unwrap();
        front.gather(&[7, 8], 1, 2).unwrap();
        let s = front.stats_snapshot();
        assert_eq!(s.cache_hits, 0);
        assert_eq!(s.cache_misses, 4);
        // Every request ran its own fetch round.
        assert_eq!(s.rounds, 2);
    }

    /// Clock mechanics at the shard level: a full ring evicts exactly
    /// one un-referenced victim per insert, referenced rows get a second
    /// chance, and invalidated rows leave stale slots that are reused
    /// without evicting anything live.
    #[test]
    fn clock_shard_evicts_one_cold_row_and_spares_referenced() {
        let mut s = CacheShard::default();
        for key in 0..4u64 {
            assert_eq!(s.put(key, vec![key as f32], 4), 0, "filling evicts nothing");
        }
        // Reference keys 0 and 2; 1 and 3 stay cold.
        assert!(s.get(0).is_some());
        assert!(s.get(2).is_some());
        // First overflow: hand at 0 spares 0 (referenced), evicts 1.
        assert_eq!(s.put(10, vec![10.0], 4), 1);
        assert!(s.rows.contains_key(&0), "referenced row survived the sweep");
        assert!(!s.rows.contains_key(&1), "cold row was the victim");
        assert_eq!(s.rows.len(), 4);
        // Invalidation removes a row but leaves its ring slot; the next
        // overflow reuses the stale slot with no live eviction.
        s.rows.remove(&3);
        assert_eq!(s.put(11, vec![11.0], 4), 0, "stale slot reused for free");
        assert_eq!(s.rows.len(), 4);
        // A re-put of a present key updates in place, never evicts.
        assert_eq!(s.put(10, vec![99.0], 4), 0);
        assert_eq!(s.get(10), Some(vec![99.0]));
        assert_eq!(s.clear(), 4);
        assert!(s.ring.is_empty() && s.hand == 0);
    }

    #[test]
    fn duplicate_keys_in_one_request_fetch_once() {
        let mock = Arc::new(MockShards::new(2, 2));
        let front = front_over(&mock, 1024);
        // batch=2, fields=2: key 5 appears three times.
        let t = front.gather(&[5, 5, 5, 9], 2, 2).unwrap();
        assert_eq!(t.shape, vec![2, 2, 2]);
        for slot in 0..3 {
            assert_eq!(t.data[slot * 2..(slot + 1) * 2], [MockShards::row_value(5, 0); 2]);
        }
        assert_eq!(t.data[6..8], [MockShards::row_value(9, 0); 2]);
    }

    #[test]
    fn concurrent_misses_coalesce_into_fewer_rounds() {
        let mock = Arc::new(MockShards::new(2, 2));
        let mut c = cfg(1 << 20);
        c.batch_window_us = 2000; // real window so threads can pile in
        c.max_stale_ms = 60_000; // keep maintenance out of the way
        let front = Arc::new(ServeFront::new(Box::new(mock.clone()), c));

        let threads = 8;
        std::thread::scope(|scope| {
            for t in 0..threads {
                let front = front.clone();
                scope.spawn(move || {
                    // Distinct keys per thread: every request misses.
                    let keys = [100 + t as u64, 200 + t as u64];
                    front.gather(&keys, 1, 2).unwrap();
                });
            }
        });
        let s = front.stats_snapshot();
        assert_eq!(s.requests, threads as u64);
        assert_eq!(s.cache_misses, 2 * threads as u64);
        // The point of the window: strictly fewer fetch rounds than
        // requests (typically 1-2 for 8 threads in a 2 ms window).
        assert!(
            s.rounds < threads as u64,
            "expected coalescing, got {} rounds for {} requests",
            s.rounds,
            threads
        );
    }

    #[test]
    fn listener_serves_the_worker_gather_vocabulary_over_tcp() {
        let mock = Arc::new(MockShards::new(2, 3));
        let front = Arc::new(front_over(&mock, 1024));
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = serve_listener(front, listener).unwrap();

        let mut client =
            ServeClient::connect(&addr.to_string(), Duration::from_secs(5)).unwrap();
        let t = client.gather(&[11, 12], 1, 2).unwrap();
        assert_eq!(t.shape, vec![1, 2, 3]);
        assert_eq!(t.data[0..3], [MockShards::row_value(11, 0); 3]);
        assert_eq!(t.data[3..6], [MockShards::row_value(12, 0); 3]);

        // A non-gather frame closes the connection rather than touching
        // the read plane.
        let mut bad = ServeClient::connect(&addr.to_string(), Duration::from_secs(5)).unwrap();
        bad.conn.send(WireMsg::Req(ShardRequest::Ping)).unwrap();
        assert!(matches!(bad.conn.recv(), Err(CodecError::Closed | CodecError::Io(_))));
    }
}
