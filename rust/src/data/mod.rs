//! Synthetic click-log data pipeline.
//!
//! Substitutes the paper's industrial datasets (Criteo-1TB / Alimama /
//! Private; see DESIGN.md §2) with a *deterministic, seeded* generator that
//! preserves the two properties the paper's analysis depends on:
//!
//! 1. **Skewed ID popularity** (Fig. 4): per-field IDs are Zipf-distributed,
//!    so most embedding rows are touched by few batches — the source of the
//!    embedding parameters' staleness tolerance (Insight 2).
//! 2. **A learnable CTR signal**: labels are drawn from a fixed random
//!    *teacher* model (logistic in per-ID latent utilities), so AUC rises
//!    with training and is bounded away from 1 by sampling + label noise.
//!    A small per-day drift creates the continual-learning regime of the
//!    paper's day-by-day train/eval protocol.
//!
//! Every sample is a pure function of `(seed, day, sample_index)`: the data
//! "exists" without being materialized, any batching scheme sees the same
//! stream, and workers "download" shards by generating them.

pub mod stats;

use crate::config::{DataConfig, ModelConfig};
use crate::util::rng::{Pcg64, Zipf};

/// Combined feature key: `field << 48 | id` — one expandable embedding
/// namespace across fields (DeepRec-style single hash table).
#[inline]
pub fn feature_key(field: usize, id: u64) -> u64 {
    ((field as u64) << 48) | (id & 0xFFFF_FFFF_FFFF)
}

#[inline]
pub fn split_key(key: u64) -> (usize, u64) {
    ((key >> 48) as usize, key & 0xFFFF_FFFF_FFFF)
}

use crate::util::rng::mix64;

/// One training/eval sample.
#[derive(Clone, Debug, PartialEq)]
pub struct Sample {
    /// One combined feature key per field, length F.
    pub keys: Vec<u64>,
    pub label: f32,
}

/// A batch of samples in struct-of-arrays layout.
#[derive(Clone, Debug)]
pub struct Batch {
    pub day: usize,
    /// First sample index of the batch within the day.
    pub start: usize,
    /// Flattened [B * F] feature keys.
    pub keys: Vec<u64>,
    pub labels: Vec<f32>,
    pub batch_size: usize,
    pub fields: usize,
}

impl Batch {
    pub fn keys_of(&self, i: usize) -> &[u64] {
        &self.keys[i * self.fields..(i + 1) * self.fields]
    }
}

/// The teacher (label) model: latent utility per feature key with per-day
/// drift. `u_d(key) = u(key) + drift * v(key, d)`, both standard normal
/// per-key draws.
#[derive(Clone, Debug)]
pub struct Teacher {
    seed: u64,
    drift: f64,
    /// Logit scale: controls class separability (hence achievable AUC).
    pub scale: f64,
    /// Logit bias: controls base CTR (class imbalance).
    pub bias: f64,
}

impl Teacher {
    pub fn new(data: &DataConfig) -> Self {
        Teacher { seed: data.teacher_seed, drift: data.drift, scale: 3.0, bias: -0.8 }
    }

    #[inline]
    fn latent(&self, key: u64) -> f64 {
        // One Box-Muller draw from a key-derived stream.
        Pcg64::new(self.seed ^ mix64(key), 0x7eac).normal()
    }

    #[inline]
    fn day_drift(&self, key: u64, day: usize) -> f64 {
        if self.drift == 0.0 {
            return 0.0;
        }
        self.drift * Pcg64::new(self.seed ^ mix64((key ^ ((day as u64) << 1)) | 1), 0xd1).normal()
    }

    /// True logit for a sample's keys on a given day.
    pub fn logit(&self, keys: &[u64], day: usize) -> f64 {
        let f = keys.len() as f64;
        let sum: f64 =
            keys.iter().map(|&k| self.latent(k) + self.day_drift(k, day)).sum();
        self.bias + self.scale * sum / f.sqrt()
    }

    /// Bayes-optimal probability for a sample (for oracle AUC measurement).
    pub fn prob(&self, keys: &[u64], day: usize) -> f64 {
        let z = self.logit(keys, day);
        1.0 / (1.0 + (-z).exp())
    }
}

/// Deterministic generator for one task's data.
#[derive(Clone, Debug)]
pub struct DataGen {
    pub model: ModelConfig,
    pub data: DataConfig,
    pub seed: u64,
    teacher: Teacher,
    zipf: Zipf,
}

impl DataGen {
    pub fn new(model: &ModelConfig, data: &DataConfig, seed: u64) -> Self {
        DataGen {
            model: model.clone(),
            data: data.clone(),
            seed,
            teacher: Teacher::new(data),
            zipf: Zipf::new(model.vocab_size, model.zipf_s),
        }
    }

    pub fn teacher(&self) -> &Teacher {
        &self.teacher
    }

    /// Generate sample `j` of `day`. Pure function of (seed, day, j).
    pub fn sample(&self, day: usize, j: usize) -> Sample {
        let mut rng = Pcg64::new(self.seed ^ mix64((day as u64) << 40 ^ j as u64), 0x5a);
        let keys: Vec<u64> = (0..self.model.fields)
            .map(|f| {
                // Per-field popularity permutation: rank r of field f maps to
                // id mix(r, f) % vocab so fields don't share hot IDs.
                let rank = self.zipf.sample(&mut rng);
                let id = mix64(rank.wrapping_mul(0x2545_F491_4F6C_DD1D) ^ (f as u64) << 17)
                    % self.model.vocab_size;
                feature_key(f, id)
            })
            .collect();
        let p = self.teacher.prob(&keys, day);
        let mut label = if rng.next_f64() < p { 1.0 } else { 0.0 };
        if self.data.label_noise > 0.0 && rng.next_f64() < self.data.label_noise {
            label = 1.0 - label;
        }
        Sample { keys, label }
    }

    /// Number of batches a day yields at a given local batch size.
    pub fn batches_per_day(&self, batch_size: usize) -> usize {
        self.data.samples_per_day / batch_size
    }

    /// Generate the batch covering samples [start, start + bsz) of `day`.
    pub fn batch(&self, day: usize, start: usize, bsz: usize) -> Batch {
        let fields = self.model.fields;
        let mut keys = Vec::with_capacity(bsz * fields);
        let mut labels = Vec::with_capacity(bsz);
        for j in start..start + bsz {
            let s = self.sample(day, j);
            keys.extend_from_slice(&s.keys);
            labels.push(s.label);
        }
        Batch { day, start, keys, labels, batch_size: bsz, fields }
    }

    /// Batch by index (batch `i` covers samples [i*bsz, (i+1)*bsz)).
    pub fn batch_by_index(&self, day: usize, index: usize, bsz: usize) -> Batch {
        self.batch(day, index * bsz, bsz)
    }

    /// Total days (base + eval period).
    pub fn total_days(&self) -> usize {
        self.data.days_base + self.data.days_eval
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> (ModelConfig, DataConfig) {
        (
            ModelConfig {
                variant: "tiny".into(),
                fields: 4,
                emb_dim: 4,
                hidden1: 8,
                hidden2: 4,
                vocab_size: 1000,
                zipf_s: 1.1,
            },
            DataConfig {
                days_base: 2,
                days_eval: 2,
                samples_per_day: 1000,
                teacher_seed: 7,
                label_noise: 0.05,
                drift: 0.02,
            },
        )
    }

    #[test]
    fn samples_are_deterministic() {
        let (m, d) = cfg();
        let g1 = DataGen::new(&m, &d, 42);
        let g2 = DataGen::new(&m, &d, 42);
        for j in 0..50 {
            assert_eq!(g1.sample(1, j), g2.sample(1, j));
        }
        // Different seed => different stream.
        let g3 = DataGen::new(&m, &d, 43);
        let same = (0..50).filter(|&j| g1.sample(1, j) == g3.sample(1, j)).count();
        assert!(same < 5);
    }

    #[test]
    fn batching_invariant_to_scheme() {
        let (m, d) = cfg();
        let g = DataGen::new(&m, &d, 42);
        let b_all = g.batch(0, 0, 64);
        let b_a = g.batch(0, 0, 32);
        let b_b = g.batch(0, 32, 32);
        assert_eq!(&b_all.keys[..32 * 4], &b_a.keys[..]);
        assert_eq!(&b_all.keys[32 * 4..], &b_b.keys[..]);
        assert_eq!(&b_all.labels[..32], &b_a.labels[..]);
        assert_eq!(&b_all.labels[32..], &b_b.labels[..]);
    }

    #[test]
    fn keys_encode_fields() {
        let (m, d) = cfg();
        let g = DataGen::new(&m, &d, 42);
        let s = g.sample(0, 0);
        for (f, &k) in s.keys.iter().enumerate() {
            let (field, id) = split_key(k);
            assert_eq!(field, f);
            assert!(id < m.vocab_size);
        }
    }

    #[test]
    fn labels_have_signal() {
        // The teacher's probabilities must correlate with drawn labels:
        // mean(p | y=1) > mean(p | y=0).
        let (m, d) = cfg();
        let g = DataGen::new(&m, &d, 42);
        let (mut p1, mut n1, mut p0, mut n0) = (0.0, 0, 0.0, 0);
        for j in 0..2000 {
            let s = g.sample(0, j);
            let p = g.teacher().prob(&s.keys, 0);
            if s.label > 0.5 {
                p1 += p;
                n1 += 1;
            } else {
                p0 += p;
                n0 += 1;
            }
        }
        assert!(n1 > 100 && n0 > 100, "degenerate labels: {n1} vs {n0}");
        assert!(p1 / n1 as f64 > p0 / n0 as f64 + 0.1);
    }

    #[test]
    fn zipf_popularity_is_skewed() {
        let (m, d) = cfg();
        let g = DataGen::new(&m, &d, 42);
        let mut counts = std::collections::HashMap::new();
        for j in 0..3000 {
            for &k in &g.sample(0, j).keys {
                *counts.entry(k).or_insert(0usize) += 1;
            }
        }
        let mut v: Vec<usize> = counts.values().copied().collect();
        v.sort_unstable_by(|a, b| b.cmp(a));
        let total: usize = v.iter().sum();
        let top10: usize = v.iter().take(v.len() / 10).sum();
        // Top-10% of IDs should carry well over half the occurrences.
        assert!(top10 as f64 / total as f64 > 0.5, "top10={top10} total={total}");
    }

    #[test]
    fn drift_changes_days() {
        let (m, mut dcfg) = cfg();
        dcfg.drift = 0.5;
        let g = DataGen::new(&m, &dcfg, 42);
        let s = g.sample(0, 0);
        let l0 = g.teacher().logit(&s.keys, 0);
        let l1 = g.teacher().logit(&s.keys, 3);
        assert!((l0 - l1).abs() > 1e-6);
    }
}
