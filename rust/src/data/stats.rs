//! Dataset statistics — the measurement behind Fig. 4 (skewed distribution
//! of ID occurrences across batches) and Insight 2 (embedding parameters
//! are updated far less often than dense parameters).

use std::collections::HashMap;

use super::DataGen;

/// Per-ID batch-occurrence statistics over `n_batches` batches of one day.
#[derive(Clone, Debug)]
pub struct OccurrenceStats {
    /// Number of distinct IDs observed.
    pub distinct_ids: usize,
    /// occurrence_counts[i] = number of batches in which the i-th ID
    /// appeared (deduplicated per batch), sorted descending.
    pub batches_per_id: Vec<u32>,
    /// Total batches scanned.
    pub n_batches: usize,
    /// Fraction of IDs that appear in at most `k` batches, for k=1..=10.
    pub cdf_small: Vec<f64>,
    /// Mean update opportunities of an ID vs a dense parameter: a dense
    /// parameter is updated every batch (ratio 1.0); an embedding row only
    /// in the batches containing its ID.
    pub mean_update_ratio: f64,
}

/// Scan `n_batches` batches of `day` at `batch_size` and aggregate the
/// per-ID occurrence distribution.
pub fn id_occurrence_stats(
    gen: &DataGen,
    day: usize,
    batch_size: usize,
    n_batches: usize,
) -> OccurrenceStats {
    let mut per_id: HashMap<u64, u32> = HashMap::new();
    let mut seen_in_batch: std::collections::HashSet<u64> = std::collections::HashSet::new();
    for b in 0..n_batches {
        let batch = gen.batch_by_index(day, b, batch_size);
        seen_in_batch.clear();
        for &k in &batch.keys {
            if seen_in_batch.insert(k) {
                *per_id.entry(k).or_insert(0) += 1;
            }
        }
    }
    let mut batches_per_id: Vec<u32> = per_id.values().copied().collect();
    batches_per_id.sort_unstable_by(|a, b| b.cmp(a));
    let n_ids = batches_per_id.len().max(1);
    let cdf_small = (1..=10)
        .map(|k| batches_per_id.iter().filter(|&&c| c <= k).count() as f64 / n_ids as f64)
        .collect();
    let mean_update_ratio = batches_per_id.iter().map(|&c| c as f64).sum::<f64>()
        / (n_ids as f64 * n_batches.max(1) as f64);
    OccurrenceStats {
        distinct_ids: per_id.len(),
        batches_per_id,
        n_batches,
        cdf_small,
        mean_update_ratio,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{DataConfig, ModelConfig};

    #[test]
    fn occurrence_distribution_is_skewed() {
        let m = ModelConfig {
            variant: "tiny".into(),
            fields: 4,
            emb_dim: 4,
            hidden1: 8,
            hidden2: 4,
            vocab_size: 5_000,
            zipf_s: 1.1,
        };
        let d = DataConfig {
            days_base: 1,
            days_eval: 1,
            samples_per_day: 10_000,
            teacher_seed: 7,
            label_noise: 0.0,
            drift: 0.0,
        };
        let gen = DataGen::new(&m, &d, 1);
        let stats = id_occurrence_stats(&gen, 0, 64, 100);
        assert!(stats.distinct_ids > 100);
        assert_eq!(stats.n_batches, 100);
        // Skew: the hottest ID is in (almost) every batch...
        assert!(stats.batches_per_id[0] as usize >= 90);
        // ...while most IDs appear in <= 10 batches (the Fig. 4 shape).
        assert!(stats.cdf_small[9] > 0.5, "cdf10={}", stats.cdf_small[9]);
        // Embedding rows see far fewer updates than dense params.
        assert!(stats.mean_update_ratio < 0.5);
        // CDF is monotone.
        for w in stats.cdf_small.windows(2) {
            assert!(w[0] <= w[1]);
        }
    }
}
