//! Wire-level trace spans: a per-thread *current trace id* that the
//! codec stamps into every frame header, plus an optional JSONL span
//! sink so one gradient push can be followed worker → front → shard →
//! apply across process boundaries.
//!
//! The id is a nonzero `u64` (0 means "no trace"). [`crate::transport::codec::encode`]
//! writes the calling thread's current id into the frame header;
//! `decode` installs the received id on the decoding thread — so a
//! request's id is naturally in scope while the serving thread handles
//! it (and is echoed back on the reply). Span emission is a no-op until
//! [`init`] opens a per-process JSONL file; ids are *always* stamped so
//! a downstream process with tracing enabled still correlates frames
//! from an upstream one without it.

use std::cell::Cell;
use std::fs::OpenOptions;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::util::json::Json;

thread_local! {
    static CURRENT: Cell<u64> = const { Cell::new(0) };
}

static SEQ: AtomicU64 = AtomicU64::new(1);
static SEED: OnceLock<u64> = OnceLock::new();

/// Allocate a fresh process-unique, run-unique trace id (never 0).
/// High entropy comes from mixing a per-process wall-clock/pid seed
/// through a bijective multiply, so ids from different processes in
/// the same run don't collide.
pub fn next_id() -> u64 {
    let seed = *SEED.get_or_init(|| {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0x9e37_79b9_7f4a_7c15);
        nanos ^ (u64::from(std::process::id()).rotate_left(40))
    });
    let id = seed
        .wrapping_add(SEQ.fetch_add(1, Ordering::Relaxed))
        .wrapping_mul(0x9e37_79b9_7f4a_7c15);
    if id == 0 {
        1
    } else {
        id
    }
}

/// Install `id` as this thread's current trace id (0 clears).
pub fn set_current(id: u64) {
    CURRENT.with(|c| c.set(id));
}

/// This thread's current trace id (0 = none).
pub fn current() -> u64 {
    CURRENT.with(|c| c.get())
}

/// Clear this thread's current trace id.
pub fn clear() {
    set_current(0);
}

struct Sink {
    role: String,
    w: BufWriter<std::fs::File>,
}

static SINK: Mutex<Option<Sink>> = Mutex::new(None);

/// Open the per-process span sink: `dir/<role>-<pid>.jsonl` (append
/// mode, so restarts of the same role keep their history). Until this
/// is called, [`span`] is a no-op.
pub fn init(dir: &str, role: &str) -> std::io::Result<PathBuf> {
    std::fs::create_dir_all(dir)?;
    let path = Path::new(dir).join(format!("{role}-{}.jsonl", std::process::id()));
    let f = OpenOptions::new().create(true).append(true).open(&path)?;
    *SINK.lock().unwrap() = Some(Sink { role: role.to_string(), w: BufWriter::new(f) });
    Ok(path)
}

/// Whether a span sink is open (export enabled).
pub fn enabled() -> bool {
    SINK.lock().unwrap().is_some()
}

/// Emit one span event as a JSONL line:
/// `{"ts_us":…,"role":…,"trace":"<016x>","event":…,…fields}`.
/// The trace id is serialized as a zero-padded hex string so the full
/// 64 bits survive JSON number handling. No-op when no sink is open.
pub fn span(event: &str, fields: Json) {
    let mut guard = SINK.lock().unwrap();
    let Some(sink) = guard.as_mut() else { return };
    let ts_us = SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0);
    let mut doc = Json::obj()
        .set("ts_us", ts_us)
        .set("role", sink.role.as_str())
        .set("trace", format!("{:016x}", current()))
        .set("event", event);
    if let (Json::Obj(doc_map), Json::Obj(extra)) = (&mut doc, fields) {
        for (k, v) in extra {
            doc_map.insert(k, v);
        }
    }
    let _ = writeln!(sink.w, "{}", doc.to_string_compact());
    let _ = sink.w.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_nonzero_and_distinct() {
        let a = next_id();
        let b = next_id();
        let c = next_id();
        assert_ne!(a, 0);
        assert_ne!(b, 0);
        assert_ne!(a, b);
        assert_ne!(b, c);
        assert_ne!(a, c);
    }

    #[test]
    fn current_is_per_thread() {
        set_current(42);
        assert_eq!(current(), 42);
        let other = std::thread::spawn(|| {
            assert_eq!(current(), 0, "fresh thread starts untraced");
            set_current(7);
            current()
        })
        .join()
        .unwrap();
        assert_eq!(other, 7);
        assert_eq!(current(), 42, "other thread's id must not leak");
        clear();
        assert_eq!(current(), 0);
    }

    #[test]
    fn span_writes_parseable_jsonl() {
        let dir = std::env::temp_dir().join(format!("gba-obs-trace-{}", std::process::id()));
        let dir_s = dir.to_str().unwrap().to_string();
        let path = init(&dir_s, "unit").unwrap();
        assert!(enabled());
        set_current(0xdead_beef);
        span("push", Json::obj().set("worker", 3usize).set("bytes", 128usize));
        clear();

        let text = std::fs::read_to_string(&path).unwrap();
        let line = text.lines().last().unwrap();
        let doc = crate::util::json::parse(line).unwrap();
        assert_eq!(doc.get("event").and_then(|j| j.as_str()), Some("push"));
        assert_eq!(doc.get("role").and_then(|j| j.as_str()), Some("unit"));
        assert_eq!(doc.get("trace").and_then(|j| j.as_str()), Some("00000000deadbeef"));
        assert_eq!(doc.get("worker").and_then(|j| j.as_usize()), Some(3));
        assert!(doc.get("ts_us").and_then(|j| j.as_f64()).unwrap() > 0.0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
