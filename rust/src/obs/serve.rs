//! The `[obs] listen` exposition endpoint: a deliberately tiny HTTP/1.0
//! server (zero dependencies, one thread) that answers every request
//! with the [`global`](super::global) registry rendered as Prometheus
//! text. Point a browser, `curl`, or a Prometheus scraper at
//! `http://<addr>/metrics`.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// Bind `listen` (`host:port`; port 0 picks a free one), spawn the
/// accept loop, and return the bound address. The thread runs for the
/// life of the process — exposition is read-only, so there is nothing
/// to shut down cleanly.
pub fn start(listen: &str) -> std::io::Result<SocketAddr> {
    let listener = TcpListener::bind(listen)?;
    let addr = listener.local_addr()?;
    std::thread::Builder::new().name("obs-metrics".into()).spawn(move || {
        for conn in listener.incoming() {
            if let Ok(stream) = conn {
                let _ = serve_one(stream);
            }
        }
    })?;
    Ok(addr)
}

fn serve_one(mut s: TcpStream) -> std::io::Result<()> {
    s.set_read_timeout(Some(Duration::from_secs(2)))?;
    // Drain the request head; we serve the same document on any path.
    let mut head = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        let n = s.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() > 64 * 1024 {
            break;
        }
    }
    let body = super::global().render();
    let resp = format!(
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    s.write_all(resp.as_bytes())?;
    s.write_all(body.as_bytes())?;
    s.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_global_registry_over_http() {
        super::super::global().counter("obs_serve_test_total").add(11);
        let addr = start("127.0.0.1:0").unwrap();
        let mut conn = TcpStream::connect(addr).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\nHost: test\r\n\r\n").unwrap();
        let mut resp = String::new();
        conn.read_to_string(&mut resp).unwrap();
        assert!(resp.starts_with("HTTP/1.0 200 OK\r\n"), "{resp}");
        assert!(resp.contains("# TYPE obs_serve_test_total counter"), "{resp}");
        assert!(resp.contains("obs_serve_test_total 11"), "{resp}");
    }
}
