//! Cluster-wide observability plane: a zero-dependency, lock-cheap
//! metrics registry plus trace spans ([`trace`]) and a `/metrics`
//! exposition listener ([`serve`]).
//!
//! Design rules (see docs/OBSERVABILITY.md for the operator view):
//!
//! * **Always-on counting, gated export.** Instrumented code paths
//!   increment atomics unconditionally — an atomic add never touches
//!   training arithmetic, so the bit-identity pins hold with or without
//!   `[obs]` configured. Only the *export* surfaces (the TCP listener,
//!   the trace JSONL sink) are opt-in.
//! * **Lock-cheap hot paths.** [`Counter`], [`Gauge`] and [`Histogram`]
//!   are plain atomics; the registry's map lock is only taken on
//!   get-or-register and on scrape. Per-batch paths cache the `Arc`
//!   handle at construction time; per-RPC paths (already a network
//!   round-trip) may look up by name.
//! * **One namespace.** Every process has one [`global()`] registry;
//!   labels are folded into the stored key as `name{label="value"}`
//!   so the map stays a flat `BTreeMap`.
//!
//! The exposition format is the Prometheus text format (counters,
//! gauges, and cumulative `_bucket`/`_sum`/`_count` histogram series);
//! [`Registry::snapshot`] is the flat numeric view the `ObsScrape`
//! shard RPC ships to the coordinator for the run-wide telemetry block.

pub mod serve;
pub mod trace;

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Monotonic atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins `f64` gauge (stored as IEEE-754 bits in an atomic).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Lock-free add of an `f64` stored as bits in an `AtomicU64`.
fn atomic_f64_add(cell: &AtomicU64, v: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + v).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

/// Fixed-bucket histogram with atomic per-bucket counts.
///
/// `bounds` are ascending upper bounds with `<=` semantics (a value
/// exactly on a bound lands in that bound's bucket, matching the
/// Prometheus `le` convention); values above the last bound land in an
/// implicit overflow (`+Inf`) bucket. Quantiles are linearly
/// interpolated inside the winning bucket, which is exact enough for
/// p50/p95/p99 at the bucket resolutions used here.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    /// `bounds.len() + 1` cells; the last is the overflow bucket.
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    /// Running sum of recorded values, as `f64` bits.
    sum: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: &[f64]) -> Histogram {
        assert!(!bounds.is_empty(), "histogram needs at least one bucket bound");
        assert!(
            bounds.windows(2).all(|w| w[0] < w[1]),
            "histogram bounds must be strictly ascending"
        );
        Histogram {
            bounds: bounds.to_vec(),
            buckets: (0..bounds.len() + 1).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
        }
    }

    /// Default bounds for latency-in-seconds metrics: 1µs .. 10s.
    pub fn latency_bounds() -> &'static [f64] {
        &[
            1e-6, 2.5e-6, 5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3,
            1e-2, 2.5e-2, 5e-2, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
        ]
    }

    /// Default bounds for byte-size metrics: 64 B .. 1 GiB in powers of 4.
    pub fn byte_bounds() -> &'static [f64] {
        &[
            64.0, 256.0, 1024.0, 4096.0, 16384.0, 65536.0, 262144.0, 1048576.0, 4194304.0,
            16777216.0, 67108864.0, 268435456.0, 1073741824.0,
        ]
    }

    pub fn record(&self, v: f64) {
        // First bound >= v, i.e. the `le` bucket this value belongs to;
        // `bounds.len()` selects the overflow bucket.
        let idx = self.bounds.partition_point(|b| *b < v);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        atomic_f64_add(&self.sum, v);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> f64 {
        f64::from_bits(self.sum.load(Ordering::Relaxed))
    }

    /// Per-bucket (non-cumulative) counts, overflow bucket last.
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }

    /// Quantile `q` in `[0, 1]`, linearly interpolated within the
    /// winning bucket (the overflow bucket reports the last bound).
    /// Returns 0.0 on an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        let counts = self.bucket_counts();
        let total: u64 = counts.iter().sum();
        if total == 0 {
            return 0.0;
        }
        let rank = (q.clamp(0.0, 1.0) * total as f64).max(1.0);
        let mut cum = 0u64;
        for (i, c) in counts.iter().enumerate() {
            cum += c;
            if (cum as f64) >= rank {
                if i == self.bounds.len() {
                    return *self.bounds.last().unwrap();
                }
                let lo = if i == 0 { 0.0 } else { self.bounds[i - 1] };
                let hi = self.bounds[i];
                let below = cum - c;
                let frac = if *c == 0 { 1.0 } else { (rank - below as f64) / *c as f64 };
                return lo + (hi - lo) * frac.clamp(0.0, 1.0);
            }
        }
        *self.bounds.last().unwrap()
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A process-wide metric namespace. Keys carry their labels inline
/// (`gba_rpc_seconds{rpc="apply"}`), so one flat ordered map holds the
/// whole exposition.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

/// Fold a single label into a metric key, Prometheus-style.
pub fn labeled(name: &str, label: &str, value: &str) -> String {
    format!("{name}{{{label}=\"{value}\"}}")
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Get-or-register a counter. Panics if `key` is already registered
    /// as a different metric type (a programming error, not a runtime
    /// condition).
    pub fn counter(&self, key: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::new())))
        {
            Metric::Counter(c) => c.clone(),
            _ => panic!("obs metric {key:?} already registered as a non-counter"),
        }
    }

    pub fn gauge(&self, key: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(key.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::new()))) {
            Metric::Gauge(g) => g.clone(),
            _ => panic!("obs metric {key:?} already registered as a non-gauge"),
        }
    }

    /// Get-or-register a histogram. The `bounds` only matter on first
    /// registration; later calls return the existing instance.
    pub fn histogram(&self, key: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(key.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds))))
        {
            Metric::Histogram(h) => h.clone(),
            _ => panic!("obs metric {key:?} already registered as a non-histogram"),
        }
    }

    /// Flat numeric snapshot: counters and gauges as-is, histograms
    /// expanded to `_count` / `_sum` / `_p50` / `_p95` / `_p99` keys
    /// (labels stay attached to the base key). This is what the
    /// `ObsScrape` RPC ships.
    pub fn snapshot(&self) -> Vec<(String, f64)> {
        let m = self.metrics.lock().unwrap();
        let mut out = Vec::with_capacity(m.len());
        for (key, metric) in m.iter() {
            match metric {
                Metric::Counter(c) => out.push((key.clone(), c.get() as f64)),
                Metric::Gauge(g) => out.push((key.clone(), g.get())),
                Metric::Histogram(h) => {
                    let (base, labels) = split_key(key);
                    let k = |suffix: &str| match labels {
                        Some(l) => format!("{base}{suffix}{{{l}}}"),
                        None => format!("{base}{suffix}"),
                    };
                    out.push((k("_count"), h.count() as f64));
                    out.push((k("_sum"), h.sum()));
                    out.push((k("_p50"), h.quantile(0.50)));
                    out.push((k("_p95"), h.quantile(0.95)));
                    out.push((k("_p99"), h.quantile(0.99)));
                }
            }
        }
        out
    }

    /// Prometheus text exposition of every registered metric.
    pub fn render(&self) -> String {
        let m = self.metrics.lock().unwrap();
        let mut out = String::new();
        let mut typed: Vec<&str> = Vec::new();
        for (key, metric) in m.iter() {
            let (base, labels) = split_key(key);
            let kind = match metric {
                Metric::Counter(_) => "counter",
                Metric::Gauge(_) => "gauge",
                Metric::Histogram(_) => "histogram",
            };
            if !typed.contains(&base) {
                out.push_str(&format!("# TYPE {base} {kind}\n"));
                typed.push(base);
            }
            match metric {
                Metric::Counter(c) => out.push_str(&format!("{key} {}\n", c.get())),
                Metric::Gauge(g) => out.push_str(&format!("{key} {}\n", fmt_f64(g.get()))),
                Metric::Histogram(h) => {
                    let bucket_key = |le: &str| match labels {
                        Some(l) => format!("{base}_bucket{{{l},le=\"{le}\"}}"),
                        None => format!("{base}_bucket{{le=\"{le}\"}}"),
                    };
                    let plain = |suffix: &str| match labels {
                        Some(l) => format!("{base}{suffix}{{{l}}}"),
                        None => format!("{base}{suffix}"),
                    };
                    let counts = h.bucket_counts();
                    let mut cum = 0u64;
                    for (i, c) in counts.iter().enumerate() {
                        cum += c;
                        let le = if i == h.bounds.len() {
                            "+Inf".to_string()
                        } else {
                            fmt_f64(h.bounds[i])
                        };
                        out.push_str(&format!("{} {cum}\n", bucket_key(&le)));
                    }
                    out.push_str(&format!("{} {}\n", plain("_sum"), fmt_f64(h.sum())));
                    out.push_str(&format!("{} {}\n", plain("_count"), h.count()));
                }
            }
        }
        out
    }
}

/// Split a stored key into its base name and the label body (the text
/// between the braces), if any.
fn split_key(key: &str) -> (&str, Option<&str>) {
    match key.find('{') {
        Some(i) => (&key[..i], Some(key[i + 1..].trim_end_matches('}'))),
        None => (key, None),
    }
}

fn fmt_f64(v: f64) -> String {
    // f64 Display is the shortest round-trip decimal ("0.5", "1",
    // "0.000001") — exactly what the exposition should show.
    v.to_string()
}

/// The process-wide registry every instrumentation site uses.
pub fn global() -> &'static Registry {
    static GLOBAL: OnceLock<Registry> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new)
}

/// A flat snapshot (as produced by [`Registry::snapshot`] or shipped by
/// the `ObsScrape` RPC) rendered as one JSON object keyed by metric
/// name — the shape the run-wide `telemetry` block embeds.
pub fn snapshot_to_json(entries: &[(String, f64)]) -> crate::util::json::Json {
    let mut obj = crate::util::json::Json::obj();
    for (k, v) in entries {
        obj = obj.set(k.as_str(), *v);
    }
    obj
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_and_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("test_total");
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        // Same key returns the same instance.
        assert_eq!(r.counter("test_total").get(), 5);

        let g = r.gauge("depth");
        g.set(3.5);
        assert_eq!(r.gauge("depth").get(), 3.5);
        g.set(-1.0);
        assert_eq!(g.get(), -1.0);
    }

    #[test]
    fn concurrent_increment_stress_exact_totals() {
        let r = Registry::new();
        let threads = 8u64;
        let per_thread = 10_000u64;
        let mut handles = Vec::new();
        for t in 0..threads {
            let c = r.counter("stress_total");
            let h = r.histogram("stress_seconds", Histogram::latency_bounds());
            handles.push(std::thread::spawn(move || {
                for i in 0..per_thread {
                    c.inc();
                    // Deterministic spread across several buckets.
                    h.record(1e-6 * ((t * per_thread + i) % 1000 + 1) as f64);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let total = threads * per_thread;
        assert_eq!(r.counter("stress_total").get(), total);
        let h = r.histogram("stress_seconds", Histogram::latency_bounds());
        assert_eq!(h.count(), total);
        assert_eq!(h.bucket_counts().iter().sum::<u64>(), total);
        // The sum is an exact multiple set: each of the 1000 values
        // 1µs..1000µs recorded exactly total/1000 times.
        let expect: f64 = (1..=1000).map(|k| 1e-6 * k as f64).sum::<f64>() * (total / 1000) as f64;
        assert!((h.sum() - expect).abs() / expect < 1e-9, "{} vs {expect}", h.sum());
    }

    #[test]
    fn histogram_bucket_boundaries_le_semantics() {
        let h = Histogram::new(&[1.0, 2.0, 4.0]);
        h.record(1.0); // exactly on a bound -> that bucket (le semantics)
        h.record(1.5);
        h.record(2.0);
        h.record(4.0);
        h.record(4.0001); // above the last bound -> overflow
        assert_eq!(h.bucket_counts(), vec![1, 2, 1, 1]);
        assert_eq!(h.count(), 5);
        h.record(0.0);
        assert_eq!(h.bucket_counts()[0], 2, "values below the first bound share bucket 0");
    }

    #[test]
    fn histogram_quantile_pins() {
        let h = Histogram::new(&[10.0, 20.0, 30.0, 40.0]);
        assert_eq!(h.quantile(0.5), 0.0, "empty histogram quantile is 0");
        // 100 values uniform in (0, 40]: exactly 25 per bucket.
        for i in 1..=100 {
            h.record(0.4 * i as f64);
        }
        // Interpolated quantiles land on the exact uniform values.
        assert!((h.quantile(0.50) - 20.0).abs() < 0.5, "p50 = {}", h.quantile(0.50));
        assert!((h.quantile(0.95) - 38.0).abs() < 0.5, "p95 = {}", h.quantile(0.95));
        assert!((h.quantile(0.25) - 10.0).abs() < 0.5, "p25 = {}", h.quantile(0.25));
        assert_eq!(h.quantile(1.0), 40.0);
        // Everything in the overflow bucket reports the last bound.
        let h2 = Histogram::new(&[1.0, 2.0]);
        h2.record(100.0);
        assert_eq!(h2.quantile(0.5), 2.0);
    }

    #[test]
    fn labeled_keys_and_render_format() {
        let r = Registry::new();
        r.counter(&labeled("rpc_total", "rpc", "push")).add(3);
        r.counter(&labeled("rpc_total", "rpc", "pull")).add(7);
        r.gauge("queue_depth").set(2.0);
        let h = r.histogram(&labeled("lat_seconds", "rpc", "push"), &[0.5, 1.0]);
        h.record(0.25);
        h.record(0.75);
        h.record(2.0);

        let text = r.render();
        assert!(text.contains("# TYPE rpc_total counter\n"), "{text}");
        assert!(text.contains("rpc_total{rpc=\"push\"} 3\n"), "{text}");
        assert!(text.contains("rpc_total{rpc=\"pull\"} 7\n"), "{text}");
        assert!(text.contains("# TYPE queue_depth gauge\n"), "{text}");
        assert!(text.contains("queue_depth 2\n"), "{text}");
        assert!(text.contains("# TYPE lat_seconds histogram\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{rpc=\"push\",le=\"0.5\"} 1\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{rpc=\"push\",le=\"1\"} 2\n"), "{text}");
        assert!(text.contains("lat_seconds_bucket{rpc=\"push\",le=\"+Inf\"} 3\n"), "{text}");
        assert!(text.contains("lat_seconds_count{rpc=\"push\"} 3\n"), "{text}");
        // The # TYPE line for a base name is emitted once even with
        // several labeled children.
        assert_eq!(text.matches("# TYPE rpc_total").count(), 1);
    }

    #[test]
    fn snapshot_expands_histograms() {
        let r = Registry::new();
        r.counter("a_total").add(2);
        r.gauge("b").set(0.5);
        let h = r.histogram("lat", &[1.0, 2.0]);
        for _ in 0..10 {
            h.record(0.5);
        }
        let snap: BTreeMap<String, f64> = r.snapshot().into_iter().collect();
        assert_eq!(snap["a_total"], 2.0);
        assert_eq!(snap["b"], 0.5);
        assert_eq!(snap["lat_count"], 10.0);
        assert!((snap["lat_sum"] - 5.0).abs() < 1e-12);
        assert!(snap["lat_p50"] > 0.0 && snap["lat_p50"] <= 1.0);
        assert!(snap.contains_key("lat_p95") && snap.contains_key("lat_p99"));
    }
}
