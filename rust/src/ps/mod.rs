//! Parameter-server request/reply types and the server itself.
//!
//! Since the sharding refactor the server lives in [`crate::shard`]: a
//! [`ShardedPs`] composed of N independent data-plane shards under one
//! shard-global [`ControlPlane`](crate::shard::ControlPlane). The seed's
//! single-mutex `PsServer` is exactly the `n_shards = 1` configuration,
//! so this module re-exports `ShardedPs` under that name — every
//! historical call site (and its numeric behavior) is unchanged.
//!
//! Since the multi-process refactor the wire vocabulary itself —
//! [`WorkItem`], [`PullReply`], [`GradPush`] — is *defined* by the
//! transport codec ([`crate::transport::codec`]) and merely re-exported
//! here: the structs the worker runtime hands the PS front are the
//! exact frame structs the transport ships, with no in-memory
//! duplicates. What stays in this module is the worker-side pre-reduce
//! [`reduce_emb_grads`] and the historical `PsServer` alias.

use crate::util::fasthash::{u64_map_with_capacity, U64Map};

use anyhow::Result;

use crate::runtime::HostTensor;

pub use crate::shard::ShardedPs;
pub use crate::transport::codec::{GradPush, PullReply, WorkItem};

/// The seed server name: a 1+-shard PS front. `PsServer::new` builds the
/// single-shard configuration; `PsServer::with_shards` scales out.
pub type PsServer = ShardedPs;

/// Aggregate a `d_emb` block into per-key sums (worker-side pre-reduce).
pub fn reduce_emb_grads(keys: &[u64], d_emb: &HostTensor) -> Vec<(u64, Vec<f32>)> {
    let dim = *d_emb.shape.last().unwrap();
    debug_assert_eq!(keys.len() * dim, d_emb.data.len());
    let mut map: U64Map<Vec<f32>> = u64_map_with_capacity(keys.len());
    for (i, &key) in keys.iter().enumerate() {
        let row = &d_emb.data[i * dim..(i + 1) * dim];
        match map.get_mut(&key) {
            Some(acc) => {
                for (a, g) in acc.iter_mut().zip(row) {
                    *a += g;
                }
            }
            None => {
                map.insert(key, row.to_vec());
            }
        }
    }
    map.into_iter().collect()
}

/// Result alias used across trainer/experiments.
pub type PsResult<T> = Result<T>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modes::{GbaPolicy, SyncPolicy};
    use crate::coordinator::{ModePolicy, WorkerId};
    use crate::embedding::EmbeddingConfig;
    use crate::optim::Sgd;
    use crate::runtime::VariantDims;

    fn dims() -> VariantDims {
        VariantDims { fields: 2, emb_dim: 2, hidden1: 4, hidden2: 3, mlp_in: 6 }
    }

    fn zero_params() -> Vec<HostTensor> {
        dims().param_shapes().into_iter().map(HostTensor::zeros).collect()
    }

    fn unit_push(worker: WorkerId, token: u64, key: u64) -> GradPush {
        GradPush {
            worker,
            token,
            dense: dims()
                .param_shapes()
                .into_iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    HostTensor { shape: s, data: vec![1.0; n] }
                })
                .collect(),
            emb: vec![(key, vec![1.0, 1.0])],
            n_samples: 8,
            loss: 0.7,
        }
    }

    fn server(policy: Box<dyn ModePolicy>) -> PsServer {
        PsServer::new(
            dims(),
            zero_params(),
            EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            policy,
        )
    }

    #[test]
    fn sync_step_averages_over_n() {
        let ps = server(Box::new(SyncPolicy::new(2)));
        ps.set_day(0, 100);
        let w0 = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let w1 = match ps.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(ps.pull(0), PullReply::Wait);
        ps.push(unit_push(0, w0.token, 5));
        assert_eq!(ps.global_step(), 0);
        ps.push(unit_push(1, w1.token, 5));
        assert_eq!(ps.global_step(), 1);
        // dense: (1+1)/2 = 1.0 applied with lr 1 -> params = -1
        let p = ps.dense_params();
        assert!((p[0].data[0] + 1.0).abs() < 1e-6);
        // embedding: sum 2.0 over 2 contributing workers -> -1 per coord
        let row = ps.emb_row(5);
        assert!((row[0] + 1.0).abs() < 1e-6);
        let counters = ps.counters();
        assert_eq!(counters.global_steps, 1);
        assert_eq!(counters.applied_gradients, 2);
        assert_eq!(counters.samples_trained, 16);
        assert_eq!(counters.dropped_batches, 0);
    }

    #[test]
    fn gba_divides_dense_by_m_even_with_drops() {
        // M = 2, iota = 0: stale tokens are dropped but divisor stays M.
        let ps = server(Box::new(GbaPolicy::with_iota(2, 0)));
        ps.set_day(0, 100);
        // Advance one step so k=1 and token 0 becomes stale (k - 0 = 1 > 0).
        let a = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        let b = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        assert_eq!((a.token, b.token), (0, 0));
        ps.push(unit_push(0, 0, 7));
        ps.push(unit_push(0, 0, 7));
        assert_eq!(ps.global_step(), 1);
        let p1 = ps.dense_params()[0].data[0]; // -(1+1)/2 = -1

        // Now push one stale (token 0) + one fresh (token 1) gradient.
        let _ = ps.pull(0);
        let _ = ps.pull(0);
        ps.push(unit_push(0, 0, 7)); // stale: k=1, tok=0, iota=0 -> dropped
        ps.push(unit_push(0, 1, 9)); // fresh
        assert_eq!(ps.global_step(), 2);
        let p2 = ps.dense_params()[0].data[0];
        // delta = -(1.0 * 1)/M = -0.5 (divisor M=2, one included entry)
        assert!((p2 - (p1 - 0.5)).abs() < 1e-6, "p1={p1} p2={p2}");
        assert_eq!(ps.counters().dropped_batches, 1);
        // Key 9: grad sum 1.0 over 1 contributing worker -> -1.0
        // (embeddings divide by worker count, Algorithm 2 L23, not by M).
        assert!((ps.emb_row(9)[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn end_of_data_and_partial_flush() {
        let ps = server(Box::new(GbaPolicy::with_iota(4, 3)));
        ps.set_day(0, 1);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        assert_eq!(ps.pull(0), PullReply::EndOfData);
        ps.push(unit_push(0, it.token, 3));
        assert!(!ps.quiescent()); // buffer non-empty
        assert!(ps.flush_partial());
        assert!(ps.quiescent());
        assert_eq!(ps.global_step(), 1);
        assert!(!ps.flush_partial());
    }

    #[test]
    fn switch_policy_flushes_and_changes_mode() {
        let ps = server(Box::new(GbaPolicy::with_iota(4, 3)));
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(i) => i,
            _ => panic!(),
        };
        ps.push(unit_push(0, it.token, 2));
        assert_eq!(ps.mode(), crate::config::ModeKind::Gba);
        ps.switch_policy(Box::new(SyncPolicy::new(2)));
        assert_eq!(ps.mode(), crate::config::ModeKind::Sync);
        // Buffered gradient was applied during the switch.
        assert_eq!(ps.counters().applied_gradients, 1);
    }

    #[test]
    fn grad_norm_collection() {
        let ps = server(Box::new(SyncPolicy::new(1)));
        ps.set_day(0, 10);
        ps.collect_grad_norms(true);
        let it = match ps.pull(0) {
            PullReply::Work(i) => i,
            _ => panic!(),
        };
        ps.push(unit_push(0, it.token, 1));
        let norms = ps.take_grad_norms();
        assert_eq!(norms.len(), 1);
        let n_dense: usize =
            dims().param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        assert!((norms[0] - (n_dense as f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn reduce_emb_grads_sums_duplicates() {
        let keys = vec![1u64, 2, 1];
        let d = HostTensor { shape: vec![3, 2], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let mut r = reduce_emb_grads(&keys, &d);
        r.sort_by_key(|(k, _)| *k);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0], (1, vec![6.0, 8.0]));
        assert_eq!(r[1], (2, vec![3.0, 4.0]));
    }

    #[test]
    fn worker_reset_unblocks_sync_barrier() {
        let ps = server(Box::new(SyncPolicy::new(2)));
        ps.set_day(0, 10);
        let _ = ps.pull(0);
        let _ = ps.pull(1);
        assert_eq!(ps.pull(0), PullReply::Wait);
        // Worker 1 dies with its claim; reset lets it re-pull.
        ps.worker_reset(1);
        assert!(matches!(ps.pull(1), PullReply::Work(_)));
    }

    #[test]
    fn loss_curve_recorded() {
        let ps = server(Box::new(SyncPolicy::new(1)));
        ps.set_day(0, 10);
        for _ in 0..3 {
            if let PullReply::Work(it) = ps.pull(0) {
                ps.push(unit_push(0, it.token, 1));
            }
        }
        let curve = ps.loss_curve();
        assert_eq!(curve.len(), 3);
        assert!((curve[0].1 - 0.7).abs() < 1e-6);
        assert_eq!(curve[2].0, 2);
    }

    /// The same scenarios must hold verbatim on a multi-shard server —
    /// the control plane is shard-global.
    #[test]
    fn sync_semantics_survive_sharding() {
        let ps = PsServer::with_shards(
            dims(),
            zero_params(),
            EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            Box::new(SyncPolicy::new(2)),
            4,
        );
        ps.set_day(0, 100);
        let w0 = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let w1 = match ps.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(ps.pull(0), PullReply::Wait);
        ps.push(unit_push(0, w0.token, 5));
        ps.push(unit_push(1, w1.token, 5));
        assert_eq!(ps.global_step(), 1);
        let p = ps.dense_params();
        assert!((p[0].data[0] + 1.0).abs() < 1e-6);
        assert!((ps.emb_row(5)[0] + 1.0).abs() < 1e-6);
        assert_eq!(ps.n_shards(), 4);
    }
}
