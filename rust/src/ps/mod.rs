//! Parameter server: dense store + embedding shards + data list +
//! gradient buffer, driven by a [`ModePolicy`] (Figure 5 / Algorithm 2).
//!
//! One in-process PS serves all worker threads. The *control* state
//! (policy, gradient buffer, data cursor, counters) sits behind one mutex
//! paired with a condvar (barrier modes park pullers there); the dense
//! parameters have their own lock, and the embedding store is internally
//! sharded — so pulls of parameters and pushes of different shards mostly
//! don't contend.

use crate::util::fasthash::{u64_map_with_capacity, U64Map};
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::coordinator::{ModePolicy, PullDecision, PushAction, WorkerId};
use crate::embedding::{EmbeddingConfig, EmbeddingStore};
use crate::metrics::TrainCounters;
use crate::optim::Optimizer;
use crate::runtime::{HostTensor, VariantDims};

/// A claim on one batch of the data list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    pub token: u64,
    /// Parameter version (global step) at pull time.
    pub version: u64,
    pub day: usize,
    pub batch_index: usize,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PullReply {
    Work(WorkItem),
    /// Blocked by the mode's gate; wait for the next apply.
    Wait,
    /// Data list exhausted for the current day.
    EndOfData,
}

/// A gradient push from a worker (Algorithm 1 L18).
#[derive(Clone, Debug)]
pub struct GradPush {
    pub worker: WorkerId,
    pub token: u64,
    /// Dense gradients (dw1, db1, dw2, db2, dw3, db3), summed over the
    /// local batch and divided by local batch size (mean-loss grads).
    pub dense: Vec<HostTensor>,
    /// Per-ID embedding gradients, summed within the local batch.
    pub emb: Vec<(u64, Vec<f32>)>,
    pub n_samples: usize,
    pub loss: f32,
}

struct DenseState {
    params: Vec<HostTensor>,
    /// Optimizer slots per tensor (planar, `numel * slots`).
    slots: Vec<Vec<f32>>,
}

struct Ctrl {
    policy: Box<dyn ModePolicy>,
    buffer: Vec<GradPush>,
    counters: TrainCounters,
    /// Data list for the current day.
    day: usize,
    next_batch: usize,
    day_batches: usize,
    /// Claims handed out but not yet pushed back.
    outstanding: usize,
    /// L2 norms of the aggregated dense gradient per apply (Fig. 3).
    grad_norms: Option<Vec<f64>>,
    /// Losses observed at each apply (weighted mean over included entries).
    loss_curve: Vec<(u64, f32)>,
}

pub struct PsServer {
    pub dims: VariantDims,
    dense: Mutex<DenseState>,
    pub emb: EmbeddingStore,
    ctrl: Mutex<Ctrl>,
    cv: Condvar,
    opt_dense: Box<dyn Optimizer>,
    opt_emb: Box<dyn Optimizer>,
}

impl PsServer {
    pub fn new(
        dims: VariantDims,
        init_params: Vec<HostTensor>,
        emb_cfg: EmbeddingConfig,
        opt_dense: Box<dyn Optimizer>,
        opt_emb: Box<dyn Optimizer>,
        policy: Box<dyn ModePolicy>,
    ) -> Self {
        assert_eq!(init_params.len(), 6, "dense params are (w1,b1,w2,b2,w3,b3)");
        let slots = init_params
            .iter()
            .map(|p| vec![0.0f32; p.numel() * opt_dense.slots()])
            .collect();
        let emb = EmbeddingStore::new(emb_cfg, opt_emb.slots());
        PsServer {
            dims,
            dense: Mutex::new(DenseState { params: init_params, slots }),
            emb,
            ctrl: Mutex::new(Ctrl {
                policy,
                buffer: Vec::new(),
                counters: TrainCounters::default(),
                day: 0,
                next_batch: 0,
                day_batches: 0,
                outstanding: 0,
                grad_norms: None,
                loss_curve: Vec::new(),
            }),
            cv: Condvar::new(),
            opt_dense,
            opt_emb,
        }
    }

    /// Point the data list at a day with `n_batches` batches.
    pub fn set_day(&self, day: usize, n_batches: usize) {
        let mut c = self.ctrl.lock().unwrap();
        c.day = day;
        c.next_batch = 0;
        c.day_batches = n_batches;
        drop(c);
        self.cv.notify_all();
    }

    /// Non-blocking pull (Algorithm 2 "pull responding").
    pub fn pull(&self, w: WorkerId) -> PullReply {
        let mut c = self.ctrl.lock().unwrap();
        if c.next_batch >= c.day_batches {
            return PullReply::EndOfData;
        }
        match c.policy.on_pull(w) {
            PullDecision::Wait => PullReply::Wait,
            PullDecision::Token(token) => {
                let item = WorkItem {
                    token,
                    version: c.policy.global_step(),
                    day: c.day,
                    batch_index: c.next_batch,
                };
                c.next_batch += 1;
                c.outstanding += 1;
                PullReply::Work(item)
            }
        }
    }

    /// Blocking pull: parks on the condvar while gated.
    pub fn pull_blocking(&self, w: WorkerId) -> PullReply {
        loop {
            match self.pull(w) {
                PullReply::Wait => {
                    let c = self.ctrl.lock().unwrap();
                    // Re-check under the lock, then park briefly. The
                    // timeout guards against missed wakeups at day ends.
                    let _unused = self
                        .cv
                        .wait_timeout(c, std::time::Duration::from_millis(50))
                        .unwrap();
                }
                other => return other,
            }
        }
    }

    /// Gradient push (Algorithm 2 "push responding"). Non-blocking for the
    /// worker; aggregation happens inline when the buffer fills.
    pub fn push(&self, grad: GradPush) {
        let mut c = self.ctrl.lock().unwrap();
        c.outstanding = c.outstanding.saturating_sub(1);
        let action = c.policy.on_push(grad.worker, grad.token);
        match action {
            PushAction::Drop => {
                c.counters.dropped_batches += 1;
            }
            PushAction::Buffer => {
                c.buffer.push(grad);
            }
            PushAction::FlushNow => {
                c.buffer.push(grad);
                self.flush(&mut c);
            }
        }
        drop(c);
        self.cv.notify_all();
    }

    /// Worker failed: forget its in-flight claim (Appendix B).
    pub fn worker_reset(&self, w: WorkerId) {
        let mut c = self.ctrl.lock().unwrap();
        c.outstanding = c.outstanding.saturating_sub(1);
        c.policy.on_worker_reset(w);
        drop(c);
        self.cv.notify_all();
    }

    /// Force-flush a partial buffer (end of day). Returns whether a flush
    /// happened.
    pub fn flush_partial(&self) -> bool {
        let mut c = self.ctrl.lock().unwrap();
        if c.buffer.is_empty() {
            return false;
        }
        self.flush(&mut c);
        drop(c);
        self.cv.notify_all();
        true
    }

    /// True when no claims are outstanding and the buffer is empty.
    pub fn quiescent(&self) -> bool {
        let c = self.ctrl.lock().unwrap();
        c.outstanding == 0 && c.buffer.is_empty()
    }

    pub fn outstanding(&self) -> usize {
        self.ctrl.lock().unwrap().outstanding
    }

    fn flush(&self, c: &mut Ctrl) {
        let tokens: Vec<u64> = c.buffer.iter().map(|g| g.token).collect();
        let spec = c.policy.flush_spec(&tokens);
        debug_assert_eq!(spec.weights.len(), c.buffer.len());
        let k = c.policy.global_step();
        let opt_step = k + 1;

        // --- dense aggregation: sum_i w_i * g_i / divisor ------------------
        let mut agg: Vec<HostTensor> =
            c.buffer[0].dense.iter().map(|t| HostTensor::zeros(t.shape.clone())).collect();
        let mut included = 0usize;
        let mut loss_acc = 0.0f64;
        let mut wsum = 0.0f64;
        for (entry, &w) in c.buffer.iter().zip(&spec.weights) {
            let staleness = k.saturating_sub(entry.token);
            if w == 0.0 {
                c.counters.dropped_batches += 1;
                continue;
            }
            c.counters.dense_staleness.record(staleness);
            included += 1;
            loss_acc += entry.loss as f64 * w as f64;
            wsum += w as f64;
            for (a, g) in agg.iter_mut().zip(&entry.dense) {
                a.axpy(w, g);
            }
        }
        if included > 0 {
            let inv = 1.0 / spec.dense_divisor;
            for a in agg.iter_mut() {
                a.scale(inv);
            }
            if let Some(norms) = c.grad_norms.as_mut() {
                let norm2: f64 = agg.iter().map(|t| {
                    let n = t.l2_norm();
                    n * n
                }).sum();
                norms.push(norm2.sqrt());
            }
            {
                let mut d = self.dense.lock().unwrap();
                let DenseState { params, slots } = &mut *d;
                for ((p, g), s) in params.iter_mut().zip(&agg).zip(slots.iter_mut()) {
                    self.opt_dense.apply(&mut p.data, &g.data, s, opt_step);
                }
            }

            // --- embedding aggregation (Algorithm 2 L21–23) ---------------
            let mut per_key: U64Map<(Vec<f32>, u32)> = u64_map_with_capacity(1024);
            for (entry, &w) in c.buffer.iter().zip(&spec.weights) {
                if w == 0.0 {
                    continue;
                }
                for (key, gsum) in &entry.emb {
                    let slot = per_key
                        .entry(*key)
                        .or_insert_with(|| (vec![0.0; gsum.len()], 0));
                    for (a, g) in slot.0.iter_mut().zip(gsum) {
                        *a += w * g;
                    }
                    slot.1 += 1;
                }
            }
            let grads: Vec<(u64, Vec<f32>, u32)> =
                per_key.into_iter().map(|(k2, (g, n))| (k2, g, n)).collect();
            self.emb.apply_grads(&grads, self.opt_emb.as_ref(), opt_step);

            c.counters.applied_gradients += included as u64;
            c.counters.samples_trained +=
                c.buffer.iter().zip(&spec.weights).filter(|(_, &w)| w > 0.0)
                    .map(|(e, _)| e.n_samples as u64).sum::<u64>();
            if wsum > 0.0 {
                let step_loss = (loss_acc / wsum) as f32;
                c.loss_curve.push((k, step_loss));
            }
        }
        c.buffer.clear();
        c.counters.global_steps += 1;
        c.policy.on_applied();
    }

    /// Snapshot of the dense parameters (the worker's parameter pull).
    pub fn dense_params(&self) -> Vec<HostTensor> {
        self.dense.lock().unwrap().params.clone()
    }

    /// Replace dense params + reset optimizer slots (checkpoint restore).
    pub fn set_dense_params(&self, params: Vec<HostTensor>) {
        let mut d = self.dense.lock().unwrap();
        assert_eq!(params.len(), d.params.len());
        d.slots = params.iter().map(|p| vec![0.0; p.numel() * self.opt_dense.slots()]).collect();
        d.params = params;
    }

    /// Export dense optimizer slots (checkpointing).
    pub fn dense_slots(&self) -> Vec<Vec<f32>> {
        self.dense.lock().unwrap().slots.clone()
    }

    pub fn set_dense_slots(&self, slots: Vec<Vec<f32>>) {
        let mut d = self.dense.lock().unwrap();
        assert_eq!(slots.len(), d.slots.len());
        d.slots = slots;
    }

    pub fn counters(&self) -> TrainCounters {
        self.ctrl.lock().unwrap().counters.clone()
    }

    pub fn reset_counters(&self) {
        let mut c = self.ctrl.lock().unwrap();
        c.counters = TrainCounters::default();
        c.loss_curve.clear();
    }

    pub fn global_step(&self) -> u64 {
        self.ctrl.lock().unwrap().policy.global_step()
    }

    pub fn mode(&self) -> crate::config::ModeKind {
        self.ctrl.lock().unwrap().policy.kind()
    }

    /// Swap the coordination policy (the *switch* operation, §1). Any
    /// buffered gradients are force-flushed under the old policy first.
    pub fn switch_policy(&self, policy: Box<dyn ModePolicy>) {
        let mut c = self.ctrl.lock().unwrap();
        if !c.buffer.is_empty() {
            self.flush(&mut c);
        }
        c.policy = policy;
        drop(c);
        self.cv.notify_all();
    }

    /// Enable Fig. 3 collection of aggregated-gradient L2 norms.
    pub fn collect_grad_norms(&self, on: bool) {
        let mut c = self.ctrl.lock().unwrap();
        c.grad_norms = if on { Some(Vec::new()) } else { None };
    }

    pub fn take_grad_norms(&self) -> Vec<f64> {
        let mut c = self.ctrl.lock().unwrap();
        c.grad_norms.replace(Vec::new()).unwrap_or_default()
    }

    /// (global step, mean loss) per apply since the last reset.
    pub fn loss_curve(&self) -> Vec<(u64, f32)> {
        self.ctrl.lock().unwrap().loss_curve.clone()
    }
}

/// Aggregate a `d_emb` block into per-key sums (worker-side pre-reduce).
pub fn reduce_emb_grads(keys: &[u64], d_emb: &HostTensor) -> Vec<(u64, Vec<f32>)> {
    let dim = *d_emb.shape.last().unwrap();
    debug_assert_eq!(keys.len() * dim, d_emb.data.len());
    let mut map: U64Map<Vec<f32>> = u64_map_with_capacity(keys.len());
    for (i, &key) in keys.iter().enumerate() {
        let row = &d_emb.data[i * dim..(i + 1) * dim];
        match map.get_mut(&key) {
            Some(acc) => {
                for (a, g) in acc.iter_mut().zip(row) {
                    *a += g;
                }
            }
            None => {
                map.insert(key, row.to_vec());
            }
        }
    }
    map.into_iter().collect()
}

/// Result alias used across trainer/experiments.
pub type PsResult<T> = Result<T>;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modes::{GbaPolicy, SyncPolicy};
    use crate::optim::Sgd;

    fn dims() -> VariantDims {
        VariantDims { fields: 2, emb_dim: 2, hidden1: 4, hidden2: 3, mlp_in: 6 }
    }

    fn zero_params() -> Vec<HostTensor> {
        dims().param_shapes().into_iter().map(HostTensor::zeros).collect()
    }

    fn unit_push(worker: WorkerId, token: u64, key: u64) -> GradPush {
        GradPush {
            worker,
            token,
            dense: dims().param_shapes().into_iter().map(|s| {
                let n: usize = s.iter().product();
                HostTensor { shape: s, data: vec![1.0; n] }
            }).collect(),
            emb: vec![(key, vec![1.0, 1.0])],
            n_samples: 8,
            loss: 0.7,
        }
    }

    fn server(policy: Box<dyn ModePolicy>) -> PsServer {
        PsServer::new(
            dims(),
            zero_params(),
            EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            policy,
        )
    }

    #[test]
    fn sync_step_averages_over_n() {
        let ps = server(Box::new(SyncPolicy::new(2)));
        ps.set_day(0, 100);
        let w0 = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let w1 = match ps.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(ps.pull(0), PullReply::Wait);
        ps.push(unit_push(0, w0.token, 5));
        assert_eq!(ps.global_step(), 0);
        ps.push(unit_push(1, w1.token, 5));
        assert_eq!(ps.global_step(), 1);
        // dense: (1+1)/2 = 1.0 applied with lr 1 -> params = -1
        let p = ps.dense_params();
        assert!((p[0].data[0] + 1.0).abs() < 1e-6);
        // embedding: sum 2.0 over 2 contributing workers -> -1 per coord
        let row = ps.emb.row(5);
        assert!((row[0] + 1.0).abs() < 1e-6);
        let counters = ps.counters();
        assert_eq!(counters.global_steps, 1);
        assert_eq!(counters.applied_gradients, 2);
        assert_eq!(counters.samples_trained, 16);
        assert_eq!(counters.dropped_batches, 0);
    }

    #[test]
    fn gba_divides_dense_by_m_even_with_drops() {
        // M = 2, iota = 0: stale tokens are dropped but divisor stays M.
        let ps = server(Box::new(GbaPolicy::with_iota(2, 0)));
        ps.set_day(0, 100);
        // Advance one step so k=1 and token 0 becomes stale (k - 0 = 1 > 0).
        let a = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        let b = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        assert_eq!((a.token, b.token), (0, 0));
        ps.push(unit_push(0, 0, 7));
        ps.push(unit_push(0, 0, 7));
        assert_eq!(ps.global_step(), 1);
        let p1 = ps.dense_params()[0].data[0]; // -(1+1)/2 = -1

        // Now push one stale (token 0) + one fresh (token 1) gradient.
        let _ = ps.pull(0);
        let _ = ps.pull(0);
        ps.push(unit_push(0, 0, 7)); // stale: k=1, tok=0, iota=0 -> dropped
        ps.push(unit_push(0, 1, 9)); // fresh
        assert_eq!(ps.global_step(), 2);
        let p2 = ps.dense_params()[0].data[0];
        // delta = -(1.0 * 1)/M = -0.5 (divisor M=2, one included entry)
        assert!((p2 - (p1 - 0.5)).abs() < 1e-6, "p1={p1} p2={p2}");
        assert_eq!(ps.counters().dropped_batches, 1);
        // Key 9: grad sum 1.0 over 1 contributing worker -> -1.0
        // (embeddings divide by worker count, Algorithm 2 L23, not by M).
        assert!((ps.emb.row(9)[0] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn end_of_data_and_partial_flush() {
        let ps = server(Box::new(GbaPolicy::with_iota(4, 3)));
        ps.set_day(0, 1);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            _ => panic!(),
        };
        assert_eq!(ps.pull(0), PullReply::EndOfData);
        ps.push(unit_push(0, it.token, 3));
        assert!(!ps.quiescent()); // buffer non-empty
        assert!(ps.flush_partial());
        assert!(ps.quiescent());
        assert_eq!(ps.global_step(), 1);
        assert!(!ps.flush_partial());
    }

    #[test]
    fn switch_policy_flushes_and_changes_mode() {
        let ps = server(Box::new(GbaPolicy::with_iota(4, 3)));
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(i) => i,
            _ => panic!(),
        };
        ps.push(unit_push(0, it.token, 2));
        assert_eq!(ps.mode(), crate::config::ModeKind::Gba);
        ps.switch_policy(Box::new(SyncPolicy::new(2)));
        assert_eq!(ps.mode(), crate::config::ModeKind::Sync);
        // Buffered gradient was applied during the switch.
        assert_eq!(ps.counters().applied_gradients, 1);
    }

    #[test]
    fn grad_norm_collection() {
        let ps = server(Box::new(SyncPolicy::new(1)));
        ps.set_day(0, 10);
        ps.collect_grad_norms(true);
        let it = match ps.pull(0) {
            PullReply::Work(i) => i,
            _ => panic!(),
        };
        ps.push(unit_push(0, it.token, 1));
        let norms = ps.take_grad_norms();
        assert_eq!(norms.len(), 1);
        let n_dense: usize = dims().param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        assert!((norms[0] - (n_dense as f64).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn reduce_emb_grads_sums_duplicates() {
        let keys = vec![1u64, 2, 1];
        let d = HostTensor { shape: vec![3, 2], data: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0] };
        let mut r = reduce_emb_grads(&keys, &d);
        r.sort_by_key(|(k, _)| *k);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0], (1, vec![6.0, 8.0]));
        assert_eq!(r[1], (2, vec![3.0, 4.0]));
    }

    #[test]
    fn worker_reset_unblocks_sync_barrier() {
        let ps = server(Box::new(SyncPolicy::new(2)));
        ps.set_day(0, 10);
        let _ = ps.pull(0);
        let _ = ps.pull(1);
        assert_eq!(ps.pull(0), PullReply::Wait);
        // Worker 1 dies with its claim; reset lets it re-pull.
        ps.worker_reset(1);
        assert!(matches!(ps.pull(1), PullReply::Work(_)));
    }

    #[test]
    fn loss_curve_recorded() {
        let ps = server(Box::new(SyncPolicy::new(1)));
        ps.set_day(0, 10);
        for _ in 0..3 {
            if let PullReply::Work(it) = ps.pull(0) {
                ps.push(unit_push(0, it.token, 1));
            }
        }
        let curve = ps.loss_curve();
        assert_eq!(curve.len(), 3);
        assert!((curve[0].1 - 0.7).abs() < 1e-6);
        assert_eq!(curve[2].0, 2);
    }
}
