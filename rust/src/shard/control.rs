//! The shard-global control plane.
//!
//! Everything the paper's token-control mechanism needs exactly once per
//! PS — the [`ModePolicy`] state machine, the token issue path, the
//! global-batch gradient buffer, the data-list cursor, counters, and the
//! condvar that parks gated pullers — lives here, *outside* any shard.
//! The data plane (N × [`super::PsShard`]) holds only partitioned
//! parameters; coordination state is never partitioned, which is what
//! keeps GBA/Sync/BSP/Hop semantics byte-identical for every `n_shards`.
//!
//! Flush protocol: the control lock is held only for *admission* — policy
//! decision, buffer hand-off, counter/loss bookkeeping, `on_applied()` —
//! and is released before any gradient arithmetic. While the resulting
//! [`FlushJob`] is applied to the shards, `applying > 0` gates every
//! state-machine entry point (pulls, pushes, resets, policy swaps), so
//! at most one flush is ever in flight and applies land in admission
//! order — exactly the ordering the seed `PsServer`'s single mutex
//! enforced, but with the heavy aggregation/apply arithmetic outside
//! the lock and fanned out across shards.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

use crate::config::ModeKind;
use crate::coordinator::{ModePolicy, PullDecision, PushAction, WorkerId};
use crate::metrics::TrainCounters;
use crate::obs;
use crate::ps::{GradPush, PullReply, WorkItem};
use crate::staleness::{GbaStaleness, StalenessPolicy};

/// An admitted aggregation, ready to be applied to the shards. Produced
/// under the control lock; consumed (and the arithmetic done) outside it.
pub struct FlushJob {
    /// The drained gradient buffer, sorted by (token, claimed batch) —
    /// canonical aggregation order, independent of which worker's push
    /// raced into the buffer first.
    pub entries: Vec<GradPush>,
    /// Per-entry aggregation weight (0.0 = decayed out, already counted).
    pub weights: Vec<f32>,
    pub dense_divisor: f32,
    /// 1-based optimizer step (`k + 1` at admission).
    pub opt_step: u64,
    /// Entries with non-zero weight; 0 means nothing to apply.
    pub included: usize,
    /// Whether the flusher should compute the aggregated-gradient norm.
    pub collect_norm: bool,
}

struct CtrlState {
    policy: Box<dyn ModePolicy>,
    /// The staleness-decay seam (`[train] staleness_policy`): gets one
    /// chance to rescale the mode policy's flush weights at admission.
    /// The default [`GbaStaleness`] is a strict no-op, preserving the
    /// paper's fixed decay bit-for-bit.
    staleness: Box<dyn StalenessPolicy>,
    /// Buffered gradients awaiting the next flush, each paired with the
    /// batch index its worker's claim covered — the canonical sort key
    /// (with the token) that makes flush aggregation order-deterministic
    /// regardless of which worker's push raced in first.
    buffer: Vec<(usize, GradPush)>,
    counters: TrainCounters,
    day: usize,
    next_batch: usize,
    day_batches: usize,
    /// Claims handed out but not yet pushed back.
    outstanding: usize,
    /// The batch index each worker's in-flight claim covers (at most one
    /// claim per worker — Algorithm 1 alternates pull/push). A reset
    /// moves the entry to `requeue` so the batch is *re-issued*, not
    /// lost: a dead worker must not leave a hole in the day's data list.
    claims: HashMap<WorkerId, usize>,
    /// Batch indices reclaimed from reset workers, served (FIFO) before
    /// the day cursor advances further.
    requeue: VecDeque<usize>,
    /// Flushes admitted but not yet applied to the shards.
    applying: usize,
    /// While `applying > 0`: the worker whose push triggered the flush
    /// (None for partial/switch flushes). That worker's next pull takes
    /// the read-your-writes fast path past the `applying` gate — it
    /// cannot race parameters it has not seen, because dense reads are
    /// separately serialized by the front's apply-exclusion snapshot
    /// lock; the gate only orders *token issue*, and the flusher's
    /// tokens are already ordered after its own flush.
    ///
    /// Honesty note: today's `ShardedPs` runs the apply synchronously on
    /// the pushing thread, so that thread never pulls mid-apply and the
    /// fast path is exercised only at this API's level (pinned by the
    /// unit test below). It becomes load-bearing the moment a front
    /// drives applies off-thread — which is exactly the contract this
    /// field pre-commits to.
    flusher: Option<WorkerId>,
    /// L2 norms of the aggregated dense gradient per apply (Fig. 3).
    grad_norms: Option<Vec<f64>>,
    /// Losses observed at each apply (weighted mean over included entries).
    loss_curve: Vec<(u64, f32)>,
}

/// Cached metric handles: resolved once at construction so the hot
/// admission paths never touch the registry's name map.
struct CtrlObs {
    buffer_depth: Arc<obs::Gauge>,
    outstanding: Arc<obs::Gauge>,
    requeue_depth: Arc<obs::Gauge>,
    applying: Arc<obs::Gauge>,
    pushes: Arc<obs::Counter>,
    flushes: Arc<obs::Counter>,
    staleness_gap: Arc<obs::Gauge>,
    staleness_bound: Arc<obs::Gauge>,
}

impl CtrlObs {
    fn new() -> Self {
        let r = obs::global();
        CtrlObs {
            buffer_depth: r.gauge("gba_ctrl_buffer_depth"),
            outstanding: r.gauge("gba_ctrl_outstanding_claims"),
            requeue_depth: r.gauge("gba_ctrl_requeue_depth"),
            applying: r.gauge("gba_ctrl_applying"),
            pushes: r.counter("gba_ctrl_pushes_total"),
            flushes: r.counter("gba_ctrl_flushes_total"),
            staleness_gap: r.gauge("gba_staleness_gap"),
            staleness_bound: r.gauge("gba_staleness_bound"),
        }
    }
}

pub struct ControlPlane {
    state: Mutex<CtrlState>,
    cv: Condvar,
    o: CtrlObs,
}

impl ControlPlane {
    pub fn new(policy: Box<dyn ModePolicy>) -> Self {
        ControlPlane {
            o: CtrlObs::new(),
            state: Mutex::new(CtrlState {
                policy,
                staleness: Box::new(GbaStaleness),
                buffer: Vec::new(),
                counters: TrainCounters::default(),
                day: 0,
                next_batch: 0,
                day_batches: 0,
                outstanding: 0,
                claims: HashMap::new(),
                requeue: VecDeque::new(),
                applying: 0,
                flusher: None,
                grad_norms: None,
                loss_curve: Vec::new(),
            }),
            cv: Condvar::new(),
        }
    }

    /// Install a staleness policy (default: [`GbaStaleness`], a strict
    /// no-op). Called once at session build, before training starts —
    /// swapping mid-run would discard issue-time snapshots.
    pub fn set_staleness(&self, staleness: Box<dyn StalenessPolicy>) {
        let mut c = self.state.lock().unwrap();
        c.staleness = staleness;
    }

    /// Point the data list at a day with `n_batches` batches.
    pub fn set_day(&self, day: usize, n_batches: usize) {
        let mut c = self.state.lock().unwrap();
        c.day = day;
        c.next_batch = 0;
        c.day_batches = n_batches;
        // Batch indices are day-relative: claims and requeued indices
        // from a previous day are meaningless now.
        c.claims.clear();
        c.requeue.clear();
        drop(c);
        self.cv.notify_all();
    }

    /// Export the four control-plane queue depths from the state we are
    /// already holding. Called at the tail of every mutating entry point
    /// — cached handles, four relaxed stores, no registry lookup.
    fn observe_queues(&self, c: &CtrlState) {
        self.o.buffer_depth.set(c.buffer.len() as f64);
        self.o.outstanding.set(c.outstanding as f64);
        self.o.requeue_depth.set(c.requeue.len() as f64);
        self.o.applying.set(c.applying as f64);
    }

    /// Block while an admitted flush is mid-apply. Every state-machine
    /// entry point funnels through this, which is what guarantees at
    /// most one [`FlushJob`] in flight and admission-ordered applies —
    /// the seed's single-mutex semantics. The timeout guards against
    /// missed wakeups.
    fn wait_not_applying<'a>(
        &self,
        mut c: MutexGuard<'a, CtrlState>,
    ) -> MutexGuard<'a, CtrlState> {
        while c.applying > 0 {
            let (guard, _timeout) =
                self.cv.wait_timeout(c, Duration::from_millis(50)).unwrap();
            c = guard;
        }
        c
    }

    /// Non-blocking pull (Algorithm 2 "pull responding"). Parks briefly
    /// while an admitted flush is still being applied, so a fresh token is
    /// never handed out against not-yet-visible parameters — the same
    /// ordering the seed's single control mutex enforced. One exception
    /// (ROADMAP follow-up (c)): the worker whose own push triggered the
    /// in-flight flush skips the gate — its program order already puts
    /// this pull after its flush, and any parameter read it goes on to
    /// make still waits on the front's apply-exclusion snapshot lock.
    pub fn pull(&self, w: WorkerId) -> PullReply {
        let mut c = self.state.lock().unwrap();
        if c.flusher != Some(w) {
            c = self.wait_not_applying(c);
        }
        if c.next_batch >= c.day_batches && c.requeue.is_empty() {
            // The cursor is spent, but an outstanding claim may still
            // come back as a re-issue (its worker died and the reclaim
            // has not landed yet). Declaring EndOfData now would orphan
            // that batch — the survivors would exit their day loops in
            // the race window before `worker_reset` requeues it. Park
            // instead: the claim resolves as a push (outstanding → 0,
            // then EndOfData) or a reset (requeue refills, the next
            // pull takes the batch).
            if c.outstanding > 0 {
                return PullReply::Wait;
            }
            return PullReply::EndOfData;
        }
        match c.policy.on_pull(w) {
            PullDecision::Wait => PullReply::Wait,
            PullDecision::Token(token) => {
                // Re-issued batches (reclaimed from reset workers) go
                // out before the day cursor advances further.
                let batch_index = match c.requeue.pop_front() {
                    Some(b) => b,
                    None => {
                        let b = c.next_batch;
                        c.next_batch += 1;
                        b
                    }
                };
                let item = WorkItem {
                    token,
                    version: c.policy.global_step(),
                    day: c.day,
                    batch_index,
                };
                // One recorded claim per worker id: Algorithm-1 drivers
                // alternate pull/push, so a second pull before the push
                // only happens in synthetic (test) schedules — there
                // the newest claim shadows the older, matching the
                // policies' own single-token-per-worker bookkeeping.
                c.claims.insert(w, batch_index);
                c.outstanding += 1;
                // Issue-time snapshot for gap-style staleness policies
                // (no-op for the default).
                c.staleness.on_issue(token);
                self.observe_queues(&c);
                PullReply::Work(item)
            }
        }
    }

    /// Blocking pull: parks on the condvar while gated.
    pub fn pull_blocking(&self, w: WorkerId) -> PullReply {
        loop {
            match self.pull(w) {
                PullReply::Wait => {
                    let c = self.state.lock().unwrap();
                    // Re-check under the lock, then park briefly. The
                    // timeout guards against missed wakeups at day ends.
                    let _unused =
                        self.cv.wait_timeout(c, Duration::from_millis(50)).unwrap();
                }
                other => return other,
            }
        }
    }

    /// Gradient push (Algorithm 2 "push responding"). Returns an admitted
    /// [`FlushJob`] when this push filled the global batch; the caller
    /// applies it to the shards and then calls [`finish_apply`].
    ///
    /// [`finish_apply`]: ControlPlane::finish_apply
    pub fn push(&self, grad: GradPush) -> Option<FlushJob> {
        let mut c = self.wait_not_applying(self.state.lock().unwrap());
        c.outstanding = c.outstanding.saturating_sub(1);
        let pusher = grad.worker;
        // The batch this grad trained, recovered from the claim ledger.
        // Synthetic pushes with no recorded claim (tests) sort last.
        let batch = c.claims.remove(&pusher).unwrap_or(usize::MAX);
        let action = c.policy.on_push(grad.worker, grad.token);
        let job = match action {
            PushAction::Drop => {
                c.counters.dropped_batches += 1;
                None
            }
            PushAction::Buffer => {
                c.buffer.push((batch, grad));
                None
            }
            PushAction::FlushNow => {
                c.buffer.push((batch, grad));
                self.o.flushes.inc();
                Some(self.begin_flush(&mut c, Some(pusher)))
            }
        };
        self.o.pushes.inc();
        self.observe_queues(&c);
        drop(c);
        self.cv.notify_all();
        job
    }

    /// Worker failed: forget its in-flight claim (Appendix B) and
    /// *re-issue* the claimed batch index — the next pull (any worker)
    /// takes it before the day cursor advances, so a dead worker leaves
    /// no hole in the day's coverage. Counted as `reissued_batches`.
    pub fn worker_reset(&self, w: WorkerId) {
        let mut c = self.wait_not_applying(self.state.lock().unwrap());
        // A reset with no recorded claim (double reset, lost ack) must
        // not drift the books: only a real claim releases a token.
        if let Some(batch) = c.claims.remove(&w) {
            c.outstanding = c.outstanding.saturating_sub(1);
            c.requeue.push_back(batch);
            c.counters.reissued_batches += 1;
        }
        c.policy.on_worker_reset(w);
        self.observe_queues(&c);
        drop(c);
        self.cv.notify_all();
    }

    /// Admit a force-flush of a partial buffer (end of day). `None` when
    /// the buffer is empty.
    pub fn begin_partial_flush(&self) -> Option<FlushJob> {
        let mut c = self.wait_not_applying(self.state.lock().unwrap());
        if c.buffer.is_empty() {
            return None;
        }
        self.o.flushes.inc();
        let job = self.begin_flush(&mut c, None);
        self.observe_queues(&c);
        Some(job)
    }

    /// Swap the coordination policy (the *switch* operation, §1). Any
    /// buffered gradients are admitted under the old policy first; the
    /// returned job (if any) must be applied by the caller.
    pub fn swap_policy(&self, policy: Box<dyn ModePolicy>) -> Option<FlushJob> {
        let mut c = self.wait_not_applying(self.state.lock().unwrap());
        let job = if c.buffer.is_empty() {
            None
        } else {
            self.o.flushes.inc();
            Some(self.begin_flush(&mut c, None))
        };
        c.policy = policy;
        self.observe_queues(&c);
        drop(c);
        self.cv.notify_all();
        job
    }

    /// The apply for an admitted flush completed; release the token gate.
    pub fn finish_apply(&self, norm: Option<f64>) {
        let mut c = self.state.lock().unwrap();
        c.applying = c.applying.saturating_sub(1);
        if c.applying == 0 {
            c.flusher = None;
        }
        if let Some(n) = norm {
            // Feed the staleness policy's movement clock first — it is
            // why collect_norm may have been forced on.
            c.staleness.on_update_norm(n);
            if let Some(v) = c.grad_norms.as_mut() {
                v.push(n);
            }
        }
        self.observe_queues(&c);
        drop(c);
        self.cv.notify_all();
    }

    /// Admission: drain the buffer, fix weights/divisor, advance the
    /// policy and all counters. All the bookkeeping the seed `PsServer`
    /// did inside `flush()` that does not touch parameters happens here,
    /// with identical arithmetic and ordering. `flusher` is the worker
    /// whose push triggered the flush (read-your-writes fast path);
    /// partial and switch flushes have none.
    fn begin_flush(&self, c: &mut CtrlState, flusher: Option<WorkerId>) -> FlushJob {
        let mut buffered = std::mem::take(&mut c.buffer);
        // Canonical aggregation order: workers race each other into the
        // buffer, so admission order depends on scheduling (thread
        // fan-out vs. a single event loop). Sorting by (token, batch)
        // before weights are assigned makes the flush arithmetic — and
        // therefore the model bits — identical across worker planes.
        // The token alone is not enough (a sync cohort shares one; GBA
        // repeats each M times), but the batch index is unique per
        // claim, so the pair is a total order.
        buffered.sort_by_key(|(batch, g)| (g.token, *batch));
        let entries: Vec<GradPush> = buffered.into_iter().map(|(_, g)| g).collect();
        let tokens: Vec<u64> = entries.iter().map(|g| g.token).collect();
        let spec = c.policy.flush_spec(&tokens);
        debug_assert_eq!(spec.weights.len(), entries.len());
        let k = c.policy.global_step();
        let opt_step = k + 1;

        // The staleness seam: the mode policy decided the base weights;
        // the staleness policy gets one in-place rescale. The default
        // `gba` policy is a strict no-op — the vector (and so every
        // downstream float op) is bit-identical to the pre-seam code.
        let mut weights = spec.weights;
        c.staleness.reweight(k, &tokens, &mut weights);
        self.o.staleness_gap.set(c.staleness.last_gap());
        if let Some(b) = c.staleness.current_bound() {
            self.o.staleness_bound.set(b);
        }

        let mut included = 0usize;
        let mut loss_acc = 0.0f64;
        let mut wsum = 0.0f64;
        for (entry, &w) in entries.iter().zip(&weights) {
            let staleness = k.saturating_sub(entry.token);
            if w == 0.0 {
                c.counters.dropped_batches += 1;
                continue;
            }
            c.counters.dense_staleness.record(staleness);
            included += 1;
            loss_acc += entry.loss as f64 * w as f64;
            wsum += w as f64;
        }
        if included > 0 {
            c.counters.applied_gradients += included as u64;
            c.counters.samples_trained += entries
                .iter()
                .zip(&weights)
                .filter(|(_, &w)| w > 0.0)
                .map(|(e, _)| e.n_samples as u64)
                .sum::<u64>();
            if wsum > 0.0 {
                let step_loss = (loss_acc / wsum) as f32;
                c.loss_curve.push((k, step_loss));
            }
        }
        c.counters.global_steps += 1;
        c.policy.on_applied();
        c.applying += 1;
        c.flusher = flusher;
        FlushJob {
            entries,
            weights,
            dense_divisor: spec.dense_divisor,
            opt_step,
            included,
            // Norm collection is forced on when the staleness policy
            // needs the movement clock (gap_aware), even if Fig. 3
            // collection is off.
            collect_norm: c.grad_norms.is_some() || c.staleness.needs_norm(),
        }
    }

    // ---- introspection ----------------------------------------------------

    /// True when no claims are outstanding, the buffer is empty, and no
    /// admitted flush is still applying.
    pub fn quiescent(&self) -> bool {
        let c = self.state.lock().unwrap();
        c.outstanding == 0 && c.buffer.is_empty() && c.applying == 0
    }

    pub fn outstanding(&self) -> usize {
        self.state.lock().unwrap().outstanding
    }

    pub fn counters(&self) -> TrainCounters {
        self.state.lock().unwrap().counters.clone()
    }

    pub fn reset_counters(&self) {
        let mut c = self.state.lock().unwrap();
        c.counters = TrainCounters::default();
        c.loss_curve.clear();
    }

    pub fn global_step(&self) -> u64 {
        self.state.lock().unwrap().policy.global_step()
    }

    pub fn mode(&self) -> ModeKind {
        self.state.lock().unwrap().policy.kind()
    }

    /// Enable Fig. 3 collection of aggregated-gradient L2 norms.
    pub fn collect_grad_norms(&self, on: bool) {
        let mut c = self.state.lock().unwrap();
        c.grad_norms = if on { Some(Vec::new()) } else { None };
    }

    pub fn take_grad_norms(&self) -> Vec<f64> {
        let mut c = self.state.lock().unwrap();
        c.grad_norms.replace(Vec::new()).unwrap_or_default()
    }

    /// (global step, mean loss) per apply since the last reset.
    pub fn loss_curve(&self) -> Vec<(u64, f32)> {
        self.state.lock().unwrap().loss_curve.clone()
    }

    /// Mean normalized parameter gap at the most recent flush — the
    /// adaptive switcher's second signal (0.0 for policies without one).
    pub fn staleness_gap(&self) -> f64 {
        self.state.lock().unwrap().staleness.last_gap()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modes::{GbaPolicy, SyncPolicy};
    use crate::runtime::HostTensor;

    fn push_of(worker: WorkerId, token: u64) -> GradPush {
        GradPush {
            worker,
            token,
            dense: vec![HostTensor { shape: vec![2], data: vec![1.0, 1.0] }],
            emb: vec![],
            n_samples: 4,
            loss: 0.5,
        }
    }

    #[test]
    fn admission_outside_apply_preserves_counters() {
        let cp = ControlPlane::new(Box::new(SyncPolicy::new(2)));
        cp.set_day(0, 10);
        let a = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let b = match cp.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert!(cp.push(push_of(0, a.token)).is_none());
        let job = cp.push(push_of(1, b.token)).expect("cohort complete");
        assert_eq!(job.entries.len(), 2);
        assert_eq!(job.included, 2);
        assert_eq!(job.opt_step, 1);
        assert_eq!(job.dense_divisor, 2.0);
        // Step advanced at admission; the gate is up until finish_apply.
        assert_eq!(cp.global_step(), 1);
        assert!(!cp.quiescent());
        cp.finish_apply(None);
        assert!(cp.quiescent());
        let c = cp.counters();
        assert_eq!(c.global_steps, 1);
        assert_eq!(c.applied_gradients, 2);
        assert_eq!(c.samples_trained, 8);
    }

    #[test]
    fn gba_decay_counts_drops_at_admission() {
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 0)));
        cp.set_day(0, 100);
        for _ in 0..2 {
            let _ = cp.pull(0);
        }
        // First global batch: both fresh.
        assert!(cp.push(push_of(0, 0)).is_none());
        let j = cp.push(push_of(0, 0)).unwrap();
        cp.finish_apply(None);
        assert_eq!(j.included, 2);
        // Second: one stale (token 0 at k=1, iota=0), one fresh.
        let _ = cp.pull(0);
        let _ = cp.pull(0);
        assert!(cp.push(push_of(0, 0)).is_none());
        let j = cp.push(push_of(0, 1)).unwrap();
        cp.finish_apply(None);
        assert_eq!(j.included, 1);
        assert_eq!(j.weights, vec![0.0, 1.0]);
        assert_eq!(cp.counters().dropped_batches, 1);
    }

    #[test]
    fn swap_policy_with_buffered_grads_admits_flush_under_old_policy() {
        // GBA with M = 3: two pushes buffer without flushing …
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(3, 3)));
        cp.set_day(0, 10);
        for _ in 0..2 {
            let it = match cp.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            assert!(cp.push(push_of(0, it.token)).is_none());
        }
        // … then the switch admits them as one flush under the *old*
        // policy: GBA's dense divisor is M even for a partial buffer.
        let job = cp.swap_policy(Box::new(SyncPolicy::new(2))).expect("buffered grads");
        assert_eq!(job.entries.len(), 2);
        assert_eq!(job.included, 2);
        assert_eq!(job.dense_divisor, 3.0, "old GBA policy decided the divisor");
        assert_eq!(job.opt_step, 1);
        // Mode changed at swap; the gate stays up until the apply lands.
        assert_eq!(cp.mode(), ModeKind::Sync);
        assert!(!cp.quiescent());
        cp.finish_apply(None);
        assert!(cp.quiescent());
        assert_eq!(cp.counters().applied_gradients, 2);
        // A fresh policy object carries its own step counter: the swap is
        // a coordination-state reset (checkpoint-inherit semantics live
        // at the session layer, not here).
        assert_eq!(cp.global_step(), 0);
    }

    /// ROADMAP follow-up (c): while a flush is mid-apply, the worker
    /// whose push triggered it pulls straight through the `applying`
    /// gate; every other worker still parks until `finish_apply`.
    #[test]
    fn read_your_writes_fast_path_skips_applying_gate_for_flusher_only() {
        use std::sync::mpsc;
        use std::sync::Arc;

        let cp = Arc::new(ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 3))));
        cp.set_day(0, 100);
        let a = match cp.pull(3) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let b = match cp.pull(3) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert!(cp.push(push_of(3, a.token)).is_none());
        let job = cp.push(push_of(3, b.token)).expect("buffer of M admits a flush");
        assert_eq!(job.included, 2);
        assert!(!cp.quiescent(), "apply gate is up");

        // The flusher (worker 3) reads its own write: token issued
        // immediately, mid-apply.
        match cp.pull(3) {
            PullReply::Work(it) => assert_eq!(it.version, 1, "sees the admitted step"),
            other => panic!("flusher was gated: {other:?}"),
        }

        // Any other worker still waits out the apply.
        let (tx, rx) = mpsc::channel();
        let gated = {
            let cp = cp.clone();
            std::thread::spawn(move || {
                let r = cp.pull(0);
                tx.send(()).unwrap();
                r
            })
        };
        assert!(
            rx.recv_timeout(Duration::from_millis(80)).is_err(),
            "non-flusher slipped past the applying gate"
        );
        cp.finish_apply(None);
        rx.recv_timeout(Duration::from_secs(5)).expect("gate never released");
        match gated.join().unwrap() {
            PullReply::Work(_) => {}
            other => panic!("{other:?}"),
        }
        // Gate down, fast-path marker cleared: nobody is special now.
        assert_eq!(cp.state.lock().unwrap().flusher, None);
    }

    #[test]
    fn partial_flush_with_empty_buffer_is_none_and_advances_nothing() {
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 3)));
        cp.set_day(0, 10);
        assert!(cp.begin_partial_flush().is_none());
        assert!(cp.begin_partial_flush().is_none(), "idempotent on empty buffer");
        assert_eq!(cp.global_step(), 0);
        assert_eq!(cp.counters().global_steps, 0);
        assert!(cp.quiescent(), "no apply gate may be left raised");
    }

    #[test]
    fn flush_where_every_entry_decayed_has_zero_included() {
        // M = 2, iota = 0: advance one step, then flush two stale grads.
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 0)));
        cp.set_day(0, 100);
        for _ in 0..4 {
            let _ = cp.pull(0);
        }
        assert!(cp.push(push_of(0, 0)).is_none());
        assert!(cp.push(push_of(0, 0)).unwrap().included == 2);
        cp.finish_apply(None);
        // k = 1 now; both remaining token-0 grads are stale (1 - 0 > 0).
        assert!(cp.push(push_of(0, 0)).is_none());
        let job = cp.push(push_of(0, 0)).expect("buffer of M admits a flush");
        assert_eq!(job.included, 0, "all entries decayed to weight zero");
        assert!(job.weights.iter().all(|&w| w == 0.0));
        cp.finish_apply(None);
        // The empty flush still advanced the step and counted the drops.
        assert_eq!(cp.global_step(), 2);
        let c = cp.counters();
        assert_eq!(c.dropped_batches, 2);
        assert_eq!(c.applied_gradients, 2);
        assert!(cp.quiescent());
    }

    /// A reset worker's claimed batch index is re-issued to the next
    /// puller (FIFO, ahead of the day cursor) and counted as reissued —
    /// a dead worker leaves no hole in the day's data list.
    #[test]
    fn worker_reset_reissues_the_claimed_batch_index() {
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(4, 3)));
        cp.set_day(0, 10);
        let a = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let b = match cp.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!((a.batch_index, b.batch_index), (0, 1));
        cp.worker_reset(1);
        assert_eq!(cp.counters().reissued_batches, 1);
        assert_eq!(cp.outstanding(), 1);
        // The reclaimed index goes out before the cursor advances …
        let c = match cp.pull(2) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(c.batch_index, 1, "reclaimed batch re-issued first");
        // … and the cursor then resumes where it left off.
        let d = match cp.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(d.batch_index, 2);
        // A reset with no claim outstanding changes nothing.
        cp.worker_reset(7);
        assert_eq!(cp.counters().reissued_batches, 1);
        assert_eq!(cp.outstanding(), 3);
    }

    /// The day stays open while a reclaimed batch awaits re-issue, even
    /// after the cursor exhausted the data list.
    #[test]
    fn reissued_batch_keeps_day_open_past_cursor_end() {
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 3)));
        cp.set_day(0, 1);
        let a = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(a.batch_index, 0);
        cp.worker_reset(0);
        // Cursor is spent, but the reclaimed batch keeps the day alive.
        let b = match cp.pull(1) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(b.batch_index, 0, "the lost batch is trained after all");
        // Worker 1 now holds the only claim: the day must not end while
        // it is outstanding (a late reset would orphan the re-issue) —
        // other pullers park instead.
        assert_eq!(cp.pull(0), PullReply::Wait);
        assert!(cp.push(push_of(1, b.token)).is_none());
        assert_eq!(cp.pull(0), PullReply::EndOfData);
        // A new day clears any stale requeue state.
        cp.set_day(1, 1);
        assert_eq!(cp.counters().reissued_batches, 1);
        let c = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!((c.day, c.batch_index), (1, 0));
    }

    /// The staleness seam dispatches at the flush point: an installed
    /// non-default policy sees every admitted entry and can rescale the
    /// mode policy's weights, and its issue/apply hooks fire on the pull
    /// and finish paths.
    #[test]
    fn staleness_policy_dispatches_at_the_flush_point() {
        use crate::staleness::{StalenessPolicy, StalenessPolicyKind};
        use std::sync::atomic::{AtomicUsize, Ordering};
        use std::sync::Arc;

        struct Probe {
            issues: Arc<AtomicUsize>,
            norms: Arc<AtomicUsize>,
            reweights: Arc<AtomicUsize>,
        }
        impl StalenessPolicy for Probe {
            fn kind(&self) -> StalenessPolicyKind {
                StalenessPolicyKind::GapAware
            }
            fn on_issue(&mut self, _token: u64) {
                self.issues.fetch_add(1, Ordering::SeqCst);
            }
            fn needs_norm(&self) -> bool {
                true
            }
            fn on_update_norm(&mut self, _norm: f64) {
                self.norms.fetch_add(1, Ordering::SeqCst);
            }
            fn reweight(&mut self, _k: u64, _tokens: &[u64], weights: &mut [f32]) {
                self.reweights.fetch_add(1, Ordering::SeqCst);
                for w in weights {
                    *w *= 0.5;
                }
            }
            fn last_gap(&self) -> f64 {
                2.0
            }
        }

        let issues = Arc::new(AtomicUsize::new(0));
        let norms = Arc::new(AtomicUsize::new(0));
        let reweights = Arc::new(AtomicUsize::new(0));
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(2, 3)));
        cp.set_staleness(Box::new(Probe {
            issues: issues.clone(),
            norms: norms.clone(),
            reweights: reweights.clone(),
        }));
        cp.set_day(0, 10);
        let a = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        let b = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert_eq!(issues.load(Ordering::SeqCst), 2, "on_issue fires per token issue");
        assert!(cp.push(push_of(0, a.token)).is_none());
        let job = cp.push(push_of(0, b.token)).expect("buffer of M admits a flush");
        assert_eq!(reweights.load(Ordering::SeqCst), 1, "reweight fires once per flush");
        assert_eq!(job.weights, vec![0.5, 0.5], "policy rescaled the mode weights");
        assert_eq!(job.included, 2, "halved weights still count as included");
        assert!(job.collect_norm, "needs_norm forces norm collection");
        cp.finish_apply(Some(1.25));
        assert_eq!(norms.load(Ordering::SeqCst), 1, "apply norm reaches the policy");
        assert_eq!(cp.staleness_gap(), 2.0, "switcher signal surfaces the gap");
    }

    /// The default staleness policy leaves the admitted weights exactly
    /// as the mode policy produced them (the bit-identity contract).
    #[test]
    fn default_staleness_is_identity_over_mode_weights() {
        use crate::coordinator::DecayStrategy;
        let cp = ControlPlane::new(Box::new(GbaPolicy::new(
            2,
            DecayStrategy::Exponential { alpha: 0.7 },
        )));
        cp.set_day(0, 100);
        for _ in 0..4 {
            let _ = cp.pull(0);
        }
        assert!(cp.push(push_of(0, 0)).is_none());
        let j = cp.push(push_of(0, 0)).unwrap();
        assert_eq!(j.weights, vec![1.0, 1.0]);
        cp.finish_apply(None);
        // k = 1: a token-0 entry must get exactly alpha^1 = 0.7.
        assert!(cp.push(push_of(0, 0)).is_none());
        let j = cp.push(push_of(0, 1)).unwrap();
        assert_eq!(j.weights[0].to_bits(), 0.7f32.to_bits());
        assert_eq!(j.weights[1].to_bits(), 1.0f32.to_bits());
        assert!(!j.collect_norm, "gba never forces norm collection");
        cp.finish_apply(None);
    }

    #[test]
    fn partial_flush_and_policy_swap() {
        let cp = ControlPlane::new(Box::new(GbaPolicy::with_iota(4, 3)));
        cp.set_day(0, 10);
        assert!(cp.begin_partial_flush().is_none());
        let it = match cp.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        assert!(cp.push(push_of(0, it.token)).is_none());
        let job = cp.begin_partial_flush().expect("partial buffer");
        assert_eq!(job.entries.len(), 1);
        cp.finish_apply(None);
        assert_eq!(cp.global_step(), 1);
        // Swap with an empty buffer admits nothing but changes the mode.
        assert!(cp.swap_policy(Box::new(SyncPolicy::new(2))).is_none());
        assert_eq!(cp.mode(), ModeKind::Sync);
    }
}
