//! Sharded parameter-server plane with cross-shard token control.
//!
//! # Control plane vs. data plane
//!
//! The GBA paper's production PS is *many* shards serving slices of the
//! model, while its token-control mechanism (§4.1, Algorithm 2) is
//! logically global: one token list, one gradient buffer of `M`, one
//! global step `k`. This module realizes that split explicitly:
//!
//! * [`ControlPlane`] (`control.rs`) — the shard-*global* coordination
//!   state: the [`ModePolicy`](crate::coordinator::ModePolicy) state
//!   machine, token issue, global-batch assembly, staleness decay
//!   bookkeeping, counters, and the condvar gating barrier-mode pullers.
//!   There is exactly one, regardless of `n_shards`; this is what makes
//!   GBA/Sync/BSP/Hop-* semantics invariant to the shard count.
//! * [`PsShard`] (`shard.rs`) — the data plane: shard `s` owns a
//!   contiguous range slice of every dense tensor (with shard-local
//!   optimizer slots) behind its own `RwLock`, plus the consistent-hash
//!   slice of the embedding keyspace in its own
//!   [`EmbeddingStore`](crate::embedding::EmbeddingStore).
//! * [`ShardRouter`] (`router.rs`) — pure placement: rendezvous
//!   (consistent) hashing for keys, range partition for dense data.
//!
//! # The transport seam
//!
//! Since the transport refactor the front holds **no shard state at
//! all**: each `PsShard` lives inside a
//! [`ShardService`](crate::transport::ShardService) reachable only
//! through a [`Conn`](crate::transport::Conn) endpoint — an in-process
//! `util/chan` duplex pair (`inproc`, the default), a localhost TCP
//! socket framed through the versioned binary codec (`socket`), or a
//! TCP connection to a separate `gba-train shard-server` OS process
//! (`remote`, addresses from `[ps] shard_addrs`). A
//! [`ShardSupervisor`](crate::transport::ShardSupervisor) owns the
//! endpoints, journals mutating requests against per-shard shard-local
//! checkpoints, and respawns — or reconnects to — a dead shard (closed
//! channel / broken socket / lost process) transparently — see
//! `transport/` for the failure story.
//!
//! # Flush pipeline
//!
//! A push is admitted under the control lock (policy decision, buffer,
//! counters). When the global batch fills, admission produces a
//! [`FlushJob`] and the lock is *released*; the pushing thread then
//! aggregates the dense gradient (identical arithmetic and entry order
//! to the seed's single-server `flush`), cuts it into per-shard range
//! slices and per-shard embedding groups, and fans `Apply` requests out
//! to every shard endpoint — requests are sent to all shards before any
//! ack is awaited, so the optimizer sweep runs `n_shards`-way parallel
//! server-side. While a job is applying, every control-plane entry point
//! waits (the `applying` gate), so at most one flush is in flight,
//! applies land in admission order, and no worker ever computes against
//! a global step whose parameters are not yet visible; an
//! apply-exclusion `RwLock` additionally keeps `dense_params()`
//! snapshots atomic across shards.
//!
//! Because dense aggregation happens once (globally), the per-shard
//! apply is elementwise, and the codec carries `f32`s as raw bits, the
//! resulting parameters are **bit-for-bit identical for every
//! `n_shards` and every transport** given the same pull/push sequence;
//! `ShardedPs` with one shard *is* the seed `PsServer` (the `ps` module
//! aliases it). The `shard_invariance` integration test and the unit
//! tests below pin this.

pub mod control;
pub mod router;
pub mod shard;

pub use control::{ControlPlane, FlushJob};
pub use router::ShardRouter;
pub use shard::{DenseShardState, PsShard, ShardStats};

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::RwLock;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::config::{OptimKind, TransportKind};
use crate::coordinator::{ModePolicy, WorkerId};
use crate::embedding::{EmbeddingConfig, RowMeta};
use crate::metrics::TrainCounters;
use crate::optim::Optimizer;
use crate::ps::{GradPush, PullReply};
use crate::runtime::{HostTensor, VariantDims};
use crate::transport::{
    EmbGradEntry, RowRecord, ShardReply, ShardRequest, ShardSpawnSpec, ShardSupervisor,
};
use crate::util::fasthash::{u64_map_with_capacity, U64Map};
use crate::util::rng::mix64;

// ---- reply unwrapping (a wrong variant is a front/service protocol bug) ----

fn expect_ok(reply: ShardReply) {
    match reply {
        ShardReply::Ok => {}
        other => panic!("shard protocol: expected Ok, got {other:?}"),
    }
}

fn expect_dense(reply: ShardReply) -> Vec<Vec<f32>> {
    match reply {
        ShardReply::Dense { dense } => dense,
        other => panic!("shard protocol: expected Dense, got {other:?}"),
    }
}

fn expect_rows(reply: ShardReply) -> (usize, Vec<f32>) {
    match reply {
        ShardReply::Rows { dim, data } => (dim as usize, data),
        other => panic!("shard protocol: expected Rows, got {other:?}"),
    }
}

fn expect_dump(reply: ShardReply) -> Vec<RowRecord> {
    match reply {
        ShardReply::RowDump { rows } => rows,
        other => panic!("shard protocol: expected RowDump, got {other:?}"),
    }
}

fn expect_stats(reply: ShardReply) -> (ShardStats, u64) {
    match reply {
        ShardReply::Stats { stats, emb_mem_bytes } => (stats, emb_mem_bytes),
        other => panic!("shard protocol: expected Stats, got {other:?}"),
    }
}

/// All the pieces of a sharded PS, named. `new`/`with_shards` wrap this
/// for the historical call sites; sessions build it directly to choose
/// the transport.
pub struct PsBuild {
    pub dims: VariantDims,
    pub init_params: Vec<HostTensor>,
    pub emb_cfg: EmbeddingConfig,
    pub opt_dense: Box<dyn Optimizer>,
    pub opt_emb: Box<dyn Optimizer>,
    pub policy: Box<dyn ModePolicy>,
    pub n_shards: usize,
    pub transport: TransportKind,
    /// `host:port` per shard-server process; length must equal
    /// `n_shards` for the `Remote` transport, empty otherwise.
    pub shard_addrs: Vec<String>,
    /// Redial window per shard-server (initial connect and recovery);
    /// `None` uses [`RECONNECT_DEADLINE`](crate::transport::RECONNECT_DEADLINE).
    pub connect_deadline: Option<Duration>,
    /// Per-shard apply fan-out (`[ps] apply_threads`); 1 is serial.
    pub apply_threads: usize,
}

impl PsBuild {
    /// [`try_build`](Self::try_build) for infallible configurations
    /// (every in-process transport). Panics where `try_build` errors —
    /// for `Remote`, prefer `try_build` so an unreachable shard-server
    /// reports instead of aborting.
    pub fn build(self) -> ShardedPs {
        self.try_build().expect("building the PS plane")
    }

    pub fn try_build(self) -> Result<ShardedPs> {
        assert_eq!(self.init_params.len(), 6, "dense params are (w1,b1,w2,b2,w3,b3)");
        assert!(self.n_shards >= 1, "need at least one shard");
        if self.transport == TransportKind::Remote {
            assert_eq!(
                self.shard_addrs.len(),
                self.n_shards,
                "remote transport needs one shard_addrs entry per shard"
            );
        }
        let router = ShardRouter::new(self.n_shards);
        let shapes: Vec<Vec<usize>> =
            self.init_params.iter().map(|t| t.shape.clone()).collect();
        let specs: Vec<ShardSpawnSpec> = (0..self.n_shards)
            .map(|s| ShardSpawnSpec {
                index: s,
                ranges: self
                    .init_params
                    .iter()
                    .map(|t| router.dense_range(s, t.numel()))
                    .collect(),
                emb_cfg: self.emb_cfg.clone(),
                opt_dense: self.opt_dense.boxed_clone(),
                opt_emb: self.opt_emb.boxed_clone(),
                addr: self.shard_addrs.get(s).cloned(),
                apply_threads: self.apply_threads,
            })
            .collect();
        let deadline =
            self.connect_deadline.unwrap_or(crate::transport::RECONNECT_DEADLINE);
        let supervisor =
            ShardSupervisor::start(self.transport, specs, &self.init_params, deadline)?;
        Ok(ShardedPs {
            dims: self.dims,
            control: ControlPlane::new(self.policy),
            router,
            shapes,
            emb_dim: self.emb_cfg.dim,
            n_dense_slots: AtomicUsize::new(self.opt_dense.slots()),
            snapshot: RwLock::new(()),
            pull_stall_ns: AtomicU64::new(0),
            supervisor,
        })
    }
}

/// The sharded parameter-server front. `n_shards = 1` over the `inproc`
/// transport reproduces the seed `PsServer` exactly (the `ps` module
/// aliases it as such).
pub struct ShardedPs {
    pub dims: VariantDims,
    control: ControlPlane,
    router: ShardRouter,
    /// Full shapes of the dense tensors (for slicing and reassembly).
    shapes: Vec<Vec<usize>>,
    emb_dim: usize,
    /// Slot floats per dense weight of the *current* optimizer — atomic
    /// because an in-place mode switch to/from the async family swaps
    /// the optimizer pair (and thus the planar slot layout) mid-run.
    n_dense_slots: AtomicUsize,
    /// Apply-exclusion lock: dense readers (parameter pulls, slot
    /// export) take `read`, a flush's apply fan-out takes `write` for
    /// its whole duration. This is what keeps multi-tensor snapshots
    /// atomic across shards — the per-shard endpoints alone would let a
    /// reader see shard 0 at step k+1 and shard 1 still at step k (the
    /// seed's single dense mutex made that state impossible). Lock
    /// order is always snapshot → endpoint slot, on every path.
    snapshot: RwLock<()>,
    /// Nanoseconds parameter pulls spent stalled behind an in-flight
    /// apply (waiting on `snapshot.read()`). *The* front-side contention
    /// metric: it shrinks as shards cut the apply's critical section.
    pull_stall_ns: AtomicU64,
    supervisor: ShardSupervisor,
}

impl ShardedPs {
    /// Single-shard constructor — signature-compatible with the seed
    /// `PsServer::new`.
    pub fn new(
        dims: VariantDims,
        init_params: Vec<HostTensor>,
        emb_cfg: EmbeddingConfig,
        opt_dense: Box<dyn Optimizer>,
        opt_emb: Box<dyn Optimizer>,
        policy: Box<dyn ModePolicy>,
    ) -> Self {
        Self::with_shards(dims, init_params, emb_cfg, opt_dense, opt_emb, policy, 1)
    }

    /// Build an `n_shards`-way partitioned PS over in-process endpoints.
    pub fn with_shards(
        dims: VariantDims,
        init_params: Vec<HostTensor>,
        emb_cfg: EmbeddingConfig,
        opt_dense: Box<dyn Optimizer>,
        opt_emb: Box<dyn Optimizer>,
        policy: Box<dyn ModePolicy>,
        n_shards: usize,
    ) -> Self {
        PsBuild {
            dims,
            init_params,
            emb_cfg,
            opt_dense,
            opt_emb,
            policy,
            n_shards,
            transport: TransportKind::InProc,
            shard_addrs: Vec::new(),
            connect_deadline: None,
            apply_threads: 1,
        }
        .build()
    }

    pub fn n_shards(&self) -> usize {
        self.router.n_shards()
    }

    /// Which transport the shard endpoints use.
    pub fn transport(&self) -> TransportKind {
        self.supervisor.transport()
    }

    /// Per-shard load/contention snapshot (Fig. 7 shard sweep).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        (0..self.n_shards())
            .map(|s| expect_stats(self.supervisor.read_call(s, ShardRequest::Stats)).0)
            .collect()
    }

    /// Scrape every shard's metrics registry (the `ObsScrape` RPC): one
    /// flat `(metric name, value)` list per shard. Over the in-process
    /// transports all shards share this process's registry, so the lists
    /// repeat; over `remote` each list is that shard-server process's
    /// own registry — the coordinator's fleet-scrape path.
    pub fn obs_scrape(&self) -> Vec<Vec<(String, f64)>> {
        (0..self.n_shards())
            .map(|s| match self.supervisor.read_call(s, ShardRequest::ObsScrape) {
                ShardReply::Obs { entries } => entries,
                other => panic!("shard protocol: expected Obs, got {other:?}"),
            })
            .collect()
    }

    /// Total nanoseconds parameter pulls spent stalled behind applies.
    pub fn pull_stall_ns(&self) -> u64 {
        self.pull_stall_ns.load(Ordering::Relaxed)
    }

    /// One read-only RPC against shard `s`, over its read slot — the
    /// in-process serving plane's door into a live training PS: a
    /// [`ServeFront`](crate::serve::ServeFront) built over a shared
    /// `ShardedPs` issues its `GatherAt`/`ReadInvalidations` fan-out
    /// through here while training flushes continue on the primary
    /// slots.
    pub fn read_call(&self, s: usize, req: ShardRequest) -> ShardReply {
        self.supervisor.read_call(s, req)
    }

    /// Owning shard of an embedding key (the router's rendezvous hash).
    pub fn shard_of_key(&self, key: u64) -> usize {
        self.router.shard_of_key(key)
    }

    /// Embedding dimension this PS serves.
    pub fn emb_dim(&self) -> usize {
        self.emb_dim
    }

    // ---- fault injection / supervision ------------------------------------

    /// Deterministically kill shard `s`: its endpoint is severed and its
    /// service (with all shard state) is gone when this returns. The
    /// next request touching the shard triggers supervisor recovery.
    pub fn kill_shard(&self, s: usize) {
        self.supervisor.kill(s);
    }

    /// Lost-shard recoveries performed so far.
    pub fn lost_shard_events(&self) -> u64 {
        self.supervisor.lost_shard_events()
    }

    /// Applies between shard-local checkpoint refreshes (journal bound).
    pub fn set_shard_ckpt_every(&self, n: usize) {
        self.supervisor.set_ckpt_every(n);
    }

    /// In-memory cap (approximate bytes) per shard journal before it
    /// spills to disk; 0 (the default) never spills.
    pub fn set_journal_spill_bytes(&self, bytes: usize) {
        self.supervisor.set_journal_spill_bytes(bytes);
    }

    /// Journal frames currently spilled to disk for shard `s`.
    pub fn journal_spilled_frames(&self, s: usize) -> u64 {
        self.supervisor.journal_spilled_frames(s)
    }

    // ---- control-plane pass-throughs --------------------------------------

    /// Point the data list at a day with `n_batches` batches.
    pub fn set_day(&self, day: usize, n_batches: usize) {
        self.control.set_day(day, n_batches);
    }

    /// Non-blocking pull (Algorithm 2 "pull responding").
    pub fn pull(&self, w: WorkerId) -> PullReply {
        self.control.pull(w)
    }

    /// Blocking pull: parks on the condvar while gated.
    pub fn pull_blocking(&self, w: WorkerId) -> PullReply {
        self.control.pull_blocking(w)
    }

    /// Worker failed: forget its in-flight claim (Appendix B).
    pub fn worker_reset(&self, w: WorkerId) {
        self.control.worker_reset(w);
    }

    /// True when no claims are outstanding, the buffer is empty and no
    /// flush is mid-apply.
    pub fn quiescent(&self) -> bool {
        self.control.quiescent()
    }

    pub fn outstanding(&self) -> usize {
        self.control.outstanding()
    }

    pub fn counters(&self) -> TrainCounters {
        self.control.counters()
    }

    pub fn reset_counters(&self) {
        self.control.reset_counters();
    }

    pub fn global_step(&self) -> u64 {
        self.control.global_step()
    }

    pub fn mode(&self) -> crate::config::ModeKind {
        self.control.mode()
    }

    /// Enable Fig. 3 collection of aggregated-gradient L2 norms.
    pub fn collect_grad_norms(&self, on: bool) {
        self.control.collect_grad_norms(on);
    }

    pub fn take_grad_norms(&self) -> Vec<f64> {
        self.control.take_grad_norms()
    }

    /// (global step, mean loss) per apply since the last reset.
    pub fn loss_curve(&self) -> Vec<(u64, f32)> {
        self.control.loss_curve()
    }

    /// Install a staleness-decay policy (`[train] staleness_policy`).
    /// Called once at session build; the default is the no-op `gba`.
    pub fn set_staleness_policy(&self, staleness: Box<dyn crate::staleness::StalenessPolicy>) {
        self.control.set_staleness(staleness);
    }

    /// Mean normalized parameter gap at the most recent flush — the
    /// adaptive switcher's second signal.
    pub fn staleness_gap(&self) -> f64 {
        self.control.staleness_gap()
    }

    /// Swap the coordination policy (the *switch* operation, §1). Any
    /// buffered gradients are force-flushed under the old policy first.
    pub fn switch_policy(&self, policy: Box<dyn ModePolicy>) {
        if let Some(job) = self.control.swap_policy(policy) {
            self.run_flush(job);
        }
    }

    /// Swap the optimizer pair on every shard (the in-place switch for
    /// mode epochs whose optimizer differs — Table 5.1 pairs Adagrad
    /// with Async., Adam with the rest). Callers must have drained the
    /// old policy first ([`switch_policy`](Self::switch_policy)):
    /// gradients admitted under the old epoch belong to the old
    /// optimizer. `reset_slots` zeroes dense and per-row optimizer
    /// state even when the shapes happen to match.
    pub fn swap_optimizer(&self, opt: OptimKind, lr: f64, reset_slots: bool) {
        // Exclude dense readers: a snapshot straddling the swap could
        // see shard 0's slots reshaped and shard 1's not.
        let _apply_excl = self.snapshot.write().unwrap();
        self.supervisor.swap_optimizer(opt, lr, reset_slots);
        self.n_dense_slots.store(
            crate::optim::make_optimizer(opt, lr).slots(),
            Ordering::Relaxed,
        );
    }

    // ---- push / flush -----------------------------------------------------

    /// Gradient push (Algorithm 2 "push responding"). Never parks
    /// waiting for *other workers* (policy gating applies to pulls
    /// only); it does wait out an in-flight apply, exactly as a push
    /// waited on the seed's control mutex mid-flush. If this push
    /// completes the global batch, the calling thread performs the
    /// aggregation and drives the shard applies.
    pub fn push(&self, grad: GradPush) {
        if let Some(job) = self.control.push(grad) {
            self.run_flush(job);
        }
    }

    /// Force-flush a partial buffer (end of day). Returns whether a flush
    /// happened.
    pub fn flush_partial(&self) -> bool {
        match self.control.begin_partial_flush() {
            Some(job) => {
                self.run_flush(job);
                true
            }
            None => false,
        }
    }

    /// Aggregate an admitted job and apply it across the shards. The
    /// dense arithmetic (entry order, weighting, divisor) is identical to
    /// the seed `PsServer::flush`, so results are bit-for-bit equal for
    /// any shard count and transport.
    fn run_flush(&self, job: FlushJob) {
        /// `finish_apply` must run even if aggregation or a shard apply
        /// panics — otherwise `applying` stays raised forever and every
        /// gated worker parks indefinitely instead of failing loudly
        /// (the locks the panic poisons take care of the loud part).
        struct FinishGuard<'a> {
            control: &'a ControlPlane,
            norm: Option<f64>,
        }
        impl Drop for FinishGuard<'_> {
            fn drop(&mut self) {
                self.control.finish_apply(self.norm.take());
            }
        }
        let mut guard = FinishGuard { control: &self.control, norm: None };
        // Shards whose shard-local checkpoint cadence comes due in this
        // flush; refreshed *after* the gate and snapshot lock drop.
        let mut ckpt_due = Vec::new();

        if job.included > 0 {
            // --- dense aggregation: sum_i w_i * g_i / divisor --------------
            let mut agg: Vec<HostTensor> =
                job.entries[0].dense.iter().map(|t| HostTensor::zeros(t.shape.clone())).collect();
            for (entry, &w) in job.entries.iter().zip(&job.weights) {
                if w == 0.0 {
                    continue;
                }
                for (a, g) in agg.iter_mut().zip(&entry.dense) {
                    a.axpy(w, g);
                }
            }
            let inv = 1.0 / job.dense_divisor;
            for a in agg.iter_mut() {
                a.scale(inv);
            }
            if job.collect_norm {
                let norm2: f64 = agg
                    .iter()
                    .map(|t| {
                        let n = t.l2_norm();
                        n * n
                    })
                    .sum();
                guard.norm = Some(norm2.sqrt());
            }

            // --- embedding aggregation (Algorithm 2 L21–23) ----------------
            let mut per_key: U64Map<(Vec<f32>, u32)> = u64_map_with_capacity(1024);
            for (entry, &w) in job.entries.iter().zip(&job.weights) {
                if w == 0.0 {
                    continue;
                }
                for (key, gsum) in &entry.emb {
                    let slot =
                        per_key.entry(*key).or_insert_with(|| (vec![0.0; gsum.len()], 0));
                    for (a, g) in slot.0.iter_mut().zip(gsum) {
                        *a += w * g;
                    }
                    slot.1 += 1;
                }
            }
            let n = self.router.n_shards();
            let mut groups: Vec<Vec<EmbGradEntry>> = (0..n).map(|_| Vec::new()).collect();
            for (key, (g, cnt)) in per_key {
                groups[self.router.shard_of_key(key)].push((key, g, cnt));
            }

            // --- fan out: one Apply request per shard ----------------------
            let reqs: Vec<ShardRequest> = groups
                .into_iter()
                .enumerate()
                .map(|(s, emb)| ShardRequest::Apply {
                    opt_step: job.opt_step,
                    dense: self.slice_dense(&agg, s),
                    emb,
                })
                .collect();
            // Exclude dense readers for the whole apply so every
            // `dense_params()` snapshot is a coherent global step.
            let _apply_excl = self.snapshot.write().unwrap();
            ckpt_due = self.supervisor.apply_all(reqs);
        }
        drop(guard); // normal path: finish_apply with any collected norm
        // Off the critical path: the apply gate is down and the snapshot
        // lock released, so the O(shard state) checkpoint sweep overlaps
        // pulls, pushes and other shards' gathers instead of stalling
        // them (ROADMAP follow-up (e), remaining half).
        if !ckpt_due.is_empty() {
            self.supervisor.refresh_due(&ckpt_due);
        }
    }

    /// Cut an aggregated dense gradient into shard `s`'s range slices.
    fn slice_dense(&self, agg: &[HostTensor], s: usize) -> Vec<Vec<f32>> {
        agg.iter()
            .map(|t| {
                let (lo, hi) = self.router.dense_range(s, t.numel());
                t.data[lo..hi].to_vec()
            })
            .collect()
    }

    // ---- dense parameter access -------------------------------------------

    /// Snapshot of the dense parameters (the worker's parameter pull),
    /// reassembled from the per-shard range slices.
    pub fn dense_params(&self) -> Vec<HostTensor> {
        let t0 = Instant::now();
        let _snap = self.snapshot.read().unwrap();
        self.pull_stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut flats: Vec<Vec<f32>> =
            self.shapes.iter().map(|s| vec![0.0f32; s.iter().product()]).collect();
        for s in 0..self.n_shards() {
            let slices = expect_dense(self.supervisor.read_call(s, ShardRequest::ReadDense));
            for (t, slice) in slices.iter().enumerate() {
                let numel: usize = self.shapes[t].iter().product();
                let (lo, hi) = self.router.dense_range(s, numel);
                flats[t][lo..hi].copy_from_slice(slice);
            }
        }
        self.shapes
            .iter()
            .zip(flats)
            .map(|(shape, data)| HostTensor { shape: shape.clone(), data })
            .collect()
    }

    /// Replace dense params + reset optimizer slots (checkpoint restore).
    pub fn set_dense_params(&self, params: Vec<HostTensor>) {
        assert_eq!(params.len(), self.shapes.len());
        let _apply_excl = self.snapshot.write().unwrap();
        for s in 0..self.n_shards() {
            let dense: Vec<Vec<f32>> = params
                .iter()
                .map(|p| {
                    let (lo, hi) = self.router.dense_range(s, p.numel());
                    p.data[lo..hi].to_vec()
                })
                .collect();
            expect_ok(self.supervisor.call(s, ShardRequest::SetDense { dense }));
        }
    }

    /// Export dense optimizer slots in the unsharded planar layout
    /// (`slot j of weight i` at `j * numel + i`), reassembled from the
    /// shard-local planar buffers.
    pub fn dense_slots(&self) -> Vec<Vec<f32>> {
        let _snap = self.snapshot.read().unwrap();
        let n_slots = self.n_dense_slots.load(Ordering::Relaxed);
        let mut out: Vec<Vec<f32>> = self
            .shapes
            .iter()
            .map(|s| vec![0.0f32; s.iter().product::<usize>() * n_slots])
            .collect();
        for s in 0..self.n_shards() {
            let shard_slots = expect_dense(self.supervisor.read_call(s, ShardRequest::ReadSlots));
            for (t, sl) in shard_slots.iter().enumerate() {
                let numel: usize = self.shapes[t].iter().product();
                let (lo, hi) = self.router.dense_range(s, numel);
                let range_len = hi - lo;
                for j in 0..n_slots {
                    out[t][j * numel + lo..j * numel + hi]
                        .copy_from_slice(&sl[j * range_len..(j + 1) * range_len]);
                }
            }
        }
        out
    }

    /// Import dense optimizer slots (inverse of [`dense_slots`]).
    ///
    /// [`dense_slots`]: ShardedPs::dense_slots
    pub fn set_dense_slots(&self, slots: Vec<Vec<f32>>) {
        assert_eq!(slots.len(), self.shapes.len());
        // Read the slot shape only *under* the snapshot lock — a
        // concurrent `swap_optimizer` holds it for write while it
        // reshapes the plane and updates `n_dense_slots`, so loading
        // first could slice with a stale pre-swap count.
        let _apply_excl = self.snapshot.write().unwrap();
        let n_slots = self.n_dense_slots.load(Ordering::Relaxed);
        for s in 0..self.n_shards() {
            let shard_slots: Vec<Vec<f32>> = slots
                .iter()
                .enumerate()
                .map(|(t, full)| {
                    let numel: usize = self.shapes[t].iter().product();
                    assert_eq!(full.len(), numel * n_slots);
                    let (lo, hi) = self.router.dense_range(s, numel);
                    let range_len = hi - lo;
                    let mut local = vec![0.0f32; range_len * n_slots];
                    for j in 0..n_slots {
                        local[j * range_len..(j + 1) * range_len]
                            .copy_from_slice(&full[j * numel + lo..j * numel + hi]);
                    }
                    local
                })
                .collect();
            expect_ok(self.supervisor.call(s, ShardRequest::SetSlots { slots: shard_slots }));
        }
    }

    // ---- embedding access (routed to the owning shard) --------------------

    /// Gather rows for a flattened key block into a `[B, F, D]` tensor:
    /// keys are grouped by owning shard, fetched with one `Gather`
    /// request per shard, and scattered back into batch order. Missing
    /// rows materialize lazily with the same key-seeded init on every
    /// shard count and transport.
    pub fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> HostTensor {
        debug_assert_eq!(keys.len(), batch * fields);
        let dim = self.emb_dim;
        let mut data = vec![0.0f32; keys.len() * dim];
        let n = self.router.n_shards();
        let mut by_shard: Vec<(Vec<usize>, Vec<u64>)> =
            (0..n).map(|_| (Vec::new(), Vec::new())).collect();
        for (i, &key) in keys.iter().enumerate() {
            let s = self.router.shard_of_hash(mix64(key));
            by_shard[s].0.push(i);
            by_shard[s].1.push(key);
        }
        for (s, (positions, skeys)) in by_shard.into_iter().enumerate() {
            if skeys.is_empty() {
                continue;
            }
            let (rdim, rows) =
                expect_rows(self.supervisor.read_call(s, ShardRequest::Gather { keys: skeys }));
            debug_assert_eq!(rdim, dim);
            for (j, &i) in positions.iter().enumerate() {
                data[i * dim..(i + 1) * dim].copy_from_slice(&rows[j * dim..(j + 1) * dim]);
            }
        }
        HostTensor { shape: vec![batch, fields, dim], data }
    }

    /// Copy one row's vector (materializing it if absent).
    pub fn emb_row(&self, key: u64) -> Vec<f32> {
        let s = self.router.shard_of_key(key);
        let (dim, data) =
            expect_rows(self.supervisor.read_call(s, ShardRequest::Gather { keys: vec![key] }));
        debug_assert_eq!(dim, self.emb_dim);
        data
    }

    pub fn emb_meta(&self, key: u64) -> Option<RowMeta> {
        let s = self.router.shard_of_key(key);
        match self.supervisor.read_call(s, ShardRequest::GetMeta { key }) {
            ShardReply::Meta { meta } => meta,
            other => panic!("shard protocol: expected Meta, got {other:?}"),
        }
    }

    /// Bulk-insert a row (checkpoint restore), routed to its shard.
    pub fn insert_emb_row(&self, key: u64, vec: Vec<f32>, state: Vec<f32>, meta: RowMeta) {
        let s = self.router.shard_of_key(key);
        expect_ok(
            self.supervisor.call(s, ShardRequest::InsertRow { key, vec, state, meta }),
        );
    }

    /// Bulk-insert a whole row set (checkpoint restore): rows are grouped
    /// by owning shard and each group travels as one `InsertRows` frame —
    /// one RPC per shard instead of one per row, which is what makes
    /// restoring a large table into remote shard processes tractable.
    pub fn insert_emb_rows(&self, rows: Vec<RowRecord>) {
        let n = self.router.n_shards();
        let mut groups: Vec<Vec<RowRecord>> = (0..n).map(|_| Vec::new()).collect();
        for row in rows {
            groups[self.router.shard_of_key(row.0)].push(row);
        }
        for (s, group) in groups.into_iter().enumerate() {
            if !group.is_empty() {
                expect_ok(self.supervisor.call(s, ShardRequest::InsertRows { rows: group }));
            }
        }
    }

    /// Iterate all rows across shards (checkpointing): shard-index
    /// order, key-sorted within each shard — exactly the shard-local
    /// stream order the sharded checkpoint files persist. Callers
    /// needing one global canonical order sort by key (as the portable
    /// `Checkpoint` does).
    pub fn for_each_emb_row(&self, mut f: impl FnMut(u64, &[f32], &[f32], RowMeta)) {
        for s in 0..self.n_shards() {
            let rows = expect_dump(self.supervisor.read_call(s, ShardRequest::DumpRows));
            for (key, vec, state, meta) in rows {
                f(key, &vec, &state, meta);
            }
        }
    }

    /// Per-shard row dump (shard-local checkpoint streams).
    pub fn dump_shard_rows(&self, s: usize) -> Vec<RowRecord> {
        expect_dump(self.supervisor.read_call(s, ShardRequest::DumpRows))
    }

    /// Per-shard dense slices in shard-local layout, with their ranges.
    pub fn dump_shard_dense(&self, s: usize) -> (Vec<(usize, usize)>, Vec<Vec<f32>>) {
        let _snap = self.snapshot.read().unwrap();
        let ranges: Vec<(usize, usize)> = self
            .shapes
            .iter()
            .map(|shape| self.router.dense_range(s, shape.iter().product()))
            .collect();
        let dense = expect_dense(self.supervisor.read_call(s, ShardRequest::ReadDense));
        (ranges, dense)
    }

    /// Number of materialized embedding rows across all shards.
    pub fn emb_len(&self) -> usize {
        (0..self.n_shards())
            .map(|s| expect_stats(self.supervisor.read_call(s, ShardRequest::Stats)).0.emb_rows)
            .sum()
    }

    /// Approximate resident bytes of the embedding plane.
    pub fn emb_memory_bytes(&self) -> usize {
        (0..self.n_shards())
            .map(|s| expect_stats(self.supervisor.read_call(s, ShardRequest::Stats)).1 as usize)
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modes::{AsyncPolicy, GbaPolicy};
    use crate::optim::{Adam, Sgd};

    fn dims() -> VariantDims {
        VariantDims { fields: 2, emb_dim: 4, hidden1: 5, hidden2: 3, mlp_in: 12 }
    }

    fn init_params(seed: f32) -> Vec<HostTensor> {
        dims()
            .param_shapes()
            .into_iter()
            .enumerate()
            .map(|(t, s)| {
                let n: usize = s.iter().product();
                HostTensor {
                    shape: s,
                    data: (0..n).map(|i| seed + t as f32 * 0.1 + i as f32 * 0.01).collect(),
                }
            })
            .collect()
    }

    fn unit_push(token: u64, keys: &[u64], g: f32) -> GradPush {
        GradPush {
            worker: 0,
            token,
            dense: dims()
                .param_shapes()
                .into_iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    HostTensor { shape: s, data: vec![g; n] }
                })
                .collect(),
            emb: keys.iter().map(|&k| (k, vec![g; 4])).collect(),
            n_samples: 8,
            loss: 0.5,
        }
    }

    fn ps_with(n_shards: usize, opt: Box<dyn Optimizer>) -> ShardedPs {
        let opt2 = opt.boxed_clone();
        ShardedPs::with_shards(
            dims(),
            init_params(0.5),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 7, shards: 2 },
            opt,
            opt2,
            Box::new(GbaPolicy::with_iota(2, 3)),
            n_shards,
        )
    }

    /// The acceptance-criteria core: identical pull/push sequences give
    /// bit-identical parameters and loss curves for every shard count.
    #[test]
    fn shard_count_invariance_bitwise() {
        let keys: Vec<u64> = (0..24).map(|i| i * 7919 + 3).collect();
        let mut results = Vec::new();
        for n_shards in [1usize, 2, 4, 7] {
            let ps = ps_with(n_shards, Box::new(Adam::new(0.01)));
            ps.set_day(0, 100);
            for step in 0..6u64 {
                for j in 0..2u64 {
                    let it = match ps.pull(0) {
                        PullReply::Work(it) => it,
                        other => panic!("{other:?}"),
                    };
                    let g = 0.3 + step as f32 * 0.05 + j as f32 * 0.01;
                    ps.push(unit_push(it.token, &keys[..(8 + step as usize)], g));
                }
            }
            let dense = ps.dense_params();
            let rows: Vec<Vec<f32>> = keys.iter().map(|&k| ps.emb_row(k)).collect();
            results.push((dense, rows, ps.loss_curve(), ps.counters().global_steps));
        }
        for r in &results[1..] {
            assert_eq!(r.0, results[0].0, "dense params differ across shard counts");
            assert_eq!(r.1, results[0].1, "embedding rows differ across shard counts");
            assert_eq!(r.2, results[0].2, "loss curves differ across shard counts");
            assert_eq!(r.3, results[0].3);
        }
        assert_eq!(results[0].3, 6);
    }

    #[test]
    fn async_policy_applies_across_shards() {
        let ps = ShardedPs::with_shards(
            dims(),
            init_params(0.0),
            EmbeddingConfig { dim: 4, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            Box::new(AsyncPolicy::new()),
            3,
        );
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        ps.push(unit_push(it.token, &[5, 6], 1.0));
        assert_eq!(ps.global_step(), 1);
        // SGD lr 1, single grad of 1.0 / divisor 1 => params -= 1 everywhere.
        let p = ps.dense_params();
        let inits = init_params(0.0);
        for (t, (tensor, want)) in p.iter().zip(&inits).enumerate() {
            for (i, (&got, &init)) in tensor.data.iter().zip(&want.data).enumerate() {
                assert!((got - (init - 1.0)).abs() < 1e-6, "t={t} i={i}: {got} vs {init}");
            }
        }
        // Embedding rows moved by -1 per coordinate (1 contributing worker).
        let row = ps.emb_row(5);
        assert!((row[0] + 1.0).abs() < 1e-6);
        assert!(ps.quiescent());
        let stats = ps.shard_stats();
        assert_eq!(stats.len(), 3);
        assert!(stats.iter().all(|s| s.applies == 1));
        let total_elems: usize = stats.iter().map(|s| s.dense_elems).sum();
        let want_elems: usize =
            dims().param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        assert_eq!(total_elems, want_elems);
    }

    #[test]
    fn dense_slots_roundtrip_across_uneven_ranges() {
        let ps = ps_with(3, Box::new(Adam::new(0.05)));
        ps.set_day(0, 10);
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        let slots = ps.dense_slots();
        // Adam has 2 slots; the m-moment of a constant gradient is nonzero.
        assert!(slots.iter().any(|s| s.iter().any(|&x| x != 0.0)));
        let single = ps_with(1, Box::new(Adam::new(0.05)));
        single.set_day(0, 10);
        for _ in 0..2 {
            let it = match single.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            single.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        assert_eq!(slots, single.dense_slots(), "slot reassembly differs from unsharded");

        // Scatter the slots back in and read them out again.
        ps.set_dense_slots(slots.clone());
        assert_eq!(ps.dense_slots(), slots);
    }

    #[test]
    fn set_dense_params_resets_slots() {
        let ps = ps_with(2, Box::new(Adam::new(0.05)));
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        ps.push(unit_push(it.token, &[9], 1.0));
        let fresh = init_params(2.0);
        ps.set_dense_params(fresh.clone());
        assert_eq!(ps.dense_params(), fresh);
        assert!(ps.dense_slots().iter().all(|s| s.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn concurrent_pushers_many_shards() {
        use std::sync::Arc;
        let ps = Arc::new(ShardedPs::with_shards(
            dims(),
            init_params(0.1),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 3, shards: 4 },
            Box::new(Sgd { lr: 0.01 }),
            Box::new(Sgd { lr: 0.01 }),
            Box::new(AsyncPolicy::new()),
            4,
        ));
        ps.set_day(0, 10_000);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ps = ps.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let it = match ps.pull_blocking(t as usize) {
                        PullReply::Work(it) => it,
                        other => panic!("{other:?}"),
                    };
                    ps.push(unit_push(it.token, &[t * 100 + i % 7], 0.05));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(ps.quiescent());
        let c = ps.counters();
        assert_eq!(c.global_steps, 200);
        assert_eq!(c.applied_gradients, 200);
        let stats = ps.shard_stats();
        assert_eq!(stats.iter().map(|s| s.applies).sum::<u64>(), 4 * 200);
    }

    /// One `InsertRows` frame per shard must land exactly the rows that
    /// per-row `InsertRow` RPCs would (the checkpoint-restore fast path).
    #[test]
    fn bulk_insert_rows_matches_single_inserts() {
        let rows: Vec<RowRecord> = (0..20u64)
            .map(|i| {
                let k = i * 7919 + 5;
                (
                    k,
                    vec![i as f32 * 0.5; 4],
                    Vec::new(), // SGD: zero slot floats per row
                    RowMeta { last_update_step: i, update_count: i as u32 + 1 },
                )
            })
            .collect();
        let bulk = ps_with(3, Box::new(Sgd { lr: 0.1 }));
        bulk.insert_emb_rows(rows.clone());
        let single = ps_with(3, Box::new(Sgd { lr: 0.1 }));
        for (k, v, st, m) in rows.clone() {
            single.insert_emb_row(k, v, st, m);
        }
        assert_eq!(bulk.emb_len(), rows.len());
        for (k, _, _, _) in &rows {
            assert_eq!(bulk.emb_row(*k), single.emb_row(*k));
            assert_eq!(
                bulk.emb_meta(*k).map(|m| (m.last_update_step, m.update_count)),
                single.emb_meta(*k).map(|m| (m.last_update_step, m.update_count)),
            );
        }
    }

    /// In-place optimizer swap (the async↔rest half of a mode switch):
    /// slots reshape to the new optimizer's planar layout, training
    /// continues, and a lost shard respawns with the *new* pair.
    #[test]
    fn swap_optimizer_reshapes_slots_and_survives_shard_loss() {
        let ps = ps_with(3, Box::new(Adam::new(0.05)));
        ps.set_day(0, 100);
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        let adam_slots = ps.dense_slots();
        assert!(adam_slots.iter().any(|s| s.iter().any(|&x| x != 0.0)));
        ps.swap_optimizer(crate::config::OptimKind::Adagrad, 0.05, true);
        let ada_slots = ps.dense_slots();
        for (t, s) in ada_slots.iter().enumerate() {
            // Adagrad: 1 slot/weight vs Adam's 2.
            assert_eq!(s.len(), adam_slots[t].len() / 2, "planar layout reshaped");
            assert!(s.iter().all(|&x| x == 0.0), "accumulators reset");
        }
        // Training continues under the new pair …
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        assert!(ps.dense_slots().iter().any(|s| s.iter().any(|&x| x != 0.0)));
        // … and a lost shard respawns with the swapped spec (a respawn
        // from the launch pair would mismatch the checkpoint's shapes).
        ps.kill_shard(1);
        let _ = ps.dense_params();
        assert_eq!(ps.lost_shard_events(), 1);
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[4], 0.1));
        }
        assert!(ps.quiescent());
    }

    /// A same-pair swap with `reset_slots = false` preserves the slot
    /// state bit-for-bit — the true tuning-free inherit.
    #[test]
    fn swap_same_optimizer_without_reset_preserves_slots() {
        let ps = ps_with(2, Box::new(Adam::new(0.05)));
        ps.set_day(0, 100);
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[5, 6], 0.3));
        }
        let before = ps.dense_slots();
        assert!(before.iter().any(|s| s.iter().any(|&x| x != 0.0)));
        ps.swap_optimizer(crate::config::OptimKind::Adam, 0.05, false);
        assert_eq!(ps.dense_slots(), before, "same-shape swap kept the slots");
    }

    /// Socket endpoints behind the same front: build, push, read back.
    /// (Bitwise transport invariance is pinned end-to-end by
    /// `tests/shard_invariance.rs`; this is the unit-level smoke.)
    #[test]
    fn socket_transport_smoke() {
        let ps = PsBuild {
            dims: dims(),
            init_params: init_params(0.0),
            emb_cfg: EmbeddingConfig { dim: 4, init_scale: 0.0, seed: 1, shards: 2 },
            opt_dense: Box::new(Sgd { lr: 1.0 }),
            opt_emb: Box::new(Sgd { lr: 1.0 }),
            policy: Box::new(AsyncPolicy::new()),
            n_shards: 2,
            transport: TransportKind::Socket,
            shard_addrs: Vec::new(),
            connect_deadline: None,
            apply_threads: 2,
        }
        .build();
        assert_eq!(ps.transport(), TransportKind::Socket);
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        ps.push(unit_push(it.token, &[5, 6], 1.0));
        let p = ps.dense_params();
        let inits = init_params(0.0);
        assert!((p[0].data[0] - (inits[0].data[0] - 1.0)).abs() < 1e-6);
        assert!((ps.emb_row(5)[0] + 1.0).abs() < 1e-6);
        assert_eq!(ps.lost_shard_events(), 0);
    }
}
