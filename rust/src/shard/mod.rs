//! Sharded parameter-server plane with cross-shard token control.
//!
//! # Control plane vs. data plane
//!
//! The GBA paper's production PS is *many* shards serving slices of the
//! model, while its token-control mechanism (§4.1, Algorithm 2) is
//! logically global: one token list, one gradient buffer of `M`, one
//! global step `k`. This module realizes that split explicitly:
//!
//! * [`ControlPlane`] (`control.rs`) — the shard-*global* coordination
//!   state: the [`ModePolicy`](crate::coordinator::ModePolicy) state
//!   machine, token issue, global-batch assembly, staleness decay
//!   bookkeeping, counters, and the condvar gating barrier-mode pullers.
//!   There is exactly one, regardless of `n_shards`; this is what makes
//!   GBA/Sync/BSP/Hop-* semantics invariant to the shard count.
//! * [`PsShard`] (`shard.rs`) — the data plane: shard `s` owns a
//!   contiguous range slice of every dense tensor (with shard-local
//!   optimizer slots) behind its own `RwLock`, plus the consistent-hash
//!   slice of the embedding keyspace in its own
//!   [`EmbeddingStore`](crate::embedding::EmbeddingStore). Pushes and
//!   pulls touching different shards never contend.
//! * [`ShardRouter`] (`router.rs`) — pure placement: rendezvous
//!   (consistent) hashing for keys, range partition for dense data.
//!
//! # Flush pipeline
//!
//! A push is admitted under the control lock (policy decision, buffer,
//! counters). When the global batch fills, admission produces a
//! [`FlushJob`] and the lock is *released*; the pushing thread then
//! aggregates the dense gradient (identical arithmetic and entry order
//! to the seed's single-server `flush`) and fans the apply out to the
//! shards — inline for `n_shards = 1`, via per-shard apply threads
//! otherwise. While a job is applying, every control-plane entry point
//! waits (the `applying` gate), so at most one flush is in flight,
//! applies land in admission order, and no worker ever computes against
//! a global step whose parameters are not yet visible; an
//! apply-exclusion `RwLock` additionally keeps `dense_params()`
//! snapshots atomic across shards. Together these reproduce the seed
//! mutex's ordering guarantees while the heavy arithmetic runs outside
//! the control lock and the optimizer sweep runs `n_shards`-way
//! parallel.
//!
//! Because dense aggregation happens once (globally) and the per-shard
//! apply is elementwise, the resulting parameters are **bit-for-bit
//! identical for every `n_shards`** given the same pull/push sequence;
//! `ShardedPs` with one shard *is* the seed `PsServer` (the `ps` module
//! aliases it). The `shard_invariance` integration test and the unit
//! tests below pin this.

pub mod control;
pub mod router;
pub mod shard;

pub use control::{ControlPlane, FlushJob};
pub use router::ShardRouter;
pub use shard::{DenseShardState, PsShard, ShardStats};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::coordinator::{ModePolicy, WorkerId};
use crate::embedding::{EmbeddingConfig, EmbeddingStore, RowMeta};
use crate::metrics::TrainCounters;
use crate::optim::Optimizer;
use crate::ps::{GradPush, PullReply};
use crate::runtime::{HostTensor, VariantDims};
use crate::util::chan;
use crate::util::fasthash::{u64_map_with_capacity, U64Map};
use crate::util::rng::mix64;

/// Shared, lock-free-readable state: the shards and their placement.
struct Core {
    router: ShardRouter,
    shards: Vec<PsShard>,
    /// Full shapes of the dense tensors (for reassembly).
    shapes: Vec<Vec<usize>>,
    emb_dim: usize,
    opt_dense: Box<dyn Optimizer>,
    opt_emb: Box<dyn Optimizer>,
    /// Apply-exclusion lock: dense readers (parameter pulls, slot
    /// export) take `read`, a flush's apply fan-out takes `write` for
    /// its whole duration. This is what keeps multi-tensor snapshots
    /// atomic across shards — the per-shard locks alone would let a
    /// reader see shard 0 at step k+1 and shard 1 still at step k (the
    /// seed's single dense mutex made that state impossible). Lock
    /// order is always snapshot → per-shard, on every path.
    snapshot: RwLock<()>,
    /// Nanoseconds parameter pulls spent stalled behind an in-flight
    /// apply (waiting on `snapshot.read()`). *The* front-side contention
    /// metric: it shrinks as shards cut the apply's critical section.
    pull_stall_ns: AtomicU64,
}

/// One shard's portion of an admitted flush, sent to its apply thread.
struct ApplyTask {
    agg: Arc<Vec<HostTensor>>,
    group: Vec<(u64, Vec<f32>, u32)>,
    opt_step: u64,
    done: Arc<ApplyBarrier>,
}

/// Countdown latch: the flusher waits until every shard acked its slice.
/// Tracks whether any shard's apply panicked so the flusher can
/// propagate the failure instead of wedging the whole PS (the seed
/// surfaced flush panics in the pushing thread; so do we).
struct ApplyBarrier {
    /// (shards still outstanding, a shard apply panicked)
    state: Mutex<(usize, bool)>,
    cv: Condvar,
}

impl ApplyBarrier {
    fn new(n: usize) -> Self {
        ApplyBarrier { state: Mutex::new((n, false)), cv: Condvar::new() }
    }

    fn signal(&self, ok: bool) {
        let mut st = self.state.lock().unwrap();
        st.0 -= 1;
        st.1 |= !ok;
        if st.0 == 0 {
            self.cv.notify_all();
        }
    }

    /// Block until all shards acked; returns true if any apply panicked.
    fn wait(&self) -> bool {
        let mut st = self.state.lock().unwrap();
        while st.0 > 0 {
            st = self.cv.wait(st).unwrap();
        }
        st.1
    }
}

/// Per-shard apply threads (only spun up for `n_shards > 1`).
struct ApplyPool {
    txs: Vec<chan::Sender<ApplyTask>>,
    handles: Vec<JoinHandle<()>>,
}

impl Drop for ApplyPool {
    fn drop(&mut self) {
        self.txs.clear(); // closes the channels; threads drain and exit
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// The sharded parameter-server front. `n_shards = 1` reproduces the
/// seed `PsServer` exactly (the `ps` module aliases it as such).
pub struct ShardedPs {
    pub dims: VariantDims,
    core: Arc<Core>,
    control: ControlPlane,
    pool: Option<ApplyPool>,
}

impl ShardedPs {
    /// Single-shard constructor — signature-compatible with the seed
    /// `PsServer::new`.
    pub fn new(
        dims: VariantDims,
        init_params: Vec<HostTensor>,
        emb_cfg: EmbeddingConfig,
        opt_dense: Box<dyn Optimizer>,
        opt_emb: Box<dyn Optimizer>,
        policy: Box<dyn ModePolicy>,
    ) -> Self {
        Self::with_shards(dims, init_params, emb_cfg, opt_dense, opt_emb, policy, 1)
    }

    /// Build an `n_shards`-way partitioned PS.
    pub fn with_shards(
        dims: VariantDims,
        init_params: Vec<HostTensor>,
        emb_cfg: EmbeddingConfig,
        opt_dense: Box<dyn Optimizer>,
        opt_emb: Box<dyn Optimizer>,
        policy: Box<dyn ModePolicy>,
        n_shards: usize,
    ) -> Self {
        assert_eq!(init_params.len(), 6, "dense params are (w1,b1,w2,b2,w3,b3)");
        assert!(n_shards >= 1, "need at least one shard");
        let router = ShardRouter::new(n_shards);
        let shapes: Vec<Vec<usize>> = init_params.iter().map(|t| t.shape.clone()).collect();
        let emb_dim = emb_cfg.dim;
        let shards: Vec<PsShard> = (0..n_shards)
            .map(|s| {
                let ranges: Vec<(usize, usize)> =
                    init_params.iter().map(|t| router.dense_range(s, t.numel())).collect();
                PsShard::new(s, ranges, &init_params, opt_dense.slots(), emb_cfg.clone(), opt_emb.slots())
            })
            .collect();
        let core = Arc::new(Core {
            router,
            shards,
            shapes,
            emb_dim,
            opt_dense,
            opt_emb,
            snapshot: RwLock::new(()),
            pull_stall_ns: AtomicU64::new(0),
        });
        let pool = (n_shards > 1).then(|| Self::start_pool(&core));
        ShardedPs { dims, core, control: ControlPlane::new(policy), pool }
    }

    fn start_pool(core: &Arc<Core>) -> ApplyPool {
        let n = core.shards.len();
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for s in 0..n {
            let (tx, rx) = chan::unbounded::<ApplyTask>();
            let core = core.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ps-shard-{s}"))
                .spawn(move || {
                    while let Ok(task) = rx.recv() {
                        // A panicking apply must still ack the barrier,
                        // or the flusher (and with it the whole control
                        // plane) would hang forever.
                        let result = std::panic::catch_unwind(
                            std::panic::AssertUnwindSafe(|| {
                                core.shards[s].apply(
                                    &task.agg,
                                    &task.group,
                                    core.opt_dense.as_ref(),
                                    core.opt_emb.as_ref(),
                                    task.opt_step,
                                );
                            }),
                        );
                        task.done.signal(result.is_ok());
                    }
                })
                .expect("spawning shard apply thread");
            txs.push(tx);
            handles.push(handle);
        }
        ApplyPool { txs, handles }
    }

    pub fn n_shards(&self) -> usize {
        self.core.shards.len()
    }

    /// Per-shard load/contention snapshot (Fig. 7 shard sweep).
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.core.shards.iter().map(|s| s.stats()).collect()
    }

    /// Total nanoseconds parameter pulls spent stalled behind applies.
    pub fn pull_stall_ns(&self) -> u64 {
        self.core.pull_stall_ns.load(Ordering::Relaxed)
    }

    // ---- control-plane pass-throughs --------------------------------------

    /// Point the data list at a day with `n_batches` batches.
    pub fn set_day(&self, day: usize, n_batches: usize) {
        self.control.set_day(day, n_batches);
    }

    /// Non-blocking pull (Algorithm 2 "pull responding").
    pub fn pull(&self, w: WorkerId) -> PullReply {
        self.control.pull(w)
    }

    /// Blocking pull: parks on the condvar while gated.
    pub fn pull_blocking(&self, w: WorkerId) -> PullReply {
        self.control.pull_blocking(w)
    }

    /// Worker failed: forget its in-flight claim (Appendix B).
    pub fn worker_reset(&self, w: WorkerId) {
        self.control.worker_reset(w);
    }

    /// True when no claims are outstanding, the buffer is empty and no
    /// flush is mid-apply.
    pub fn quiescent(&self) -> bool {
        self.control.quiescent()
    }

    pub fn outstanding(&self) -> usize {
        self.control.outstanding()
    }

    pub fn counters(&self) -> TrainCounters {
        self.control.counters()
    }

    pub fn reset_counters(&self) {
        self.control.reset_counters();
    }

    pub fn global_step(&self) -> u64 {
        self.control.global_step()
    }

    pub fn mode(&self) -> crate::config::ModeKind {
        self.control.mode()
    }

    /// Enable Fig. 3 collection of aggregated-gradient L2 norms.
    pub fn collect_grad_norms(&self, on: bool) {
        self.control.collect_grad_norms(on);
    }

    pub fn take_grad_norms(&self) -> Vec<f64> {
        self.control.take_grad_norms()
    }

    /// (global step, mean loss) per apply since the last reset.
    pub fn loss_curve(&self) -> Vec<(u64, f32)> {
        self.control.loss_curve()
    }

    /// Swap the coordination policy (the *switch* operation, §1). Any
    /// buffered gradients are force-flushed under the old policy first.
    pub fn switch_policy(&self, policy: Box<dyn ModePolicy>) {
        if let Some(job) = self.control.swap_policy(policy) {
            self.run_flush(job);
        }
    }

    // ---- push / flush -----------------------------------------------------

    /// Gradient push (Algorithm 2 "push responding"). Never parks
    /// waiting for *other workers* (policy gating applies to pulls
    /// only); it does wait out an in-flight apply, exactly as a push
    /// waited on the seed's control mutex mid-flush. If this push
    /// completes the global batch, the calling thread performs the
    /// aggregation and drives the shard applies.
    pub fn push(&self, grad: GradPush) {
        if let Some(job) = self.control.push(grad) {
            self.run_flush(job);
        }
    }

    /// Force-flush a partial buffer (end of day). Returns whether a flush
    /// happened.
    pub fn flush_partial(&self) -> bool {
        match self.control.begin_partial_flush() {
            Some(job) => {
                self.run_flush(job);
                true
            }
            None => false,
        }
    }

    /// Aggregate an admitted job and apply it across the shards. The
    /// dense arithmetic (entry order, weighting, divisor) is identical to
    /// the seed `PsServer::flush`, so results are bit-for-bit equal for
    /// any shard count.
    fn run_flush(&self, job: FlushJob) {
        /// `finish_apply` must run even if aggregation or a shard apply
        /// panics — otherwise `applying` stays raised forever and every
        /// gated worker parks indefinitely instead of failing loudly
        /// (the locks the panic poisons take care of the loud part).
        struct FinishGuard<'a> {
            control: &'a ControlPlane,
            norm: Option<f64>,
        }
        impl Drop for FinishGuard<'_> {
            fn drop(&mut self) {
                self.control.finish_apply(self.norm.take());
            }
        }
        let mut guard = FinishGuard { control: &self.control, norm: None };

        if job.included > 0 {
            // --- dense aggregation: sum_i w_i * g_i / divisor --------------
            let mut agg: Vec<HostTensor> =
                job.entries[0].dense.iter().map(|t| HostTensor::zeros(t.shape.clone())).collect();
            for (entry, &w) in job.entries.iter().zip(&job.weights) {
                if w == 0.0 {
                    continue;
                }
                for (a, g) in agg.iter_mut().zip(&entry.dense) {
                    a.axpy(w, g);
                }
            }
            let inv = 1.0 / job.dense_divisor;
            for a in agg.iter_mut() {
                a.scale(inv);
            }
            if job.collect_norm {
                let norm2: f64 = agg
                    .iter()
                    .map(|t| {
                        let n = t.l2_norm();
                        n * n
                    })
                    .sum();
                guard.norm = Some(norm2.sqrt());
            }

            // --- embedding aggregation (Algorithm 2 L21–23) ----------------
            let mut per_key: U64Map<(Vec<f32>, u32)> = u64_map_with_capacity(1024);
            for (entry, &w) in job.entries.iter().zip(&job.weights) {
                if w == 0.0 {
                    continue;
                }
                for (key, gsum) in &entry.emb {
                    let slot =
                        per_key.entry(*key).or_insert_with(|| (vec![0.0; gsum.len()], 0));
                    for (a, g) in slot.0.iter_mut().zip(gsum) {
                        *a += w * g;
                    }
                    slot.1 += 1;
                }
            }
            let n = self.core.router.n_shards();
            let mut groups: Vec<Vec<(u64, Vec<f32>, u32)>> = (0..n).map(|_| Vec::new()).collect();
            for (key, (g, cnt)) in per_key {
                groups[self.core.router.shard_of_key(key)].push((key, g, cnt));
            }

            self.apply_to_shards(agg, groups, job.opt_step);
        }
        drop(guard); // normal path: finish_apply with any collected norm
    }

    fn apply_to_shards(
        &self,
        agg: Vec<HostTensor>,
        mut groups: Vec<Vec<(u64, Vec<f32>, u32)>>,
        opt_step: u64,
    ) {
        // Exclude dense readers for the whole apply so every
        // `dense_params()` snapshot is a coherent global step.
        let _apply_excl = self.core.snapshot.write().unwrap();
        match &self.pool {
            None => {
                let core = &self.core;
                for (shard, group) in core.shards.iter().zip(&groups) {
                    shard.apply(
                        &agg,
                        group,
                        core.opt_dense.as_ref(),
                        core.opt_emb.as_ref(),
                        opt_step,
                    );
                }
            }
            Some(pool) => {
                let agg = Arc::new(agg);
                let done = Arc::new(ApplyBarrier::new(pool.txs.len()));
                for (tx, group) in pool.txs.iter().zip(groups.drain(..)) {
                    let task =
                        ApplyTask { agg: agg.clone(), group, opt_step, done: done.clone() };
                    tx.send(task).unwrap_or_else(|_| panic!("shard apply pool closed"));
                }
                if done.wait() {
                    panic!("a shard apply thread panicked; parameters may be inconsistent");
                }
            }
        }
    }

    // ---- dense parameter access -------------------------------------------

    /// Snapshot of the dense parameters (the worker's parameter pull),
    /// reassembled from the per-shard range slices.
    pub fn dense_params(&self) -> Vec<HostTensor> {
        let t0 = Instant::now();
        let _snap = self.core.snapshot.read().unwrap();
        self.core.pull_stall_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        let mut flats: Vec<Vec<f32>> =
            self.core.shapes.iter().map(|s| vec![0.0f32; s.iter().product()]).collect();
        for shard in &self.core.shards {
            shard.read_params_into(&mut flats);
        }
        self.core
            .shapes
            .iter()
            .zip(flats)
            .map(|(shape, data)| HostTensor { shape: shape.clone(), data })
            .collect()
    }

    /// Replace dense params + reset optimizer slots (checkpoint restore).
    pub fn set_dense_params(&self, params: Vec<HostTensor>) {
        assert_eq!(params.len(), self.core.shapes.len());
        let _apply_excl = self.core.snapshot.write().unwrap();
        let slots = self.core.opt_dense.slots();
        for shard in &self.core.shards {
            let mut d = shard.dense.write().unwrap();
            for (t, p) in params.iter().enumerate() {
                let (lo, hi) = shard.ranges[t];
                d.params[t].copy_from_slice(&p.data[lo..hi]);
                d.slots[t] = vec![0.0; (hi - lo) * slots];
            }
        }
    }

    /// Export dense optimizer slots in the unsharded planar layout
    /// (`slot j of weight i` at `j * numel + i`), reassembled from the
    /// shard-local planar buffers.
    pub fn dense_slots(&self) -> Vec<Vec<f32>> {
        let _snap = self.core.snapshot.read().unwrap();
        let n_slots = self.core.opt_dense.slots();
        let mut out: Vec<Vec<f32>> = self
            .core
            .shapes
            .iter()
            .map(|s| vec![0.0f32; s.iter().product::<usize>() * n_slots])
            .collect();
        for shard in &self.core.shards {
            let d = shard.dense.read().unwrap();
            for (t, shard_slots) in d.slots.iter().enumerate() {
                let (lo, hi) = shard.ranges[t];
                let range_len = hi - lo;
                let numel: usize = self.core.shapes[t].iter().product();
                for j in 0..n_slots {
                    out[t][j * numel + lo..j * numel + hi]
                        .copy_from_slice(&shard_slots[j * range_len..(j + 1) * range_len]);
                }
            }
        }
        out
    }

    /// Import dense optimizer slots (inverse of [`dense_slots`]).
    ///
    /// [`dense_slots`]: ShardedPs::dense_slots
    pub fn set_dense_slots(&self, slots: Vec<Vec<f32>>) {
        assert_eq!(slots.len(), self.core.shapes.len());
        let _apply_excl = self.core.snapshot.write().unwrap();
        let n_slots = self.core.opt_dense.slots();
        for shard in &self.core.shards {
            let mut d = shard.dense.write().unwrap();
            for (t, full) in slots.iter().enumerate() {
                let numel: usize = self.core.shapes[t].iter().product();
                assert_eq!(full.len(), numel * n_slots);
                let (lo, hi) = shard.ranges[t];
                let range_len = hi - lo;
                for j in 0..n_slots {
                    d.slots[t][j * range_len..(j + 1) * range_len]
                        .copy_from_slice(&full[j * numel + lo..j * numel + hi]);
                }
            }
        }
    }

    // ---- embedding access (routed to the owning shard) --------------------

    /// Gather rows for a flattened key block into a `[B, F, D]` tensor,
    /// routing each key to its owning shard. Missing rows materialize
    /// lazily with the same key-seeded init on every shard count. Each
    /// key is hashed exactly once, shared between the cross-shard route
    /// and the store's internal sub-shard pick.
    pub fn gather(&self, keys: &[u64], batch: usize, fields: usize) -> HostTensor {
        debug_assert_eq!(keys.len(), batch * fields);
        let dim = self.core.emb_dim;
        let mut data = vec![0.0f32; keys.len() * dim];
        for (i, &key) in keys.iter().enumerate() {
            let h = mix64(key);
            let shard = &self.core.shards[self.core.router.shard_of_hash(h)];
            shard.emb.read_row_into_hashed(key, h, &mut data[i * dim..(i + 1) * dim]);
        }
        HostTensor { shape: vec![batch, fields, dim], data }
    }

    #[inline]
    fn emb_store_of(&self, key: u64) -> &EmbeddingStore {
        &self.core.shards[self.core.router.shard_of_key(key)].emb
    }

    /// Copy one row's vector (materializing it if absent).
    pub fn emb_row(&self, key: u64) -> Vec<f32> {
        self.emb_store_of(key).row(key)
    }

    pub fn emb_meta(&self, key: u64) -> Option<RowMeta> {
        self.emb_store_of(key).meta(key)
    }

    /// Bulk-insert a row (checkpoint restore), routed to its shard.
    pub fn insert_emb_row(&self, key: u64, vec: Vec<f32>, state: Vec<f32>, meta: RowMeta) {
        self.emb_store_of(key).insert_row(key, vec, state, meta);
    }

    /// Iterate all rows across shards (checkpointing). Shard-index order;
    /// callers needing a canonical order sort by key (as `Checkpoint`
    /// does).
    pub fn for_each_emb_row(&self, mut f: impl FnMut(u64, &[f32], &[f32], RowMeta)) {
        for shard in &self.core.shards {
            shard.emb.for_each_row(&mut f);
        }
    }

    /// Number of materialized embedding rows across all shards.
    pub fn emb_len(&self) -> usize {
        self.core.shards.iter().map(|s| s.emb.len()).sum()
    }

    /// Approximate resident bytes of the embedding plane.
    pub fn emb_memory_bytes(&self) -> usize {
        self.core.shards.iter().map(|s| s.emb.memory_bytes()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::modes::{AsyncPolicy, GbaPolicy};
    use crate::optim::{Adam, Sgd};

    fn dims() -> VariantDims {
        VariantDims { fields: 2, emb_dim: 4, hidden1: 5, hidden2: 3, mlp_in: 12 }
    }

    fn init_params(seed: f32) -> Vec<HostTensor> {
        dims()
            .param_shapes()
            .into_iter()
            .enumerate()
            .map(|(t, s)| {
                let n: usize = s.iter().product();
                HostTensor {
                    shape: s,
                    data: (0..n).map(|i| seed + t as f32 * 0.1 + i as f32 * 0.01).collect(),
                }
            })
            .collect()
    }

    fn unit_push(token: u64, keys: &[u64], g: f32) -> GradPush {
        GradPush {
            worker: 0,
            token,
            dense: dims()
                .param_shapes()
                .into_iter()
                .map(|s| {
                    let n: usize = s.iter().product();
                    HostTensor { shape: s, data: vec![g; n] }
                })
                .collect(),
            emb: keys.iter().map(|&k| (k, vec![g; 4])).collect(),
            n_samples: 8,
            loss: 0.5,
        }
    }

    fn ps_with(n_shards: usize, opt: Box<dyn Optimizer>) -> ShardedPs {
        let opt2 = opt.boxed_clone();
        ShardedPs::with_shards(
            dims(),
            init_params(0.5),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 7, shards: 2 },
            opt,
            opt2,
            Box::new(GbaPolicy::with_iota(2, 3)),
            n_shards,
        )
    }

    /// The acceptance-criteria core: identical pull/push sequences give
    /// bit-identical parameters and loss curves for every shard count.
    #[test]
    fn shard_count_invariance_bitwise() {
        let keys: Vec<u64> = (0..24).map(|i| i * 7919 + 3).collect();
        let mut results = Vec::new();
        for n_shards in [1usize, 2, 4, 7] {
            let ps = ps_with(n_shards, Box::new(Adam::new(0.01)));
            ps.set_day(0, 100);
            for step in 0..6u64 {
                for j in 0..2u64 {
                    let it = match ps.pull(0) {
                        PullReply::Work(it) => it,
                        other => panic!("{other:?}"),
                    };
                    let g = 0.3 + step as f32 * 0.05 + j as f32 * 0.01;
                    ps.push(unit_push(it.token, &keys[..(8 + step as usize)], g));
                }
            }
            let dense = ps.dense_params();
            let rows: Vec<Vec<f32>> = keys.iter().map(|&k| ps.emb_row(k)).collect();
            results.push((dense, rows, ps.loss_curve(), ps.counters().global_steps));
        }
        for r in &results[1..] {
            assert_eq!(r.0, results[0].0, "dense params differ across shard counts");
            assert_eq!(r.1, results[0].1, "embedding rows differ across shard counts");
            assert_eq!(r.2, results[0].2, "loss curves differ across shard counts");
            assert_eq!(r.3, results[0].3);
        }
        assert_eq!(results[0].3, 6);
    }

    #[test]
    fn async_policy_applies_across_shards() {
        let ps = ShardedPs::with_shards(
            dims(),
            init_params(0.0),
            EmbeddingConfig { dim: 4, init_scale: 0.0, seed: 1, shards: 2 },
            Box::new(Sgd { lr: 1.0 }),
            Box::new(Sgd { lr: 1.0 }),
            Box::new(AsyncPolicy::new()),
            3,
        );
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        ps.push(unit_push(it.token, &[5, 6], 1.0));
        assert_eq!(ps.global_step(), 1);
        // SGD lr 1, single grad of 1.0 / divisor 1 => params -= 1 everywhere.
        let p = ps.dense_params();
        let inits = init_params(0.0);
        for (t, (tensor, want)) in p.iter().zip(&inits).enumerate() {
            for (i, (&got, &init)) in tensor.data.iter().zip(&want.data).enumerate() {
                assert!((got - (init - 1.0)).abs() < 1e-6, "t={t} i={i}: {got} vs {init}");
            }
        }
        // Embedding rows moved by -1 per coordinate (1 contributing worker).
        let row = ps.emb_row(5);
        assert!((row[0] + 1.0).abs() < 1e-6);
        assert!(ps.quiescent());
        let stats = ps.shard_stats();
        assert_eq!(stats.len(), 3);
        assert!(stats.iter().all(|s| s.applies == 1));
        let total_elems: usize = stats.iter().map(|s| s.dense_elems).sum();
        let want_elems: usize =
            dims().param_shapes().iter().map(|s| s.iter().product::<usize>()).sum();
        assert_eq!(total_elems, want_elems);
    }

    #[test]
    fn dense_slots_roundtrip_across_uneven_ranges() {
        let ps = ps_with(3, Box::new(Adam::new(0.05)));
        ps.set_day(0, 10);
        for _ in 0..2 {
            let it = match ps.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            ps.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        let slots = ps.dense_slots();
        // Adam has 2 slots; the m-moment of a constant gradient is nonzero.
        assert!(slots.iter().any(|s| s.iter().any(|&x| x != 0.0)));
        let single = ps_with(1, Box::new(Adam::new(0.05)));
        single.set_day(0, 10);
        for _ in 0..2 {
            let it = match single.pull(0) {
                PullReply::Work(it) => it,
                other => panic!("{other:?}"),
            };
            single.push(unit_push(it.token, &[1, 2, 3], 0.7));
        }
        assert_eq!(slots, single.dense_slots(), "slot reassembly differs from unsharded");

        // Scatter the slots back in and read them out again.
        ps.set_dense_slots(slots.clone());
        assert_eq!(ps.dense_slots(), slots);
    }

    #[test]
    fn set_dense_params_resets_slots() {
        let ps = ps_with(2, Box::new(Adam::new(0.05)));
        ps.set_day(0, 10);
        let it = match ps.pull(0) {
            PullReply::Work(it) => it,
            other => panic!("{other:?}"),
        };
        ps.push(unit_push(it.token, &[9], 1.0));
        let fresh = init_params(2.0);
        ps.set_dense_params(fresh.clone());
        assert_eq!(ps.dense_params(), fresh);
        assert!(ps.dense_slots().iter().all(|s| s.iter().all(|&x| x == 0.0)));
    }

    #[test]
    fn concurrent_pushers_many_shards() {
        use std::sync::Arc;
        let ps = Arc::new(ShardedPs::with_shards(
            dims(),
            init_params(0.1),
            EmbeddingConfig { dim: 4, init_scale: 0.05, seed: 3, shards: 4 },
            Box::new(Sgd { lr: 0.01 }),
            Box::new(Sgd { lr: 0.01 }),
            Box::new(AsyncPolicy::new()),
            4,
        ));
        ps.set_day(0, 10_000);
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let ps = ps.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let it = match ps.pull_blocking(t as usize) {
                        PullReply::Work(it) => it,
                        other => panic!("{other:?}"),
                    };
                    ps.push(unit_push(it.token, &[t * 100 + i % 7], 0.05));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert!(ps.quiescent());
        let c = ps.counters();
        assert_eq!(c.global_steps, 200);
        assert_eq!(c.applied_gradients, 200);
        let stats = ps.shard_stats();
        assert_eq!(stats.iter().map(|s| s.applies).sum::<u64>(), 4 * 200);
    }
}
