//! A single parameter-server shard: the data plane.
//!
//! Each [`PsShard`] owns
//!
//! * a contiguous **range slice** of every dense tensor (parameters plus
//!   shard-local planar optimizer slots) behind its own `RwLock` — pulls
//!   take read locks, applies take the write lock, and two shards never
//!   share a lock, and
//! * an [`EmbeddingStore`] holding the **consistent-hash slice** of the
//!   embedding keyspace routed to this shard.
//!
//! Shards hold no coordination state whatsoever — see
//! [`super::control::ControlPlane`] for the control plane.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::RwLock;
use std::time::Instant;

use crate::embedding::{EmbeddingConfig, EmbeddingStore};
use crate::optim::Optimizer;
use crate::runtime::HostTensor;

/// Dense state owned by one shard: per-tensor contiguous slices.
pub struct DenseShardState {
    /// `params[t]` is the `[lo, hi)` slice of tensor `t`'s flat data.
    pub params: Vec<Vec<f32>>,
    /// Optimizer slots per tensor, planar in the *shard-local* index
    /// (`range_len * slots` floats; slot `j` of local weight `i` lives at
    /// `j * range_len + i`). Elementwise optimizers make this layout
    /// bit-identical to applying on the unsharded tensor.
    pub slots: Vec<Vec<f32>>,
}

/// Monotonic per-shard load counters (relaxed atomics; read for
/// reporting only).
#[derive(Default)]
pub struct ShardCounters {
    /// Dense applies executed by this shard.
    pub applies: AtomicU64,
    /// Nanoseconds this shard spent inside its apply (dense optimizer
    /// sweep + embedding grads). The per-flush wall cost is the *max*
    /// across shards, so imbalance here is what caps scale-out.
    pub apply_ns: AtomicU64,
    /// Embedding keys routed here for gradient application.
    pub emb_keys_applied: AtomicU64,
}

/// A point-in-time snapshot of one shard's load (for Fig. 7 reporting).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    pub applies: u64,
    pub apply_ns: u64,
    pub emb_keys_applied: u64,
    pub emb_rows: usize,
    pub dense_elems: usize,
}

pub struct PsShard {
    pub index: usize,
    /// `(lo, hi)` into each dense tensor's flat data.
    pub ranges: Vec<(usize, usize)>,
    pub dense: RwLock<DenseShardState>,
    pub emb: EmbeddingStore,
    pub counters: ShardCounters,
}

impl PsShard {
    /// Carve shard `index`'s slices out of the full initial parameters.
    pub fn new(
        index: usize,
        ranges: Vec<(usize, usize)>,
        init_params: &[HostTensor],
        dense_slots: usize,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), init_params.len());
        let params: Vec<Vec<f32>> = ranges
            .iter()
            .zip(init_params)
            .map(|(&(lo, hi), t)| t.data[lo..hi].to_vec())
            .collect();
        let slots: Vec<Vec<f32>> =
            ranges.iter().map(|&(lo, hi)| vec![0.0f32; (hi - lo) * dense_slots]).collect();
        Self::from_parts(index, ranges, params, slots, emb_cfg, emb_slots)
    }

    /// Build a shard from already-sliced state — the respawn path: a
    /// [`ShardSupervisor`](crate::transport::ShardSupervisor) restores a
    /// lost shard from its shard-local checkpoint's dense/slot slices.
    pub fn from_parts(
        index: usize,
        ranges: Vec<(usize, usize)>,
        params: Vec<Vec<f32>>,
        slots: Vec<Vec<f32>>,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), params.len());
        debug_assert_eq!(ranges.len(), slots.len());
        for (&(lo, hi), p) in ranges.iter().zip(&params) {
            debug_assert_eq!(hi - lo, p.len());
        }
        PsShard {
            index,
            ranges,
            dense: RwLock::new(DenseShardState { params, slots }),
            emb: EmbeddingStore::new(emb_cfg, emb_slots),
            counters: ShardCounters::default(),
        }
    }

    /// Apply this shard's pre-sliced portion of an aggregated dense
    /// gradient (`dense[t]` is exactly the `[lo, hi)` cut of tensor `t`,
    /// as carried by an `Apply` wire request), then its group of per-key
    /// embedding gradients.
    pub fn apply(
        &self,
        dense: &[Vec<f32>],
        emb_group: &[(u64, Vec<f32>, u32)],
        opt_dense: &dyn Optimizer,
        opt_emb: &dyn Optimizer,
        opt_step: u64,
    ) {
        let t0 = Instant::now();
        let mut d = self.dense.write().unwrap();
        let DenseShardState { params, slots } = &mut *d;
        debug_assert_eq!(dense.len(), params.len(), "apply: slice count mismatch");
        for ((p, s), g) in params.iter_mut().zip(slots.iter_mut()).zip(dense) {
            opt_dense.apply(p, g, s, opt_step);
        }
        drop(d);
        self.counters.applies.fetch_add(1, Ordering::Relaxed);

        if !emb_group.is_empty() {
            self.emb.apply_grads(emb_group, opt_emb, opt_step);
            self.counters.emb_keys_applied.fetch_add(emb_group.len() as u64, Ordering::Relaxed);
        }
        let elapsed = t0.elapsed();
        self.counters.apply_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        crate::obs::global()
            .histogram(
                &crate::obs::labeled("gba_shard_apply_seconds", "shard", &self.index.to_string()),
                crate::obs::Histogram::latency_bounds(),
            )
            .record(elapsed.as_secs_f64());
    }

    /// Copy this shard's parameter slices into full-size flat buffers.
    pub fn read_params_into(&self, out: &mut [Vec<f32>]) {
        let d = self.dense.read().unwrap();
        for (t, p) in d.params.iter().enumerate() {
            let (lo, hi) = self.ranges[t];
            out[t][lo..hi].copy_from_slice(p);
        }
    }

    pub fn stats(&self) -> ShardStats {
        let dense_elems = self.ranges.iter().map(|&(lo, hi)| hi - lo).sum();
        ShardStats {
            shard: self.index,
            applies: self.counters.applies.load(Ordering::Relaxed),
            apply_ns: self.counters.apply_ns.load(Ordering::Relaxed),
            emb_keys_applied: self.counters.emb_keys_applied.load(Ordering::Relaxed),
            emb_rows: self.emb.len(),
            dense_elems,
        }
    }
}
