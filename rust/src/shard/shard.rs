//! A single parameter-server shard: the data plane.
//!
//! Each [`PsShard`] owns
//!
//! * a contiguous **range slice** of every dense tensor (parameters plus
//!   shard-local planar optimizer slots) behind its own `RwLock` — pulls
//!   take read locks, applies take the write lock, and two shards never
//!   share a lock, and
//! * an [`EmbeddingStore`] holding the **consistent-hash slice** of the
//!   embedding keyspace routed to this shard.
//!
//! The apply hot path fans out inside one shard: the dense sweep splits
//! every tensor's index range across up to `apply_threads` scoped
//! workers on disjoint sub-ranges (elementwise optimizers ⇒ disjoint
//! writes ⇒ bit-identical to the serial sweep), and the embedding pass
//! parallelizes across the store's internal lock-shards. See
//! `docs/PERF.md` for the measurement loop behind this.
//!
//! Shards hold no coordination state whatsoever — see
//! [`super::control::ControlPlane`] for the control plane.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

use crate::embedding::{EmbeddingConfig, EmbeddingStore};
use crate::obs::{self, Histogram};
use crate::optim::Optimizer;
use crate::runtime::HostTensor;

/// Dense state owned by one shard: per-tensor contiguous slices.
pub struct DenseShardState {
    /// `params[t]` is the `[lo, hi)` slice of tensor `t`'s flat data.
    pub params: Vec<Vec<f32>>,
    /// Optimizer slots per tensor, planar in the *shard-local* index
    /// (`range_len * slots` floats; slot `j` of local weight `i` lives at
    /// `j * range_len + i`). Elementwise optimizers make this layout
    /// bit-identical to applying on the unsharded tensor.
    pub slots: Vec<Vec<f32>>,
}

/// Monotonic per-shard load counters (relaxed atomics; read for
/// reporting only).
#[derive(Default)]
pub struct ShardCounters {
    /// Dense applies executed by this shard.
    pub applies: AtomicU64,
    /// Nanoseconds this shard spent inside its apply (dense optimizer
    /// sweep + embedding grads), measured from write-lock acquisition —
    /// queueing behind readers is recorded separately as
    /// `gba_shard_apply_lock_wait_seconds`. The per-flush wall cost is
    /// the *max* across shards, so imbalance here is what caps scale-out.
    pub apply_ns: AtomicU64,
    /// Embedding keys routed here for gradient application.
    pub emb_keys_applied: AtomicU64,
}

/// A point-in-time snapshot of one shard's load (for Fig. 7 reporting).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    pub applies: u64,
    pub apply_ns: u64,
    pub emb_keys_applied: u64,
    pub emb_rows: usize,
    pub dense_elems: usize,
}

/// Minimum dense elements per worker before the parallel sweep engages —
/// below this, scoped-thread spawn overhead beats the parallel win.
const MIN_DENSE_ELEMS_PER_WORKER: usize = 4096;

/// Cap on keys buffered in the embedding-invalidation log. When an
/// apply pushes the total past this, the oldest entries drop and the
/// log's `floor` rises — readers whose cursor predates the floor get
/// `full = true` and must treat their whole cache as invalid. 64k keys
/// × 8 bytes bounds the log at ~512 KiB per shard.
const INVAL_LOG_MAX_KEYS: usize = 65_536;

/// Bounded log of embedding keys touched by recent applies, drained by
/// the serving plane's `ReadInvalidations` RPC to evict stale hot-cache
/// rows. `floor` is the highest apply step whose keys have been dropped
/// (0 = nothing dropped yet).
struct InvalLog {
    upto: u64,
    floor: u64,
    total_keys: usize,
    entries: VecDeque<(u64, Vec<u64>)>,
}

/// One worker's cut of one tensor: disjoint `[a,b)` views of the
/// parameter slice, its gradient, and each optimizer state plane.
struct DenseUnit<'a> {
    param: &'a mut [f32],
    grad: &'a [f32],
    planes: Vec<&'a mut [f32]>,
}

fn run_units(units: &mut [DenseUnit<'_>], opt: &dyn Optimizer, step: u64) {
    for u in units.iter_mut() {
        opt.apply_planes(u.param, u.grad, &mut u.planes, step);
    }
}

/// Run the dense optimizer sweep, splitting every tensor's index range
/// across up to `threads` scoped workers on disjoint sub-ranges. The
/// optimizers are elementwise, so the disjoint writes make the result
/// bit-identical to the serial sweep regardless of interleaving.
/// Returns the number of workers actually used.
fn apply_dense(
    params: &mut [Vec<f32>],
    slots: &mut [Vec<f32>],
    dense: &[Vec<f32>],
    opt: &dyn Optimizer,
    step: u64,
    threads: usize,
) -> usize {
    let total: usize = params.iter().map(|p| p.len()).sum();
    let workers = threads.max(1).min((total / MIN_DENSE_ELEMS_PER_WORKER).max(1));
    if workers <= 1 {
        for ((p, s), g) in params.iter_mut().zip(slots.iter_mut()).zip(dense) {
            opt.apply(p, g, s, step);
        }
        return 1;
    }
    let n_slots = opt.slots();
    let mut parts: Vec<Vec<DenseUnit<'_>>> = (0..workers).map(|_| Vec::new()).collect();
    // Tensors whose slice lengths don't match the optimizer layout take
    // the plain `apply` unchanged (same behavior as the serial sweep).
    let mut odd: Vec<usize> = Vec::new();
    for (t, ((p, s), g)) in params.iter_mut().zip(slots.iter_mut()).zip(dense).enumerate() {
        let n = p.len();
        if g.len() != n || s.len() != n * n_slots {
            odd.push(t);
            continue;
        }
        // Planar state -> per-slot plane views, then cut param, grad and
        // every plane at the same worker boundaries.
        let mut planes: Vec<&mut [f32]> = Vec::with_capacity(n_slots);
        let mut rest = s.as_mut_slice();
        for _ in 0..n_slots {
            let (head, tail) = rest.split_at_mut(n);
            planes.push(head);
            rest = tail;
        }
        let mut rest_p = p.as_mut_slice();
        let mut rest_g = g.as_slice();
        let mut start = 0;
        for (k, part) in parts.iter_mut().enumerate() {
            let end = n * (k + 1) / workers;
            let len = end - start;
            let (hp, tp) = rest_p.split_at_mut(len);
            rest_p = tp;
            let (hg, tg) = rest_g.split_at(len);
            rest_g = tg;
            let mut hplanes = Vec::with_capacity(n_slots);
            for plane in planes.iter_mut() {
                let (h, t) = std::mem::take(plane).split_at_mut(len);
                hplanes.push(h);
                *plane = t;
            }
            part.push(DenseUnit { param: hp, grad: hg, planes: hplanes });
            start = end;
        }
    }
    std::thread::scope(|scope| {
        let mut parts = parts.into_iter();
        let mut own = parts.next().unwrap();
        let handles: Vec<_> = parts
            .map(|mut units| scope.spawn(move || run_units(&mut units, opt, step)))
            .collect();
        run_units(&mut own, opt, step);
        for h in handles {
            h.join().unwrap();
        }
    });
    for t in odd {
        opt.apply(&mut params[t], &dense[t], &mut slots[t], step);
    }
    workers
}

pub struct PsShard {
    pub index: usize,
    /// `(lo, hi)` into each dense tensor's flat data.
    pub ranges: Vec<(usize, usize)>,
    pub dense: RwLock<DenseShardState>,
    pub emb: EmbeddingStore,
    pub counters: ShardCounters,
    /// Apply seqlock for snapshot-consistent serving reads: holds
    /// `2 * opt_step + 1` while an apply for `opt_step` is in flight and
    /// `2 * opt_step` once it has fully landed (dense *and* embedding).
    /// [`gather_rows_at`](Self::gather_rows_at) retries until it reads
    /// the same even value on both sides of the row reads, so a served
    /// row block never straddles an apply.
    apply_seq: AtomicU64,
    /// Recently-invalidated embedding keys for the serving plane.
    inval: Mutex<InvalLog>,
    /// Worker fan-out for one apply (`[ps] apply_threads`).
    apply_threads: usize,
    // Obs handles resolved once at construction: `labeled` allocates and
    // the registry lookup takes a lock, neither of which belongs in the
    // per-apply hot path.
    apply_hist: Arc<Histogram>,
    lock_wait_hist: Arc<Histogram>,
    workers_hist: Arc<Histogram>,
}

impl PsShard {
    /// Carve shard `index`'s slices out of the full initial parameters.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        index: usize,
        ranges: Vec<(usize, usize)>,
        init_params: &[HostTensor],
        dense_slots: usize,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
        apply_threads: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), init_params.len());
        let params: Vec<Vec<f32>> = ranges
            .iter()
            .zip(init_params)
            .map(|(&(lo, hi), t)| t.data[lo..hi].to_vec())
            .collect();
        let slots: Vec<Vec<f32>> =
            ranges.iter().map(|&(lo, hi)| vec![0.0f32; (hi - lo) * dense_slots]).collect();
        Self::from_parts(index, ranges, params, slots, emb_cfg, emb_slots, apply_threads)
    }

    /// Build a shard from already-sliced state — the respawn path: a
    /// [`ShardSupervisor`](crate::transport::ShardSupervisor) restores a
    /// lost shard from its shard-local checkpoint's dense/slot slices.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        index: usize,
        ranges: Vec<(usize, usize)>,
        params: Vec<Vec<f32>>,
        slots: Vec<Vec<f32>>,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
        apply_threads: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), params.len());
        debug_assert_eq!(ranges.len(), slots.len());
        for (&(lo, hi), p) in ranges.iter().zip(&params) {
            debug_assert_eq!(hi - lo, p.len());
        }
        let label = index.to_string();
        let reg = obs::global();
        PsShard {
            index,
            ranges,
            dense: RwLock::new(DenseShardState { params, slots }),
            emb: EmbeddingStore::new(emb_cfg, emb_slots),
            counters: ShardCounters::default(),
            apply_seq: AtomicU64::new(0),
            inval: Mutex::new(InvalLog {
                upto: 0,
                floor: 0,
                total_keys: 0,
                entries: VecDeque::new(),
            }),
            apply_threads: apply_threads.max(1),
            apply_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_seconds", "shard", &label),
                Histogram::latency_bounds(),
            ),
            lock_wait_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_lock_wait_seconds", "shard", &label),
                Histogram::latency_bounds(),
            ),
            workers_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_workers", "shard", &label),
                &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0],
            ),
        }
    }

    /// Apply this shard's pre-sliced portion of an aggregated dense
    /// gradient (`dense[t]` is exactly the `[lo, hi)` cut of tensor `t`,
    /// as carried by an `Apply` wire request), then its group of per-key
    /// embedding gradients.
    pub fn apply(
        &self,
        dense: &[Vec<f32>],
        emb_group: &[(u64, Vec<f32>, u32)],
        opt_dense: &dyn Optimizer,
        opt_emb: &dyn Optimizer,
        opt_step: u64,
    ) {
        // Seqlock goes odd before any state changes; applies on one
        // shard are serialized by the flush path, so the store pair
        // never races another apply.
        self.apply_seq.store(opt_step * 2 + 1, Ordering::Release);
        // Queueing behind readers is contention, not apply cost — record
        // it separately and start the apply clock once the lock is held.
        let t_lock = Instant::now();
        let mut d = self.dense.write().unwrap();
        self.lock_wait_hist.record(t_lock.elapsed().as_secs_f64());
        let t0 = Instant::now();
        let DenseShardState { params, slots } = &mut *d;
        debug_assert_eq!(dense.len(), params.len(), "apply: slice count mismatch");
        let workers = apply_dense(params, slots, dense, opt_dense, opt_step, self.apply_threads);
        drop(d);
        self.counters.applies.fetch_add(1, Ordering::Relaxed);
        self.workers_hist.record(workers as f64);

        if !emb_group.is_empty() {
            self.emb.apply_grads_threaded(emb_group, opt_emb, opt_step, self.apply_threads);
            self.counters.emb_keys_applied.fetch_add(emb_group.len() as u64, Ordering::Relaxed);
        }
        {
            let mut log = self.inval.lock().unwrap();
            log.upto = log.upto.max(opt_step);
            if !emb_group.is_empty() {
                let keys: Vec<u64> = emb_group.iter().map(|(k, _, _)| *k).collect();
                log.total_keys += keys.len();
                log.entries.push_back((opt_step, keys));
                while log.total_keys > INVAL_LOG_MAX_KEYS {
                    let Some((step, dropped)) = log.entries.pop_front() else { break };
                    log.total_keys -= dropped.len();
                    log.floor = log.floor.max(step);
                }
            }
        }
        // Rows and dense state are fully landed: seqlock goes even.
        self.apply_seq.store(opt_step * 2, Ordering::Release);
        let elapsed = t0.elapsed();
        self.counters.apply_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        self.apply_hist.record(elapsed.as_secs_f64());
    }

    /// Seqlock-consistent embedding gather for the serving plane:
    /// materialize-and-read `keys` like a plain `Gather`, but retry the
    /// whole block until the apply seqlock reads the same *even* value
    /// on both sides — the returned rows are exactly the state after
    /// the returned step's apply, never a half-applied mix. Lazy row
    /// materialization is deterministic in the key, so it never
    /// perturbs the snapshot.
    pub fn gather_rows_at(&self, keys: &[u64]) -> (u64, usize, Vec<f32>) {
        let dim = self.emb.dim();
        let mut data = vec![0.0f32; keys.len() * dim];
        loop {
            let s0 = self.apply_seq.load(Ordering::Acquire);
            if s0 & 1 == 1 {
                // An apply is in flight; its emb writes grab the same
                // store locks we read under, so just yield and re-poll.
                std::thread::yield_now();
                continue;
            }
            for (i, &key) in keys.iter().enumerate() {
                self.emb.read_row_into(key, &mut data[i * dim..(i + 1) * dim]);
            }
            if self.apply_seq.load(Ordering::Acquire) == s0 {
                return (s0 >> 1, dim, data);
            }
        }
    }

    /// Drain the invalidation log: `(upto, full, keys)` where `keys`
    /// are the embedding keys applies with step > `since` touched,
    /// `upto` is the latest applied step, and `full` means the bounded
    /// log dropped entries past `since` — the caller must invalidate
    /// everything it has cached.
    pub fn invalidations_since(&self, since: u64) -> (u64, bool, Vec<u64>) {
        let log = self.inval.lock().unwrap();
        let full = since < log.floor;
        let mut keys = Vec::new();
        if !full {
            for (step, ks) in log.entries.iter() {
                if *step > since {
                    keys.extend_from_slice(ks);
                }
            }
        }
        (log.upto, full, keys)
    }

    /// Copy this shard's parameter slices into full-size flat buffers.
    pub fn read_params_into(&self, out: &mut [Vec<f32>]) {
        let d = self.dense.read().unwrap();
        for (t, p) in d.params.iter().enumerate() {
            let (lo, hi) = self.ranges[t];
            out[t][lo..hi].copy_from_slice(p);
        }
    }

    pub fn stats(&self) -> ShardStats {
        let dense_elems = self.ranges.iter().map(|&(lo, hi)| hi - lo).sum();
        ShardStats {
            shard: self.index,
            applies: self.counters.applies.load(Ordering::Relaxed),
            apply_ns: self.counters.apply_ns.load(Ordering::Relaxed),
            emb_keys_applied: self.counters.emb_keys_applied.load(Ordering::Relaxed),
            emb_rows: self.emb.len(),
            dense_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, Sgd};
    use crate::util::rng::Pcg64;

    fn grads(rng: &mut Pcg64, lens: &[usize]) -> Vec<Vec<f32>> {
        lens.iter().map(|&n| (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()).collect()
    }

    /// The tentpole pin: one shard driven through identical apply
    /// sequences (dense + embedding) at 1, 2 and 8 apply threads must
    /// end bit-identical — parameters, optimizer slots, and rows.
    #[test]
    fn apply_threads_sweep_bit_identical() {
        // Big enough that the parallel sweep actually engages at 8
        // threads (see MIN_DENSE_ELEMS_PER_WORKER), plus a sub-chunk
        // tensor for the remainder paths.
        let lens = [40_000usize, 37];
        let ranges: Vec<(usize, usize)> = lens.iter().map(|&n| (0, n)).collect();
        let init: Vec<HostTensor> = lens
            .iter()
            .map(|&n| HostTensor {
                shape: vec![n],
                data: (0..n).map(|i| (i % 13) as f32 * 0.1 - 0.5).collect(),
            })
            .collect();
        let opt_d = Adam::new(0.01);
        let opt_e = Adam::new(0.05);
        let emb_cfg = EmbeddingConfig { dim: 8, init_scale: 0.05, seed: 11, shards: 8 };

        type Snap = (Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<(u64, Vec<u32>)>);
        let run = |threads: usize| -> Snap {
            let shard = PsShard::new(
                0,
                ranges.clone(),
                &init,
                opt_d.slots(),
                emb_cfg.clone(),
                opt_e.slots(),
                threads,
            );
            let mut rng = Pcg64::seeded(40);
            for step in 1..=4 {
                let dense = grads(&mut rng, &lens);
                let emb: Vec<(u64, Vec<f32>, u32)> = (0..100u64)
                    .map(|k| {
                        let g: Vec<f32> = (0..8).map(|_| rng.next_f32() - 0.5).collect();
                        (k * 3, g, 1 + (k % 2) as u32)
                    })
                    .collect();
                shard.apply(&dense, &emb, &opt_d, &opt_e, step);
            }
            let d = shard.dense.read().unwrap();
            let p: Vec<Vec<u32>> =
                d.params.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect();
            let s: Vec<Vec<u32>> =
                d.slots.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect();
            let mut rows: Vec<(u64, Vec<u32>)> = Vec::new();
            shard.emb.for_each_row(|k, v, st, _| {
                rows.push((k, v.iter().chain(st).map(|x| x.to_bits()).collect()));
            });
            rows.sort_by_key(|r| r.0);
            (p, s, rows)
        };

        let base = run(1);
        for threads in [2, 8] {
            assert_eq!(base, run(threads), "apply_threads={threads} diverged");
        }
    }

    #[test]
    fn parallel_sweep_engages_and_matches_serial() {
        // 40k elems at 8 threads must actually fan out — guard against
        // the size threshold silently forcing the serial path — and the
        // fanned-out result must match one serial apply exactly.
        let n = 40_000;
        let mut params = vec![vec![0.1f32; n]];
        let mut slots = vec![vec![0.0f32; 2 * n]];
        let dense = vec![vec![0.5f32; n]];
        let opt = Adam::new(0.01);
        let w = apply_dense(&mut params, &mut slots, &dense, &opt, 1, 8);
        assert!(w > 1, "expected parallel fan-out, got {w} worker(s)");
        let mut p2 = vec![vec![0.1f32; n]];
        let mut s2 = vec![vec![0.0f32; 2 * n]];
        opt.apply(&mut p2[0], &dense[0], &mut s2[0], 1);
        assert!(params[0].iter().zip(&p2[0]).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(slots[0].iter().zip(&s2[0]).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    /// Zero-init shard whose embedding rows move by exactly +1.0 per
    /// apply (Sgd lr 1.0, grad −1.0): row value == applied step.
    fn unit_shard() -> PsShard {
        let init = vec![HostTensor { shape: vec![4], data: vec![0.0; 4] }];
        let emb_cfg = EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 2 };
        PsShard::new(0, vec![(0, 4)], &init, 0, emb_cfg, 0, 1)
    }

    fn unit_apply(shard: &PsShard, keys: &[u64], step: u64) {
        let opt = Sgd { lr: 1.0 };
        let emb: Vec<(u64, Vec<f32>, u32)> =
            keys.iter().map(|&k| (k, vec![-1.0, -1.0], 1)).collect();
        shard.apply(&[vec![0.0; 4]], &emb, &opt, &opt, step);
    }

    #[test]
    fn gather_rows_at_reports_the_applied_step() {
        let shard = unit_shard();
        let keys = [3u64, 11, 7];
        let (step, dim, data) = shard.gather_rows_at(&keys);
        assert_eq!((step, dim), (0, 2));
        assert!(data.iter().all(|&x| x == 0.0), "zero-init rows before any apply");
        for s in 1..=4 {
            unit_apply(&shard, &keys, s);
        }
        let (step, dim, data) = shard.gather_rows_at(&keys);
        assert_eq!((step, dim), (4, 2));
        assert!(data.iter().all(|&x| x == 4.0), "row value == applied step, got {data:?}");
    }

    #[test]
    fn gather_rows_at_never_observes_a_half_applied_step() {
        let shard = std::sync::Arc::new(unit_shard());
        let keys: Vec<u64> = (0..16).map(|k| k * 5 + 1).collect();
        let applier = {
            let shard = shard.clone();
            let keys = keys.clone();
            std::thread::spawn(move || {
                for s in 1..=200 {
                    unit_apply(&shard, &keys, s);
                }
            })
        };
        // Every apply moves *every* served row by +1, so a consistent
        // snapshot has all components equal to the reported step; any
        // half-applied mix would show two adjacent values.
        let mut last_step = 0;
        while last_step < 200 {
            let (step, _, data) = shard.gather_rows_at(&keys);
            assert!(step >= last_step, "steps must be monotone: {step} < {last_step}");
            for &x in &data {
                assert_eq!(x, step as f32, "row straddles apply at step {step}: {data:?}");
            }
            last_step = step;
        }
        applier.join().unwrap();
    }

    #[test]
    fn invalidation_log_reports_keys_past_cursor() {
        let shard = unit_shard();
        unit_apply(&shard, &[1, 2], 1);
        unit_apply(&shard, &[3], 2);
        let (upto, full, mut keys) = shard.invalidations_since(0);
        keys.sort_unstable();
        assert_eq!((upto, full, keys), (2, false, vec![1, 2, 3]));
        let (upto, full, keys) = shard.invalidations_since(1);
        assert_eq!((upto, full, keys), (2, false, vec![3]));
        let (upto, full, keys) = shard.invalidations_since(2);
        assert_eq!((upto, full, keys), (2, false, vec![]));
    }

    #[test]
    fn invalidation_log_overflow_raises_floor_and_reports_full() {
        let shard = unit_shard();
        let big: Vec<u64> = (0..40_000u64).collect();
        let bigger: Vec<u64> = (40_000..80_000u64).collect();
        unit_apply(&shard, &big, 1);
        unit_apply(&shard, &bigger, 2);
        // 80k keys exceed the 64k cap: step 1's entry dropped, floor = 1.
        let (upto, full, keys) = shard.invalidations_since(0);
        assert_eq!((upto, full), (2, true));
        assert!(keys.is_empty(), "a full invalidation reports no key list");
        let (upto, full, keys) = shard.invalidations_since(1);
        assert_eq!((upto, full), (2, false));
        assert_eq!(keys.len(), 40_000, "step 2's entry survives the trim");
    }

    #[test]
    fn mismatched_grad_length_falls_back_to_plain_apply() {
        // A tensor whose gradient slice doesn't match the layout skips
        // the fan-out and keeps the plain `apply` semantics (SGD zips,
        // so only the overlapping prefix updates).
        let n = 40_000;
        let mut params = vec![vec![1.0f32; n]];
        let mut slots = vec![vec![]];
        let dense = vec![vec![1.0f32; 10]];
        let opt = Sgd { lr: 1.0 };
        let w = apply_dense(&mut params, &mut slots, &dense, &opt, 1, 8);
        assert!(w > 1, "threshold is on param elems, fan-out still reported");
        assert!(params[0][..10].iter().all(|&x| x == 0.0));
        assert!(params[0][10..].iter().all(|&x| x == 1.0));
    }
}
