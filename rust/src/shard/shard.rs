//! A single parameter-server shard: the data plane.
//!
//! Each [`PsShard`] owns
//!
//! * a contiguous **range slice** of every dense tensor (parameters plus
//!   shard-local planar optimizer slots) behind its own `RwLock` — pulls
//!   take read locks, applies take the write lock, and two shards never
//!   share a lock, and
//! * an [`EmbeddingStore`] holding the **consistent-hash slice** of the
//!   embedding keyspace routed to this shard.
//!
//! The apply hot path fans out inside one shard: the dense sweep splits
//! every tensor's index range across up to `apply_threads` scoped
//! workers on disjoint sub-ranges (elementwise optimizers ⇒ disjoint
//! writes ⇒ bit-identical to the serial sweep), and the embedding pass
//! parallelizes across the store's internal lock-shards. See
//! `docs/PERF.md` for the measurement loop behind this.
//!
//! Shards hold no coordination state whatsoever — see
//! [`super::control::ControlPlane`] for the control plane.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};
use std::time::Instant;

use crate::embedding::{EmbeddingConfig, EmbeddingStore};
use crate::obs::{self, Histogram};
use crate::optim::Optimizer;
use crate::runtime::HostTensor;

/// Dense state owned by one shard: per-tensor contiguous slices.
pub struct DenseShardState {
    /// `params[t]` is the `[lo, hi)` slice of tensor `t`'s flat data.
    pub params: Vec<Vec<f32>>,
    /// Optimizer slots per tensor, planar in the *shard-local* index
    /// (`range_len * slots` floats; slot `j` of local weight `i` lives at
    /// `j * range_len + i`). Elementwise optimizers make this layout
    /// bit-identical to applying on the unsharded tensor.
    pub slots: Vec<Vec<f32>>,
}

/// Monotonic per-shard load counters (relaxed atomics; read for
/// reporting only).
#[derive(Default)]
pub struct ShardCounters {
    /// Dense applies executed by this shard.
    pub applies: AtomicU64,
    /// Nanoseconds this shard spent inside its apply (dense optimizer
    /// sweep + embedding grads), measured from write-lock acquisition —
    /// queueing behind readers is recorded separately as
    /// `gba_shard_apply_lock_wait_seconds`. The per-flush wall cost is
    /// the *max* across shards, so imbalance here is what caps scale-out.
    pub apply_ns: AtomicU64,
    /// Embedding keys routed here for gradient application.
    pub emb_keys_applied: AtomicU64,
}

/// A point-in-time snapshot of one shard's load (for Fig. 7 reporting).
#[derive(Clone, Debug, Default)]
pub struct ShardStats {
    pub shard: usize,
    pub applies: u64,
    pub apply_ns: u64,
    pub emb_keys_applied: u64,
    pub emb_rows: usize,
    pub dense_elems: usize,
}

/// Minimum dense elements per worker before the parallel sweep engages —
/// below this, scoped-thread spawn overhead beats the parallel win.
const MIN_DENSE_ELEMS_PER_WORKER: usize = 4096;

/// One worker's cut of one tensor: disjoint `[a,b)` views of the
/// parameter slice, its gradient, and each optimizer state plane.
struct DenseUnit<'a> {
    param: &'a mut [f32],
    grad: &'a [f32],
    planes: Vec<&'a mut [f32]>,
}

fn run_units(units: &mut [DenseUnit<'_>], opt: &dyn Optimizer, step: u64) {
    for u in units.iter_mut() {
        opt.apply_planes(u.param, u.grad, &mut u.planes, step);
    }
}

/// Run the dense optimizer sweep, splitting every tensor's index range
/// across up to `threads` scoped workers on disjoint sub-ranges. The
/// optimizers are elementwise, so the disjoint writes make the result
/// bit-identical to the serial sweep regardless of interleaving.
/// Returns the number of workers actually used.
fn apply_dense(
    params: &mut [Vec<f32>],
    slots: &mut [Vec<f32>],
    dense: &[Vec<f32>],
    opt: &dyn Optimizer,
    step: u64,
    threads: usize,
) -> usize {
    let total: usize = params.iter().map(|p| p.len()).sum();
    let workers = threads.max(1).min((total / MIN_DENSE_ELEMS_PER_WORKER).max(1));
    if workers <= 1 {
        for ((p, s), g) in params.iter_mut().zip(slots.iter_mut()).zip(dense) {
            opt.apply(p, g, s, step);
        }
        return 1;
    }
    let n_slots = opt.slots();
    let mut parts: Vec<Vec<DenseUnit<'_>>> = (0..workers).map(|_| Vec::new()).collect();
    // Tensors whose slice lengths don't match the optimizer layout take
    // the plain `apply` unchanged (same behavior as the serial sweep).
    let mut odd: Vec<usize> = Vec::new();
    for (t, ((p, s), g)) in params.iter_mut().zip(slots.iter_mut()).zip(dense).enumerate() {
        let n = p.len();
        if g.len() != n || s.len() != n * n_slots {
            odd.push(t);
            continue;
        }
        // Planar state -> per-slot plane views, then cut param, grad and
        // every plane at the same worker boundaries.
        let mut planes: Vec<&mut [f32]> = Vec::with_capacity(n_slots);
        let mut rest = s.as_mut_slice();
        for _ in 0..n_slots {
            let (head, tail) = rest.split_at_mut(n);
            planes.push(head);
            rest = tail;
        }
        let mut rest_p = p.as_mut_slice();
        let mut rest_g = g.as_slice();
        let mut start = 0;
        for (k, part) in parts.iter_mut().enumerate() {
            let end = n * (k + 1) / workers;
            let len = end - start;
            let (hp, tp) = rest_p.split_at_mut(len);
            rest_p = tp;
            let (hg, tg) = rest_g.split_at(len);
            rest_g = tg;
            let mut hplanes = Vec::with_capacity(n_slots);
            for plane in planes.iter_mut() {
                let (h, t) = std::mem::take(plane).split_at_mut(len);
                hplanes.push(h);
                *plane = t;
            }
            part.push(DenseUnit { param: hp, grad: hg, planes: hplanes });
            start = end;
        }
    }
    std::thread::scope(|scope| {
        let mut parts = parts.into_iter();
        let mut own = parts.next().unwrap();
        let handles: Vec<_> = parts
            .map(|mut units| scope.spawn(move || run_units(&mut units, opt, step)))
            .collect();
        run_units(&mut own, opt, step);
        for h in handles {
            h.join().unwrap();
        }
    });
    for t in odd {
        opt.apply(&mut params[t], &dense[t], &mut slots[t], step);
    }
    workers
}

pub struct PsShard {
    pub index: usize,
    /// `(lo, hi)` into each dense tensor's flat data.
    pub ranges: Vec<(usize, usize)>,
    pub dense: RwLock<DenseShardState>,
    pub emb: EmbeddingStore,
    pub counters: ShardCounters,
    /// Worker fan-out for one apply (`[ps] apply_threads`).
    apply_threads: usize,
    // Obs handles resolved once at construction: `labeled` allocates and
    // the registry lookup takes a lock, neither of which belongs in the
    // per-apply hot path.
    apply_hist: Arc<Histogram>,
    lock_wait_hist: Arc<Histogram>,
    workers_hist: Arc<Histogram>,
}

impl PsShard {
    /// Carve shard `index`'s slices out of the full initial parameters.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        index: usize,
        ranges: Vec<(usize, usize)>,
        init_params: &[HostTensor],
        dense_slots: usize,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
        apply_threads: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), init_params.len());
        let params: Vec<Vec<f32>> = ranges
            .iter()
            .zip(init_params)
            .map(|(&(lo, hi), t)| t.data[lo..hi].to_vec())
            .collect();
        let slots: Vec<Vec<f32>> =
            ranges.iter().map(|&(lo, hi)| vec![0.0f32; (hi - lo) * dense_slots]).collect();
        Self::from_parts(index, ranges, params, slots, emb_cfg, emb_slots, apply_threads)
    }

    /// Build a shard from already-sliced state — the respawn path: a
    /// [`ShardSupervisor`](crate::transport::ShardSupervisor) restores a
    /// lost shard from its shard-local checkpoint's dense/slot slices.
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        index: usize,
        ranges: Vec<(usize, usize)>,
        params: Vec<Vec<f32>>,
        slots: Vec<Vec<f32>>,
        emb_cfg: EmbeddingConfig,
        emb_slots: usize,
        apply_threads: usize,
    ) -> Self {
        debug_assert_eq!(ranges.len(), params.len());
        debug_assert_eq!(ranges.len(), slots.len());
        for (&(lo, hi), p) in ranges.iter().zip(&params) {
            debug_assert_eq!(hi - lo, p.len());
        }
        let label = index.to_string();
        let reg = obs::global();
        PsShard {
            index,
            ranges,
            dense: RwLock::new(DenseShardState { params, slots }),
            emb: EmbeddingStore::new(emb_cfg, emb_slots),
            counters: ShardCounters::default(),
            apply_threads: apply_threads.max(1),
            apply_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_seconds", "shard", &label),
                Histogram::latency_bounds(),
            ),
            lock_wait_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_lock_wait_seconds", "shard", &label),
                Histogram::latency_bounds(),
            ),
            workers_hist: reg.histogram(
                &obs::labeled("gba_shard_apply_workers", "shard", &label),
                &[1.0, 2.0, 4.0, 8.0, 16.0, 32.0],
            ),
        }
    }

    /// Apply this shard's pre-sliced portion of an aggregated dense
    /// gradient (`dense[t]` is exactly the `[lo, hi)` cut of tensor `t`,
    /// as carried by an `Apply` wire request), then its group of per-key
    /// embedding gradients.
    pub fn apply(
        &self,
        dense: &[Vec<f32>],
        emb_group: &[(u64, Vec<f32>, u32)],
        opt_dense: &dyn Optimizer,
        opt_emb: &dyn Optimizer,
        opt_step: u64,
    ) {
        // Queueing behind readers is contention, not apply cost — record
        // it separately and start the apply clock once the lock is held.
        let t_lock = Instant::now();
        let mut d = self.dense.write().unwrap();
        self.lock_wait_hist.record(t_lock.elapsed().as_secs_f64());
        let t0 = Instant::now();
        let DenseShardState { params, slots } = &mut *d;
        debug_assert_eq!(dense.len(), params.len(), "apply: slice count mismatch");
        let workers = apply_dense(params, slots, dense, opt_dense, opt_step, self.apply_threads);
        drop(d);
        self.counters.applies.fetch_add(1, Ordering::Relaxed);
        self.workers_hist.record(workers as f64);

        if !emb_group.is_empty() {
            self.emb.apply_grads_threaded(emb_group, opt_emb, opt_step, self.apply_threads);
            self.counters.emb_keys_applied.fetch_add(emb_group.len() as u64, Ordering::Relaxed);
        }
        let elapsed = t0.elapsed();
        self.counters.apply_ns.fetch_add(elapsed.as_nanos() as u64, Ordering::Relaxed);
        self.apply_hist.record(elapsed.as_secs_f64());
    }

    /// Copy this shard's parameter slices into full-size flat buffers.
    pub fn read_params_into(&self, out: &mut [Vec<f32>]) {
        let d = self.dense.read().unwrap();
        for (t, p) in d.params.iter().enumerate() {
            let (lo, hi) = self.ranges[t];
            out[t][lo..hi].copy_from_slice(p);
        }
    }

    pub fn stats(&self) -> ShardStats {
        let dense_elems = self.ranges.iter().map(|&(lo, hi)| hi - lo).sum();
        ShardStats {
            shard: self.index,
            applies: self.counters.applies.load(Ordering::Relaxed),
            apply_ns: self.counters.apply_ns.load(Ordering::Relaxed),
            emb_keys_applied: self.counters.emb_keys_applied.load(Ordering::Relaxed),
            emb_rows: self.emb.len(),
            dense_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, Sgd};
    use crate::util::rng::Pcg64;

    fn grads(rng: &mut Pcg64, lens: &[usize]) -> Vec<Vec<f32>> {
        lens.iter().map(|&n| (0..n).map(|_| rng.next_f32() * 2.0 - 1.0).collect()).collect()
    }

    /// The tentpole pin: one shard driven through identical apply
    /// sequences (dense + embedding) at 1, 2 and 8 apply threads must
    /// end bit-identical — parameters, optimizer slots, and rows.
    #[test]
    fn apply_threads_sweep_bit_identical() {
        // Big enough that the parallel sweep actually engages at 8
        // threads (see MIN_DENSE_ELEMS_PER_WORKER), plus a sub-chunk
        // tensor for the remainder paths.
        let lens = [40_000usize, 37];
        let ranges: Vec<(usize, usize)> = lens.iter().map(|&n| (0, n)).collect();
        let init: Vec<HostTensor> = lens
            .iter()
            .map(|&n| HostTensor {
                shape: vec![n],
                data: (0..n).map(|i| (i % 13) as f32 * 0.1 - 0.5).collect(),
            })
            .collect();
        let opt_d = Adam::new(0.01);
        let opt_e = Adam::new(0.05);
        let emb_cfg = EmbeddingConfig { dim: 8, init_scale: 0.05, seed: 11, shards: 8 };

        type Snap = (Vec<Vec<u32>>, Vec<Vec<u32>>, Vec<(u64, Vec<u32>)>);
        let run = |threads: usize| -> Snap {
            let shard = PsShard::new(
                0,
                ranges.clone(),
                &init,
                opt_d.slots(),
                emb_cfg.clone(),
                opt_e.slots(),
                threads,
            );
            let mut rng = Pcg64::seeded(40);
            for step in 1..=4 {
                let dense = grads(&mut rng, &lens);
                let emb: Vec<(u64, Vec<f32>, u32)> = (0..100u64)
                    .map(|k| {
                        let g: Vec<f32> = (0..8).map(|_| rng.next_f32() - 0.5).collect();
                        (k * 3, g, 1 + (k % 2) as u32)
                    })
                    .collect();
                shard.apply(&dense, &emb, &opt_d, &opt_e, step);
            }
            let d = shard.dense.read().unwrap();
            let p: Vec<Vec<u32>> =
                d.params.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect();
            let s: Vec<Vec<u32>> =
                d.slots.iter().map(|t| t.iter().map(|x| x.to_bits()).collect()).collect();
            let mut rows: Vec<(u64, Vec<u32>)> = Vec::new();
            shard.emb.for_each_row(|k, v, st, _| {
                rows.push((k, v.iter().chain(st).map(|x| x.to_bits()).collect()));
            });
            rows.sort_by_key(|r| r.0);
            (p, s, rows)
        };

        let base = run(1);
        for threads in [2, 8] {
            assert_eq!(base, run(threads), "apply_threads={threads} diverged");
        }
    }

    #[test]
    fn parallel_sweep_engages_and_matches_serial() {
        // 40k elems at 8 threads must actually fan out — guard against
        // the size threshold silently forcing the serial path — and the
        // fanned-out result must match one serial apply exactly.
        let n = 40_000;
        let mut params = vec![vec![0.1f32; n]];
        let mut slots = vec![vec![0.0f32; 2 * n]];
        let dense = vec![vec![0.5f32; n]];
        let opt = Adam::new(0.01);
        let w = apply_dense(&mut params, &mut slots, &dense, &opt, 1, 8);
        assert!(w > 1, "expected parallel fan-out, got {w} worker(s)");
        let mut p2 = vec![vec![0.1f32; n]];
        let mut s2 = vec![vec![0.0f32; 2 * n]];
        opt.apply(&mut p2[0], &dense[0], &mut s2[0], 1);
        assert!(params[0].iter().zip(&p2[0]).all(|(a, b)| a.to_bits() == b.to_bits()));
        assert!(slots[0].iter().zip(&s2[0]).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    #[test]
    fn mismatched_grad_length_falls_back_to_plain_apply() {
        // A tensor whose gradient slice doesn't match the layout skips
        // the fan-out and keeps the plain `apply` semantics (SGD zips,
        // so only the overlapping prefix updates).
        let n = 40_000;
        let mut params = vec![vec![1.0f32; n]];
        let mut slots = vec![vec![]];
        let dense = vec![vec![1.0f32; 10]];
        let opt = Sgd { lr: 1.0 };
        let w = apply_dense(&mut params, &mut slots, &dense, &opt, 1, 8);
        assert!(w > 1, "threshold is on param elems, fan-out still reported");
        assert!(params[0][..10].iter().all(|&x| x == 0.0));
        assert!(params[0][10..].iter().all(|&x| x == 1.0));
    }
}
