//! Placement: which PS shard owns which piece of state.
//!
//! Two partitioning schemes, one per parameter class:
//!
//! * **Embedding keys** — consistent hashing via *rendezvous* (highest
//!   random weight): shard = argmax over shards of `mix64(key ⊕ tag(s))`.
//!   Rendezvous hashing gives near-perfect balance (each key picks its
//!   shard independently and uniformly) and the consistent-hashing
//!   minimal-migration property: growing `n → n+1` shards only moves the
//!   keys whose new-shard weight wins — about `1/(n+1)` of them — and
//!   every migrated key moves *to* the new shard, never between old ones.
//! * **Dense parameters** — contiguous range partition: shard `s` owns
//!   `[s·len/n, (s+1)·len/n)` of every dense tensor's flat data. Ranges
//!   are deterministic in `(len, n)`, cover the tensor exactly, and keep
//!   each shard's slice cache-contiguous for the optimizer sweep.
//!
//! The router is pure (no locks, no state beyond `n_shards`), so both
//! the front (`ShardedPs`) and the per-shard apply threads can consult it
//! freely.

use crate::util::rng::mix64;

/// Odd multiplier deriving a per-shard tag stream (splitmix64 constant).
const SHARD_TAG_MUL: u64 = 0x9E37_79B9_7F4A_7C15;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardRouter {
    n_shards: usize,
}

impl ShardRouter {
    pub fn new(n_shards: usize) -> Self {
        assert!(n_shards >= 1, "need at least one shard");
        ShardRouter { n_shards }
    }

    #[inline]
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Owning shard of an embedding key (rendezvous hashing).
    #[inline]
    pub fn shard_of_key(&self, key: u64) -> usize {
        if self.n_shards == 1 {
            return 0;
        }
        self.shard_of_hash(mix64(key))
    }

    /// Owning shard given a pre-computed `mix64(key)`. Hot paths that
    /// also hand the hash to the embedding store (gather) call this so
    /// each key is hashed once, not once per consumer. `mix64` is a
    /// bijection, so routing on the hash preserves every consistency
    /// property of routing on the key.
    #[inline]
    pub fn shard_of_hash(&self, hash: u64) -> usize {
        if self.n_shards == 1 {
            return 0;
        }
        let mut best = 0usize;
        let mut best_w = 0u64;
        for s in 0..self.n_shards {
            let w = mix64(hash ^ (s as u64).wrapping_mul(SHARD_TAG_MUL));
            if s == 0 || w > best_w {
                best = s;
                best_w = w;
            }
        }
        best
    }

    /// `[start, end)` of a flat dense buffer of `len` owned by shard `s`.
    #[inline]
    pub fn dense_range(&self, s: usize, len: usize) -> (usize, usize) {
        debug_assert!(s < self.n_shards);
        (s * len / self.n_shards, (s + 1) * len / self.n_shards)
    }
}

impl Default for ShardRouter {
    fn default() -> Self {
        ShardRouter::new(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_shard_owns_everything() {
        let r = ShardRouter::new(1);
        for key in [0u64, 1, 99, u64::MAX] {
            assert_eq!(r.shard_of_key(key), 0);
        }
        assert_eq!(r.dense_range(0, 17), (0, 17));
    }

    #[test]
    fn routing_is_deterministic() {
        let a = ShardRouter::new(8);
        let b = ShardRouter::new(8);
        for key in 0..1000u64 {
            assert_eq!(a.shard_of_key(key), b.shard_of_key(key));
        }
    }

    #[test]
    fn dense_ranges_tile_exactly() {
        for n in 1..=9usize {
            let r = ShardRouter::new(n);
            for len in [0usize, 1, 5, 64, 1000, 1001] {
                let mut covered = 0usize;
                for s in 0..n {
                    let (lo, hi) = r.dense_range(s, len);
                    assert_eq!(lo, covered, "n={n} len={len} s={s}");
                    assert!(hi >= lo);
                    covered = hi;
                }
                assert_eq!(covered, len);
            }
        }
    }

}
