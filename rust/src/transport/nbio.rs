//! Nonblocking buffered connections for the event-driven fronts.
//!
//! [`BufConn`] wraps a `TcpStream` kept permanently in nonblocking mode
//! and speaks the same length-prefixed [`codec`] frames as
//! [`SocketConn`](super::SocketConn) — but never parks a thread on the
//! socket. Incoming bytes accumulate in an input buffer until a whole
//! frame is present ([`try_recv`](BufConn::try_recv)); outgoing frames
//! queue in an output buffer and drain opportunistically
//! ([`try_flush`](BufConn::try_flush)). One readiness loop can therefore
//! sweep hundreds of connections on a single thread: each sweep is a
//! `try_flush` + `try_recv` per connection, with no per-connection
//! thread, lock, or blocking read anywhere.
//!
//! The blocking helpers ([`recv_deadline`](BufConn::recv_deadline),
//! [`send_all`](BufConn::send_all)) exist for the protocol edges that
//! are genuinely sequential — handshakes, farewells, epoch switches —
//! and are implemented as bounded poll-sleep loops, since OS read
//! timeouts do not apply to a nonblocking socket. The [`Conn`] impl
//! uses them with no deadline, so a `BufConn` can stand in anywhere a
//! [`SocketConn`](super::SocketConn) did.
//!
//! Bit-identity note: frames cross this type byte-for-byte as they do a
//! `SocketConn` — same codec, same framing, same rx/tx byte metrics —
//! so swapping one in changes scheduling, never payloads.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use super::codec::{self, CodecError, WireMsg, MAX_FRAME_BYTES};
use super::endpoint::Conn;

/// How long the blocking helpers sleep between polls. Short enough that
/// a handshake round-trip costs ~a millisecond of added latency, long
/// enough not to spin a core while a peer thinks.
const POLL_SLEEP: Duration = Duration::from_millis(1);

/// Read chunk size per `try_recv` syscall. Frames are usually far
/// smaller; large gather replies just take a few reads.
const READ_CHUNK: usize = 64 * 1024;

/// A codec-framed connection over a *nonblocking* socket, with
/// buffered, retryable reads and writes.
pub struct BufConn {
    stream: TcpStream,
    /// Bytes received but not yet parsed into a frame.
    in_buf: Vec<u8>,
    /// Encoded frames queued for the peer, already length-prefixed.
    out_buf: Vec<u8>,
    /// How much of `out_buf` has been written.
    out_pos: usize,
}

impl BufConn {
    /// Take ownership of a stream and switch it to nonblocking mode.
    pub fn new(stream: TcpStream) -> std::io::Result<BufConn> {
        // Frames are small and latency-bound; never batch them.
        let _ = stream.set_nodelay(true);
        stream.set_nonblocking(true)?;
        // A leftover read timeout from a previous (blocking) life of the
        // stream is meaningless now; clear it defensively.
        let _ = stream.set_read_timeout(None);
        Ok(BufConn { stream, in_buf: Vec::new(), out_buf: Vec::new(), out_pos: 0 })
    }

    /// Queue one frame for the peer and opportunistically flush. The
    /// frame is fully buffered on `Ok`, whether or not any bytes moved;
    /// only a dead peer errors.
    pub fn queue_send(&mut self, msg: &WireMsg) -> Result<(), CodecError> {
        // Encode straight into the output buffer — reserve the 4-byte
        // length slot, append the body in place, patch the slot — so
        // large dense/gather frames skip the intermediate body Vec and
        // its copy. Bytes on the wire are identical to encode-then-copy.
        let start = self.out_buf.len();
        self.out_buf.extend_from_slice(&[0u8; 4]);
        codec::encode_into(&mut self.out_buf, msg);
        let body_len = self.out_buf.len() - start - 4;
        let len = match u32::try_from(body_len) {
            Ok(len) if len <= MAX_FRAME_BYTES => len,
            _ => {
                self.out_buf.truncate(start);
                return Err(CodecError::Oversize(u32::try_from(body_len).unwrap_or(u32::MAX)));
            }
        };
        self.out_buf[start..start + 4].copy_from_slice(&len.to_le_bytes());
        codec::record_frame_bytes("tx", msg, body_len + 4);
        self.try_flush().map(|_| ())
    }

    /// Push queued output toward the peer without blocking. `Ok(true)`
    /// when the queue is fully drained, `Ok(false)` when the socket
    /// would block with bytes still pending.
    pub fn try_flush(&mut self) -> Result<bool, CodecError> {
        while self.out_pos < self.out_buf.len() {
            match self.stream.write(&self.out_buf[self.out_pos..]) {
                Ok(0) => return Err(CodecError::Io(ErrorKind::WriteZero)),
                Ok(n) => self.out_pos += n,
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(false),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(CodecError::Io(e.kind())),
            }
        }
        self.out_buf.clear();
        self.out_pos = 0;
        Ok(true)
    }

    /// Bytes queued but not yet written.
    pub fn pending_out(&self) -> usize {
        self.out_buf.len() - self.out_pos
    }

    /// Try to produce one frame without blocking. `Ok(None)` means no
    /// complete frame is available yet; `Err(Closed)` a peer that hung
    /// up cleanly between frames; `Err(Truncated)` one that died
    /// mid-frame.
    pub fn try_recv(&mut self) -> Result<Option<WireMsg>, CodecError> {
        loop {
            if let Some(msg) = self.parse_frame()? {
                return Ok(Some(msg));
            }
            // Need more bytes. Read until a frame completes, the socket
            // would block, or the peer is gone.
            let start = self.in_buf.len();
            self.in_buf.resize(start + READ_CHUNK, 0);
            match self.stream.read(&mut self.in_buf[start..]) {
                Ok(0) => {
                    self.in_buf.truncate(start);
                    return Err(if self.in_buf.is_empty() {
                        CodecError::Closed
                    } else {
                        CodecError::Truncated
                    });
                }
                Ok(n) => {
                    self.in_buf.truncate(start + n);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    self.in_buf.truncate(start);
                    return Ok(None);
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {
                    self.in_buf.truncate(start);
                }
                Err(e) => {
                    self.in_buf.truncate(start);
                    return Err(CodecError::Io(e.kind()));
                }
            }
        }
    }

    /// Parse one complete frame off the front of `in_buf`, if present.
    fn parse_frame(&mut self) -> Result<Option<WireMsg>, CodecError> {
        if self.in_buf.len() < 4 {
            return Ok(None);
        }
        let len = u32::from_le_bytes([self.in_buf[0], self.in_buf[1], self.in_buf[2], self.in_buf[3]]);
        if len > MAX_FRAME_BYTES {
            return Err(CodecError::Oversize(len));
        }
        let total = 4 + len as usize;
        if self.in_buf.len() < total {
            return Ok(None);
        }
        let msg = codec::decode(&self.in_buf[4..total])?;
        codec::record_frame_bytes("rx", &msg, total);
        self.in_buf.drain(..total);
        Ok(Some(msg))
    }

    /// Block (poll-sleep) until a frame arrives, the peer dies, or the
    /// deadline passes (`Err(Io(TimedOut))`). Pending output keeps
    /// draining while we wait, so a request/reply exchange can't wedge
    /// on an unflushed request.
    pub fn recv_deadline(&mut self, deadline: Option<Duration>) -> Result<WireMsg, CodecError> {
        let t0 = Instant::now();
        loop {
            self.try_flush()?;
            if let Some(msg) = self.try_recv()? {
                return Ok(msg);
            }
            if let Some(d) = deadline {
                if t0.elapsed() > d {
                    return Err(CodecError::Io(ErrorKind::TimedOut));
                }
            }
            std::thread::sleep(POLL_SLEEP);
        }
    }

    /// Queue a frame and block (poll-sleep) until every queued byte is
    /// on the wire or the deadline passes.
    pub fn send_all(
        &mut self,
        msg: &WireMsg,
        deadline: Option<Duration>,
    ) -> Result<(), CodecError> {
        let t0 = Instant::now();
        self.queue_send(msg)?;
        while !self.try_flush()? {
            if let Some(d) = deadline {
                if t0.elapsed() > d {
                    return Err(CodecError::Io(ErrorKind::TimedOut));
                }
            }
            std::thread::sleep(POLL_SLEEP);
        }
        Ok(())
    }

    /// Best-effort liveness probe of the peer, without consuming input.
    /// `true` means the peer is certainly gone (clean close or reset);
    /// `false` means it *may* be alive — an idle open socket and a live
    /// peer look identical, so callers must treat `false` as "assume
    /// alive". Used by the worker front to let a redialing worker
    /// replace its own dead connection instead of dying as a duplicate.
    pub fn peer_dead(&mut self) -> bool {
        let mut probe = [0u8; 1];
        match self.stream.peek(&mut probe) {
            Ok(0) => true, // orderly shutdown: nothing more will come
            Ok(_) => false,
            Err(e) if e.kind() == ErrorKind::WouldBlock => false,
            Err(e) => matches!(
                e.kind(),
                ErrorKind::ConnectionReset
                    | ErrorKind::ConnectionAborted
                    | ErrorKind::BrokenPipe
                    | ErrorKind::NotConnected
            ),
        }
    }
}

/// The [`Conn`] impl makes a `BufConn` a drop-in for the blocking
/// request/reply paths (handshakes, epoch switches): `send` drains the
/// queue, `recv` waits for a frame, both without deadline.
impl Conn for BufConn {
    fn send(&mut self, msg: WireMsg) -> Result<(), CodecError> {
        self.send_all(&msg, None)
    }

    fn recv(&mut self) -> Result<WireMsg, CodecError> {
        self.recv_deadline(None)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::codec::{ShardReply, ShardRequest};
    use crate::transport::SocketConn;
    use std::net::TcpListener;

    fn pair() -> (BufConn, SocketConn) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        (BufConn::new(server).unwrap(), SocketConn::new(client))
    }

    #[test]
    fn frames_roundtrip_against_a_blocking_peer() {
        let (mut buf, mut peer) = pair();
        peer.send(WireMsg::Req(ShardRequest::Gather { keys: vec![1, 2, 3] })).unwrap();
        // The frame is already in the socket; one try_recv sees it.
        let t0 = Instant::now();
        let msg = loop {
            if let Some(m) = buf.try_recv().unwrap() {
                break m;
            }
            assert!(t0.elapsed() < Duration::from_secs(5), "frame never arrived");
            std::thread::sleep(Duration::from_millis(1));
        };
        match msg {
            WireMsg::Req(ShardRequest::Gather { keys }) => assert_eq!(keys, vec![1, 2, 3]),
            other => panic!("{other:?}"),
        }
        buf.queue_send(&WireMsg::Reply(ShardReply::Rows { dim: 2, data: vec![0.5; 6] })).unwrap();
        while !buf.try_flush().unwrap() {}
        match peer.recv().unwrap() {
            WireMsg::Reply(ShardReply::Rows { dim, data }) => {
                assert_eq!(dim, 2);
                assert_eq!(data, vec![0.5; 6]);
            }
            other => panic!("{other:?}"),
        }
    }

    /// A frame delivered byte-by-byte accumulates across try_recv calls
    /// and parses only once complete — the partial-frame discipline the
    /// event loop depends on.
    #[test]
    fn partial_frames_accumulate_until_complete() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut buf = BufConn::new(server).unwrap();

        let body = codec::encode(&WireMsg::Req(ShardRequest::GetMeta { key: 42 }));
        let mut frame = (body.len() as u32).to_le_bytes().to_vec();
        frame.extend_from_slice(&body);
        let t0 = Instant::now();
        for (i, byte) in frame.iter().enumerate() {
            client.write_all(std::slice::from_ref(byte)).unwrap();
            client.flush().unwrap();
            if i + 1 < frame.len() {
                // Wait for the byte to land, then confirm no frame yet.
                while buf.in_buf.len() < i + 1 {
                    assert!(buf.try_recv().unwrap().is_none(), "parsed an incomplete frame");
                    assert!(t0.elapsed() < Duration::from_secs(10), "bytes never arrived");
                    std::thread::sleep(Duration::from_millis(1));
                }
            }
        }
        let msg = loop {
            if let Some(m) = buf.try_recv().unwrap() {
                break m;
            }
            assert!(t0.elapsed() < Duration::from_secs(10), "complete frame never parsed");
            std::thread::sleep(Duration::from_millis(1));
        };
        assert!(matches!(msg, WireMsg::Req(ShardRequest::GetMeta { key: 42 })));
    }

    #[test]
    fn clean_close_is_closed_midframe_is_truncated() {
        // Clean close between frames.
        let (mut buf, peer) = pair();
        drop(peer);
        let t0 = Instant::now();
        loop {
            match buf.try_recv() {
                Err(CodecError::Closed) => break,
                Ok(None) => {
                    assert!(t0.elapsed() < Duration::from_secs(5));
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => panic!("expected Closed, got {other:?}"),
            }
        }

        // Death mid-frame.
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let mut client = TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        let mut buf = BufConn::new(server).unwrap();
        client.write_all(&100u32.to_le_bytes()).unwrap(); // promises 100 bytes
        client.write_all(&[1, 2, 3]).unwrap(); // delivers 3
        client.flush().unwrap();
        drop(client);
        let t0 = Instant::now();
        loop {
            match buf.try_recv() {
                Err(CodecError::Truncated) => break,
                Ok(None) => {
                    assert!(t0.elapsed() < Duration::from_secs(5));
                    std::thread::sleep(Duration::from_millis(1));
                }
                other => panic!("expected Truncated, got {other:?}"),
            }
        }
    }

    #[test]
    fn peer_dead_detects_a_closed_peer_not_an_idle_one() {
        let (mut buf, peer) = pair();
        assert!(!buf.peer_dead(), "an idle live peer is not dead");
        drop(peer);
        // Closing is asynchronous; poll until the FIN lands.
        let t0 = Instant::now();
        while !buf.peer_dead() {
            assert!(t0.elapsed() < Duration::from_secs(5), "close never observed");
            std::thread::sleep(Duration::from_millis(1));
        }
    }

    /// The blocking Conn impl interoperates with a SocketConn — the
    /// handshake paths use exactly this.
    #[test]
    fn conn_impl_blocks_like_a_socket_conn() {
        let (mut buf, mut peer) = pair();
        let t = std::thread::spawn(move || {
            peer.send(WireMsg::Req(ShardRequest::Ping)).unwrap();
            peer.recv().unwrap()
        });
        assert!(matches!(buf.recv().unwrap(), WireMsg::Req(ShardRequest::Ping)));
        buf.send(WireMsg::Reply(ShardReply::Ok)).unwrap();
        assert!(matches!(t.join().unwrap(), WireMsg::Reply(ShardReply::Ok)));
    }
}
