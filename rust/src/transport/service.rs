//! The shard server: owns one [`PsShard`] (plus its own optimizer
//! instances) and executes the shard-plane RPC against it.
//!
//! This is the half of the PS that leaves the worker process: the service
//! holds *all* of a shard's state and is reachable only through a
//! [`Conn`], so running it behind a TCP socket instead of an in-process
//! channel changes nothing but the transport. Optimizers are cloned into
//! the service (they are deterministic config, not state — mutable state
//! lives in the shard's slot buffers), which is what makes a respawned
//! service bit-compatible with the one it replaces.

use super::codec::{CodecError, RowRecord, ShardReply, ShardRequest, WireMsg};
use super::endpoint::Conn;
use crate::obs;
use crate::optim::{make_optimizer, Optimizer};
use crate::shard::PsShard;
use crate::util::json::Json;

pub struct ShardService {
    shard: PsShard,
    opt_dense: Box<dyn Optimizer>,
    opt_emb: Box<dyn Optimizer>,
}

impl ShardService {
    pub fn new(shard: PsShard, opt_dense: Box<dyn Optimizer>, opt_emb: Box<dyn Optimizer>) -> Self {
        ShardService { shard, opt_dense, opt_emb }
    }

    /// Execute one request. Every request produces exactly one reply —
    /// the strict alternation the endpoints rely on. (`&mut self`
    /// because `SwapPolicy` replaces the service's optimizer pair; every
    /// other verb touches only shard state behind its own locks.)
    pub fn handle(&mut self, req: ShardRequest) -> ShardReply {
        obs::global()
            .counter(&obs::labeled("gba_shard_requests_total", "rpc", req.kind_name()))
            .inc();
        match req {
            ShardRequest::Ping => ShardReply::Ok,
            ShardRequest::Hello { shard, dense_slots, emb_slots, emb_dim } => {
                // A front that dialed the wrong server or was launched
                // with a mode whose optimizer shape differs must die at
                // connect, not diverge silently. Asserting (not erroring)
                // is deliberate: it kills this service — and for a
                // shard-server process, the process — leaving the reason
                // in its log while the front sees the dropped connection.
                assert_eq!(shard as usize, self.shard.index, "Hello: wrong shard dialed");
                assert_eq!(
                    dense_slots as usize,
                    self.opt_dense.slots(),
                    "Hello: dense optimizer shape mismatch (front/server --mode disagree?)"
                );
                assert_eq!(
                    emb_slots as usize,
                    self.opt_emb.slots(),
                    "Hello: embedding optimizer shape mismatch (front/server --mode disagree?)"
                );
                assert_eq!(emb_dim as usize, self.shard.emb.dim(), "Hello: emb_dim mismatch");
                ShardReply::Ok
            }
            ShardRequest::Apply { opt_step, dense, emb } => {
                obs::trace::span(
                    "shard_apply",
                    Json::obj().set("shard", self.shard.index).set("opt_step", opt_step),
                );
                self.shard.apply(
                    &dense,
                    &emb,
                    self.opt_dense.as_ref(),
                    self.opt_emb.as_ref(),
                    opt_step,
                );
                ShardReply::Ok
            }
            ShardRequest::ReadDense => {
                let d = self.shard.dense.read().unwrap();
                ShardReply::Dense { dense: d.params.clone() }
            }
            ShardRequest::ReadSlots => {
                let d = self.shard.dense.read().unwrap();
                ShardReply::Dense { dense: d.slots.clone() }
            }
            ShardRequest::SetDense { dense } => {
                let n_slots = self.opt_dense.slots();
                let mut d = self.shard.dense.write().unwrap();
                assert_eq!(dense.len(), d.params.len(), "SetDense tensor count");
                for (t, slice) in dense.into_iter().enumerate() {
                    let (lo, hi) = self.shard.ranges[t];
                    assert_eq!(slice.len(), hi - lo, "SetDense slice length");
                    d.params[t] = slice;
                    // Checkpoint-restore semantics: fresh optimizer state.
                    d.slots[t] = vec![0.0; (hi - lo) * n_slots];
                }
                ShardReply::Ok
            }
            ShardRequest::SetSlots { slots } => {
                let n_slots = self.opt_dense.slots();
                let mut d = self.shard.dense.write().unwrap();
                assert_eq!(slots.len(), d.slots.len(), "SetSlots tensor count");
                for (t, slice) in slots.into_iter().enumerate() {
                    let (lo, hi) = self.shard.ranges[t];
                    assert_eq!(slice.len(), (hi - lo) * n_slots, "SetSlots slice length");
                    d.slots[t] = slice;
                }
                ShardReply::Ok
            }
            ShardRequest::Gather { keys } => {
                let dim = self.shard.emb.dim();
                let mut data = vec![0.0f32; keys.len() * dim];
                for (i, &key) in keys.iter().enumerate() {
                    self.shard.emb.read_row_into(key, &mut data[i * dim..(i + 1) * dim]);
                }
                ShardReply::Rows { dim: dim as u64, data }
            }
            ShardRequest::GetMeta { key } => ShardReply::Meta { meta: self.shard.emb.meta(key) },
            ShardRequest::InsertRow { key, vec, state, meta } => {
                self.shard.emb.insert_row(key, vec, state, meta);
                ShardReply::Ok
            }
            ShardRequest::InsertRows { rows } => {
                for (key, vec, state, meta) in rows {
                    self.shard.emb.insert_row(key, vec, state, meta);
                }
                ShardReply::Ok
            }
            ShardRequest::DumpRows => {
                let mut rows: Vec<RowRecord> = Vec::with_capacity(self.shard.emb.len());
                self.shard.emb.for_each_row(|k, v, st, m| {
                    rows.push((k, v.to_vec(), st.to_vec(), m));
                });
                // Canonical order: the shard-local checkpoint stream is
                // byte-stable regardless of hash-map iteration order.
                rows.sort_by_key(|(k, _, _, _)| *k);
                ShardReply::RowDump { rows }
            }
            ShardRequest::Stats => ShardReply::Stats {
                stats: self.shard.stats(),
                emb_mem_bytes: self.shard.emb.memory_bytes() as u64,
            },
            ShardRequest::SwapPolicy { opt, lr, reset_slots } => {
                // In-place mode switch (§1): install the new epoch's
                // optimizer pair. Slot state survives only a same-shape
                // swap that did not ask for a reset — across optimizer
                // kinds the old accumulators are meaningless and are
                // zeroed at the new shape.
                let opt_dense = make_optimizer(opt, lr);
                let opt_emb = make_optimizer(opt, lr);
                let same_shape = opt_dense.slots() == self.opt_dense.slots()
                    && opt_emb.slots() == self.opt_emb.slots();
                if reset_slots || !same_shape {
                    let n_slots = opt_dense.slots();
                    let mut d = self.shard.dense.write().unwrap();
                    for (slot, &(lo, hi)) in d.slots.iter_mut().zip(&self.shard.ranges) {
                        *slot = vec![0.0; (hi - lo) * n_slots];
                    }
                    drop(d);
                    self.shard.emb.reset_state(opt_emb.slots());
                }
                self.opt_dense = opt_dense;
                self.opt_emb = opt_emb;
                ShardReply::Ok
            }
            ShardRequest::ObsScrape => {
                // Fleet scrape: hand the coordinator this process's whole
                // registry (in a shard-server process that is exactly the
                // shard's metrics; in-process it is the shared registry).
                ShardReply::Obs { entries: obs::global().snapshot() }
            }
        }
    }
}

/// Serve one connection until the peer goes away. Any receive error or
/// protocol violation ends the loop — and with it the thread and the
/// shard's state, which is precisely what "losing a shard" means.
pub fn serve(service: ShardService, conn: Box<dyn Conn>) {
    let _ = serve_counting(service, conn);
}

/// [`serve`], but reporting how many requests were handled and why the
/// loop exited (tests assert on the exit cause).
pub fn serve_counting(mut service: ShardService, mut conn: Box<dyn Conn>) -> (u64, CodecError) {
    let mut handled = 0u64;
    loop {
        match conn.recv() {
            Ok(WireMsg::Req(req)) => {
                let reply = service.handle(req);
                handled += 1;
                if let Err(e) = conn.send(WireMsg::Reply(reply)) {
                    return (handled, e);
                }
            }
            Ok(_) => return (handled, CodecError::Malformed("expected a request frame")),
            Err(e) => return (handled, e),
        }
    }
}
