//! The shard server: owns one [`PsShard`] (plus its own optimizer
//! instances) and executes the shard-plane RPC against it.
//!
//! This is the half of the PS that leaves the worker process: the service
//! holds *all* of a shard's state and is reachable only through a
//! [`Conn`], so running it behind a TCP socket instead of an in-process
//! channel changes nothing but the transport. Optimizers are cloned into
//! the service (they are deterministic config, not state — mutable state
//! lives in the shard's slot buffers), which is what makes a respawned
//! service bit-compatible with the one it replaces.
//!
//! Two connections reach each shard: the **primary** (mutating)
//! connection served by [`serve`]/[`serve_counting`], and a **read-only
//! companion** served by [`serve_reads`] over an [`Arc`] handle to the
//! same shard ([`ShardService::shard_handle`]). Reads — embedding
//! gathers above all — answer on the companion while an `Apply` is in
//! flight on the primary, instead of queueing behind it; the shard's
//! own `RwLock`s are the only synchronization, exactly as when both
//! verbs shared one connection.

use std::sync::Arc;

use super::codec::{CodecError, RowRecord, ShardReply, ShardRequest, WireMsg};
use super::endpoint::Conn;
use crate::obs;
use crate::optim::{make_optimizer, Optimizer};
use crate::shard::PsShard;
use crate::util::json::Json;

pub struct ShardService {
    shard: Arc<PsShard>,
    opt_dense: Box<dyn Optimizer>,
    opt_emb: Box<dyn Optimizer>,
}

/// Execute one *read-only* request against the shard, or hand a
/// mutating request back to the caller. The single dispatch point for
/// what "read-only" means on the wire: both the primary service and the
/// read-only companion loop route through here, so the two connections
/// can never disagree about a verb's side effects.
fn try_handle_read(shard: &PsShard, req: ShardRequest) -> Result<ShardReply, ShardRequest> {
    Ok(match req {
        ShardRequest::Ping => ShardReply::Ok,
        ShardRequest::ReadHello { shard: s } => {
            // The companion-connection handshake: same wrong-number
            // policy as `Hello` — a front that dialed the wrong server
            // must die at connect, not read another model's rows.
            assert_eq!(s as usize, shard.index, "ReadHello: wrong shard dialed");
            ShardReply::Ok
        }
        ShardRequest::ReadDense => {
            let d = shard.dense.read().unwrap();
            ShardReply::Dense { dense: d.params.clone() }
        }
        ShardRequest::ReadSlots => {
            let d = shard.dense.read().unwrap();
            ShardReply::Dense { dense: d.slots.clone() }
        }
        ShardRequest::Gather { keys } => {
            let dim = shard.emb.dim();
            let mut data = vec![0.0f32; keys.len() * dim];
            for (i, &key) in keys.iter().enumerate() {
                shard.emb.read_row_into(key, &mut data[i * dim..(i + 1) * dim]);
            }
            ShardReply::Rows { dim: dim as u64, data }
        }
        ShardRequest::GatherAt { keys } => {
            // Serving-plane gather: same rows as `Gather`, read under
            // the shard's apply seqlock and stamped with the step they
            // are consistent at.
            let (step, dim, data) = shard.gather_rows_at(&keys);
            ShardReply::RowsAt { step, dim: dim as u64, data }
        }
        ShardRequest::ReadInvalidations { since } => {
            let (upto, full, keys) = shard.invalidations_since(since);
            ShardReply::Invalidations { upto, full, keys }
        }
        ShardRequest::GetMeta { key } => ShardReply::Meta { meta: shard.emb.meta(key) },
        ShardRequest::DumpRows => {
            let mut rows: Vec<RowRecord> = Vec::with_capacity(shard.emb.len());
            shard.emb.for_each_row(|k, v, st, m| {
                rows.push((k, v.to_vec(), st.to_vec(), m));
            });
            // Canonical order: the shard-local checkpoint stream is
            // byte-stable regardless of hash-map iteration order.
            rows.sort_by_key(|(k, _, _, _)| *k);
            ShardReply::RowDump { rows }
        }
        ShardRequest::Stats => ShardReply::Stats {
            stats: shard.stats(),
            emb_mem_bytes: shard.emb.memory_bytes() as u64,
        },
        ShardRequest::ObsScrape => {
            // Fleet scrape: hand the coordinator this process's whole
            // registry (in a shard-server process that is exactly the
            // shard's metrics; in-process it is the shared registry).
            ShardReply::Obs { entries: obs::global().snapshot() }
        }
        other => return Err(other),
    })
}

impl ShardService {
    pub fn new(shard: PsShard, opt_dense: Box<dyn Optimizer>, opt_emb: Box<dyn Optimizer>) -> Self {
        ShardService { shard: Arc::new(shard), opt_dense, opt_emb }
    }

    /// A shared handle to the shard, for a read-only companion loop
    /// ([`serve_reads`]) running beside this service.
    pub fn shard_handle(&self) -> Arc<PsShard> {
        self.shard.clone()
    }

    /// Execute one request. Every request produces exactly one reply —
    /// the strict alternation the endpoints rely on. (`&mut self`
    /// because `SwapPolicy` replaces the service's optimizer pair; every
    /// other verb touches only shard state behind its own locks.)
    pub fn handle(&mut self, req: ShardRequest) -> ShardReply {
        obs::global()
            .counter(&obs::labeled("gba_shard_requests_total", "rpc", req.kind_name()))
            .inc();
        let req = match try_handle_read(&self.shard, req) {
            Ok(reply) => return reply,
            Err(req) => req,
        };
        match req {
            ShardRequest::Hello { shard, dense_slots, emb_slots, emb_dim, cfg_digest } => {
                // A front that dialed the wrong server or was launched
                // with a mode whose optimizer shape differs must die at
                // connect, not diverge silently. Asserting (not erroring)
                // is deliberate: it kills this service — and for a
                // shard-server process, the process — leaving the reason
                // in its log while the front sees the dropped connection.
                assert_eq!(shard as usize, self.shard.index, "Hello: wrong shard dialed");
                assert_eq!(
                    dense_slots as usize,
                    self.opt_dense.slots(),
                    "Hello: dense optimizer shape mismatch (front/server --mode disagree?)"
                );
                assert_eq!(
                    emb_slots as usize,
                    self.opt_emb.slots(),
                    "Hello: embedding optimizer shape mismatch (front/server --mode disagree?)"
                );
                assert_eq!(emb_dim as usize, self.shard.emb.dim(), "Hello: emb_dim mismatch");
                assert_eq!(
                    cfg_digest,
                    crate::optim::config_digest(self.opt_dense.as_ref(), self.opt_emb.as_ref()),
                    "Hello: optimizer config digest mismatch (same shape but different \
                     lr/kind pair — front and server were launched from different configs)"
                );
                ShardReply::Ok
            }
            ShardRequest::Apply { opt_step, dense, emb } => {
                obs::trace::span(
                    "shard_apply",
                    Json::obj().set("shard", self.shard.index).set("opt_step", opt_step),
                );
                self.shard.apply(
                    &dense,
                    &emb,
                    self.opt_dense.as_ref(),
                    self.opt_emb.as_ref(),
                    opt_step,
                );
                ShardReply::Ok
            }
            ShardRequest::SetDense { dense } => {
                let n_slots = self.opt_dense.slots();
                let mut d = self.shard.dense.write().unwrap();
                assert_eq!(dense.len(), d.params.len(), "SetDense tensor count");
                for (t, slice) in dense.into_iter().enumerate() {
                    let (lo, hi) = self.shard.ranges[t];
                    assert_eq!(slice.len(), hi - lo, "SetDense slice length");
                    d.params[t] = slice;
                    // Checkpoint-restore semantics: fresh optimizer state.
                    d.slots[t] = vec![0.0; (hi - lo) * n_slots];
                }
                ShardReply::Ok
            }
            ShardRequest::SetSlots { slots } => {
                let n_slots = self.opt_dense.slots();
                let mut d = self.shard.dense.write().unwrap();
                assert_eq!(slots.len(), d.slots.len(), "SetSlots tensor count");
                for (t, slice) in slots.into_iter().enumerate() {
                    let (lo, hi) = self.shard.ranges[t];
                    assert_eq!(slice.len(), (hi - lo) * n_slots, "SetSlots slice length");
                    d.slots[t] = slice;
                }
                ShardReply::Ok
            }
            ShardRequest::InsertRow { key, vec, state, meta } => {
                self.shard.emb.insert_row(key, vec, state, meta);
                ShardReply::Ok
            }
            ShardRequest::InsertRows { rows } => {
                for (key, vec, state, meta) in rows {
                    self.shard.emb.insert_row(key, vec, state, meta);
                }
                ShardReply::Ok
            }
            ShardRequest::SwapPolicy { opt, lr, reset_slots } => {
                // In-place mode switch (§1): install the new epoch's
                // optimizer pair. Slot state survives only a same-shape
                // swap that did not ask for a reset — across optimizer
                // kinds the old accumulators are meaningless and are
                // zeroed at the new shape.
                let opt_dense = make_optimizer(opt, lr);
                let opt_emb = make_optimizer(opt, lr);
                let same_shape = opt_dense.slots() == self.opt_dense.slots()
                    && opt_emb.slots() == self.opt_emb.slots();
                if reset_slots || !same_shape {
                    let n_slots = opt_dense.slots();
                    let mut d = self.shard.dense.write().unwrap();
                    for (slot, &(lo, hi)) in d.slots.iter_mut().zip(&self.shard.ranges) {
                        *slot = vec![0.0; (hi - lo) * n_slots];
                    }
                    drop(d);
                    self.shard.emb.reset_state(opt_emb.slots());
                }
                self.opt_dense = opt_dense;
                self.opt_emb = opt_emb;
                ShardReply::Ok
            }
            // Read verbs were consumed by `try_handle_read` above.
            _ => unreachable!("read verb fell through try_handle_read"),
        }
    }
}

/// Serve one connection until the peer goes away. Any receive error or
/// protocol violation ends the loop — and with it the thread and the
/// shard's state, which is precisely what "losing a shard" means.
pub fn serve(service: ShardService, conn: Box<dyn Conn>) {
    let _ = serve_counting(service, conn);
}

/// [`serve`], but reporting how many requests were handled and why the
/// loop exited (tests assert on the exit cause).
pub fn serve_counting(mut service: ShardService, mut conn: Box<dyn Conn>) -> (u64, CodecError) {
    let shard = service.shard_handle();
    let mut handled = 0u64;
    loop {
        match conn.recv() {
            // Gather is the read hot path: stream its rows reply
            // straight into the connection out-buffer instead of
            // materializing the `keys.len() * dim` float block first
            // (same counter and bytes metric as the generic path).
            Ok(WireMsg::Req(ShardRequest::Gather { keys })) => {
                obs::global()
                    .counter(&obs::labeled("gba_shard_requests_total", "rpc", "gather"))
                    .inc();
                handled += 1;
                let dim = shard.emb.dim();
                if let Err(e) = conn.send_rows(dim, keys.len(), &mut |i, row| {
                    shard.emb.read_row_into(keys[i], row);
                }) {
                    return (handled, e);
                }
            }
            Ok(WireMsg::Req(req)) => {
                let reply = service.handle(req);
                handled += 1;
                if let Err(e) = conn.send(WireMsg::Reply(reply)) {
                    return (handled, e);
                }
            }
            Ok(_) => return (handled, CodecError::Malformed("expected a request frame")),
            Err(e) => return (handled, e),
        }
    }
}

/// Serve the read-only companion connection: only verbs without side
/// effects execute; a mutating request on this connection is a protocol
/// violation that ends the loop (the supervisor routes every mutation
/// over the primary, so this can only be a bug or a hostile peer —
/// either way the shard's state must not change through the back door).
/// Exits quietly when the peer hangs up; shard state lives with the
/// *primary* connection, so a dead read companion loses nothing.
pub fn serve_reads(shard: Arc<PsShard>, mut conn: Box<dyn Conn>) -> (u64, CodecError) {
    let mut handled = 0u64;
    loop {
        match conn.recv() {
            // Same streaming Gather hot path as `serve_counting` — the
            // companion connection is where serving gathers land.
            Ok(WireMsg::Req(ShardRequest::Gather { keys })) => {
                obs::global()
                    .counter(&obs::labeled("gba_shard_requests_total", "rpc", "gather"))
                    .inc();
                handled += 1;
                let dim = shard.emb.dim();
                if let Err(e) = conn.send_rows(dim, keys.len(), &mut |i, row| {
                    shard.emb.read_row_into(keys[i], row);
                }) {
                    return (handled, e);
                }
            }
            Ok(WireMsg::Req(req)) => {
                obs::global()
                    .counter(&obs::labeled("gba_shard_requests_total", "rpc", req.kind_name()))
                    .inc();
                let reply = match try_handle_read(&shard, req) {
                    Ok(reply) => reply,
                    Err(req) => {
                        eprintln!(
                            "shard {}: mutating {} on the read-only connection; closing it",
                            shard.index,
                            req.kind_name()
                        );
                        return (handled, CodecError::Malformed("mutating request on a read connection"));
                    }
                };
                handled += 1;
                if let Err(e) = conn.send(WireMsg::Reply(reply)) {
                    return (handled, e);
                }
            }
            Ok(_) => return (handled, CodecError::Malformed("expected a request frame")),
            Err(e) => return (handled, e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingConfig;
    use crate::optim::config_digest;

    fn tiny_service(dense_lr: f64) -> ShardService {
        let shard = PsShard::from_parts(
            0,
            vec![(0, 4)],
            vec![vec![0.0; 4]],
            vec![vec![]],
            EmbeddingConfig { dim: 4, ..EmbeddingConfig::default() },
            0,
            1,
        );
        ShardService::new(
            shard,
            make_optimizer(crate::config::OptimKind::Sgd, dense_lr),
            make_optimizer(crate::config::OptimKind::Sgd, 0.01),
        )
    }

    fn hello_for(dense_lr: f64) -> ShardRequest {
        let (d, e) = (
            make_optimizer(crate::config::OptimKind::Sgd, dense_lr),
            make_optimizer(crate::config::OptimKind::Sgd, 0.01),
        );
        ShardRequest::Hello {
            shard: 0,
            dense_slots: 0,
            emb_slots: 0,
            emb_dim: 4,
            cfg_digest: config_digest(d.as_ref(), e.as_ref()),
        }
    }

    #[test]
    fn hello_accepts_a_matching_config_digest() {
        let mut svc = tiny_service(0.05);
        assert!(matches!(svc.handle(hello_for(0.05)), ShardReply::Ok));
    }

    /// The gap the slot-count handshake cannot see: identical optimizer
    /// shapes, different learning rate. The digest must kill the connect.
    #[test]
    #[should_panic(expected = "config digest mismatch")]
    fn hello_rejects_a_same_shape_different_lr_front() {
        let mut svc = tiny_service(0.05);
        svc.handle(hello_for(0.1));
    }
}
