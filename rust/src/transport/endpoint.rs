//! Shard endpoints: one blocking, strictly request/reply connection per
//! shard, with two interchangeable implementations.
//!
//! * [`ChanConn`] — in-process: a [`chan::duplex`] pair moving [`WireMsg`]
//!   values directly (no serialization). This wraps today's `util/chan`
//!   seam bit-for-bit: the structs that used to ride the apply-pool
//!   channel now ride the same channel type, just behind the [`Conn`]
//!   trait.
//! * [`SocketConn`] — TCP on localhost: every message passes through the
//!   versioned [`codec`](super::codec) as a length-prefixed frame. Because
//!   `f32`s travel as raw bits, results are bit-for-bit identical to the
//!   in-process transport (pinned by `tests/shard_invariance.rs`).
//!
//! A dead peer — dropped channel end, closed or reset socket — surfaces
//! as `Err(CodecError)` from `send`/`recv`; the
//! [`ShardSupervisor`](super::ShardSupervisor) turns that into the
//! lost-shard recovery path. Connections carry no in-band failure
//! protocol: liveness *is* the protocol.

use std::net::TcpStream;
use std::time::Instant;

use super::codec::{self, CodecError, ShardReply, ShardRequest, WireMsg};
use crate::obs;
use crate::util::chan;

/// A bidirectional, blocking message pipe. Calls must alternate
/// send/recv per request — the per-shard slot lock in the supervisor
/// enforces this, so no sequence numbers are needed on the wire.
pub trait Conn: Send {
    fn send(&mut self, msg: WireMsg) -> Result<(), CodecError>;
    fn recv(&mut self) -> Result<WireMsg, CodecError>;

    /// Send a [`ShardReply::Rows`] reply whose rows are produced by
    /// `fill(row_index, row_slice)`. The default materializes the full
    /// float block and goes through [`send`](Conn::send) — correct for
    /// value-moving connections ([`ChanConn`]) — while [`SocketConn`]
    /// overrides it to scatter/gather-encode rows straight into the
    /// frame's out-buffer ([`codec::write_rows_frame`]), skipping the
    /// `keys.len() * dim` staging `Vec` on the gather reply hot path.
    fn send_rows(
        &mut self,
        dim: usize,
        n_rows: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> Result<(), CodecError> {
        let mut data = vec![0.0f32; n_rows * dim];
        for (i, row) in data.chunks_exact_mut(dim.max(1)).enumerate().take(n_rows) {
            fill(i, row);
        }
        self.send(WireMsg::Reply(ShardReply::Rows { dim: dim as u64, data }))
    }
}

/// In-process endpoint over a [`chan::duplex`] pair. The channel
/// carries `(trace_id, msg)` so the sender's current trace id crosses
/// the thread boundary exactly as the codec header carries it across a
/// socket (no serialization of the message itself).
pub struct ChanConn {
    pub pipe: chan::Duplex<(u64, WireMsg)>,
}

impl Conn for ChanConn {
    fn send(&mut self, msg: WireMsg) -> Result<(), CodecError> {
        self.pipe.tx.send((obs::trace::current(), msg)).map_err(|_| CodecError::Closed)
    }

    fn recv(&mut self) -> Result<WireMsg, CodecError> {
        let (trace_id, msg) = self.pipe.rx.recv().map_err(|_| CodecError::Closed)?;
        obs::trace::set_current(trace_id);
        Ok(msg)
    }
}

/// TCP endpoint framing every message through the codec.
pub struct SocketConn {
    pub stream: TcpStream,
}

impl SocketConn {
    pub fn new(stream: TcpStream) -> Self {
        // Frames are small and latency-bound; never batch them.
        let _ = stream.set_nodelay(true);
        SocketConn { stream }
    }
}

impl Conn for SocketConn {
    fn send(&mut self, msg: WireMsg) -> Result<(), CodecError> {
        codec::write_frame(&mut self.stream, &msg)
    }

    fn recv(&mut self) -> Result<WireMsg, CodecError> {
        codec::read_frame(&mut self.stream)
    }

    fn send_rows(
        &mut self,
        dim: usize,
        n_rows: usize,
        fill: &mut dyn FnMut(usize, &mut [f32]),
    ) -> Result<(), CodecError> {
        codec::write_rows_frame(&mut self.stream, dim, n_rows, fill)
    }
}

/// A connection whose peer is gone. `kill_shard` swaps this in so the
/// next RPC fails deterministically (no half-open states in tests).
pub struct DeadConn;

impl Conn for DeadConn {
    fn send(&mut self, _msg: WireMsg) -> Result<(), CodecError> {
        Err(CodecError::Closed)
    }

    fn recv(&mut self) -> Result<WireMsg, CodecError> {
        Err(CodecError::Closed)
    }
}

/// One blocking RPC: send the request, wait for its reply. Every call
/// lands in the client-side per-RPC latency histogram, labeled by the
/// request kind — *including* failed calls: a dead or wedged peer is
/// exactly the tail the straggler signal needs, so the elapsed time is
/// recorded before the error propagates.
pub fn rpc(conn: &mut dyn Conn, req: ShardRequest) -> Result<ShardReply, CodecError> {
    let kind = req.kind_name();
    let t0 = Instant::now();
    let reply = conn.send(WireMsg::Req(req)).and_then(|()| conn.recv()).and_then(|msg| match msg {
        WireMsg::Reply(r) => Ok(r),
        _ => Err(CodecError::Malformed("expected a reply frame")),
    });
    obs::global()
        .histogram(
            &obs::labeled("gba_shard_rpc_seconds", "rpc", kind),
            obs::Histogram::latency_bounds(),
        )
        .record(t0.elapsed().as_secs_f64());
    reply
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chan_conn_roundtrip_and_close() {
        let (a, b) = chan::duplex();
        let mut client = ChanConn { pipe: a };
        let mut server = ChanConn { pipe: b };
        client.send(WireMsg::Req(ShardRequest::Ping)).unwrap();
        assert!(matches!(server.recv().unwrap(), WireMsg::Req(ShardRequest::Ping)));
        server.send(WireMsg::Reply(ShardReply::Ok)).unwrap();
        assert!(matches!(client.recv().unwrap(), WireMsg::Reply(ShardReply::Ok)));
        drop(server);
        assert_eq!(client.recv().unwrap_err(), CodecError::Closed);
    }

    #[test]
    fn socket_conn_roundtrip_on_localhost() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = SocketConn::new(stream);
            match conn.recv().unwrap() {
                WireMsg::Req(ShardRequest::Gather { keys }) => {
                    assert_eq!(keys, vec![7, 8]);
                }
                other => panic!("{other:?}"),
            }
            conn.send(WireMsg::Reply(ShardReply::Rows { dim: 2, data: vec![1.0; 4] }))
                .unwrap();
        });
        let mut client = SocketConn::new(TcpStream::connect(addr).unwrap());
        client
            .send(WireMsg::Req(ShardRequest::Gather { keys: vec![7, 8] }))
            .unwrap();
        match client.recv().unwrap() {
            WireMsg::Reply(ShardReply::Rows { dim, data }) => {
                assert_eq!(dim, 2);
                assert_eq!(data.len(), 4);
            }
            other => panic!("{other:?}"),
        }
        server.join().unwrap();
        // Server side hung up: the next recv reports a closed peer.
        assert!(client.recv().is_err());
    }

    #[test]
    fn send_rows_decodes_as_a_plain_rows_reply_on_both_transports() {
        // Socket: the streaming override must produce a frame the
        // standard reader decodes as ShardReply::Rows.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let server = std::thread::spawn(move || {
            let (stream, _) = listener.accept().unwrap();
            let mut conn = SocketConn::new(stream);
            conn.send_rows(2, 3, &mut |i, row| {
                row[0] = i as f32;
                row[1] = -(i as f32);
            })
            .unwrap();
        });
        let mut client = SocketConn::new(TcpStream::connect(addr).unwrap());
        match client.recv().unwrap() {
            WireMsg::Reply(ShardReply::Rows { dim, data }) => {
                assert_eq!(dim, 2);
                assert_eq!(data, vec![0.0, -0.0, 1.0, -1.0, 2.0, -2.0]);
            }
            other => panic!("{other:?}"),
        }
        server.join().unwrap();

        // Channel: the default materializing path carries the same reply.
        let (a, b) = chan::duplex();
        let mut tx = ChanConn { pipe: a };
        let mut rx = ChanConn { pipe: b };
        tx.send_rows(2, 2, &mut |i, row| row.fill(i as f32 + 0.5)).unwrap();
        match rx.recv().unwrap() {
            WireMsg::Reply(ShardReply::Rows { dim, data }) => {
                assert_eq!(dim, 2);
                assert_eq!(data, vec![0.5, 0.5, 1.5, 1.5]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dead_conn_always_fails() {
        let mut d = DeadConn;
        assert_eq!(d.send(WireMsg::Reply(ShardReply::Ok)).unwrap_err(), CodecError::Closed);
        assert_eq!(d.recv().unwrap_err(), CodecError::Closed);
    }
}
