//! Remote shard endpoints: the client and server halves of the
//! `transport = "remote"` deployment, where each PS shard is a separate
//! OS process (`gba-train shard-server`) on its own TCP address.
//!
//! Protocol-wise there is nothing new here — a remote shard speaks the
//! exact same codec frames over the exact same [`SocketConn`] as the
//! in-process `socket` transport, so results stay bit-for-bit identical
//! across all three transports. What *is* new is the lifecycle:
//!
//! * **Client side** ([`connect_retry`]): the front cannot spawn a
//!   remote process, only dial it. Connection attempts retry with
//!   backoff up to [`RECONNECT_DEADLINE`], which is what lets the
//!   [`ShardSupervisor`](super::ShardSupervisor) treat a shard-server
//!   that crashed and was restarted (by an operator, a supervisor
//!   daemon, or a test harness) like any other lost shard: reconnect,
//!   install the shard-local checkpoint over the wire (`SetDense`,
//!   `SetSlots`, one bulk `InsertRows`), replay the journal.
//! * **Server side** ([`serve_shard`]): one accept loop dispatching on
//!   each connection's *first frame*. A `ReadHello` opens a read-only
//!   companion connection onto the **current** shard generation, served
//!   on its own thread ([`serve_reads`]) so gathers and checkpoint
//!   reads answer while an `Apply` is in flight on the primary. Any
//!   other first request is a **primary** connection — also served on
//!   its own thread (the accept loop must stay free to take the read
//!   companion dialed while the primary is live), with a **fresh shard
//!   per primary**. The front's checkpoint
//!   is authoritative — a server that accepted a reconnect holds no
//!   state worth preserving (the front could not know what the dying
//!   connection left behind), so every primary starts from the
//!   config-derived initial state and lets the install overwrite it.
//!   This makes reconnect semantics deterministic: the rebuilt shard is
//!   bit-identical to the lost one, exactly as in-process respawn is.
//!
//! Both halves are plain library code so tests can run real accept
//! loops on threads; the `shard-server` subcommand in `main.rs` is a
//! thin wrapper that binds, prints its address, and calls
//! [`serve_shard`].

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::codec::{ShardReply, ShardRequest, WireMsg};
use super::endpoint::{Conn, SocketConn};
use super::service::{serve_counting, serve_reads};
use super::supervisor::{ShardCheckpoint, ShardSpawnSpec};
use crate::runtime::HostTensor;
use crate::shard::PsShard;

/// How long the front keeps dialing a shard address before declaring the
/// shard unrecoverable. Long enough to ride out a shard-server restart;
/// short enough that a mis-typed address fails the run, not the shift.
pub const RECONNECT_DEADLINE: Duration = Duration::from_secs(20);

/// How long the accept loop waits for a freshly accepted connection's
/// first frame. Real peers (the supervisor) send it immediately after
/// connect; a silent junk peer must not wedge the accept loop forever.
const FIRST_FRAME_TIMEOUT: Duration = Duration::from_secs(10);

/// Dial `addr` until it accepts or `deadline` elapses, backing off
/// 10 ms → 500 ms between attempts. `None` means nobody ever listened.
///
/// Each attempt is individually bounded by the remaining deadline via
/// `connect_timeout` — a peer that silently drops SYNs (firewalled
/// port, dead host) must not park us in the kernel's own
/// minutes-long connect timeout, because recovery calls this while
/// holding every shard slot lock. The worst-case overshoot past the
/// deadline is one 250 ms floor attempt.
pub fn connect_retry(addr: &str, deadline: Duration) -> Option<SocketConn> {
    let t0 = Instant::now();
    let mut backoff = Duration::from_millis(10);
    loop {
        // connect_timeout rejects a zero duration; floor the cap so the
        // final attempt still gets a brief real try.
        let cap = deadline.saturating_sub(t0.elapsed()).max(Duration::from_millis(250));
        let attempt = match addr.to_socket_addrs().ok().and_then(|mut a| a.next()) {
            Some(sa) => TcpStream::connect_timeout(&sa, cap),
            None => Err(std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                "unresolvable shard address",
            )),
        };
        match attempt {
            Ok(stream) => return Some(SocketConn::new(stream)),
            Err(_) => {
                let elapsed = t0.elapsed();
                if elapsed >= deadline {
                    return None;
                }
                std::thread::sleep(backoff.min(deadline - elapsed));
                backoff = (backoff * 2).min(Duration::from_millis(500));
            }
        }
    }
}

/// Run one shard's accept loop forever: accept a connection, dispatch
/// on its first frame (`ReadHello` → read-only companion onto the
/// current generation, anything else → a fresh primary), and hand it
/// to its own serving thread — the accept loop itself never blocks on
/// a served connection, because the supervisor dials the companion
/// while its primary is live. Returns only when the listener fails.
///
/// Logs go to stderr — stdout belongs to the launcher, which prints
/// exactly one `listening on` line that process supervisors (and the
/// `process_shards` test) parse.
pub fn serve_shard(
    listener: TcpListener,
    spec: ShardSpawnSpec,
    init_params: &[HostTensor],
) -> std::io::Result<()> {
    // The generation read companions attach to: the shard behind the
    // most recent primary connection. A companion outliving its primary
    // serves that generation's (now orphaned) state until its own
    // socket closes — the supervisor redials both on recovery.
    let mut current: Option<Arc<PsShard>> = None;
    loop {
        let (stream, peer) = listener.accept()?;
        let _ = stream.set_read_timeout(Some(FIRST_FRAME_TIMEOUT));
        let mut conn = SocketConn::new(stream);
        let first = match conn.recv() {
            Ok(WireMsg::Req(req)) => req,
            other => {
                eprintln!(
                    "shard {}: dropping connection from {peer}: no first request ({other:?})",
                    spec.index
                );
                continue;
            }
        };
        if let ShardRequest::ReadHello { shard } = first {
            let Some(gen) = current.clone() else {
                eprintln!(
                    "shard {}: read companion from {peer} before any primary; dropping",
                    spec.index
                );
                continue;
            };
            // Same wrong-number policy as the primary `Hello`: die at
            // connect, loudly.
            assert_eq!(shard as usize, spec.index, "ReadHello: wrong shard dialed");
            if conn.send(WireMsg::Reply(ShardReply::Ok)).is_err() {
                continue;
            }
            let _ = conn.stream.set_read_timeout(None);
            let index = spec.index;
            std::thread::Builder::new()
                .name(format!("ps-shard-{index}-read"))
                .spawn(move || {
                    let (handled, exit) = serve_reads(gen, Box::new(conn));
                    eprintln!(
                        "shard {index}: read companion from {peer} ended after {handled} \
                         requests ({exit})"
                    );
                })
                .expect("spawning read companion thread");
            continue;
        }
        eprintln!("shard {}: serving connection from {peer}", spec.index);
        let mut service = spec.service_at(&ShardCheckpoint::initial(&spec, init_params));
        current = Some(service.shard_handle());
        // Serve the primary on its own thread so the accept loop stays
        // free for the read companion the supervisor dials *while* this
        // primary is live (serving it inline would deadlock that
        // handshake). A reconnecting front makes the old thread's recv
        // fail, so it dies with its socket; the fresh accept above
        // hands the new primary a fresh shard exactly as before.
        let index = spec.index;
        std::thread::Builder::new()
            .name(format!("ps-shard-{index}"))
            .spawn(move || {
                // The dispatched first request belongs to this primary:
                // execute it before entering the serve loop (it is
                // request 1 of the connection's tally).
                let reply = service.handle(first);
                if conn.send(WireMsg::Reply(reply)).is_err() {
                    eprintln!(
                        "shard {index}: connection from {peer} ended after 1 request; \
                         awaiting reconnect"
                    );
                    return;
                }
                let _ = conn.stream.set_read_timeout(None);
                let (handled, exit) = serve_counting(service, Box::new(conn));
                eprintln!(
                    "shard {index}: connection from {peer} ended after {} requests ({exit}); \
                     awaiting reconnect",
                    handled + 1
                );
            })
            .expect("spawning shard primary thread");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingConfig;
    use crate::optim::Sgd;
    use crate::transport::codec::{ShardReply, ShardRequest};
    use crate::transport::endpoint::rpc;

    fn spec() -> ShardSpawnSpec {
        ShardSpawnSpec {
            index: 0,
            ranges: vec![(0, 4)],
            emb_cfg: EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 2 },
            opt_dense: Box::new(Sgd { lr: 1.0 }),
            opt_emb: Box::new(Sgd { lr: 1.0 }),
            addr: None,
            apply_threads: 1,
        }
    }

    #[test]
    fn connect_retry_gives_up_without_listener() {
        // A port from the dynamic range with nothing bound: bind-then-drop.
        let addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t0 = Instant::now();
        assert!(connect_retry(&addr, Duration::from_millis(120)).is_none());
        assert!(t0.elapsed() >= Duration::from_millis(100));
    }

    /// The accept loop hands every primary connection a fresh shard, so
    /// state written on one connection is gone on the next — the
    /// reconnect contract the supervisor's checkpoint install relies on.
    #[test]
    fn serve_shard_resets_state_per_connection() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let init = vec![HostTensor { shape: vec![4], data: vec![1.0, 2.0, 3.0, 4.0] }];
        std::thread::spawn(move || {
            let _ = serve_shard(listener, spec(), &init);
        });

        let mut conn = connect_retry(&addr, Duration::from_secs(5)).expect("first connect");
        match rpc(&mut conn, ShardRequest::SetDense { dense: vec![vec![9.0; 4]] }).unwrap() {
            ShardReply::Ok => {}
            other => panic!("{other:?}"),
        }
        match rpc(&mut conn, ShardRequest::ReadDense).unwrap() {
            ShardReply::Dense { dense } => assert_eq!(dense, vec![vec![9.0; 4]]),
            other => panic!("{other:?}"),
        }
        drop(conn); // sever: the server loops back to accept

        let mut conn = connect_retry(&addr, Duration::from_secs(5)).expect("reconnect");
        match rpc(&mut conn, ShardRequest::ReadDense).unwrap() {
            ShardReply::Dense { dense } => {
                assert_eq!(dense, vec![vec![1.0, 2.0, 3.0, 4.0]], "fresh shard per connection")
            }
            other => panic!("{other:?}"),
        }
    }

    /// A `ReadHello` connection attaches to the current primary's shard
    /// generation and answers reads on its own thread, while the
    /// primary connection stays open (and possibly busy) beside it.
    #[test]
    fn read_companion_serves_the_current_generation() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let init = vec![HostTensor { shape: vec![4], data: vec![1.0, 2.0, 3.0, 4.0] }];
        std::thread::spawn(move || {
            let _ = serve_shard(listener, spec(), &init);
        });

        let mut primary = connect_retry(&addr, Duration::from_secs(5)).expect("primary connect");
        match rpc(&mut primary, ShardRequest::SetDense { dense: vec![vec![9.0; 4]] }).unwrap() {
            ShardReply::Ok => {}
            other => panic!("{other:?}"),
        }

        let mut reader = connect_retry(&addr, Duration::from_secs(5)).expect("read connect");
        match rpc(&mut reader, ShardRequest::ReadHello { shard: 0 }).unwrap() {
            ShardReply::Ok => {}
            other => panic!("ReadHello rejected: {other:?}"),
        }
        // The companion reads the state the *primary* wrote: same shard.
        match rpc(&mut reader, ShardRequest::ReadDense).unwrap() {
            ShardReply::Dense { dense } => assert_eq!(dense, vec![vec![9.0; 4]]),
            other => panic!("{other:?}"),
        }
        // A mutating verb on the read companion closes it.
        assert!(rpc(&mut reader, ShardRequest::SetDense { dense: vec![vec![0.0; 4]] }).is_err());
        // The primary is unaffected.
        match rpc(&mut primary, ShardRequest::Ping).unwrap() {
            ShardReply::Ok => {}
            other => panic!("{other:?}"),
        }
    }
}
