//! Versioned binary codec for the PS wire protocol — and the canonical
//! definition site of the worker-plane vocabulary.
//!
//! [`GradPush`], [`PullReply`] and [`WorkItem`] live *here*, not in
//! `ps`: the structs the worker runtime produces and consumes are the
//! exact frame structs the transport ships (the `ps` module re-exports
//! them for the historical import path). There is no separate
//! "in-memory" gradient or pull type anywhere — in-process, socket and
//! remote deployments run one code path that differs only in the
//! [`Conn`](super::Conn) implementation carrying these frames.
//!
//! Every message crossing a shard endpoint — the worker-plane vocabulary
//! above and the shard-plane RPC ([`ShardRequest`]/[`ShardReply`]) —
//! encodes to a length-prefixed frame:
//!
//! ```text
//! len: u32 LE  |  version: u8  |  trace_id: u64 LE  |  tag: u8  |  payload
//! ```
//!
//! `trace_id` (wire version 2) is the sending thread's current trace id
//! ([`crate::obs::trace`], 0 = untraced): [`encode`] stamps it,
//! [`decode`] installs it on the receiving thread, so one gradient push
//! can be followed worker → front → shard → apply across processes.
//!
//! The payload is flat little-endian primitives (`f32` travels as its raw
//! IEEE-754 bits, so NaN payloads and infinities round-trip exactly —
//! required for the transport-invariance guarantee). There is no serde in
//! the offline build environment; like `util/json`, this is a small
//! self-contained implementation, hand-rolled against the message structs.
//!
//! Robustness rules (pinned by `tests/transport_codec.rs`):
//!
//! * a frame with the wrong version byte is rejected ([`CodecError::BadVersion`]),
//! * a truncated frame or payload is rejected ([`CodecError::Truncated`]),
//!   never panicked on, and no allocation is sized from untrusted lengths
//!   beyond the bytes actually present,
//! * trailing bytes after a well-formed payload are rejected
//!   ([`CodecError::Malformed`]) — a frame is exactly one message.

use std::io::{Read, Write};

use crate::config::{ModeKind, OptimKind};
use crate::coordinator::WorkerId;
use crate::embedding::RowMeta;
use crate::runtime::HostTensor;
use crate::shard::ShardStats;

/// A claim on one batch of the data list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkItem {
    pub token: u64,
    /// Parameter version (global step) at pull time.
    pub version: u64,
    pub day: usize,
    pub batch_index: usize,
}

/// What a pull returns: work, a gate, or exhaustion.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PullReply {
    Work(WorkItem),
    /// Blocked by the mode's gate; wait for the next apply.
    Wait,
    /// Data list exhausted for the current day.
    EndOfData,
}

/// A gradient push from a worker (Algorithm 1 L18).
#[derive(Clone, Debug)]
pub struct GradPush {
    pub worker: WorkerId,
    pub token: u64,
    /// Dense gradients (dw1, db1, dw2, db2, dw3, db3), summed over the
    /// local batch and divided by local batch size (mean-loss grads).
    pub dense: Vec<HostTensor>,
    /// Per-ID embedding gradients, summed within the local batch.
    pub emb: Vec<(u64, Vec<f32>)>,
    pub n_samples: usize,
    pub loss: f32,
}

/// Bump on any incompatible layout change.
/// History: 1 = original layout; 2 = a `trace_id: u64` header field
/// between the version byte and the tag (mixed-version peers reject
/// each other loudly with [`CodecError::BadVersion`]).
pub const WIRE_VERSION: u8 = 2;

/// Upper bound on a frame body (defense against corrupt length prefixes).
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

/// Aggregated per-key embedding gradient: (key, gradient sum, workers).
pub type EmbGradEntry = (u64, Vec<f32>, u32);

/// One materialized embedding row: (key, vector, optimizer state, meta).
pub type RowRecord = (u64, Vec<f32>, Vec<f32>, RowMeta);

/// Decode-side failure modes.
#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    /// Clean end-of-stream at a frame boundary (peer closed).
    Closed,
    /// Stream or buffer ended inside a frame.
    Truncated,
    BadVersion(u8),
    BadTag(u8),
    /// Length prefix exceeds [`MAX_FRAME_BYTES`].
    Oversize(u32),
    /// Structurally invalid payload (bad enum tag, shape mismatch, junk).
    Malformed(&'static str),
    Io(std::io::ErrorKind),
}

impl std::fmt::Display for CodecError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodecError::Closed => write!(f, "connection closed"),
            CodecError::Truncated => write!(f, "truncated frame"),
            CodecError::BadVersion(v) => write!(f, "wire version {v} (want {WIRE_VERSION})"),
            CodecError::BadTag(t) => write!(f, "unknown message tag {t}"),
            CodecError::Oversize(n) => write!(f, "frame of {n} bytes exceeds cap"),
            CodecError::Malformed(what) => write!(f, "malformed payload: {what}"),
            CodecError::Io(kind) => write!(f, "io error: {kind:?}"),
        }
    }
}

impl std::error::Error for CodecError {}

/// Everything that can cross a PS wire, one flat tag space.
#[derive(Clone, Debug)]
pub enum WireMsg {
    /// Worker → PS gradient push (Algorithm 1 L18).
    Push(GradPush),
    /// PS → worker pull response (token / wait / end-of-data).
    Pull(PullReply),
    /// Front → shard RPC request.
    Req(ShardRequest),
    /// Shard → front RPC reply.
    Reply(ShardReply),
    /// Remote worker → front session request.
    WorkerReq(WorkerRequest),
    /// Front → remote worker session reply.
    WorkerRep(WorkerReply),
}

/// The worker-plane session RPC: everything a `gba-train worker`
/// process sends the front's worker service
/// ([`WorkerFront`](super::WorkerFront)). The in-day verbs mirror
/// [`PsClient`](crate::worker::PsClient) one-to-one — `Pull`, `Push`,
/// `Gather`, `DenseParams`, `Reset` — wrapped by the session frames:
/// a connect-time `Hello` identity/shape handshake, `BeginDay` (blocks
/// server-side until the front starts a day), and `EndOfDay` returning
/// the worker's [`WorkerStats`](crate::worker::WorkerStats) fields.
#[derive(Clone, Debug)]
pub enum WorkerRequest {
    /// Identity/shape handshake: the worker declares who it is and the
    /// config-derived shape it will train with. The front asserts
    /// agreement so a worker launched with the wrong config, mode or id
    /// fails loudly at connect instead of silently diverging (learning
    /// rates and data details beyond `samples_per_day` stay the
    /// operator's contract — see docs/DEPLOY.md).
    Hello {
        worker: u64,
        local_batch: u64,
        fields: u32,
        emb_dim: u32,
        seed: u64,
        samples_per_day: u64,
    },
    /// Ask for the next training day; the reply arrives when the front
    /// starts one (or the connection closes — the session is over).
    BeginDay,
    /// Algorithm 1 pull; the front answers with blocking semantics, so
    /// `PullReply::Wait` never crosses the wire.
    Pull { worker: u64 },
    /// Gradient push (the same frame struct the shard plane ships).
    Push(GradPush),
    /// Embedding gather for one batch's flattened key block.
    Gather { keys: Vec<u64>, batch: u64, fields: u64 },
    /// Dense parameter snapshot.
    DenseParams,
    /// Worker-side failure: forget the in-flight claim (Appendix B).
    Reset { worker: u64 },
    /// Day finished: stats back to the front, field-for-field
    /// [`WorkerStats`](crate::worker::WorkerStats).
    EndOfDay { batches: u64, samples: u64, failures: u64, busy_sec: f64 },
    /// The mode re-handshake, worker half: after the front answers a
    /// `BeginDay` with [`WorkerReply::Switch`], the worker re-derives
    /// its shape from its own config file at the announced mode and
    /// declares it here — the same keys as `Hello`, plus the epoch id
    /// and the new mode's worker count, so both ends prove they agree
    /// on *which* switch they are performing and what it trains. The
    /// front answers [`WorkerReply::Epoch`]; any disagreement fails the
    /// run loudly (a worker training the old shape would silently
    /// corrupt the new epoch).
    SwitchMode {
        epoch: u64,
        worker: u64,
        workers: u64,
        local_batch: u64,
        fields: u32,
        emb_dim: u32,
        seed: u64,
        samples_per_day: u64,
    },
}

/// Replies to [`WorkerRequest`], one per request shape.
#[derive(Clone, Debug)]
pub enum WorkerReply {
    /// Generic ack (`Hello` / `Push` / `Reset` / `EndOfDay`).
    Ok,
    /// `BeginDay`: a day started.
    Day { day: u64 },
    /// `BeginDay`: the session ended cleanly — the worker exits 0. An
    /// abrupt connection loss is *not* a clean end (the front crashed);
    /// this farewell frame is what distinguishes the two.
    SessionOver,
    /// `Pull` payload.
    Pull(PullReply),
    /// `Gather` payload: the `[batch, fields, dim]` tensor.
    Emb(HostTensor),
    /// `DenseParams` payload.
    Dense(Vec<HostTensor>),
    /// `BeginDay`: the session advanced its mode epoch instead of
    /// starting a day. The worker must re-derive its shape for `mode`
    /// and answer with [`WorkerRequest::SwitchMode`] before any further
    /// day is served.
    Switch { epoch: u64, mode: ModeKind },
    /// `SwitchMode` accepted: the worker is admitted to `epoch` and
    /// loops back to `BeginDay`.
    Epoch { epoch: u64 },
}

/// The shard-plane RPC: every way the front touches a data-plane shard.
/// Mutating requests (`Apply`, `SetDense`, `SetSlots`, `InsertRow`) are
/// journaled by the [`ShardSupervisor`](super::ShardSupervisor) for
/// replay after a lost shard; reads are not.
#[derive(Clone, Debug)]
pub enum ShardRequest {
    /// Liveness probe (control message).
    Ping,
    /// Apply this shard's slice of an admitted flush: pre-sliced dense
    /// aggregate (one `Vec<f32>` per tensor, already cut to the shard's
    /// range) plus its group of per-key embedding gradients.
    Apply { opt_step: u64, dense: Vec<Vec<f32>>, emb: Vec<EmbGradEntry> },
    /// Read the shard's dense parameter slices.
    ReadDense,
    /// Read the shard's planar optimizer-slot slices.
    ReadSlots,
    /// Replace dense parameter slices (resets optimizer slots).
    SetDense { dense: Vec<Vec<f32>> },
    /// Replace planar optimizer-slot slices.
    SetSlots { slots: Vec<Vec<f32>> },
    /// Materialize-and-read embedding rows for a key block.
    Gather { keys: Vec<u64> },
    /// Per-row metadata lookup.
    GetMeta { key: u64 },
    /// Bulk-insert one row (checkpoint restore).
    InsertRow { key: u64, vec: Vec<f32>, state: Vec<f32>, meta: RowMeta },
    /// Dump every materialized row (shard-local checkpoint stream).
    DumpRows,
    /// Load/contention counters snapshot.
    Stats,
    /// Insert a whole block of rows in one frame — the checkpoint-restore
    /// and remote-state-install path (one RPC per shard instead of one
    /// per row).
    InsertRows { rows: Vec<RowRecord> },
    /// Connect-time identity/shape handshake (remote transport): the
    /// front declares which shard it thinks it dialed and the optimizer
    /// shape it will aggregate for. The server asserts agreement — a
    /// swapped `shard_addrs` entry or a `--mode` mismatch that changes
    /// the optimizer pair (async vs. the rest, Table 5.1) dies loudly at
    /// connect instead of silently diverging. `cfg_digest` folds the
    /// optimizer kinds *and* learning rates (`optim::config_digest`) so a
    /// same-shape different-lr shard server also fails at connect rather
    /// than training two configs against one model.
    Hello { shard: u64, dense_slots: u32, emb_slots: u32, emb_dim: u32, cfg_digest: u64 },
    /// In-place mode switch, shard half: install a fresh optimizer pair
    /// of `opt` at `lr` for every subsequent `Apply`. `reset_slots`
    /// zeroes the dense slot buffers and every row's optimizer state
    /// (always forced when the new optimizer's slot shape differs —
    /// stale accumulators are meaningless across optimizer kinds);
    /// a same-shape swap with `reset_slots = false` preserves them, the
    /// true tuning-free inherit. Mutating: journaled and replayed like
    /// any other state change.
    SwapPolicy { opt: OptimKind, lr: f64, reset_slots: bool },
    /// Scrape the serving process's obs registry (read-only, not
    /// journaled): the coordinator folds every shard's snapshot into
    /// the run-wide telemetry block.
    ObsScrape,
    /// Opens a *read-only* companion connection to an already-serving
    /// shard (remote transport): the front declares which shard's read
    /// plane it wants to attach to, the server acks and then serves
    /// only non-mutating verbs on this connection, against the same
    /// live shard state the primary connection mutates. This is what
    /// lets `Gather`/`ReadDense` overlap an in-flight `Apply` instead
    /// of queueing behind it on one socket.
    ReadHello { shard: u64 },
    /// Snapshot gather for the serving plane: like `Gather`, but the
    /// reply also names the shard's applied step and the whole read is
    /// taken under the shard's apply seqlock — the rows are guaranteed
    /// not to straddle an in-flight `Apply`. The serve front fans one
    /// of these out per involved shard and retries until every shard
    /// reports the same step, so a served batch never observes a
    /// half-applied global batch.
    GatherAt { keys: Vec<u64> },
    /// Drain the shard's embedding-invalidation log: every key whose
    /// row changed in an apply with step > `since`. Read-only (the log
    /// is a serving-plane artifact, not shard state) — the serve front
    /// polls this to evict stale hot-cache entries.
    ReadInvalidations { since: u64 },
}

impl ShardRequest {
    /// Short stable label for per-RPC metrics (`{rpc="apply"}` etc.).
    pub fn kind_name(&self) -> &'static str {
        match self {
            ShardRequest::Ping => "ping",
            ShardRequest::Apply { .. } => "apply",
            ShardRequest::ReadDense => "read_dense",
            ShardRequest::ReadSlots => "read_slots",
            ShardRequest::SetDense { .. } => "set_dense",
            ShardRequest::SetSlots { .. } => "set_slots",
            ShardRequest::Gather { .. } => "gather",
            ShardRequest::GetMeta { .. } => "get_meta",
            ShardRequest::InsertRow { .. } => "insert_row",
            ShardRequest::DumpRows => "dump_rows",
            ShardRequest::Stats => "stats",
            ShardRequest::InsertRows { .. } => "insert_rows",
            ShardRequest::Hello { .. } => "hello",
            ShardRequest::SwapPolicy { .. } => "swap_policy",
            ShardRequest::ObsScrape => "obs_scrape",
            ShardRequest::ReadHello { .. } => "read_hello",
            ShardRequest::GatherAt { .. } => "gather_at",
            ShardRequest::ReadInvalidations { .. } => "read_invalidations",
        }
    }
}

impl WorkerRequest {
    /// Short stable label for per-RPC metrics (`{rpc="push"}` etc.).
    pub fn kind_name(&self) -> &'static str {
        match self {
            WorkerRequest::Hello { .. } => "hello",
            WorkerRequest::BeginDay => "begin_day",
            WorkerRequest::Pull { .. } => "pull",
            WorkerRequest::Push(_) => "push",
            WorkerRequest::Gather { .. } => "gather",
            WorkerRequest::DenseParams => "dense_params",
            WorkerRequest::Reset { .. } => "reset",
            WorkerRequest::EndOfDay { .. } => "end_of_day",
            WorkerRequest::SwitchMode { .. } => "switch_mode",
        }
    }
}

/// Replies, one per request shape.
#[derive(Clone, Debug)]
pub enum ShardReply {
    /// Generic ack (Ping / mutating requests).
    Ok,
    /// `ReadDense` / `ReadSlots` payload.
    Dense { dense: Vec<Vec<f32>> },
    /// `Gather` payload: `keys.len() * dim` floats, row-major.
    Rows { dim: u64, data: Vec<f32> },
    Meta { meta: Option<RowMeta> },
    /// `DumpRows` payload, sorted by key for stream stability.
    RowDump { rows: Vec<RowRecord> },
    Stats { stats: ShardStats, emb_mem_bytes: u64 },
    /// `ObsScrape` payload: the registry's flat numeric snapshot.
    Obs { entries: Vec<(String, f64)> },
    /// `GatherAt` payload: `Rows` plus the shard's applied step the
    /// rows were read at (seqlock-consistent — see `GatherAt`).
    RowsAt { step: u64, dim: u64, data: Vec<f32> },
    /// `ReadInvalidations` payload. `upto` is the shard's latest
    /// applied step; `keys` are the rows invalidated by applies with
    /// step > the request's `since`. `full` means the bounded log has
    /// dropped entries past `since` — the caller must treat *every*
    /// cached row as invalid.
    Invalidations { upto: u64, full: bool, keys: Vec<u64> },
}

// ---- encode -----------------------------------------------------------------

fn put_u8(b: &mut Vec<u8>, x: u8) {
    b.push(x);
}

fn put_u32(b: &mut Vec<u8>, x: u32) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, x: u64) {
    b.extend_from_slice(&x.to_le_bytes());
}

fn put_f32(b: &mut Vec<u8>, x: f32) {
    put_u32(b, x.to_bits());
}

fn put_f64(b: &mut Vec<u8>, x: f64) {
    put_u64(b, x.to_bits());
}

fn put_str(b: &mut Vec<u8>, s: &str) {
    put_u32(b, s.len() as u32);
    b.extend_from_slice(s.as_bytes());
}

/// Raw f32 wire bytes, no count prefix — the body shared by [`put_f32s`]
/// and the scatter/gather rows-frame writer ([`write_rows_frame`]).
fn append_f32_bytes(b: &mut Vec<u8>, xs: &[f32]) {
    if cfg!(target_endian = "little") {
        // SAFETY: on a little-endian host an f32's in-memory bytes are
        // exactly its wire encoding (`to_le_bytes(to_bits(x))`), and any
        // `&[f32]` is readable as raw bytes — so the whole slice appends
        // with one bulk copy instead of the per-element staging loop.
        // This writes dense/gather reply payloads straight from the
        // source slices. Byte output is identical to the scalar loop.
        let bytes = unsafe {
            std::slice::from_raw_parts(xs.as_ptr() as *const u8, std::mem::size_of_val(xs))
        };
        b.extend_from_slice(bytes);
    } else {
        for &x in xs {
            put_f32(b, x);
        }
    }
}

fn put_f32s(b: &mut Vec<u8>, xs: &[f32]) {
    put_u32(b, xs.len() as u32);
    append_f32_bytes(b, xs);
}

fn put_f32_vecs(b: &mut Vec<u8>, xss: &[Vec<f32>]) {
    put_u32(b, xss.len() as u32);
    for xs in xss {
        put_f32s(b, xs);
    }
}

fn put_meta(b: &mut Vec<u8>, m: &RowMeta) {
    put_u64(b, m.last_update_step);
    put_u32(b, m.update_count);
}

fn put_tensor(b: &mut Vec<u8>, t: &HostTensor) {
    put_u32(b, t.shape.len() as u32);
    for &d in &t.shape {
        put_u64(b, d as u64);
    }
    put_f32s(b, &t.data);
}

fn put_row_records(b: &mut Vec<u8>, rows: &[RowRecord]) {
    put_u32(b, rows.len() as u32);
    for (key, vec, state, meta) in rows {
        put_u64(b, *key);
        put_f32s(b, vec);
        put_f32s(b, state);
        put_meta(b, meta);
    }
}

fn put_grad_push(b: &mut Vec<u8>, g: &GradPush) {
    put_u64(b, g.worker as u64);
    put_u64(b, g.token);
    put_u32(b, g.dense.len() as u32);
    for t in &g.dense {
        put_tensor(b, t);
    }
    put_u32(b, g.emb.len() as u32);
    for (key, gsum) in &g.emb {
        put_u64(b, *key);
        put_f32s(b, gsum);
    }
    put_u64(b, g.n_samples as u64);
    put_f32(b, g.loss);
}

fn put_pull_reply(b: &mut Vec<u8>, p: &PullReply) {
    match p {
        PullReply::Work(it) => {
            put_u8(b, 0);
            put_u64(b, it.token);
            put_u64(b, it.version);
            put_u64(b, it.day as u64);
            put_u64(b, it.batch_index as u64);
        }
        PullReply::Wait => put_u8(b, 1),
        PullReply::EndOfData => put_u8(b, 2),
    }
}

/// Encode one message body (version + trace id + tag + payload, no
/// length prefix). The trace id is the encoding thread's current one
/// ([`crate::obs::trace::current`], 0 when untraced).
pub fn encode(msg: &WireMsg) -> Vec<u8> {
    let mut b = Vec::with_capacity(64);
    encode_into(&mut b, msg);
    b
}

/// [`encode`], appending to an existing buffer. The event-driven
/// transport fronts encode straight into a connection's output buffer
/// with this, skipping the intermediate body allocation and copy.
pub fn encode_into(b: &mut Vec<u8>, msg: &WireMsg) {
    put_u8(b, WIRE_VERSION);
    put_u64(b, crate::obs::trace::current());
    match msg {
        WireMsg::Push(g) => {
            put_u8(b, 1);
            put_grad_push(b, g);
        }
        WireMsg::Pull(p) => {
            put_u8(b, 2);
            put_pull_reply(b, p);
        }
        WireMsg::Req(r) => {
            put_u8(b, 3);
            encode_req(b, r);
        }
        WireMsg::Reply(r) => {
            put_u8(b, 4);
            encode_reply(b, r);
        }
        WireMsg::WorkerReq(r) => {
            put_u8(b, 5);
            encode_worker_req(b, r);
        }
        WireMsg::WorkerRep(r) => {
            put_u8(b, 6);
            encode_worker_reply(b, r);
        }
    }
}

fn encode_worker_req(b: &mut Vec<u8>, r: &WorkerRequest) {
    match r {
        WorkerRequest::Hello { worker, local_batch, fields, emb_dim, seed, samples_per_day } => {
            put_u8(b, 0);
            put_u64(b, *worker);
            put_u64(b, *local_batch);
            put_u32(b, *fields);
            put_u32(b, *emb_dim);
            put_u64(b, *seed);
            put_u64(b, *samples_per_day);
        }
        WorkerRequest::BeginDay => put_u8(b, 1),
        WorkerRequest::Pull { worker } => {
            put_u8(b, 2);
            put_u64(b, *worker);
        }
        WorkerRequest::Push(g) => {
            put_u8(b, 3);
            put_grad_push(b, g);
        }
        WorkerRequest::Gather { keys, batch, fields } => {
            put_u8(b, 4);
            put_u32(b, keys.len() as u32);
            for &k in keys {
                put_u64(b, k);
            }
            put_u64(b, *batch);
            put_u64(b, *fields);
        }
        WorkerRequest::DenseParams => put_u8(b, 5),
        WorkerRequest::Reset { worker } => {
            put_u8(b, 6);
            put_u64(b, *worker);
        }
        WorkerRequest::EndOfDay { batches, samples, failures, busy_sec } => {
            put_u8(b, 7);
            put_u64(b, *batches);
            put_u64(b, *samples);
            put_u64(b, *failures);
            put_f64(b, *busy_sec);
        }
        WorkerRequest::SwitchMode {
            epoch,
            worker,
            workers,
            local_batch,
            fields,
            emb_dim,
            seed,
            samples_per_day,
        } => {
            put_u8(b, 8);
            put_u64(b, *epoch);
            put_u64(b, *worker);
            put_u64(b, *workers);
            put_u64(b, *local_batch);
            put_u32(b, *fields);
            put_u32(b, *emb_dim);
            put_u64(b, *seed);
            put_u64(b, *samples_per_day);
        }
    }
}

fn encode_worker_reply(b: &mut Vec<u8>, r: &WorkerReply) {
    match r {
        WorkerReply::Ok => put_u8(b, 0),
        WorkerReply::Day { day } => {
            put_u8(b, 1);
            put_u64(b, *day);
        }
        WorkerReply::Pull(p) => {
            put_u8(b, 2);
            put_pull_reply(b, p);
        }
        WorkerReply::Emb(t) => {
            put_u8(b, 3);
            put_tensor(b, t);
        }
        WorkerReply::Dense(ts) => {
            put_u8(b, 4);
            put_u32(b, ts.len() as u32);
            for t in ts {
                put_tensor(b, t);
            }
        }
        WorkerReply::SessionOver => put_u8(b, 5),
        WorkerReply::Switch { epoch, mode } => {
            put_u8(b, 6);
            put_u64(b, *epoch);
            put_u8(b, mode.wire_id());
        }
        WorkerReply::Epoch { epoch } => {
            put_u8(b, 7);
            put_u64(b, *epoch);
        }
    }
}

fn encode_req(b: &mut Vec<u8>, r: &ShardRequest) {
    match r {
        ShardRequest::Ping => put_u8(b, 0),
        ShardRequest::Apply { opt_step, dense, emb } => {
            put_u8(b, 1);
            put_u64(b, *opt_step);
            put_f32_vecs(b, dense);
            put_u32(b, emb.len() as u32);
            for (key, gsum, workers) in emb {
                put_u64(b, *key);
                put_f32s(b, gsum);
                put_u32(b, *workers);
            }
        }
        ShardRequest::ReadDense => put_u8(b, 2),
        ShardRequest::ReadSlots => put_u8(b, 3),
        ShardRequest::SetDense { dense } => {
            put_u8(b, 4);
            put_f32_vecs(b, dense);
        }
        ShardRequest::SetSlots { slots } => {
            put_u8(b, 5);
            put_f32_vecs(b, slots);
        }
        ShardRequest::Gather { keys } => {
            put_u8(b, 6);
            put_u32(b, keys.len() as u32);
            for &k in keys {
                put_u64(b, k);
            }
        }
        ShardRequest::GetMeta { key } => {
            put_u8(b, 7);
            put_u64(b, *key);
        }
        ShardRequest::InsertRow { key, vec, state, meta } => {
            put_u8(b, 8);
            put_u64(b, *key);
            put_f32s(b, vec);
            put_f32s(b, state);
            put_meta(b, meta);
        }
        ShardRequest::DumpRows => put_u8(b, 9),
        ShardRequest::Stats => put_u8(b, 10),
        ShardRequest::InsertRows { rows } => {
            put_u8(b, 11);
            put_row_records(b, rows);
        }
        ShardRequest::Hello { shard, dense_slots, emb_slots, emb_dim, cfg_digest } => {
            put_u8(b, 12);
            put_u64(b, *shard);
            put_u32(b, *dense_slots);
            put_u32(b, *emb_slots);
            put_u32(b, *emb_dim);
            put_u64(b, *cfg_digest);
        }
        ShardRequest::SwapPolicy { opt, lr, reset_slots } => {
            put_u8(b, 13);
            put_u8(b, opt.wire_id());
            put_f64(b, *lr);
            put_u8(b, *reset_slots as u8);
        }
        ShardRequest::ObsScrape => put_u8(b, 14),
        ShardRequest::ReadHello { shard } => {
            put_u8(b, 15);
            put_u64(b, *shard);
        }
        ShardRequest::GatherAt { keys } => {
            put_u8(b, 16);
            put_u32(b, keys.len() as u32);
            for &k in keys {
                put_u64(b, k);
            }
        }
        ShardRequest::ReadInvalidations { since } => {
            put_u8(b, 17);
            put_u64(b, *since);
        }
    }
}

fn encode_reply(b: &mut Vec<u8>, r: &ShardReply) {
    match r {
        ShardReply::Ok => put_u8(b, 0),
        ShardReply::Dense { dense } => {
            put_u8(b, 1);
            put_f32_vecs(b, dense);
        }
        ShardReply::Rows { dim, data } => {
            put_u8(b, 2);
            put_u64(b, *dim);
            put_f32s(b, data);
        }
        ShardReply::Meta { meta } => {
            put_u8(b, 3);
            match meta {
                None => put_u8(b, 0),
                Some(m) => {
                    put_u8(b, 1);
                    put_meta(b, m);
                }
            }
        }
        ShardReply::RowDump { rows } => {
            put_u8(b, 4);
            put_row_records(b, rows);
        }
        ShardReply::Stats { stats, emb_mem_bytes } => {
            put_u8(b, 5);
            put_u64(b, stats.shard as u64);
            put_u64(b, stats.applies);
            put_u64(b, stats.apply_ns);
            put_u64(b, stats.emb_keys_applied);
            put_u64(b, stats.emb_rows as u64);
            put_u64(b, stats.dense_elems as u64);
            put_u64(b, *emb_mem_bytes);
        }
        ShardReply::Obs { entries } => {
            put_u8(b, 6);
            put_u32(b, entries.len() as u32);
            for (name, value) in entries {
                put_str(b, name);
                put_f64(b, *value);
            }
        }
        ShardReply::RowsAt { step, dim, data } => {
            put_u8(b, 7);
            put_u64(b, *step);
            put_u64(b, *dim);
            put_f32s(b, data);
        }
        ShardReply::Invalidations { upto, full, keys } => {
            put_u8(b, 8);
            put_u64(b, *upto);
            put_u8(b, *full as u8);
            put_u32(b, keys.len() as u32);
            for &k in keys {
                put_u64(b, k);
            }
        }
    }
}

// ---- decode -----------------------------------------------------------------

/// Bulk-reinterpret a validated `4 * n`-byte slice as `n` f32s: one
/// sized allocation plus one memcpy on little-endian hosts, where the
/// wire layout (LE f32 bit patterns) *is* the in-memory layout. Output
/// is bit-identical to the per-element `from_le_bytes` loop, which
/// remains the path on big-endian hosts.
fn bytes_to_f32s(raw: &[u8]) -> Vec<f32> {
    let n = raw.len() / 4;
    debug_assert_eq!(raw.len(), n * 4);
    if cfg!(target_endian = "little") {
        let mut out = vec![0.0f32; n];
        // SAFETY: `out` owns exactly `n * 4` writable bytes, `raw` holds
        // exactly `n * 4` readable bytes, the two can't overlap (`out`
        // is a fresh allocation), and every 4-byte pattern is a valid
        // f32 — NaN payloads included, which the fuzz suite exercises.
        unsafe {
            std::ptr::copy_nonoverlapping(raw.as_ptr(), out.as_mut_ptr() as *mut u8, n * 4);
        }
        out
    } else {
        raw.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
    }
}

/// Bounds-checked cursor over one frame body. Every length read is
/// validated against the bytes actually remaining before any allocation.
struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.b.len() - self.i < n {
            return Err(CodecError::Truncated);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize64(&mut self) -> Result<usize, CodecError> {
        usize::try_from(self.u64()?).map_err(|_| CodecError::Malformed("usize overflow"))
    }

    fn str(&mut self) -> Result<String, CodecError> {
        let n = self.u32()? as usize;
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| CodecError::Malformed("non-utf8 string"))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, CodecError> {
        let n = self.u32()? as usize;
        let raw = self.take(n * 4)?;
        Ok(bytes_to_f32s(raw))
    }

    /// A `u32`-counted vector of `u64`s, length-checked before any
    /// allocation (shared by both `Gather` request shapes).
    fn u64s(&mut self) -> Result<Vec<u64>, CodecError> {
        let n = self.u32()? as usize;
        if self.b.len() - self.i < n * 8 {
            return Err(CodecError::Truncated);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.u64()?);
        }
        Ok(out)
    }

    fn f32_vecs(&mut self) -> Result<Vec<Vec<f32>>, CodecError> {
        let n = self.u32()? as usize;
        // Each vector costs at least its own 4-byte count on the wire;
        // bound the count against the remaining bytes before allocating.
        if self.b.len() - self.i < n * 4 {
            return Err(CodecError::Truncated);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32s()?);
        }
        Ok(out)
    }

    fn meta(&mut self) -> Result<RowMeta, CodecError> {
        Ok(RowMeta { last_update_step: self.u64()?, update_count: self.u32()? })
    }

    fn row_records(&mut self) -> Result<Vec<RowRecord>, CodecError> {
        let n = self.u32()? as usize;
        let mut rows = Vec::new();
        for _ in 0..n {
            let key = self.u64()?;
            let vec = self.f32s()?;
            let state = self.f32s()?;
            let meta = self.meta()?;
            rows.push((key, vec, state, meta));
        }
        Ok(rows)
    }

    fn tensor(&mut self) -> Result<HostTensor, CodecError> {
        let rank = self.u32()? as usize;
        // A dimension costs 8 bytes on the wire; bound before allocating.
        if self.b.len() - self.i < rank * 8 {
            return Err(CodecError::Truncated);
        }
        let mut shape = Vec::with_capacity(rank);
        for _ in 0..rank {
            shape.push(self.usize64()?);
        }
        let data = self.f32s()?;
        let numel = shape
            .iter()
            .try_fold(1usize, |a, &d| a.checked_mul(d))
            .ok_or(CodecError::Malformed("tensor shape overflow"))?;
        if numel != data.len() {
            return Err(CodecError::Malformed("tensor shape/data mismatch"));
        }
        Ok(HostTensor { shape, data })
    }

    fn grad_push(&mut self) -> Result<GradPush, CodecError> {
        let worker = self.usize64()?;
        let token = self.u64()?;
        let n_dense = self.u32()? as usize;
        let mut dense = Vec::new();
        for _ in 0..n_dense {
            dense.push(self.tensor()?);
        }
        let n_emb = self.u32()? as usize;
        let mut emb = Vec::new();
        for _ in 0..n_emb {
            let key = self.u64()?;
            emb.push((key, self.f32s()?));
        }
        let n_samples = self.usize64()?;
        let loss = self.f32()?;
        Ok(GradPush { worker, token, dense, emb, n_samples, loss })
    }

    fn pull_reply(&mut self) -> Result<PullReply, CodecError> {
        Ok(match self.u8()? {
            0 => PullReply::Work(WorkItem {
                token: self.u64()?,
                version: self.u64()?,
                day: self.usize64()?,
                batch_index: self.usize64()?,
            }),
            1 => PullReply::Wait,
            2 => PullReply::EndOfData,
            _ => return Err(CodecError::Malformed("pull reply tag")),
        })
    }

    fn done(&self) -> Result<(), CodecError> {
        if self.i == self.b.len() {
            Ok(())
        } else {
            Err(CodecError::Malformed("trailing bytes"))
        }
    }
}

/// Decode one frame body produced by [`encode`]. The frame's trace id
/// is installed as the decoding thread's current one, so span emission
/// while handling the message correlates with the sender's.
pub fn decode(body: &[u8]) -> Result<WireMsg, CodecError> {
    let mut rd = Rd { b: body, i: 0 };
    let version = rd.u8()?;
    if version != WIRE_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let trace_id = rd.u64()?;
    crate::obs::trace::set_current(trace_id);
    let tag = rd.u8()?;
    let msg = match tag {
        1 => WireMsg::Push(rd.grad_push()?),
        2 => WireMsg::Pull(rd.pull_reply()?),
        3 => WireMsg::Req(decode_req(&mut rd)?),
        4 => WireMsg::Reply(decode_reply(&mut rd)?),
        5 => WireMsg::WorkerReq(decode_worker_req(&mut rd)?),
        6 => WireMsg::WorkerRep(decode_worker_reply(&mut rd)?),
        other => return Err(CodecError::BadTag(other)),
    };
    rd.done()?;
    Ok(msg)
}

fn decode_worker_req(rd: &mut Rd) -> Result<WorkerRequest, CodecError> {
    Ok(match rd.u8()? {
        0 => WorkerRequest::Hello {
            worker: rd.u64()?,
            local_batch: rd.u64()?,
            fields: rd.u32()?,
            emb_dim: rd.u32()?,
            seed: rd.u64()?,
            samples_per_day: rd.u64()?,
        },
        1 => WorkerRequest::BeginDay,
        2 => WorkerRequest::Pull { worker: rd.u64()? },
        3 => WorkerRequest::Push(rd.grad_push()?),
        4 => WorkerRequest::Gather {
            keys: rd.u64s()?,
            batch: rd.u64()?,
            fields: rd.u64()?,
        },
        5 => WorkerRequest::DenseParams,
        6 => WorkerRequest::Reset { worker: rd.u64()? },
        7 => WorkerRequest::EndOfDay {
            batches: rd.u64()?,
            samples: rd.u64()?,
            failures: rd.u64()?,
            busy_sec: rd.f64()?,
        },
        8 => WorkerRequest::SwitchMode {
            epoch: rd.u64()?,
            worker: rd.u64()?,
            workers: rd.u64()?,
            local_batch: rd.u64()?,
            fields: rd.u32()?,
            emb_dim: rd.u32()?,
            seed: rd.u64()?,
            samples_per_day: rd.u64()?,
        },
        _ => return Err(CodecError::Malformed("worker request tag")),
    })
}

fn decode_worker_reply(rd: &mut Rd) -> Result<WorkerReply, CodecError> {
    Ok(match rd.u8()? {
        0 => WorkerReply::Ok,
        1 => WorkerReply::Day { day: rd.u64()? },
        2 => WorkerReply::Pull(rd.pull_reply()?),
        3 => WorkerReply::Emb(rd.tensor()?),
        4 => {
            let n = rd.u32()? as usize;
            let mut ts = Vec::new();
            for _ in 0..n {
                ts.push(rd.tensor()?);
            }
            WorkerReply::Dense(ts)
        }
        5 => WorkerReply::SessionOver,
        6 => WorkerReply::Switch {
            epoch: rd.u64()?,
            mode: ModeKind::from_wire(rd.u8()?)
                .map_err(|_| CodecError::Malformed("mode wire id"))?,
        },
        7 => WorkerReply::Epoch { epoch: rd.u64()? },
        _ => return Err(CodecError::Malformed("worker reply tag")),
    })
}

fn decode_req(rd: &mut Rd) -> Result<ShardRequest, CodecError> {
    Ok(match rd.u8()? {
        0 => ShardRequest::Ping,
        1 => {
            let opt_step = rd.u64()?;
            let dense = rd.f32_vecs()?;
            let n = rd.u32()? as usize;
            let mut emb = Vec::new();
            for _ in 0..n {
                let key = rd.u64()?;
                let gsum = rd.f32s()?;
                let workers = rd.u32()?;
                emb.push((key, gsum, workers));
            }
            ShardRequest::Apply { opt_step, dense, emb }
        }
        2 => ShardRequest::ReadDense,
        3 => ShardRequest::ReadSlots,
        4 => ShardRequest::SetDense { dense: rd.f32_vecs()? },
        5 => ShardRequest::SetSlots { slots: rd.f32_vecs()? },
        6 => ShardRequest::Gather { keys: rd.u64s()? },
        7 => ShardRequest::GetMeta { key: rd.u64()? },
        8 => {
            let key = rd.u64()?;
            let vec = rd.f32s()?;
            let state = rd.f32s()?;
            let meta = rd.meta()?;
            ShardRequest::InsertRow { key, vec, state, meta }
        }
        9 => ShardRequest::DumpRows,
        10 => ShardRequest::Stats,
        11 => ShardRequest::InsertRows { rows: rd.row_records()? },
        12 => ShardRequest::Hello {
            shard: rd.u64()?,
            dense_slots: rd.u32()?,
            emb_slots: rd.u32()?,
            emb_dim: rd.u32()?,
            cfg_digest: rd.u64()?,
        },
        13 => ShardRequest::SwapPolicy {
            opt: OptimKind::from_wire(rd.u8()?)
                .map_err(|_| CodecError::Malformed("optimizer wire id"))?,
            lr: rd.f64()?,
            reset_slots: match rd.u8()? {
                0 => false,
                1 => true,
                _ => return Err(CodecError::Malformed("reset_slots flag")),
            },
        },
        14 => ShardRequest::ObsScrape,
        15 => ShardRequest::ReadHello { shard: rd.u64()? },
        16 => ShardRequest::GatherAt { keys: rd.u64s()? },
        17 => ShardRequest::ReadInvalidations { since: rd.u64()? },
        _ => return Err(CodecError::Malformed("shard request tag")),
    })
}

fn decode_reply(rd: &mut Rd) -> Result<ShardReply, CodecError> {
    Ok(match rd.u8()? {
        0 => ShardReply::Ok,
        1 => ShardReply::Dense { dense: rd.f32_vecs()? },
        2 => {
            let dim = rd.u64()?;
            ShardReply::Rows { dim, data: rd.f32s()? }
        }
        3 => ShardReply::Meta {
            meta: match rd.u8()? {
                0 => None,
                1 => Some(rd.meta()?),
                _ => return Err(CodecError::Malformed("meta option tag")),
            },
        },
        4 => ShardReply::RowDump { rows: rd.row_records()? },
        5 => {
            let stats = ShardStats {
                shard: rd.usize64()?,
                applies: rd.u64()?,
                apply_ns: rd.u64()?,
                emb_keys_applied: rd.u64()?,
                emb_rows: rd.usize64()?,
                dense_elems: rd.usize64()?,
            };
            let emb_mem_bytes = rd.u64()?;
            ShardReply::Stats { stats, emb_mem_bytes }
        }
        6 => {
            let n = rd.u32()? as usize;
            let mut entries = Vec::new();
            for _ in 0..n {
                let name = rd.str()?;
                let value = rd.f64()?;
                entries.push((name, value));
            }
            ShardReply::Obs { entries }
        }
        7 => {
            let step = rd.u64()?;
            let dim = rd.u64()?;
            ShardReply::RowsAt { step, dim, data: rd.f32s()? }
        }
        8 => ShardReply::Invalidations {
            upto: rd.u64()?,
            full: match rd.u8()? {
                0 => false,
                1 => true,
                _ => return Err(CodecError::Malformed("invalidations full flag")),
            },
            keys: rd.u64s()?,
        },
        _ => return Err(CodecError::Malformed("shard reply tag")),
    })
}

// ---- stream framing ---------------------------------------------------------

/// Short label for the outer message kind (wire byte-size metrics).
pub fn wire_kind(msg: &WireMsg) -> &'static str {
    match msg {
        WireMsg::Push(_) => "push",
        WireMsg::Pull(_) => "pull",
        WireMsg::Req(_) => "req",
        WireMsg::Reply(_) => "reply",
        WireMsg::WorkerReq(_) => "worker_req",
        WireMsg::WorkerRep(_) => "worker_rep",
    }
}

pub(crate) fn record_frame_bytes(direction: &str, msg: &WireMsg, bytes: usize) {
    let key = crate::obs::labeled(
        if direction == "tx" { "gba_wire_tx_bytes" } else { "gba_wire_rx_bytes" },
        "msg",
        wire_kind(msg),
    );
    crate::obs::global()
        .histogram(&key, crate::obs::Histogram::byte_bounds())
        .record(bytes as f64);
}

/// Write one length-prefixed frame.
pub fn write_frame<W: Write>(w: &mut W, msg: &WireMsg) -> Result<(), CodecError> {
    let body = encode(msg);
    let len = u32::try_from(body.len()).map_err(|_| CodecError::Oversize(u32::MAX))?;
    if len > MAX_FRAME_BYTES {
        return Err(CodecError::Oversize(len));
    }
    // One buffer, one write: a frame is never interleaved on the stream.
    let mut out = Vec::with_capacity(body.len() + 4);
    out.extend_from_slice(&len.to_le_bytes());
    out.extend_from_slice(&body);
    w.write_all(&out).map_err(|e| CodecError::Io(e.kind()))?;
    w.flush().map_err(|e| CodecError::Io(e.kind()))?;
    record_frame_bytes("tx", msg, out.len());
    Ok(())
}

/// Scatter/gather encode for `Gather` replies: write one length-prefixed
/// [`ShardReply::Rows`] frame whose rows are produced *into* the frame's
/// output buffer by `fill(row_index, row_slice)` — the shard never
/// assembles the `keys.len() * dim` float `Vec` the materializing path
/// builds before encoding. Byte output (and the tx-bytes metric sample)
/// is identical to
/// `write_frame(w, &WireMsg::Reply(ShardReply::Rows { dim, data }))`,
/// pinned by `rows_frame_streaming_encode_is_byte_identical`.
///
/// `fill` writes through a `dim`-sized scratch row, so on little-endian
/// hosts each row costs one bulk byte copy into the out-buffer; one
/// buffer, one write — a frame is never interleaved on the stream.
pub fn write_rows_frame<W: Write>(
    w: &mut W,
    dim: usize,
    n_rows: usize,
    fill: &mut dyn FnMut(usize, &mut [f32]),
) -> Result<(), CodecError> {
    let floats = n_rows * dim;
    let mut out = Vec::with_capacity(4 + 1 + 8 + 1 + 1 + 8 + 4 + floats.saturating_mul(4));
    out.extend_from_slice(&[0u8; 4]); // length prefix, patched below
    put_u8(&mut out, WIRE_VERSION);
    put_u64(&mut out, crate::obs::trace::current());
    put_u8(&mut out, 4); // outer tag: Reply
    put_u8(&mut out, 2); // reply tag: Rows
    put_u64(&mut out, dim as u64);
    put_u32(&mut out, floats as u32);
    let mut row = vec![0.0f32; dim];
    for i in 0..n_rows {
        fill(i, &mut row);
        append_f32_bytes(&mut out, &row);
    }
    let len = u32::try_from(out.len() - 4).map_err(|_| CodecError::Oversize(u32::MAX))?;
    if len > MAX_FRAME_BYTES {
        return Err(CodecError::Oversize(len));
    }
    out[..4].copy_from_slice(&len.to_le_bytes());
    w.write_all(&out).map_err(|e| CodecError::Io(e.kind()))?;
    w.flush().map_err(|e| CodecError::Io(e.kind()))?;
    // Metric parity with `write_frame`'s record for a Reply message.
    crate::obs::global()
        .histogram(
            &crate::obs::labeled("gba_wire_tx_bytes", "msg", "reply"),
            crate::obs::Histogram::byte_bounds(),
        )
        .record(out.len() as f64);
    Ok(())
}

/// Read one frame. Clean EOF *between* frames is [`CodecError::Closed`];
/// EOF inside a frame is [`CodecError::Truncated`].
pub fn read_frame<R: Read>(r: &mut R) -> Result<WireMsg, CodecError> {
    let mut len4 = [0u8; 4];
    if let Err(e) = r.read_exact(&mut len4) {
        return Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof => CodecError::Closed,
            kind => CodecError::Io(kind),
        });
    }
    let len = u32::from_le_bytes(len4);
    if len > MAX_FRAME_BYTES {
        return Err(CodecError::Oversize(len));
    }
    let mut body = vec![0u8; len as usize];
    if let Err(e) = r.read_exact(&mut body) {
        return Err(match e.kind() {
            std::io::ErrorKind::UnexpectedEof => CodecError::Truncated,
            kind => CodecError::Io(kind),
        });
    }
    let msg = decode(&body)?;
    record_frame_bytes("rx", &msg, body.len() + 4);
    Ok(msg)
}

/// Encoded size of a message including its 4-byte length prefix —
/// for calibrating `[cluster] wire_ms` against real payload sizes.
/// (Encodes to measure; don't call it on a hot path.)
pub fn frame_size(msg: &WireMsg) -> usize {
    encode(msg).len() + 4
}

#[cfg(test)]
mod tests {
    use super::*;

    fn push() -> GradPush {
        GradPush {
            worker: 3,
            token: 41,
            dense: vec![
                HostTensor { shape: vec![2, 2], data: vec![1.0, -2.5, f32::NAN, 0.0] },
                HostTensor { shape: vec![3], data: vec![f32::INFINITY, -0.0, 7.25] },
            ],
            emb: vec![(u64::MAX, vec![0.5, -0.5]), (0, vec![])],
            n_samples: 8,
            loss: 0.125,
        }
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn grad_push_roundtrip_preserves_bits() {
        let g = push();
        let body = encode(&WireMsg::Push(g.clone()));
        let back = match decode(&body).unwrap() {
            WireMsg::Push(g) => g,
            other => panic!("{other:?}"),
        };
        assert_eq!(back.worker, g.worker);
        assert_eq!(back.token, g.token);
        assert_eq!(back.dense.len(), 2);
        for (a, b) in back.dense.iter().zip(&g.dense) {
            assert_eq!(a.shape, b.shape);
            assert_eq!(bits(&a.data), bits(&b.data));
        }
        assert_eq!(back.emb.len(), 2);
        assert_eq!(back.emb[0].0, u64::MAX);
        assert_eq!(bits(&back.emb[0].1), bits(&g.emb[0].1));
        assert!(back.emb[1].1.is_empty());
        assert_eq!(back.n_samples, 8);
        assert_eq!(back.loss.to_bits(), g.loss.to_bits());
    }

    #[test]
    fn pull_reply_roundtrip_all_variants() {
        for p in [
            PullReply::Work(WorkItem { token: 9, version: 2, day: 1, batch_index: 77 }),
            PullReply::Wait,
            PullReply::EndOfData,
        ] {
            let body = encode(&WireMsg::Pull(p));
            match decode(&body).unwrap() {
                WireMsg::Pull(back) => assert_eq!(back, p),
                other => panic!("{other:?}"),
            }
        }
    }

    #[test]
    fn insert_rows_roundtrip_preserves_bits_and_truncation_rejected() {
        let rows: Vec<RowRecord> = vec![
            (
                u64::MAX,
                vec![1.0, f32::NAN, -0.0],
                vec![0.5, f32::INFINITY, 2.0, -3.0, 0.0, 9.75],
                RowMeta { last_update_step: 7, update_count: 3 },
            ),
            (0, vec![], vec![], RowMeta { last_update_step: 0, update_count: 0 }),
        ];
        let body = encode(&WireMsg::Req(ShardRequest::InsertRows { rows: rows.clone() }));
        let back = match decode(&body).unwrap() {
            WireMsg::Req(ShardRequest::InsertRows { rows }) => rows,
            other => panic!("{other:?}"),
        };
        assert_eq!(back.len(), rows.len());
        for ((k, v, st, m), (wk, wv, wst, wm)) in back.iter().zip(&rows) {
            assert_eq!(k, wk);
            assert_eq!(bits(v), bits(wv));
            assert_eq!(bits(st), bits(wst));
            assert_eq!(m.last_update_step, wm.last_update_step);
            assert_eq!(m.update_count, wm.update_count);
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded a truncated InsertRows at {cut}");
        }
    }

    #[test]
    fn hello_roundtrip() {
        let req = ShardRequest::Hello {
            shard: u64::MAX,
            dense_slots: 2,
            emb_slots: 1,
            emb_dim: 16,
            cfg_digest: 0xdead_beef_cafe_f00d,
        };
        let body = encode(&WireMsg::Req(req));
        match decode(&body).unwrap() {
            WireMsg::Req(ShardRequest::Hello { shard, dense_slots, emb_slots, emb_dim, cfg_digest }) => {
                assert_eq!(shard, u64::MAX);
                assert_eq!((dense_slots, emb_slots, emb_dim), (2, 1, 16));
                assert_eq!(cfg_digest, 0xdead_beef_cafe_f00d);
            }
            other => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err());
        }
    }

    #[test]
    fn worker_request_roundtrip_all_variants() {
        let reqs = vec![
            WorkerRequest::Hello {
                worker: 3,
                local_batch: 16,
                fields: 4,
                emb_dim: 8,
                seed: u64::MAX,
                samples_per_day: 4096,
            },
            WorkerRequest::BeginDay,
            WorkerRequest::Pull { worker: u64::MAX },
            WorkerRequest::Push(push()),
            WorkerRequest::Gather { keys: vec![u64::MAX, 0, 7], batch: 2, fields: 3 },
            WorkerRequest::DenseParams,
            WorkerRequest::Reset { worker: 9 },
            WorkerRequest::EndOfDay {
                batches: 12,
                samples: 192,
                failures: 1,
                busy_sec: 0.125,
            },
            WorkerRequest::SwitchMode {
                epoch: u64::MAX,
                worker: 3,
                workers: 8,
                local_batch: 16,
                fields: 4,
                emb_dim: 8,
                seed: 42,
                samples_per_day: 4096,
            },
        ];
        for req in reqs {
            let body = encode(&WireMsg::WorkerReq(req.clone()));
            let back = match decode(&body).unwrap() {
                WireMsg::WorkerReq(back) => back,
                other => panic!("{other:?}"),
            };
            // GradPush carries floats (compared as raw bits); everything
            // else is integers — Debug equality pins both faithfully.
            match (&back, &req) {
                (WorkerRequest::Push(a), WorkerRequest::Push(w)) => {
                    assert_eq!(a.worker, w.worker);
                    assert_eq!(a.token, w.token);
                    assert_eq!(a.n_samples, w.n_samples);
                    assert_eq!(a.loss.to_bits(), w.loss.to_bits());
                    assert_eq!(a.dense.len(), w.dense.len());
                    for (x, y) in a.dense.iter().zip(&w.dense) {
                        assert_eq!(x.shape, y.shape);
                        assert_eq!(bits(&x.data), bits(&y.data));
                    }
                    assert_eq!(a.emb.len(), w.emb.len());
                    for ((ka, va), (kw, vw)) in a.emb.iter().zip(&w.emb) {
                        assert_eq!(ka, kw);
                        assert_eq!(bits(va), bits(vw));
                    }
                }
                _ => assert_eq!(format!("{back:?}"), format!("{req:?}")),
            }
            for cut in 0..body.len() {
                assert!(decode(&body[..cut]).is_err(), "decoded truncated worker req at {cut}");
            }
        }
    }

    #[test]
    fn worker_reply_roundtrip_preserves_bits() {
        let t = HostTensor { shape: vec![2, 2, 2], data: vec![1.0, f32::NAN, -0.0, 2.5, 0.0, -1.0, 3.0, f32::INFINITY] };
        let replies = vec![
            WorkerReply::Ok,
            WorkerReply::Day { day: 41 },
            WorkerReply::SessionOver,
            WorkerReply::Switch { epoch: 3, mode: crate::config::ModeKind::Gba },
            WorkerReply::Epoch { epoch: u64::MAX },
            WorkerReply::Pull(PullReply::Work(WorkItem { token: 5, version: 2, day: 1, batch_index: 7 })),
            WorkerReply::Emb(t.clone()),
            WorkerReply::Dense(vec![t.clone(), HostTensor { shape: vec![0], data: vec![] }]),
        ];
        for rep in replies {
            let body = encode(&WireMsg::WorkerRep(rep.clone()));
            match (decode(&body).unwrap(), &rep) {
                (WireMsg::WorkerRep(WorkerReply::Ok), WorkerReply::Ok) => {}
                (WireMsg::WorkerRep(WorkerReply::SessionOver), WorkerReply::SessionOver) => {}
                (WireMsg::WorkerRep(WorkerReply::Day { day }), WorkerReply::Day { day: w }) => {
                    assert_eq!(day, *w)
                }
                (WireMsg::WorkerRep(WorkerReply::Pull(p)), WorkerReply::Pull(w)) => {
                    assert_eq!(p, *w)
                }
                (
                    WireMsg::WorkerRep(WorkerReply::Switch { epoch, mode }),
                    WorkerReply::Switch { epoch: we, mode: wm },
                ) => {
                    assert_eq!(epoch, *we);
                    assert_eq!(mode, *wm);
                }
                (
                    WireMsg::WorkerRep(WorkerReply::Epoch { epoch }),
                    WorkerReply::Epoch { epoch: we },
                ) => assert_eq!(epoch, *we),
                (WireMsg::WorkerRep(WorkerReply::Emb(a)), WorkerReply::Emb(w)) => {
                    assert_eq!(a.shape, w.shape);
                    assert_eq!(bits(&a.data), bits(&w.data));
                }
                (WireMsg::WorkerRep(WorkerReply::Dense(a)), WorkerReply::Dense(w)) => {
                    assert_eq!(a.len(), w.len());
                    for (x, y) in a.iter().zip(w) {
                        assert_eq!(x.shape, y.shape);
                        assert_eq!(bits(&x.data), bits(&y.data));
                    }
                }
                (other, _) => panic!("{other:?}"),
            }
            for cut in 0..body.len() {
                assert!(decode(&body[..cut]).is_err(), "decoded truncated worker reply at {cut}");
            }
        }
    }

    #[test]
    fn swap_policy_roundtrip_and_truncation_rejected() {
        for (opt, lr, reset) in [
            (OptimKind::Adam, 0.001, false),
            (OptimKind::Adagrad, 0.05, true),
            (OptimKind::Sgd, f64::MIN_POSITIVE, true),
        ] {
            let body =
                encode(&WireMsg::Req(ShardRequest::SwapPolicy { opt, lr, reset_slots: reset }));
            match decode(&body).unwrap() {
                WireMsg::Req(ShardRequest::SwapPolicy { opt: o, lr: l, reset_slots: r }) => {
                    assert_eq!(o, opt);
                    assert_eq!(l.to_bits(), lr.to_bits());
                    assert_eq!(r, reset);
                }
                other => panic!("{other:?}"),
            }
            for cut in 0..body.len() {
                assert!(decode(&body[..cut]).is_err(), "decoded truncated SwapPolicy at {cut}");
            }
        }
        // A junk reset flag or optimizer id is Malformed, not a bool cast.
        let mut body =
            encode(&WireMsg::Req(ShardRequest::SwapPolicy {
                opt: OptimKind::Adam,
                lr: 0.01,
                reset_slots: true,
            }));
        *body.last_mut().unwrap() = 7;
        assert_eq!(decode(&body).unwrap_err(), CodecError::Malformed("reset_slots flag"));
    }

    #[test]
    fn end_of_day_busy_sec_travels_as_f64_bits() {
        let req = WorkerRequest::EndOfDay {
            batches: 1,
            samples: 2,
            failures: 0,
            busy_sec: f64::NAN,
        };
        let body = encode(&WireMsg::WorkerReq(req));
        match decode(&body).unwrap() {
            WireMsg::WorkerReq(WorkerRequest::EndOfDay { busy_sec, .. }) => {
                assert_eq!(busy_sec.to_bits(), f64::NAN.to_bits());
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn wrong_version_rejected() {
        let mut body = encode(&WireMsg::Req(ShardRequest::Ping));
        body[0] = WIRE_VERSION + 1;
        assert_eq!(decode(&body).unwrap_err(), CodecError::BadVersion(WIRE_VERSION + 1));
    }

    #[test]
    fn truncation_rejected_at_every_cut() {
        let body = encode(&WireMsg::Push(push()));
        for cut in 0..body.len() {
            match decode(&body[..cut]) {
                Err(_) => {}
                Ok(m) => panic!("decoded from {cut}/{} bytes: {m:?}", body.len()),
            }
        }
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut body = encode(&WireMsg::Reply(ShardReply::Ok));
        body.push(0);
        assert_eq!(decode(&body).unwrap_err(), CodecError::Malformed("trailing bytes"));
    }

    #[test]
    fn tensor_shape_mismatch_rejected() {
        // Hand-build a Push whose tensor claims more elements than sent.
        let mut b = vec![WIRE_VERSION];
        b.extend_from_slice(&0u64.to_le_bytes()); // trace id (untraced)
        b.push(1); // outer tag: Push
        b.extend_from_slice(&0u64.to_le_bytes()); // worker
        b.extend_from_slice(&0u64.to_le_bytes()); // token
        b.extend_from_slice(&1u32.to_le_bytes()); // 1 dense tensor
        b.extend_from_slice(&1u32.to_le_bytes()); // rank 1
        b.extend_from_slice(&5u64.to_le_bytes()); // shape [5]
        b.extend_from_slice(&2u32.to_le_bytes()); // but only 2 floats
        b.extend_from_slice(&1.0f32.to_bits().to_le_bytes());
        b.extend_from_slice(&2.0f32.to_bits().to_le_bytes());
        b.extend_from_slice(&0u32.to_le_bytes()); // no emb
        b.extend_from_slice(&0u64.to_le_bytes()); // n_samples
        b.extend_from_slice(&0.0f32.to_bits().to_le_bytes()); // loss
        assert_eq!(decode(&b).unwrap_err(), CodecError::Malformed("tensor shape/data mismatch"));
    }

    #[test]
    fn trace_id_travels_in_the_header() {
        crate::obs::trace::set_current(0xfeed_f00d_dead_beef);
        let body = encode(&WireMsg::Req(ShardRequest::Ping));
        crate::obs::trace::clear();
        assert_eq!(crate::obs::trace::current(), 0);
        // Decoding installs the frame's id on this thread.
        assert!(matches!(decode(&body).unwrap(), WireMsg::Req(ShardRequest::Ping)));
        assert_eq!(crate::obs::trace::current(), 0xfeed_f00d_dead_beef);
        // Replies encoded while handling echo the same id.
        let reply = encode(&WireMsg::Reply(ShardReply::Ok));
        assert_eq!(&reply[1..9], &0xfeed_f00d_dead_beef_u64.to_le_bytes());
        crate::obs::trace::clear();
        // An untraced frame carries (and installs) id 0.
        let body = encode(&WireMsg::Req(ShardRequest::Ping));
        assert_eq!(&body[1..9], &[0u8; 8]);
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated Ping at {cut}");
        }
    }

    #[test]
    fn read_hello_roundtrip() {
        let body = encode(&WireMsg::Req(ShardRequest::ReadHello { shard: 7 }));
        match decode(&body).unwrap() {
            WireMsg::Req(ShardRequest::ReadHello { shard }) => assert_eq!(shard, 7),
            other => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated ReadHello at {cut}");
        }
    }

    #[test]
    fn obs_scrape_roundtrip() {
        let body = encode(&WireMsg::Req(ShardRequest::ObsScrape));
        assert!(matches!(decode(&body).unwrap(), WireMsg::Req(ShardRequest::ObsScrape)));

        let entries = vec![
            ("gba_shard_requests_total{rpc=\"apply\"}".to_string(), 42.0),
            ("gba_shard_apply_seconds_p95".to_string(), 0.00125),
            ("empty".to_string(), f64::NEG_INFINITY),
        ];
        let body = encode(&WireMsg::Reply(ShardReply::Obs { entries: entries.clone() }));
        match decode(&body).unwrap() {
            WireMsg::Reply(ShardReply::Obs { entries: back }) => {
                assert_eq!(back.len(), entries.len());
                for ((n, v), (wn, wv)) in back.iter().zip(&entries) {
                    assert_eq!(n, wn);
                    assert_eq!(v.to_bits(), wv.to_bits());
                }
            }
            other => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated Obs at {cut}");
        }
    }

    #[test]
    fn stream_framing_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &WireMsg::Req(ShardRequest::Gather { keys: vec![1, 2, 3] }))
            .unwrap();
        write_frame(&mut buf, &WireMsg::Reply(ShardReply::Ok)).unwrap();
        let mut r = &buf[..];
        assert!(matches!(
            read_frame(&mut r).unwrap(),
            WireMsg::Req(ShardRequest::Gather { .. })
        ));
        assert!(matches!(read_frame(&mut r).unwrap(), WireMsg::Reply(ShardReply::Ok)));
        assert_eq!(read_frame(&mut r).unwrap_err(), CodecError::Closed);
        // EOF mid-frame is Truncated, not Closed.
        let mut r = &buf[..3];
        assert_eq!(read_frame(&mut r).unwrap_err(), CodecError::Truncated);
        let mut r = &buf[..6];
        assert_eq!(read_frame(&mut r).unwrap_err(), CodecError::Truncated);
    }

    #[test]
    fn oversize_length_prefix_rejected_without_allocating() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut r = &buf[..];
        assert_eq!(read_frame(&mut r).unwrap_err(), CodecError::Oversize(u32::MAX));
    }

    #[test]
    fn frame_size_matches_written_bytes() {
        let msg = WireMsg::Push(push());
        let mut buf = Vec::new();
        write_frame(&mut buf, &msg).unwrap();
        assert_eq!(frame_size(&msg), buf.len());
    }

    #[test]
    fn gather_at_roundtrip_and_truncation_rejected() {
        let body = encode(&WireMsg::Req(ShardRequest::GatherAt { keys: vec![u64::MAX, 0, 7] }));
        match decode(&body).unwrap() {
            WireMsg::Req(ShardRequest::GatherAt { keys }) => {
                assert_eq!(keys, vec![u64::MAX, 0, 7])
            }
            other => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated GatherAt at {cut}");
        }
    }

    #[test]
    fn read_invalidations_roundtrip_and_truncation_rejected() {
        let body =
            encode(&WireMsg::Req(ShardRequest::ReadInvalidations { since: u64::MAX - 1 }));
        match decode(&body).unwrap() {
            WireMsg::Req(ShardRequest::ReadInvalidations { since }) => {
                assert_eq!(since, u64::MAX - 1)
            }
            other => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated ReadInvalidations at {cut}");
        }
    }

    #[test]
    fn rows_at_roundtrip_preserves_bits_and_truncation_rejected() {
        let rep = ShardReply::RowsAt {
            step: u64::MAX,
            dim: 3,
            data: vec![1.0, f32::NAN, -0.0, f32::INFINITY, 0.5, -2.0],
        };
        let body = encode(&WireMsg::Reply(rep.clone()));
        match (decode(&body).unwrap(), &rep) {
            (
                WireMsg::Reply(ShardReply::RowsAt { step, dim, data }),
                ShardReply::RowsAt { step: ws, dim: wd, data: wdata },
            ) => {
                assert_eq!(step, *ws);
                assert_eq!(dim, *wd);
                assert_eq!(bits(&data), bits(wdata));
            }
            (other, _) => panic!("{other:?}"),
        }
        for cut in 0..body.len() {
            assert!(decode(&body[..cut]).is_err(), "decoded truncated RowsAt at {cut}");
        }
    }

    #[test]
    fn invalidations_roundtrip_and_junk_full_flag_rejected() {
        for (full, keys) in [(false, vec![1u64, u64::MAX]), (true, vec![])] {
            let body = encode(&WireMsg::Reply(ShardReply::Invalidations {
                upto: 42,
                full,
                keys: keys.clone(),
            }));
            match decode(&body).unwrap() {
                WireMsg::Reply(ShardReply::Invalidations { upto, full: f, keys: k }) => {
                    assert_eq!(upto, 42);
                    assert_eq!(f, full);
                    assert_eq!(k, keys);
                }
                other => panic!("{other:?}"),
            }
            for cut in 0..body.len() {
                assert!(decode(&body[..cut]).is_err(), "decoded truncated Invalidations at {cut}");
            }
        }
        // A junk `full` byte is Malformed, not a bool cast.
        let mut body = encode(&WireMsg::Reply(ShardReply::Invalidations {
            upto: 0,
            full: false,
            keys: vec![],
        }));
        let flag_at = body.len() - 4 - 1; // before the empty keys count
        body[flag_at] = 9;
        assert_eq!(decode(&body).unwrap_err(), CodecError::Malformed("invalidations full flag"));
    }

    #[test]
    fn rows_frame_streaming_encode_is_byte_identical() {
        let dim = 3usize;
        let rows: Vec<Vec<f32>> = vec![
            vec![1.0, f32::NAN, -0.0],
            vec![f32::INFINITY, 0.5, -2.0],
            vec![0.0, 7.25, f32::MIN_POSITIVE],
        ];
        let data: Vec<f32> = rows.iter().flatten().copied().collect();
        crate::obs::trace::set_current(0x0123_4567_89ab_cdef);
        let mut materialized = Vec::new();
        write_frame(
            &mut materialized,
            &WireMsg::Reply(ShardReply::Rows { dim: dim as u64, data }),
        )
        .unwrap();
        let mut streamed = Vec::new();
        write_rows_frame(&mut streamed, dim, rows.len(), &mut |i, out| {
            out.copy_from_slice(&rows[i]);
        })
        .unwrap();
        crate::obs::trace::clear();
        assert_eq!(streamed, materialized);
        // Zero rows and zero dim are well-formed frames too.
        let mut a = Vec::new();
        write_frame(&mut a, &WireMsg::Reply(ShardReply::Rows { dim: 4, data: vec![] })).unwrap();
        let mut b = Vec::new();
        write_rows_frame(&mut b, 4, 0, &mut |_, _| unreachable!()).unwrap();
        assert_eq!(a, b);
    }
}
