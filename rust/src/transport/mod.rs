//! Message transport plane: PS shards behind a wire.
//!
//! PR 1 partitioned the parameter server into N data-plane shards under
//! one shard-global control plane, but the shards were plain structs in
//! the worker process. This module moves them behind a transport seam so
//! the sharded PS becomes the skeleton of a real multi-process parameter
//! server:
//!
//! * [`codec`] — a versioned, length-prefixed binary codec for everything
//!   that crosses the wire: the worker-plane vocabulary
//!   (`GradPush`/`PullReply`/`WorkItem`) and the shard-plane RPC
//!   ([`ShardRequest`]/[`ShardReply`]). No external deps; `f32`s travel
//!   as raw IEEE-754 bits so results are transport-invariant bit-for-bit.
//! * [`endpoint`] — the [`Conn`] abstraction with two interchangeable
//!   implementations: [`ChanConn`] over a `util/chan` duplex pair
//!   (in-process, no serialization) and [`SocketConn`] over localhost TCP
//!   (every message framed through the codec). Selected by
//!   `[ps] transport = "inproc" | "socket"` / `--transport`.
//! * [`service`] — the server side: a [`ShardService`] owns one
//!   [`PsShard`](crate::shard::PsShard) plus its own optimizer clones and
//!   executes RPCs until its connection dies. Nothing reaches shard state
//!   except through a connection.
//! * [`supervisor`] — the [`ShardSupervisor`]: spawns services, journals
//!   mutating requests against per-shard **shard-local checkpoints**
//!   (spilling the journal to disk past `[ps] journal_spill_bytes`), and
//!   on a dead endpoint (closed channel / broken socket / dropped remote
//!   peer) respawns — or, for `remote`, reconnects to — the shard from
//!   its checkpoint and replays the journal — the lost-shard extension
//!   of the paper's lost-token tolerance (Appendix B), pinned by
//!   `tests/shard_failure.rs` and `tests/process_shards.rs`.
//! * [`remote`] — the multi-process deployment: [`connect_retry`] dials
//!   a `gba-train shard-server` process (transport `"remote"`,
//!   addresses from `[ps] shard_addrs`), and [`serve_shard`] is that
//!   process's accept loop — a fresh shard per connection, state
//!   installed over the wire by the front.
//! * [`nbio`] — [`BufConn`], a nonblocking buffered connection speaking
//!   the same codec frames: partial reads accumulate, writes queue and
//!   drain opportunistically, so one readiness loop can sweep hundreds
//!   of connections without a thread per peer. No tokio — std
//!   `TcpStream` in nonblocking mode is the whole dependency.
//! * [`worker_front`] — the *worker* plane's front half (`[cluster]
//!   workers = "remote"`): [`WorkerFront`] accepts `gba-train worker`
//!   processes after a `Hello` identity/shape handshake and serves
//!   *every* worker's training day on **one event-loop thread** —
//!   `Pull`/`Push`/`Gather`/`DenseParams`/`Reset` against the PS front,
//!   `BeginDay`/`EndOfDay` around it — over the same codec. The
//!   worker-side half is `worker::remote`.
//!
//! The front (`shard::ShardedPs`) performs admission, aggregation and
//! reassembly exactly as before; every parameter byte it reads or writes
//! now moves through these endpoints. The worker-plane vocabulary
//! ([`GradPush`], [`PullReply`], [`WorkItem`]) is *defined* in [`codec`]
//! — workers hand the front the very structs the wire ships.

pub mod codec;
pub mod endpoint;
pub mod nbio;
pub mod remote;
pub mod service;
pub mod supervisor;
pub mod worker_front;

pub use codec::{
    CodecError, EmbGradEntry, GradPush, PullReply, RowRecord, ShardReply, ShardRequest,
    WireMsg, WorkItem, WorkerReply, WorkerRequest,
};
pub use endpoint::{ChanConn, Conn, DeadConn, SocketConn};
pub use nbio::BufConn;
pub use remote::{connect_retry, serve_shard, RECONNECT_DEADLINE};
pub use service::{serve, serve_counting, serve_reads, ShardService};
pub use supervisor::{ShardCheckpoint, ShardSpawnSpec, ShardSupervisor, DEFAULT_CKPT_EVERY};
pub use worker_front::{WorkerFront, WorkerShape, WORKER_ACCEPT_DEADLINE};
