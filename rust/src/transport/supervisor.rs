//! Shard supervision: spawning, journaling, lost-shard detection and
//! respawn.
//!
//! Every shard runs behind a [`Conn`]; the supervisor is the only thing
//! that talks to it. Failure model (the paper's Appendix B, extended from
//! lost *tokens* to lost *shards*):
//!
//! * **Detection.** A dead shard — exited service thread, dropped
//!   channel, broken socket — surfaces as an RPC error. There is no
//!   heartbeat; the first request to touch the corpse finds it.
//! * **Durability.** Each shard has a *shard-local checkpoint* (its dense
//!   slices, optimizer-slot slices and embedding rows — nothing global)
//!   refreshed every `ckpt_every` applies, plus a write-ahead journal of
//!   every mutating request since that checkpoint.
//! * **Recovery.** On error the supervisor respawns the service from the
//!   checkpoint, replays the journal (deterministic, so the rebuilt shard
//!   is bit-identical — including the request whose failure exposed the
//!   death, which is how the affected global batch is re-admitted), and
//!   the control plane never observes more than a counter tick. Rows that
//!   were only ever *gathered* (never updated) are not journaled: they
//!   re-materialize from the key-seeded init with identical values on
//!   next access.
//!
//! The per-shard slot mutex enforces strict request/reply alternation on
//! each connection; the flush fan-out locks all slots in index order, so
//! shard applies run in parallel server-side while fronts never deadlock.
//!
//! Two deliberate semantics, inherited from one-connection-per-shard:
//!
//! * Reads queue behind an in-flight apply on the same shard (the fan-out
//!   holds every slot for its duration). The in-process plane let gathers
//!   overlap applies via per-row locks; restoring that over the wire
//!   needs a second (read) connection per shard — a ROADMAP follow-up.
//! * [`ShardStats`](crate::shard::ShardStats) counters are
//!   *per-incarnation*: a respawned shard restarts them at zero (state is
//!   checkpointed, load telemetry is not). Check `lost_shard_events`
//!   before comparing per-shard load numbers across a faulty run.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::thread::JoinHandle;

use super::codec::{RowRecord, ShardReply, ShardRequest, WireMsg};
use super::endpoint::{rpc, ChanConn, Conn, DeadConn, SocketConn};
use super::service::{serve, ShardService};
use crate::config::TransportKind;
use crate::embedding::EmbeddingConfig;
use crate::optim::Optimizer;
use crate::runtime::HostTensor;
use crate::shard::PsShard;
use crate::util::chan;

/// Applies between shard-local checkpoint refreshes (journal bound).
pub const DEFAULT_CKPT_EVERY: usize = 16;

/// Everything needed to (re)build one shard's service from scratch.
/// Optimizers here are templates — each spawn gets its own clones.
pub struct ShardSpawnSpec {
    pub index: usize,
    /// `(lo, hi)` into each dense tensor's flat data.
    pub ranges: Vec<(usize, usize)>,
    pub emb_cfg: EmbeddingConfig,
    pub opt_dense: Box<dyn Optimizer>,
    pub opt_emb: Box<dyn Optimizer>,
}

/// A shard-local checkpoint: one shard's complete state, shard-layout
/// terms only (range slices, planar slot slices, its own rows). Unlike
/// the portable [`Checkpoint`](crate::checkpoint::Checkpoint) this keeps
/// optimizer state — respawn must resume mid-stream, not switch modes.
#[derive(Clone, Debug)]
pub struct ShardCheckpoint {
    pub dense: Vec<Vec<f32>>,
    pub slots: Vec<Vec<f32>>,
    pub rows: Vec<RowRecord>,
}

impl ShardCheckpoint {
    /// The state a shard is born with: carved initial parameters, zeroed
    /// optimizer slots, no materialized rows.
    pub fn initial(spec: &ShardSpawnSpec, init_params: &[HostTensor]) -> ShardCheckpoint {
        let n_slots = spec.opt_dense.slots();
        let dense: Vec<Vec<f32>> = spec
            .ranges
            .iter()
            .zip(init_params)
            .map(|(&(lo, hi), t)| t.data[lo..hi].to_vec())
            .collect();
        let slots: Vec<Vec<f32>> =
            spec.ranges.iter().map(|&(lo, hi)| vec![0.0f32; (hi - lo) * n_slots]).collect();
        ShardCheckpoint { dense, slots, rows: Vec::new() }
    }
}

/// Build and launch one shard service from a checkpoint; returns the
/// front's endpoint and the service thread's handle.
fn spawn_service(
    kind: TransportKind,
    spec: &ShardSpawnSpec,
    ckpt: &ShardCheckpoint,
) -> (Box<dyn Conn>, JoinHandle<()>) {
    let shard = PsShard::from_parts(
        spec.index,
        spec.ranges.clone(),
        ckpt.dense.clone(),
        ckpt.slots.clone(),
        spec.emb_cfg.clone(),
        spec.opt_emb.slots(),
    );
    for (key, vec, state, meta) in &ckpt.rows {
        shard.emb.insert_row(*key, vec.clone(), state.clone(), *meta);
    }
    let service =
        ShardService::new(shard, spec.opt_dense.boxed_clone(), spec.opt_emb.boxed_clone());
    let name = format!("ps-shard-{}", spec.index);
    match kind {
        TransportKind::InProc => {
            let (client, server) = chan::duplex::<WireMsg>();
            let handle = std::thread::Builder::new()
                .name(name)
                .spawn(move || serve(service, Box::new(ChanConn { pipe: server })))
                .expect("spawning shard service thread");
            (Box::new(ChanConn { pipe: client }), handle)
        }
        TransportKind::Socket => {
            let listener =
                std::net::TcpListener::bind("127.0.0.1:0").expect("binding shard socket");
            let addr = listener.local_addr().expect("shard socket addr");
            let handle = std::thread::Builder::new()
                .name(name)
                .spawn(move || {
                    if let Ok((stream, _peer)) = listener.accept() {
                        serve(service, Box::new(SocketConn::new(stream)));
                    }
                })
                .expect("spawning shard service thread");
            let stream =
                std::net::TcpStream::connect(addr).expect("connecting to shard socket");
            (Box::new(SocketConn::new(stream)), handle)
        }
    }
}

/// Live per-shard connection state, guarded by one mutex per shard.
struct ShardSlot {
    conn: Box<dyn Conn>,
    handle: Option<JoinHandle<()>>,
    ckpt: ShardCheckpoint,
    /// Mutating requests since `ckpt`, in execution order.
    wal: Vec<ShardRequest>,
    applies_since_ckpt: usize,
}

pub struct ShardSupervisor {
    kind: TransportKind,
    specs: Vec<ShardSpawnSpec>,
    slots: Vec<Mutex<ShardSlot>>,
    lost_events: AtomicU64,
    ckpt_every: AtomicUsize,
}

fn is_mutating(req: &ShardRequest) -> bool {
    matches!(
        req,
        ShardRequest::Apply { .. }
            | ShardRequest::SetDense { .. }
            | ShardRequest::SetSlots { .. }
            | ShardRequest::InsertRow { .. }
    )
}

impl ShardSupervisor {
    /// Spawn every shard's service from its initial parameters.
    pub fn start(
        kind: TransportKind,
        specs: Vec<ShardSpawnSpec>,
        init_params: &[HostTensor],
    ) -> Self {
        let slots = specs
            .iter()
            .map(|spec| {
                let ckpt = ShardCheckpoint::initial(spec, init_params);
                let (conn, handle) = spawn_service(kind, spec, &ckpt);
                Mutex::new(ShardSlot {
                    conn,
                    handle: Some(handle),
                    ckpt,
                    wal: Vec::new(),
                    applies_since_ckpt: 0,
                })
            })
            .collect();
        ShardSupervisor {
            kind,
            specs,
            slots,
            lost_events: AtomicU64::new(0),
            ckpt_every: AtomicUsize::new(DEFAULT_CKPT_EVERY),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.specs.len()
    }

    pub fn transport(&self) -> TransportKind {
        self.kind
    }

    /// Lost-shard recoveries performed so far.
    pub fn lost_shard_events(&self) -> u64 {
        self.lost_events.load(Ordering::Relaxed)
    }

    /// Applies between shard-local checkpoint refreshes. This is the
    /// durability/throughput knob: a refresh reads the shard's full
    /// state (dense, slots, every row) on the flush critical path, so
    /// small values bound the journal tightly but stall every `n`-th
    /// flush; large values make flushes uniformly fast but grow the
    /// journal and the replay window.
    pub fn set_ckpt_every(&self, n: usize) {
        self.ckpt_every.store(n.max(1), Ordering::Relaxed);
    }

    /// One RPC to shard `s`, with journaling and lost-shard recovery.
    pub fn call(&self, s: usize, req: ShardRequest) -> ShardReply {
        let mut guard = self.slots[s].lock().unwrap();
        self.exec(s, &mut guard, req)
    }

    fn exec(&self, s: usize, guard: &mut MutexGuard<'_, ShardSlot>, req: ShardRequest) -> ShardReply {
        let slot = &mut **guard;
        let is_apply = matches!(req, ShardRequest::Apply { .. });
        // One copy is retained per call: mutating requests journal a
        // clone (the journal replay *is* their retry), reads keep a
        // clone only because a failed send consumes the original.
        let retry = if is_mutating(&req) {
            slot.wal.push(req.clone());
            None
        } else {
            Some(req.clone())
        };
        match rpc(slot.conn.as_mut(), req) {
            Ok(reply) => {
                if is_apply {
                    self.note_apply(s, slot);
                }
                reply
            }
            Err(_) => {
                self.recover(s, slot);
                match retry {
                    // The journal replay inside `recover` already applied
                    // this request to the rebuilt shard.
                    None => ShardReply::Ok,
                    Some(again) => rpc(slot.conn.as_mut(), again).unwrap_or_else(|e| {
                        panic!("shard {s} unreachable after respawn: {e}")
                    }),
                }
            }
        }
    }

    /// Fan one admitted flush out to every shard: journal + send to all
    /// (server-side applies run concurrently), then collect acks, then
    /// recover any shard that died. Callers hold the PS snapshot lock, so
    /// locking every slot in index order here cannot deadlock against the
    /// single-slot paths.
    pub fn apply_all(&self, reqs: Vec<ShardRequest>) {
        assert_eq!(reqs.len(), self.slots.len());
        let mut guards: Vec<MutexGuard<'_, ShardSlot>> =
            self.slots.iter().map(|m| m.lock().unwrap()).collect();
        let n = guards.len();
        let mut sent = vec![false; n];
        for (i, req) in reqs.into_iter().enumerate() {
            let slot = &mut *guards[i];
            debug_assert!(is_mutating(&req));
            slot.wal.push(req.clone());
            sent[i] = slot.conn.send(WireMsg::Req(req)).is_ok();
        }
        let mut ok = vec![false; n];
        for i in 0..n {
            let slot = &mut *guards[i];
            ok[i] = sent[i] && matches!(slot.conn.recv(), Ok(WireMsg::Reply(ShardReply::Ok)));
        }
        for i in 0..n {
            let slot = &mut *guards[i];
            if ok[i] {
                self.note_apply(i, slot);
            } else {
                self.recover(i, slot);
            }
        }
    }

    /// Deterministically kill shard `s`'s endpoint and service (fault
    /// injection): the connection is severed and the service thread — and
    /// with it all shard state — is gone when this returns. The next RPC
    /// touching the shard takes the recovery path.
    pub fn kill(&self, s: usize) {
        let mut guard = self.slots[s].lock().unwrap();
        let slot = &mut *guard;
        // Dropping the old endpoint closes the channel / socket …
        let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
        // … which makes the service loop exit; join so the death is
        // complete, not in flight, when the injection returns.
        if let Some(h) = slot.handle.take() {
            let _ = h.join();
        }
    }

    /// Apply bookkeeping: refresh the shard-local checkpoint when the
    /// journal hits the cadence bound.
    fn note_apply(&self, s: usize, slot: &mut ShardSlot) {
        slot.applies_since_ckpt += 1;
        if slot.applies_since_ckpt >= self.ckpt_every.load(Ordering::Relaxed)
            && self.refresh_ckpt(slot).is_err()
        {
            // Died between the apply ack and the snapshot reads.
            self.recover(s, slot);
        }
    }

    /// Snapshot the live shard into `slot.ckpt` and truncate the journal.
    fn refresh_ckpt(&self, slot: &mut ShardSlot) -> Result<(), ()> {
        let dense = match rpc(slot.conn.as_mut(), ShardRequest::ReadDense) {
            Ok(ShardReply::Dense { dense }) => dense,
            _ => return Err(()),
        };
        let slots = match rpc(slot.conn.as_mut(), ShardRequest::ReadSlots) {
            Ok(ShardReply::Dense { dense }) => dense,
            _ => return Err(()),
        };
        let rows = match rpc(slot.conn.as_mut(), ShardRequest::DumpRows) {
            Ok(ShardReply::RowDump { rows }) => rows,
            _ => return Err(()),
        };
        slot.ckpt = ShardCheckpoint { dense, slots, rows };
        slot.wal.clear();
        slot.applies_since_ckpt = 0;
        Ok(())
    }

    /// The lost-shard path: respawn from the shard-local checkpoint and
    /// replay the journal. Panics only on a double fault (the respawned
    /// shard dying during replay), which no caller can meaningfully
    /// survive.
    fn recover(&self, s: usize, slot: &mut ShardSlot) {
        self.lost_events.fetch_add(1, Ordering::Relaxed);
        let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
        if let Some(h) = slot.handle.take() {
            let _ = h.join();
        }
        let (conn, handle) = spawn_service(self.kind, &self.specs[s], &slot.ckpt);
        slot.conn = conn;
        slot.handle = Some(handle);
        for req in &slot.wal {
            match rpc(slot.conn.as_mut(), req.clone()) {
                Ok(ShardReply::Ok) => {}
                other => panic!("shard {s}: journal replay after respawn failed: {other:?}"),
            }
        }
        if self.refresh_ckpt(slot).is_err() {
            panic!("shard {s}: checkpoint refresh after respawn failed");
        }
    }
}

impl Drop for ShardSupervisor {
    fn drop(&mut self) {
        for m in &self.slots {
            // A front thread that panicked mid-RPC poisons its slot;
            // shutdown must still close the connection and reap the
            // service thread.
            let mut guard = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            let slot = &mut *guard;
            let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
            if let Some(h) = slot.handle.take() {
                let _ = h.join();
            }
        }
    }
}
