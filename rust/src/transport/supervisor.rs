//! Shard supervision: spawning, journaling, lost-shard detection and
//! respawn.
//!
//! Every shard runs behind a [`Conn`]; the supervisor is the only thing
//! that talks to it. Failure model (the paper's Appendix B, extended from
//! lost *tokens* to lost *shards*):
//!
//! * **Detection.** A dead shard — exited service thread, dropped
//!   channel, broken socket — surfaces as an RPC error. There is no
//!   heartbeat; the first request to touch the corpse finds it.
//! * **Durability.** Each shard has a *shard-local checkpoint* (its dense
//!   slices, optimizer-slot slices and embedding rows — nothing global)
//!   refreshed every `ckpt_every` applies, plus a write-ahead journal of
//!   every mutating request since that checkpoint.
//! * **Recovery.** On error the supervisor respawns the service from the
//!   checkpoint, replays the journal (deterministic, so the rebuilt shard
//!   is bit-identical — including the request whose failure exposed the
//!   death, which is how the affected global batch is re-admitted), and
//!   the control plane never observes more than a counter tick. Rows that
//!   were only ever *gathered* (never updated) are not journaled: they
//!   re-materialize from the key-seeded init with identical values on
//!   next access.
//!
//! The per-shard slot mutex enforces strict request/reply alternation on
//! each connection; the flush fan-out locks all slots in index order, so
//! shard applies run in parallel server-side while fronts never deadlock.
//!
//! Each shard carries **two** connections: the *primary* (every
//! mutating verb, checkpoint reads, recovery) and a *read-only
//! companion* ([`read_call`](ShardSupervisor::read_call)) behind its
//! own slot mutex. Gathers and other side-effect-free reads ride the
//! companion, so they answer while an `Apply` is in flight on the
//! primary instead of queueing behind the fan-out — restoring the
//! overlap the in-process plane's per-row locks always had. On the
//! server both connections reach one shard; its own `RwLock`s are the
//! only synchronization. Lock order where both slots are held:
//! primary, then read.
//!
//! One deliberate semantic, inherited from per-incarnation serving:
//!
//! * [`ShardStats`](crate::shard::ShardStats) counters are
//!   *per-incarnation*: a respawned shard restarts them at zero (state is
//!   checkpointed, load telemetry is not). Check `lost_shard_events`
//!   before comparing per-shard load numbers across a faulty run.

use std::io::{BufReader, BufWriter, Write};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::thread::JoinHandle;

use super::codec::{self, CodecError, RowRecord, ShardReply, ShardRequest, WireMsg};
use super::endpoint::{rpc, ChanConn, Conn, DeadConn, SocketConn};
use super::remote;
use super::service::{serve, serve_reads, ShardService};
use crate::config::{OptimKind, TransportKind};
use crate::embedding::EmbeddingConfig;
use crate::obs;
use crate::optim::{make_optimizer, Optimizer};
use crate::runtime::HostTensor;
use crate::shard::PsShard;
use crate::util::chan;

/// Applies between shard-local checkpoint refreshes (journal bound).
pub const DEFAULT_CKPT_EVERY: usize = 16;

/// Everything needed to (re)build one shard's service from scratch.
/// Optimizers here are templates — each spawn gets its own clones.
pub struct ShardSpawnSpec {
    pub index: usize,
    /// `(lo, hi)` into each dense tensor's flat data.
    pub ranges: Vec<(usize, usize)>,
    pub emb_cfg: EmbeddingConfig,
    pub opt_dense: Box<dyn Optimizer>,
    pub opt_emb: Box<dyn Optimizer>,
    /// `host:port` of the shard's `shard-server` process. Required by
    /// the `Remote` transport; ignored by `InProc`/`Socket`.
    pub addr: Option<String>,
    /// Worker fan-out inside one apply (`[ps] apply_threads`).
    pub apply_threads: usize,
}

impl ShardSpawnSpec {
    /// Materialize a service holding this shard at checkpoint `ckpt` —
    /// the one construction path shared by every transport's (re)spawn
    /// and by the `shard-server` accept loop.
    ///
    /// The embedding store is shaped by the *checkpoint's* `emb_slots`,
    /// not the spec's current optimizer: across an in-place optimizer
    /// swap there is a window where the latest checkpoint still holds
    /// pre-swap row state while the spec already carries the new pair —
    /// a recovery in that window must install the rows it has (the
    /// journaled `SwapPolicy` replay then reshapes them), not panic on
    /// a state-length assert.
    pub fn service_at(&self, ckpt: &ShardCheckpoint) -> ShardService {
        let shard = PsShard::from_parts(
            self.index,
            self.ranges.clone(),
            ckpt.dense.clone(),
            ckpt.slots.clone(),
            self.emb_cfg.clone(),
            ckpt.emb_slots,
            self.apply_threads,
        );
        for (key, vec, state, meta) in &ckpt.rows {
            shard.emb.insert_row(*key, vec.clone(), state.clone(), *meta);
        }
        ShardService::new(shard, self.opt_dense.boxed_clone(), self.opt_emb.boxed_clone())
    }
}

/// A shard-local checkpoint: one shard's complete state, shard-layout
/// terms only (range slices, planar slot slices, its own rows). Unlike
/// the portable [`Checkpoint`](crate::checkpoint::Checkpoint) this keeps
/// optimizer state — respawn must resume mid-stream, not switch modes.
#[derive(Clone, Debug)]
pub struct ShardCheckpoint {
    pub dense: Vec<Vec<f32>>,
    pub slots: Vec<Vec<f32>>,
    pub rows: Vec<RowRecord>,
    /// Optimizer-state floats per embedding weight *at snapshot time* —
    /// the shape `rows` carry. Recorded in the checkpoint (rather than
    /// read off the spec at restore time) so a recovery landing in the
    /// window of an in-flight optimizer swap rebuilds the store at the
    /// rows' actual shape.
    pub emb_slots: usize,
}

impl ShardCheckpoint {
    /// The state a shard is born with: carved initial parameters, zeroed
    /// optimizer slots, no materialized rows.
    pub fn initial(spec: &ShardSpawnSpec, init_params: &[HostTensor]) -> ShardCheckpoint {
        let n_slots = spec.opt_dense.slots();
        let dense: Vec<Vec<f32>> = spec
            .ranges
            .iter()
            .zip(init_params)
            .map(|(&(lo, hi), t)| t.data[lo..hi].to_vec())
            .collect();
        let slots: Vec<Vec<f32>> =
            spec.ranges.iter().map(|&(lo, hi)| vec![0.0f32; (hi - lo) * n_slots]).collect();
        ShardCheckpoint { dense, slots, rows: Vec::new(), emb_slots: spec.opt_emb.slots() }
    }
}

/// Everything one (re)spawn produces: the primary endpoint, the
/// read-only companion endpoint, and — for in-process transports — the
/// threads behind them (the `Socket` read companion is served by a
/// thread the accept thread detaches; it exits when its socket closes).
struct Spawned {
    conn: Box<dyn Conn>,
    read_conn: Box<dyn Conn>,
    handle: Option<JoinHandle<()>>,
    read_handle: Option<JoinHandle<()>>,
}

/// Build and launch one shard service from a checkpoint; returns the
/// front's two endpoints (primary + read companion) and, for in-process
/// transports, the service threads' handles. For the `Remote` transport
/// nothing is spawned — the shard-server process already exists; its
/// fresh shard is brought to `ckpt` by installing the state over the
/// wire on the primary, then a second connection is attached to it with
/// the `ReadHello` handshake. An unreachable or mis-shaped remote peer
/// is an `Err` (the in-process transports can only fail on environment
/// exhaustion, which stays a panic): at session build the error
/// surfaces through `TrainSession::new`, while mid-training recovery
/// turns it into the fatal double-fault panic.
fn spawn_service(
    kind: TransportKind,
    spec: &ShardSpawnSpec,
    ckpt: &ShardCheckpoint,
    connect_deadline: std::time::Duration,
) -> Result<Spawned, String> {
    let name = format!("ps-shard-{}", spec.index);
    let read_name = format!("ps-shard-{}-read", spec.index);
    Ok(match kind {
        TransportKind::InProc => {
            let service = spec.service_at(ckpt);
            let shard = service.shard_handle();
            let (client, server) = chan::duplex::<(u64, WireMsg)>();
            let (read_client, read_server) = chan::duplex::<(u64, WireMsg)>();
            let handle = std::thread::Builder::new()
                .name(name)
                .spawn(move || serve(service, Box::new(ChanConn { pipe: server })))
                .expect("spawning shard service thread");
            let read_handle = std::thread::Builder::new()
                .name(read_name)
                .spawn(move || {
                    let _ = serve_reads(shard, Box::new(ChanConn { pipe: read_server }));
                })
                .expect("spawning shard read thread");
            Spawned {
                conn: Box::new(ChanConn { pipe: client }),
                read_conn: Box::new(ChanConn { pipe: read_client }),
                handle: Some(handle),
                read_handle: Some(read_handle),
            }
        }
        TransportKind::Socket => {
            let service = spec.service_at(ckpt);
            let shard = service.shard_handle();
            let listener =
                std::net::TcpListener::bind("127.0.0.1:0").expect("binding shard socket");
            let addr = listener.local_addr().expect("shard socket addr");
            let handle = std::thread::Builder::new()
                .name(name)
                .spawn(move || {
                    // Two sequential connects from one client: accept
                    // order is their connect order — primary first,
                    // read companion second.
                    if let Ok((stream, _peer)) = listener.accept() {
                        if let Ok((read_stream, _peer)) = listener.accept() {
                            let _ = std::thread::Builder::new().name(read_name).spawn(
                                move || {
                                    let _ = serve_reads(
                                        shard,
                                        Box::new(SocketConn::new(read_stream)),
                                    );
                                },
                            );
                        }
                        serve(service, Box::new(SocketConn::new(stream)));
                    }
                })
                .expect("spawning shard service thread");
            let stream =
                std::net::TcpStream::connect(addr).expect("connecting to shard socket");
            let read_stream =
                std::net::TcpStream::connect(addr).expect("connecting to shard read socket");
            Spawned {
                conn: Box::new(SocketConn::new(stream)),
                read_conn: Box::new(SocketConn::new(read_stream)),
                handle: Some(handle),
                read_handle: None,
            }
        }
        TransportKind::Remote => {
            let addr = spec
                .addr
                .as_deref()
                .expect("remote transport requires a shard_addrs entry per shard");
            let mut conn = remote::connect_retry(addr, connect_deadline).ok_or_else(|| {
                format!(
                    "shard {}: no shard-server reachable at {addr} within {:?}",
                    spec.index, connect_deadline
                )
            })?;
            install_checkpoint(&mut conn, spec, ckpt).map_err(|e| {
                format!("shard {}: installing checkpoint at {addr}: {e}", spec.index)
            })?;
            // The companion attaches to the generation the install just
            // created; connected only now so the server has a current
            // generation to hand it.
            let mut read_conn =
                remote::connect_retry(addr, connect_deadline).ok_or_else(|| {
                    format!(
                        "shard {}: no shard-server reachable at {addr} for the read \
                         companion",
                        spec.index
                    )
                })?;
            match rpc(&mut read_conn, ShardRequest::ReadHello { shard: spec.index as u64 }) {
                Ok(ShardReply::Ok) => {}
                other => {
                    return Err(format!(
                        "shard {}: read-companion handshake at {addr} failed: {other:?}",
                        spec.index
                    ))
                }
            }
            Spawned {
                conn: Box::new(conn),
                read_conn: Box::new(read_conn),
                handle: None,
                read_handle: None,
            }
        }
    })
}

/// Bring a freshly-accepted remote shard to checkpoint state over the
/// wire: the `Hello` identity/shape handshake first (a swapped
/// `shard_addrs` entry or a mode whose optimizer shape differs must
/// fail loudly at connect, not silently diverge — the server asserts
/// and the dropped connection surfaces here as an error), then dense
/// slices (which resets the optimizer slots), then the slots, then
/// every materialized row in one bulk frame.
fn install_checkpoint(
    conn: &mut SocketConn,
    spec: &ShardSpawnSpec,
    ckpt: &ShardCheckpoint,
) -> Result<(), CodecError> {
    let mut reqs = vec![
        ShardRequest::Hello {
            shard: spec.index as u64,
            dense_slots: spec.opt_dense.slots() as u32,
            emb_slots: spec.opt_emb.slots() as u32,
            emb_dim: spec.emb_cfg.dim as u32,
            cfg_digest: crate::optim::config_digest(
                spec.opt_dense.as_ref(),
                spec.opt_emb.as_ref(),
            ),
        },
        ShardRequest::SetDense { dense: ckpt.dense.clone() },
        ShardRequest::SetSlots { slots: ckpt.slots.clone() },
    ];
    if !ckpt.rows.is_empty() {
        reqs.push(ShardRequest::InsertRows { rows: ckpt.rows.clone() });
    }
    for req in reqs {
        match rpc(conn, req)? {
            ShardReply::Ok => {}
            _ => return Err(CodecError::Malformed("expected Ok installing checkpoint")),
        }
    }
    Ok(())
}

/// Monotonic source for unique journal-spill file names (several
/// supervisors can coexist in one test process).
static JOURNAL_SEQ: AtomicU64 = AtomicU64::new(0);

/// The mutating-request journal for one shard: an in-memory tail plus an
/// optional on-disk spill segment. When the tail's (approximate) byte
/// size exceeds the configured cap, it is drained to the spill file as
/// already-encoded codec frames — so a long checkpoint cadence costs
/// disk, not resident memory, and replay order (disk segment first,
/// oldest to newest, then the tail) is preserved exactly.
struct Journal {
    mem: Vec<ShardRequest>,
    mem_bytes: usize,
    /// Frames in the spill file, all older than anything in `mem`.
    spilled: u64,
    path: PathBuf,
    writer: Option<BufWriter<std::fs::File>>,
    /// Obs gauges (cached handles, set on every push/clear): resident
    /// journal bytes and spilled frame count, labeled by shard.
    g_mem_bytes: Arc<obs::Gauge>,
    g_spilled: Arc<obs::Gauge>,
}

/// Approximate in-memory footprint of a journaled request — cheap to
/// compute (no encoding) and close enough to meter the spill cap.
fn approx_req_bytes(req: &ShardRequest) -> usize {
    let vecs = |xss: &[Vec<f32>]| xss.iter().map(|xs| 32 + xs.len() * 4).sum::<usize>();
    32 + match req {
        ShardRequest::Apply { dense, emb, .. } => {
            vecs(dense) + emb.iter().map(|(_, g, _)| 48 + g.len() * 4).sum::<usize>()
        }
        ShardRequest::SetDense { dense } => vecs(dense),
        ShardRequest::SetSlots { slots } => vecs(slots),
        ShardRequest::InsertRow { vec, state, .. } => 48 + (vec.len() + state.len()) * 4,
        ShardRequest::InsertRows { rows } => {
            rows.iter().map(|(_, v, s, _)| 80 + (v.len() + s.len()) * 4).sum::<usize>()
        }
        _ => 0,
    }
}

impl Journal {
    fn new(shard: usize) -> Journal {
        let seq = JOURNAL_SEQ.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir()
            .join(format!("gba-journal-{}-{seq}-shard{shard}.wal", std::process::id()));
        let shard_label = shard.to_string();
        Journal {
            mem: Vec::new(),
            mem_bytes: 0,
            spilled: 0,
            path,
            writer: None,
            g_mem_bytes: obs::global()
                .gauge(&obs::labeled("gba_journal_mem_bytes", "shard", &shard_label)),
            g_spilled: obs::global()
                .gauge(&obs::labeled("gba_journal_spilled_frames", "shard", &shard_label)),
        }
    }

    /// Append one request; spill the whole in-memory tail once it
    /// outgrows `cap` bytes (`cap == 0` disables spilling).
    fn push(&mut self, req: ShardRequest, cap: usize) {
        self.mem_bytes += approx_req_bytes(&req);
        self.mem.push(req);
        if cap > 0 && self.mem_bytes > cap {
            let writer = self.writer.get_or_insert_with(|| {
                BufWriter::new(
                    std::fs::File::create(&self.path).expect("creating journal spill file"),
                )
            });
            for req in self.mem.drain(..) {
                codec::write_frame(writer, &WireMsg::Req(req)).expect("journal spill write");
                self.spilled += 1;
            }
            self.mem_bytes = 0;
        }
        self.g_mem_bytes.set(self.mem_bytes as f64);
        self.g_spilled.set(self.spilled as f64);
    }

    /// Visit every journaled request in execution order: the on-disk
    /// segment (streamed, never fully resident), then the memory tail.
    fn for_each(&mut self, mut f: impl FnMut(ShardRequest)) {
        if self.spilled > 0 {
            if let Some(w) = self.writer.as_mut() {
                w.flush().expect("flushing journal spill");
            }
            let mut r = BufReader::new(
                std::fs::File::open(&self.path).expect("opening journal spill"),
            );
            for _ in 0..self.spilled {
                match codec::read_frame(&mut r) {
                    Ok(WireMsg::Req(req)) => f(req),
                    other => panic!("journal spill corrupt: {other:?}"),
                }
            }
        }
        for req in &self.mem {
            f(req.clone());
        }
    }

    fn clear(&mut self) {
        self.mem.clear();
        self.mem_bytes = 0;
        if self.spilled > 0 {
            self.writer = None;
            let _ = std::fs::remove_file(&self.path);
            self.spilled = 0;
        }
        self.g_mem_bytes.set(0.0);
        self.g_spilled.set(0.0);
    }

    /// Frames currently sitting in the spill file (test observability).
    fn spilled_frames(&self) -> u64 {
        self.spilled
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        if self.spilled > 0 {
            self.writer = None;
            let _ = std::fs::remove_file(&self.path);
        }
    }
}

/// Live per-shard connection state, guarded by one mutex per shard.
struct ShardSlot {
    conn: Box<dyn Conn>,
    handle: Option<JoinHandle<()>>,
    ckpt: ShardCheckpoint,
    /// Mutating requests since `ckpt`, in execution order.
    wal: Journal,
    applies_since_ckpt: usize,
}

/// The read-only companion connection, behind its own mutex so reads
/// never contend with the primary slot. No journal, no checkpoint:
/// reads have nothing to replay, and recovery (which needs the journal)
/// always runs through the primary slot.
struct ReadSlot {
    conn: Box<dyn Conn>,
    handle: Option<JoinHandle<()>>,
}

pub struct ShardSupervisor {
    kind: TransportKind,
    /// (Re)spawn recipes, one per shard. Behind per-shard mutexes
    /// because an in-place mode switch ([`swap_optimizer`]) replaces a
    /// spec's optimizer pair mid-run — a later respawn must rebuild the
    /// shard with the *current* epoch's optimizers, not the launch
    /// pair. Lock order where both are held: slot, then spec.
    ///
    /// [`swap_optimizer`]: Self::swap_optimizer
    specs: Vec<Mutex<ShardSpawnSpec>>,
    slots: Vec<Mutex<ShardSlot>>,
    /// Read-only companions, index-aligned with `slots`. Lock order
    /// where both are held: `slots[s]`, then `read_slots[s]`.
    read_slots: Vec<Mutex<ReadSlot>>,
    lost_events: AtomicU64,
    ckpt_every: AtomicUsize,
    /// In-memory journal cap before spilling to disk (0 = never spill).
    journal_spill_bytes: AtomicUsize,
    /// Redial window for remote shard-servers (initial connect and
    /// recovery); `[ps] connect_deadline_ms`.
    connect_deadline: std::time::Duration,
}

fn is_mutating(req: &ShardRequest) -> bool {
    matches!(
        req,
        ShardRequest::Apply { .. }
            | ShardRequest::SetDense { .. }
            | ShardRequest::SetSlots { .. }
            | ShardRequest::InsertRow { .. }
            | ShardRequest::InsertRows { .. }
            | ShardRequest::SwapPolicy { .. }
    )
}

impl ShardSupervisor {
    /// Spawn every shard's service from its initial parameters. For the
    /// `Remote` transport an unreachable shard-server within
    /// `connect_deadline` is an `Err` — the caller (ultimately
    /// `TrainSession::new`) reports it instead of panicking.
    pub fn start(
        kind: TransportKind,
        specs: Vec<ShardSpawnSpec>,
        init_params: &[HostTensor],
        connect_deadline: std::time::Duration,
    ) -> anyhow::Result<Self> {
        let mut slots = Vec::with_capacity(specs.len());
        let mut read_slots = Vec::with_capacity(specs.len());
        for spec in &specs {
            let ckpt = ShardCheckpoint::initial(spec, init_params);
            let spawned = spawn_service(kind, spec, &ckpt, connect_deadline)
                .map_err(|e| anyhow::anyhow!(e))?;
            slots.push(Mutex::new(ShardSlot {
                conn: spawned.conn,
                handle: spawned.handle,
                ckpt,
                wal: Journal::new(spec.index),
                applies_since_ckpt: 0,
            }));
            read_slots.push(Mutex::new(ReadSlot {
                conn: spawned.read_conn,
                handle: spawned.read_handle,
            }));
        }
        Ok(ShardSupervisor {
            kind,
            specs: specs.into_iter().map(Mutex::new).collect(),
            slots,
            read_slots,
            lost_events: AtomicU64::new(0),
            ckpt_every: AtomicUsize::new(DEFAULT_CKPT_EVERY),
            journal_spill_bytes: AtomicUsize::new(0),
            connect_deadline,
        })
    }

    pub fn n_shards(&self) -> usize {
        self.specs.len()
    }

    pub fn transport(&self) -> TransportKind {
        self.kind
    }

    /// Lost-shard recoveries performed so far.
    pub fn lost_shard_events(&self) -> u64 {
        self.lost_events.load(Ordering::Relaxed)
    }

    /// Applies between shard-local checkpoint refreshes. This is the
    /// durability/throughput knob: a refresh reads the shard's full
    /// state (dense, slots, every row), so small values bound the
    /// journal tightly at the cost of frequent snapshot sweeps; large
    /// values grow the journal and the replay window. Since the
    /// deferred-refresh change the sweep runs *after* the triggering
    /// flush releases the apply gate ([`refresh_due`](Self::refresh_due))
    /// — it holds one slot lock, not the whole plane, so other shards'
    /// gathers and every pull proceed during it.
    pub fn set_ckpt_every(&self, n: usize) {
        self.ckpt_every.store(n.max(1), Ordering::Relaxed);
    }

    /// In-memory cap (approximate bytes) per shard journal before it
    /// spills to a temp file on disk; 0 disables spilling. With a cap
    /// set, stretching `ckpt_every` costs disk instead of memory.
    pub fn set_journal_spill_bytes(&self, bytes: usize) {
        self.journal_spill_bytes.store(bytes, Ordering::Relaxed);
    }

    /// Frames currently spilled to disk for shard `s` (test hook).
    pub fn journal_spilled_frames(&self, s: usize) -> u64 {
        self.slots[s].lock().unwrap().wal.spilled_frames()
    }

    /// One RPC to shard `s` on the primary connection, with journaling
    /// and lost-shard recovery.
    pub fn call(&self, s: usize, req: ShardRequest) -> ShardReply {
        let mut guard = self.slots[s].lock().unwrap();
        self.exec(s, &mut guard, req)
    }

    /// One *read-only* RPC to shard `s` on its read companion. Holds
    /// only the read slot on the happy path, so the call answers while
    /// an `Apply` (or the whole flush fan-out) holds the primary slot —
    /// the overlap that motivates the second connection. The request
    /// must be side-effect-free: the server closes the companion on any
    /// mutating verb.
    ///
    /// A dead companion takes the full recovery path: lock primary then
    /// read (the global lock order), retry once (another thread may
    /// have already recovered the shard and with it this connection),
    /// then [`recover`](Self::recover) and retry again.
    pub fn read_call(&self, s: usize, req: ShardRequest) -> ShardReply {
        debug_assert!(!is_mutating(&req), "mutating request routed to read_call");
        {
            let mut rs = self.read_slots[s].lock().unwrap();
            if let Ok(reply) = rpc(rs.conn.as_mut(), req.clone()) {
                return reply;
            }
        }
        // Companion dead. Take both slots in order; by the time the
        // primary lock is ours, a concurrent recovery may have replaced
        // both connections already — retry before recovering again.
        let mut guard = self.slots[s].lock().unwrap();
        let mut rs = self.read_slots[s].lock().unwrap();
        if let Ok(reply) = rpc(rs.conn.as_mut(), req.clone()) {
            return reply;
        }
        self.recover(s, &mut guard, &mut rs);
        rpc(rs.conn.as_mut(), req)
            .unwrap_or_else(|e| panic!("shard {s} read companion unreachable after respawn: {e}"))
    }

    fn exec(&self, s: usize, guard: &mut MutexGuard<'_, ShardSlot>, req: ShardRequest) -> ShardReply {
        let slot = &mut **guard;
        let is_apply = matches!(req, ShardRequest::Apply { .. });
        // One copy is retained per call: mutating requests journal a
        // clone (the journal replay *is* their retry), reads keep a
        // clone only because a failed send consumes the original.
        let retry = if is_mutating(&req) {
            slot.wal.push(req.clone(), self.journal_spill_bytes.load(Ordering::Relaxed));
            None
        } else {
            Some(req.clone())
        };
        match rpc(slot.conn.as_mut(), req) {
            Ok(reply) => {
                if is_apply {
                    self.note_apply(s, slot);
                }
                reply
            }
            Err(_) => {
                self.recover_locked(s, slot);
                match retry {
                    // The journal replay inside `recover` already applied
                    // this request to the rebuilt shard.
                    None => ShardReply::Ok,
                    Some(again) => rpc(slot.conn.as_mut(), again).unwrap_or_else(|e| {
                        panic!("shard {s} unreachable after respawn: {e}")
                    }),
                }
            }
        }
    }

    /// Fan one admitted flush out to every shard: journal + send to all
    /// (server-side applies run concurrently), then collect acks, then
    /// recover any shard that died. Callers hold the PS snapshot lock, so
    /// locking every slot in index order here cannot deadlock against the
    /// single-slot paths.
    ///
    /// Returns the shards whose checkpoint-refresh cadence came due.
    /// The refresh itself — an O(shard state) `ReadDense`/`ReadSlots`/
    /// `DumpRows` sweep — deliberately does *not* happen here: it would
    /// run with every slot locked and the apply gate up, stalling every
    /// gather and pull behind it. The flush driver calls
    /// [`refresh_due`](Self::refresh_due) after releasing the gate.
    pub fn apply_all(&self, reqs: Vec<ShardRequest>) -> Vec<usize> {
        assert_eq!(reqs.len(), self.slots.len());
        let mut guards: Vec<MutexGuard<'_, ShardSlot>> =
            self.slots.iter().map(|m| m.lock().unwrap()).collect();
        let n = guards.len();
        let mut sent = vec![false; n];
        for (i, req) in reqs.into_iter().enumerate() {
            let slot = &mut *guards[i];
            debug_assert!(is_mutating(&req));
            slot.wal.push(req.clone(), self.journal_spill_bytes.load(Ordering::Relaxed));
            sent[i] = slot.conn.send(WireMsg::Req(req)).is_ok();
        }
        let mut ok = vec![false; n];
        for i in 0..n {
            let slot = &mut *guards[i];
            ok[i] = sent[i] && matches!(slot.conn.recv(), Ok(WireMsg::Reply(ShardReply::Ok)));
        }
        let mut due = Vec::new();
        for i in 0..n {
            let slot = &mut *guards[i];
            if ok[i] {
                slot.applies_since_ckpt += 1;
                if slot.applies_since_ckpt >= self.ckpt_every.load(Ordering::Relaxed) {
                    due.push(i);
                }
            } else {
                // Recovery refreshes the checkpoint itself; no deferral.
                self.recover_locked(i, slot);
            }
        }
        due
    }

    /// Refresh the shard-local checkpoints of the shards [`apply_all`]
    /// reported due — one slot lock at a time, with the apply gate
    /// already down, so the snapshot reads overlap normal traffic on
    /// every other shard instead of blocking the whole plane. The
    /// cadence is re-checked under the lock: a concurrent recovery may
    /// already have refreshed (and so truncated the journal).
    ///
    /// [`apply_all`]: Self::apply_all
    pub fn refresh_due(&self, due: &[usize]) {
        for &s in due {
            let mut guard = self.slots[s].lock().unwrap();
            let slot = &mut *guard;
            if slot.applies_since_ckpt >= self.ckpt_every.load(Ordering::Relaxed)
                && self.refresh_ckpt(s, slot).is_err()
            {
                // Died between the apply ack and the snapshot reads.
                self.recover_locked(s, slot);
            }
        }
    }

    /// In-place mode switch, shard plane: install the new epoch's
    /// optimizer pair (`SwapPolicy` RPC) on every shard and update the
    /// respawn specs so a later lost-shard recovery rebuilds with the
    /// *current* optimizers. Three steps per shard, each leaving the
    /// journal consistent with what a replay would need:
    ///
    /// 1. refresh the shard-local checkpoint (truncating the journal) —
    ///    pre-swap `Apply` frames must never be replayed under the new
    ///    optimizer;
    /// 2. send the journaled `SwapPolicy` (a shard lost mid-RPC replays
    ///    it from the journal during recovery, on a service already
    ///    rebuilt from the not-yet-updated spec — i.e. the old pair —
    ///    so the replay lands on the same state the live shard had);
    /// 3. update the spec and refresh again, so the checkpoint's slot
    ///    shapes match the spec the next respawn will use.
    ///
    /// Remote caveat (documented in docs/DEPLOY.md): a `shard-server`
    /// process derives its *fresh-connection* optimizer pair from its
    /// launch `--mode`. Swaps within an optimizer family (every
    /// non-async mode shares one pair, Table 5.1) recover transparently;
    /// after a swap that changes the family, restart the shard-server
    /// with the new mode before the next recovery or the connect-time
    /// `Hello` shape check will fail loudly.
    pub fn swap_optimizer(&self, opt: OptimKind, lr: f64, reset_slots: bool) {
        for s in 0..self.n_shards() {
            {
                let mut guard = self.slots[s].lock().unwrap();
                let slot = &mut *guard;
                if self.refresh_ckpt(s, slot).is_err() {
                    self.recover_locked(s, slot);
                }
            }
            match self.call(s, ShardRequest::SwapPolicy { opt, lr, reset_slots }) {
                ShardReply::Ok => {}
                other => panic!("shard {s}: SwapPolicy rejected: {other:?}"),
            }
            {
                let mut spec = self.specs[s].lock().unwrap();
                spec.opt_dense = make_optimizer(opt, lr);
                spec.opt_emb = make_optimizer(opt, lr);
            }
            let mut guard = self.slots[s].lock().unwrap();
            let slot = &mut *guard;
            if self.refresh_ckpt(s, slot).is_err() {
                self.recover_locked(s, slot);
            }
        }
    }

    /// Deterministically kill shard `s`'s endpoint and service (fault
    /// injection): the connection is severed and the service thread — and
    /// with it all shard state — is gone when this returns. The next RPC
    /// touching the shard takes the recovery path.
    pub fn kill(&self, s: usize) {
        let mut guard = self.slots[s].lock().unwrap();
        let mut rs = self.read_slots[s].lock().unwrap();
        let slot = &mut *guard;
        // Dropping the old endpoints closes the channels / sockets …
        let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
        let _ = std::mem::replace(&mut rs.conn, Box::new(DeadConn));
        // … which makes the service loops exit; join so the death is
        // complete, not in flight, when the injection returns.
        if let Some(h) = slot.handle.take() {
            let _ = h.join();
        }
        if let Some(h) = rs.handle.take() {
            let _ = h.join();
        }
    }

    /// Apply bookkeeping: refresh the shard-local checkpoint when the
    /// journal hits the cadence bound.
    fn note_apply(&self, s: usize, slot: &mut ShardSlot) {
        slot.applies_since_ckpt += 1;
        if slot.applies_since_ckpt >= self.ckpt_every.load(Ordering::Relaxed)
            && self.refresh_ckpt(s, slot).is_err()
        {
            // Died between the apply ack and the snapshot reads.
            self.recover_locked(s, slot);
        }
    }

    /// Snapshot the live shard into `slot.ckpt` and truncate the journal.
    fn refresh_ckpt(&self, s: usize, slot: &mut ShardSlot) -> Result<(), ()> {
        let dense = match rpc(slot.conn.as_mut(), ShardRequest::ReadDense) {
            Ok(ShardReply::Dense { dense }) => dense,
            _ => return Err(()),
        };
        let slots = match rpc(slot.conn.as_mut(), ShardRequest::ReadSlots) {
            Ok(ShardReply::Dense { dense }) => dense,
            _ => return Err(()),
        };
        let rows = match rpc(slot.conn.as_mut(), ShardRequest::DumpRows) {
            Ok(ShardReply::RowDump { rows }) => rows,
            _ => return Err(()),
        };
        // The shape the dumped rows actually carry. Derived from the
        // rows themselves when any exist (authoritative even mid-swap);
        // from the spec otherwise. Lock order: slot (held), then spec.
        let emb_slots = match rows.first() {
            Some((_, vec, state, _)) if !vec.is_empty() => state.len() / vec.len(),
            _ => self.specs[s].lock().unwrap().opt_emb.slots(),
        };
        slot.ckpt = ShardCheckpoint { dense, slots, rows, emb_slots };
        slot.wal.clear();
        slot.applies_since_ckpt = 0;
        Ok(())
    }

    /// [`recover`](Self::recover) for callers holding only the primary
    /// slot: takes the read slot (respecting the primary-then-read lock
    /// order) and recovers both connections.
    fn recover_locked(&self, s: usize, slot: &mut ShardSlot) {
        let mut rs = self.read_slots[s].lock().unwrap();
        self.recover(s, slot, &mut rs);
    }

    /// The lost-shard path: respawn (or, for a remote peer, reconnect to)
    /// the shard from the shard-local checkpoint and replay the journal.
    /// For `Remote` this is the reconnect-and-replay protocol — the
    /// shard-server hands every new primary connection a fresh shard, the
    /// checkpoint is installed over the wire, and the journal brings it
    /// back to the exact lost state. Both connections are replaced as a
    /// pair — whichever died first, the other points at the dead (or
    /// superseded) incarnation and must go with it. Panics only on a
    /// double fault (the respawned shard dying during replay), which no
    /// caller can meaningfully survive.
    fn recover(&self, s: usize, slot: &mut ShardSlot, rs: &mut ReadSlot) {
        self.lost_events.fetch_add(1, Ordering::Relaxed);
        obs::global()
            .counter(&obs::labeled("gba_shard_recoveries_total", "shard", &s.to_string()))
            .inc();
        obs::trace::span(
            "shard_recover",
            crate::util::json::Json::obj().set("shard", s),
        );
        let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
        let _ = std::mem::replace(&mut rs.conn, Box::new(DeadConn));
        if let Some(h) = slot.handle.take() {
            let _ = h.join();
        }
        if let Some(h) = rs.handle.take() {
            let _ = h.join();
        }
        let spec = self.specs[s].lock().unwrap();
        let spawned = spawn_service(self.kind, &spec, &slot.ckpt, self.connect_deadline)
            .unwrap_or_else(|e| panic!("shard {s}: respawn after loss failed: {e}"));
        drop(spec);
        slot.conn = spawned.conn;
        slot.handle = spawned.handle;
        rs.conn = spawned.read_conn;
        rs.handle = spawned.read_handle;
        let ShardSlot { conn, wal, .. } = &mut *slot;
        wal.for_each(|req| match rpc(conn.as_mut(), req) {
            Ok(ShardReply::Ok) => {}
            other => panic!("shard {s}: journal replay after respawn failed: {other:?}"),
        });
        if self.refresh_ckpt(s, slot).is_err() {
            panic!("shard {s}: checkpoint refresh after respawn failed");
        }
    }
}

impl Drop for ShardSupervisor {
    fn drop(&mut self) {
        // Sever the read companions first: their loops exit as soon as
        // the connection drops, and (for InProc) their threads hold an
        // `Arc` of the shard that must die for the shard to free.
        for m in &self.read_slots {
            let mut rs = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            let _ = std::mem::replace(&mut rs.conn, Box::new(DeadConn));
            if let Some(h) = rs.handle.take() {
                let _ = h.join();
            }
        }
        for m in &self.slots {
            // A front thread that panicked mid-RPC poisons its slot;
            // shutdown must still close the connection and reap the
            // service thread.
            let mut guard = match m.lock() {
                Ok(g) => g,
                Err(poisoned) => poisoned.into_inner(),
            };
            let slot = &mut *guard;
            let _ = std::mem::replace(&mut slot.conn, Box::new(DeadConn));
            if let Some(h) = slot.handle.take() {
                let _ = h.join();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::embedding::EmbeddingConfig;
    use crate::optim::Sgd;
    use std::time::Duration;

    fn spec() -> ShardSpawnSpec {
        ShardSpawnSpec {
            index: 0,
            ranges: vec![(0, 4)],
            emb_cfg: EmbeddingConfig { dim: 2, init_scale: 0.0, seed: 1, shards: 1 },
            opt_dense: Box::new(Sgd { lr: 1.0 }),
            opt_emb: Box::new(Sgd { lr: 1.0 }),
            addr: None,
            apply_threads: 1,
        }
    }

    fn start(kind: TransportKind) -> Arc<ShardSupervisor> {
        let init = vec![crate::runtime::HostTensor { shape: vec![4], data: vec![0.0; 4] }];
        Arc::new(
            ShardSupervisor::start(kind, vec![spec()], &init, Duration::from_secs(5)).unwrap(),
        )
    }

    /// The seam the read companion exists for: a gather must answer
    /// while the primary slot is held (as it is for the whole flush
    /// fan-out when an apply is in flight), instead of queueing on it.
    #[test]
    fn gather_answers_while_the_primary_slot_is_held() {
        for kind in [TransportKind::InProc, TransportKind::Socket] {
            let sup = start(kind);
            // Materialize a row through the primary first.
            match sup.call(
                0,
                ShardRequest::InsertRow {
                    key: 7,
                    vec: vec![1.5, 2.5],
                    state: vec![],
                    meta: Default::default(),
                },
            ) {
                ShardReply::Ok => {}
                other => panic!("{other:?}"),
            }
            // An apply is "in flight": its thread owns the primary slot.
            let primary_busy = sup.slots[0].lock().unwrap();
            let (tx, rx) = std::sync::mpsc::channel();
            let s2 = sup.clone();
            std::thread::spawn(move || {
                let _ = tx.send(s2.read_call(0, ShardRequest::Gather { keys: vec![7] }));
            });
            let reply = rx
                .recv_timeout(Duration::from_secs(5))
                .expect("gather queued behind the held primary slot");
            match reply {
                ShardReply::Rows { dim, data } => {
                    assert_eq!(dim, 2);
                    assert_eq!(data, vec![1.5, 2.5]);
                }
                other => panic!("{other:?}"),
            }
            drop(primary_busy);
        }
    }

    /// A dead read companion recovers through the normal lost-shard
    /// path and the retried read answers — with the shard state the
    /// journal replay rebuilt.
    #[test]
    fn read_call_recovers_a_dead_companion() {
        let sup = start(TransportKind::InProc);
        match sup.call(
            0,
            ShardRequest::InsertRow {
                key: 3,
                vec: vec![4.0, 5.0],
                state: vec![],
                meta: Default::default(),
            },
        ) {
            ShardReply::Ok => {}
            other => panic!("{other:?}"),
        }
        sup.kill(0);
        match sup.read_call(0, ShardRequest::Gather { keys: vec![3] }) {
            ShardReply::Rows { dim, data } => {
                assert_eq!(dim, 2);
                assert_eq!(data, vec![4.0, 5.0], "journal replay restored the row");
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(sup.lost_shard_events(), 1);
    }
}
